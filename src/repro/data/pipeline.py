"""Data pipeline: deterministic synthetic LM stream + memmap token corpus.

Both sources are *stateless functions of (step, shard)* so the pipeline is
exactly resumable from a checkpointed step with no replay buffer — the
fault-tolerance story needs the data side to be restartable too.  Each
data-parallel host pulls only its shard of the global batch.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    shard: int = 0              # this host's data shard
    num_shards: int = 1
    corpus_path: Optional[str] = None    # .bin int32 tokens (memmap)

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards


class LMPipeline:
    """batch(step) -> {tokens, labels}; deterministic and resumable."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._corpus = None
        if cfg.corpus_path:
            self._corpus = np.memmap(cfg.corpus_path, dtype=np.int32,
                                     mode="r")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard]))
        if self._corpus is None:
            # synthetic but learnable: arithmetic sequences mod vocab with
            # per-sequence stride + 10% noise — a model reduces loss from
            # bigram statistics within tens of steps (pure iid tokens
            # cannot be learned at all)
            idx = np.arange(cfg.seq_len + 1)
            start = rng.integers(0, cfg.vocab, (cfg.local_batch, 1))
            stride = rng.integers(1, 4, (cfg.local_batch, 1))
            seq = ((start + stride * idx[None, :]) % cfg.vocab)
            noise = rng.integers(0, cfg.vocab,
                                 (cfg.local_batch, cfg.seq_len + 1))
            seq = np.where(rng.random(seq.shape) < 0.1, noise,
                           seq).astype(np.int32)
        else:
            n = self._corpus.shape[0] - (cfg.seq_len + 1)
            starts = rng.integers(0, n, cfg.local_batch)
            seq = np.stack([self._corpus[s:s + cfg.seq_len + 1]
                            for s in starts]).astype(np.int32)
            seq = np.clip(seq, 0, cfg.vocab - 1)
        return {"tokens": seq[:, :-1],
                "labels": seq[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1

    # -- checkpointable state ------------------------------------------

    def state(self, step: int) -> Dict:
        return {"step": step, "seed": self.cfg.seed,
                "shard": self.cfg.shard,
                "num_shards": self.cfg.num_shards}

    def save_state(self, path: str, step: int) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.state(step), f)
        os.replace(tmp, path)

    @staticmethod
    def load_state(path: str) -> Dict:
        with open(path) as f:
            return json.load(f)
