from repro.data.pipeline import DataConfig, LMPipeline

__all__ = ["DataConfig", "LMPipeline"]
