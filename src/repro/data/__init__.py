from repro.data.pipeline import DataConfig, LMPipeline
