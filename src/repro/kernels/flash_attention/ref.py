"""Pure-jnp oracle for tiled causal attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        lengths: Optional[jax.Array] = None, *,
                        causal: bool = True,
                        scale: Optional[float] = None) -> jax.Array:
    """q (B, QH, Sq, D); k/v (B, KVH, Sk, D) with GQA broadcast."""
    batch, qh, seq_q, head_dim = q.shape
    _, kvh, seq_k, _ = k.shape
    group = qh // kvh
    if scale is None:
        scale = head_dim ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qpos = jnp.arange(seq_q)[:, None]
    kpos = jnp.arange(seq_k)[None, :]
    mask = jnp.ones((seq_q, seq_k), bool)
    if causal:
        mask = kpos <= qpos
    mask = mask[None, None]
    if lengths is not None:
        mask = jnp.logical_and(mask,
                               kpos[None, None] < lengths[:, None, None, None])
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: Optional[jax.Array] = None, *,
                            causal: bool = True,
                            scale: Optional[float] = None,
                            chunk: int = 1024) -> jax.Array:
    """XLA fallback with O(S * chunk) score memory: lax.scan over query
    chunks.  This is what the dry-run lowers for long prefill (the Pallas
    kernel replaces it on real TPUs)."""
    batch, qh, seq_q, head_dim = q.shape
    _, kvh, seq_k, _ = k.shape
    group = qh // kvh
    if scale is None:
        scale = head_dim ** -0.5
    chunk = min(chunk, seq_q)
    if seq_q % chunk != 0:
        return flash_attention_ref(q, k, v, lengths, causal=causal,
                                   scale=scale)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    n_chunks = seq_q // chunk
    qc = q.reshape(batch, qh, n_chunks, chunk, head_dim)
    qc = qc.transpose(2, 0, 1, 3, 4)               # (n, B, H, c, D)
    kpos = jnp.arange(seq_k)[None, None, None, :]
    lmask = (kpos < lengths[:, None, None, None]) if lengths is not None \
        else True

    # checkpoint the chunk: backward recomputes the (B,H,c,S) scores
    # instead of saving them as scan residuals (hundreds of GB at 32k)
    @jax.checkpoint
    def chunk_attn(i, qi):
        s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32) * scale,
                       kr.astype(jnp.float32))
        mask = jnp.broadcast_to(lmask, s.shape) if lengths is not None \
            else jnp.ones_like(s, bool)
        if causal:
            qpos = i * chunk + jnp.arange(chunk)[None, None, :, None]
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
        return o.astype(q.dtype)

    def body(_, args):
        i, qi = args
        return None, chunk_attn(i, qi)

    _, outs = jax.lax.scan(body, None,
                           (jnp.arange(n_chunks), qc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(batch, qh, seq_q, head_dim)
    return out
