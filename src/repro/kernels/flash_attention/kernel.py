"""Tiled causal flash attention (prefill path).

Standard flash-style running softmax with BlockSpec VMEM tiling; GQA is
handled by mapping each q-head grid index onto its kv head in the
``index_map`` (no materialized head broadcast).  Sequence-length masking
rides in SMEM via scalar prefetch, like the paged kernel's block table.

Layout: q (B, QH, S, D); k/v (B, KVH, S, D); out (B, QH, S, D).
Grid: (B, QH, Sq/bq, Sk/bk), k blocks innermost.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, scale: float, causal: bool):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    length = lengths_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip k blocks strictly above the diagonal band
    live = (ik * block_k <= iq * block_q + block_q - 1) if causal else True

    @pl.when(jnp.logical_and(live, ik * block_k < length))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < length
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           lengths: Optional[jax.Array] = None, *,
                           causal: bool = True,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    batch, qh, seq_q, head_dim = q.shape
    _, kvh, seq_k, _ = k.shape
    assert qh % kvh == 0
    group = qh // kvh
    if scale is None:
        scale = head_dim ** -0.5
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    assert seq_q % block_q == 0 and seq_k % block_k == 0
    if lengths is None:
        lengths = jnp.full((batch,), seq_k, jnp.int32)

    grid = (batch, qh, seq_q // block_q, seq_k // block_k)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, scale=float(scale),
                               causal=causal)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, head_dim),
                         lambda b, h, iq, ik, ln: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, head_dim),
                         lambda b, h, iq, ik, ln, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, head_dim),
                         lambda b, h, iq, ik, ln, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, head_dim),
                               lambda b, h, iq, ik, ln: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, head_dim), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
