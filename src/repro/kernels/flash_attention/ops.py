"""Public jitted entry point for prefill attention."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import (flash_attention_chunked,
                                               flash_attention_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "scale", "impl",
                                             "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    lengths: Optional[jax.Array] = None, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    impl: str = "auto") -> jax.Array:
    """Causal (or full) attention, (B, H, S, D) layout, GQA-aware."""
    if impl == "auto":
        impl = "kernel" if _on_tpu() else "xla"
    if impl == "xla":
        if q.shape[2] >= 2048:     # keep score memory O(S * chunk)
            return flash_attention_chunked(q, k, v, lengths, causal=causal,
                                           scale=scale)
        return flash_attention_ref(q, k, v, lengths, causal=causal,
                                   scale=scale)
    return flash_attention_kernel(q, k, v, lengths, causal=causal,
                                  scale=scale, block_q=block_q,
                                  block_k=block_k,
                                  interpret=(impl == "kernel_interpret"))
