"""Generic double-indirection gather — the Tiara ISA's Load-chain as a
BlockSpec.

``out[i] = pool[table[ids[i]]]``: both the request list and the
translation table ride in SMEM via scalar prefetch, and the HBM page each
grid step DMAs into VMEM is chosen by dereferencing *two* levels of
indirection inside the ``index_map`` — a 2-level page-table walk executed
by the memory system itself, one pass, no materialized intermediate.

Used for MoE expert-slab gather (expert id -> translation table -> slab)
and raw KV block fetch outside attention.  Rows are (row_words,) and the
pool is (n_rows, row_words).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, table_ref, pool_ref, o_ref):
    del ids_ref, table_ref       # consumed by the index_map (the point!)
    o_ref[...] = pool_ref[...]


def tiara_gather_kernel(pool: jax.Array, table: jax.Array,
                        ids: jax.Array, *, interpret: bool = False
                        ) -> jax.Array:
    """pool (N, R); table (T,) int32: logical -> physical row;
    ids (n,) int32: requested logical rows.  Returns (n, R)."""
    n_rows, row_words = pool.shape
    (n_req,) = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_req,),
        in_specs=[
            pl.BlockSpec((1, row_words),
                         lambda i, ids_r, tbl_r: (tbl_r[ids_r[i]], 0)),
        ],
        out_specs=pl.BlockSpec((1, row_words),
                               lambda i, ids_r, tbl_r: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_req, row_words), pool.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table.astype(jnp.int32), pool)
