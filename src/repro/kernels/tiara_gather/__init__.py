from repro.kernels.tiara_gather.ops import tiara_gather
from repro.kernels.tiara_gather.kernel import tiara_gather_kernel
from repro.kernels.tiara_gather.ref import tiara_gather_ref

__all__ = ["tiara_gather", "tiara_gather_kernel", "tiara_gather_ref"]
