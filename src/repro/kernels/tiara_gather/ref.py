"""Pure-jnp oracle for the double-indirection gather."""

from __future__ import annotations

import jax


def tiara_gather_ref(pool: jax.Array, table: jax.Array,
                     ids: jax.Array) -> jax.Array:
    return pool[table[ids]]
