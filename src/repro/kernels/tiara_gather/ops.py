"""Public jitted entry point for the double-indirection gather."""

from __future__ import annotations

import functools

import jax

from repro.kernels.tiara_gather.kernel import tiara_gather_kernel
from repro.kernels.tiara_gather.ref import tiara_gather_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl",))
def tiara_gather(pool: jax.Array, table: jax.Array, ids: jax.Array, *,
                 impl: str = "auto") -> jax.Array:
    """out[i] = pool[table[ids[i]]] — one fused pass on TPU."""
    if impl == "auto":
        impl = "kernel" if _on_tpu() else "xla"
    if impl == "xla":
        return tiara_gather_ref(pool, table, ids)
    return tiara_gather_kernel(pool, table, ids,
                               interpret=(impl == "kernel_interpret"))
