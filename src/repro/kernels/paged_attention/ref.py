"""Pure-jnp oracle for paged decode attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_tables: jax.Array,
                        lengths: jax.Array, *,
                        scale: Optional[float] = None) -> jax.Array:
    """out[b, h, g] = softmax(q . K[b]) V[b] over the first lengths[b]
    tokens of the pages named by block_tables[b]."""
    batch, kvh, group, head_dim = q.shape
    _, page_size, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    if scale is None:
        scale = head_dim ** -0.5

    # gather: (B, maxp, page, KVH, D) -> (B, S, KVH, D)
    k = k_pages[block_tables].reshape(batch, max_pages * page_size, kvh,
                                      head_dim)
    v = v_pages[block_tables].reshape(batch, max_pages * page_size, kvh,
                                      head_dim)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    pos = jnp.arange(max_pages * page_size)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
