"""Public jitted entry point for paged decode attention.

Chooses the Pallas kernel on TPU (interpret-mode on CPU for validation)
or the pure-jnp reference as an XLA fallback, and handles the
(B, QH, D) <-> (B, KVH, G, D) GQA grouping.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("scale", "impl"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    scale: Optional[float] = None,
                    impl: str = "auto") -> jax.Array:
    """Decode attention against a paged KV cache.

    q: (B, QH, D) — one new token per sequence;
    k_pages/v_pages: (P, page, KVH, D); block_tables: (B, maxp) int32;
    lengths: (B,) int32.  QH must be a multiple of KVH (GQA).

    impl: 'auto' | 'kernel' | 'kernel_interpret' | 'xla'.
    'auto' uses the Pallas kernel on TPU and XLA elsewhere (the kernel in
    interpret mode is for correctness tests, not speed).
    """
    batch, qh, head_dim = q.shape
    kvh = k_pages.shape[2]
    assert qh % kvh == 0, f"q heads {qh} not a multiple of kv heads {kvh}"
    group = qh // kvh
    qg = q.reshape(batch, kvh, group, head_dim)

    if impl == "auto":
        impl = "kernel" if _on_tpu() else "xla"
    if impl == "xla":
        out = paged_attention_ref(qg, k_pages, v_pages, block_tables,
                                  lengths, scale=scale)
    else:
        out = paged_attention_kernel(
            qg, k_pages, v_pages, block_tables, lengths, scale=scale,
            interpret=(impl == "kernel_interpret"))
    return out.reshape(batch, qh, head_dim)
