"""Paged-attention decode kernel — Tiara's register-chained load on TPU.

The Indirection Wall on a TPU: decode attention must read KV data whose
HBM location is known only through the Block Table.  A host-driven design
gathers pages with XLA ops (extra HBM round trips and a materialized
contiguous copy).  Here the *loaded value is the next address*: the block
table rides in SMEM via scalar prefetch, and each grid step's BlockSpec
``index_map`` dereferences it to choose which HBM page the next DMA brings
into VMEM — the exact analogue of a Tiara MP chaining ``Load``s, with the
async-copy/compute overlap playing the paper's ``async Memcpy + Wait``.

Layout:
  q            (B, KVH, G, D)     one new token per sequence, grouped GQA
  k/v_pages    (P, page, KVH, D)  the paged KV pool
  block_tables (B, maxp) int32    logical page i of seq b -> physical page
  lengths      (B,) int32         tokens currently in each sequence
  out          (B, KVH, G, D)

Grid: (B, KVH, maxp), pages innermost; flash-style running softmax in
VMEM scratch.  Pages past a sequence's length still prefetch (the table
pads with page 0) but their compute is skipped with ``pl.when`` — the
standard dummy-fetch idiom for data-dependent grids.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(lengths_ref, tables_ref,      # scalar prefetch (SMEM)
                       q_ref, k_ref, v_ref,          # VMEM blocks
                       o_ref,                        # VMEM output block
                       m_scr, l_scr, acc_scr,        # VMEM scratch
                       *, page_size: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(i * page_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, page)
        pos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]                                  # (G, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(i == pl.num_programs(2) - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_kernel(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """Raw pallas_call wrapper; use repro.kernels.paged_attention.ops for
    the jitted public entry point."""
    batch, kvh, group, head_dim = q.shape
    n_pages, page_size, kvh_p, head_dim_p = k_pages.shape
    assert (kvh_p, head_dim_p) == (kvh, head_dim), "KV layout mismatch"
    assert v_pages.shape == k_pages.shape
    b_t, max_pages = block_tables.shape
    assert b_t == batch and lengths.shape == (batch,)
    if scale is None:
        scale = head_dim ** -0.5

    grid = (batch, kvh, max_pages)
    kernel = functools.partial(_paged_attn_kernel, page_size=page_size,
                               scale=float(scale))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, head_dim),
                         lambda b, h, i, ln, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, head_dim),
                         lambda b, h, i, ln, bt: (bt[b, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, head_dim),
                         lambda b, h, i, ln, bt: (bt[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, head_dim),
                               lambda b, h, i, ln, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, head_dim), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      q, k_pages, v_pages)
