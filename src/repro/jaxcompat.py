"""Small shims over jax API drift, so version checks live in one place.

The container pins jax 0.4.37; newer APIs used by the launch/distributed
code get a portable spelling here.
"""

from __future__ import annotations

import jax
from jax import lax


def mesh_context(mesh):
    """The ambient-mesh context manager across jax versions:
    ``jax.set_mesh`` where it exists (>= 0.6), else the mesh itself
    (``with mesh:`` — the 0.4.x spelling)."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def axis_size(a):
    """``lax.axis_size`` landed after 0.4.x; ``psum(1, axis)`` is the
    portable form (valid inside shard_map/pmap collectives)."""
    return lax.axis_size(a) if hasattr(lax, "axis_size") else lax.psum(1, a)
