"""Small shims over jax API drift, so version checks live in one place.

The container pins jax 0.4.37; newer APIs used by the launch/distributed
code get a portable spelling here.
"""

from __future__ import annotations

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh


def mesh_context(mesh):
    """The ambient-mesh context manager across jax versions:
    ``jax.set_mesh`` where it exists (>= 0.6), else the mesh itself
    (``with mesh:`` — the 0.4.x spelling)."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def axis_size(a):
    """``lax.axis_size`` landed after 0.4.x; ``psum(1, axis)`` is the
    portable form (valid inside shard_map/pmap collectives)."""
    return lax.axis_size(a) if hasattr(lax, "axis_size") else lax.psum(1, a)


def shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: ``jax.shard_map`` where it
    exists (>= 0.6), else ``jax.experimental.shard_map.shard_map``.

    Replication checking is disabled on every path (``check_rep`` /
    ``check_vma``, whichever the installed jax spells): the checker
    rejects collectives under ``lax.cond`` even when the predicate is
    replicated — exactly the sharded VM engine's conflict-fallback
    shape."""
    import inspect

    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    kwargs = {}
    params = inspect.signature(sm).parameters
    for flag in ("check_vma", "check_rep"):
        if flag in params:
            kwargs[flag] = False
            break
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **kwargs)


def make_device_mesh(n_devices: int, axis: str = "pool") -> Mesh:
    """A 1-D mesh over the first ``n_devices`` local devices.  Raises
    with a actionable message when the host exposes fewer devices (on
    CPU: relaunch under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    devs = jax.devices()
    if len(devs) < n_devices:
        raise RuntimeError(
            f"sharded execution needs {n_devices} devices but this "
            f"process sees {len(devs)}; on CPU relaunch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices}")
    return Mesh(np.asarray(devs[:n_devices]), (axis,))


def device_count() -> int:
    return len(jax.devices())
