"""Expert-parallel MoE with fully local dispatch — §Perf cell 2.

Baseline ``moe_apply`` expresses dispatch as dense scatters into
(E, C, D) buffers and lets GSPMD partition them; on the 16x16 mesh XLA
routes the token buffers with PB-scale all-gather/all-reduce chains
(EXPERIMENTS.md §Perf).  This variant applies the paper's move at the MoE
layer: *the tokens are the requests; resolve them where the experts
live.*  Under ``shard_map``:

  * activations are dp-sharded and model-replicated, so every model rank
    already holds its dp-shard's tokens: it dispatches *locally* into
    buffers for its OWN E/16 experts — no dispatch collective at all;
  * expert weights are EP-sharded over model and FSDP-sharded over data:
    the data-dim shards all-gather once per layer (standard FSDP);
  * each rank's expert outputs combine with ONE psum over the model axis
    (every token's routed expert lives on exactly one rank).

Per layer the wire carries O(weights/16 + activations) instead of the
scatter cascade.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.models.moe import MoESpec, _capacity


def make_moe_ep(mesh: Mesh, dp: Tuple[str, ...], spec: MoESpec,
                model_axis: str = "model"):
    """Returns fn(params, x) -> (y, aux) matching moe_apply semantics."""
    msize = mesh.shape[model_axis]
    assert spec.n_experts % msize == 0, (spec.n_experts, msize)
    e_local = spec.n_experts // msize
    data_axis = "data"

    def local(router, wi, wg, wo, shared, x):
        # x (B_local, S, D) — model-replicated; weights: router (D, E),
        # wi/wg (E_local, D/dsz, F), wo (E_local, F, D/dsz)
        b, s, d = x.shape
        t = b * s
        xf = x.reshape(t, d)
        e, k = spec.n_experts, spec.top_k
        cap = _capacity(t, spec)      # per-dp-shard capacity

        # FSDP: reassemble the D-sharded expert weights once per layer
        wi_full = lax.all_gather(wi, data_axis, axis=1, tiled=True)
        wg_full = lax.all_gather(wg, data_axis, axis=1, tiled=True)
        wo_full = lax.all_gather(wo, data_axis, axis=2, tiled=True)

        logits = (xf @ router).astype(jnp.float32)           # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)
        flat_oh = onehot.reshape(t * k, e)
        pos = jnp.cumsum(flat_oh, axis=0) - flat_oh
        pos = jnp.sum(pos * flat_oh, axis=-1)
        eflat = eidx.reshape(t * k)
        keep = pos < cap

        # local dispatch: only this rank's experts
        midx = lax.axis_index(model_axis)
        mine = (eflat // e_local) == midx
        live = (keep & mine).astype(xf.dtype)
        le = jnp.clip(eflat - midx * e_local, 0, e_local - 1)
        slot = jnp.minimum(pos, cap - 1)
        x_rep = jnp.repeat(xf, k, axis=0) * live[:, None]
        disp = jnp.zeros((e_local, cap, d), xf.dtype)
        disp = disp.at[le, slot].add(x_rep)

        h = jnp.einsum("ecd,edf->ecf", disp, wi_full.astype(xf.dtype))
        g = jnp.einsum("ecd,edf->ecf", disp, wg_full.astype(xf.dtype))
        out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                             wo_full.astype(xf.dtype))

        y = out_buf[le, slot] * live[:, None]                # (T*K, D)
        y = (y.reshape(t, k, d)
             * gate[..., None].astype(xf.dtype)).sum(axis=1)
        # every token's expert output lives on exactly one model rank
        y = lax.psum(y, model_axis)

        if shared is not None:
            sh_wi, sh_wg, sh_wo = shared
            # shared expert is TP-sharded over model on F: partial + psum
            hs = jax.nn.silu(xf @ sh_wg) * (xf @ sh_wi)
            y = y + lax.psum(hs @ sh_wo, model_axis)

        me = probs.mean(axis=0)
        ce = onehot.sum(axis=1).astype(jnp.float32).mean(axis=0)
        balance = spec.balance_coef * e * jnp.sum(me * ce) / k
        zloss = spec.router_z_coef * jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        aux = lax.pmean(balance + zloss, dp)   # tokens are dp-sharded
        return y.reshape(b, s, d), aux

    # shared expert TP over model on F; D replicated inside the region
    shared_specs = ((P(None, "model"), P(None, "model"),
                     P("model", None)) if spec.shared_expert else None)

    def apply(params, x):
        shared = None
        if spec.shared_expert:
            sh = params["shared"]
            shared = (sh["wi"], sh["wg"], sh["wo"])

        def wrapped(router, wi, wg, wo, x, *maybe_shared):
            return local(router, wi, wg, wo,
                         maybe_shared if maybe_shared else None, x)

        in_specs = [P(None, None),                      # router (tiny)
                    P("model", "data", None),           # wi
                    P("model", "data", None),           # wg
                    P("model", None, "data"),           # wo
                    P(tuple(dp), None, None)]           # x
        args = [params["router"], params["wi"], params["wg"],
                params["wo"], x]
        if shared is not None:
            in_specs += list(shared_specs)
            args += list(shared)
        fn = shard_map(wrapped, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=(P(tuple(dp), None, None), P()),
                       check_rep=False)
        return fn(*args)

    return apply
