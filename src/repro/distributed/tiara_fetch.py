"""One-round remote indirection resolution — Tiara's 1-RTT on the ICI.

The pod-level Indirection Wall: a consumer shard holds *logical* block ids
whose translation table and payload pages live on owner shards (co-partitioned: logical id i and
its physical page belong to the same owner, as each memory node resolves
into its own DRAM — the paper's setting).  Client-
side resolution (one-sided-RDMA style) costs one collective round per
indirection level:

    round 1: gather table entries from owners   (ids -> physical)
    round 2: gather payload rows from owners    (physical -> data)

``tiara_fetch`` ships the *request* to the owner instead — exactly the
paper's pre-registered operator executing on the memory side:

    all_to_all(requests) -> owner resolves locally (register-chained
    loads against its own table+pool shards) -> all_to_all(payloads)

Two collectives total, *independent of indirection depth*, and only
(requests + payloads) cross the wire — never intermediate pointers.
``client_side_fetch`` implements the baseline for the same layout; the
roofline test asserts the round/byte reduction from the lowered HLO.

Layout (per shard, axis size P): table (T/P,) int32 — logical id i owned
by shard i // (T/P); pool (N/P, R) — physical row p owned by shard
p // (N/P); ids (n,) per shard, any logical ids.
"""

from __future__ import annotations


import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.compile import masked_row_gather


def _owner_route(ids, owner, n_shards: int, quota: int):
    """Bucket ids by owner shard with a fixed per-destination quota.
    Returns (routed (n_shards, quota) int32 with -1 padding,
             inverse positions to un-permute results)."""
    n = ids.shape[0]
    # stable rank of each id within its owner bucket
    onehot = owner[:, None] == jnp.arange(n_shards)[None, :]
    rank = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(rank * onehot, axis=1)                    # (n,)
    ok = slot < quota
    flat_pos = owner * quota + jnp.minimum(slot, quota - 1)
    routed = jnp.full((n_shards * quota,), -1, jnp.int32)
    routed = routed.at[flat_pos].set(jnp.where(ok, ids.astype(jnp.int32),
                                               -1))
    return routed.reshape(n_shards, quota), flat_pos, ok


def make_tiara_fetch(mesh: Mesh, axis: str, n_logical: int, n_rows: int,
                     quota: int):
    """Build the one-round fetch for a pool sharded over ``axis``."""
    n_shards = mesh.shape[axis]
    t_shard = n_logical // n_shards
    r_shard = n_rows // n_shards

    def local(table_l, pool_l, ids):
        my = lax.axis_index(axis)
        owner = (ids // t_shard).astype(jnp.int32)
        routed, flat_pos, ok = _owner_route(ids, owner, n_shards, quota)
        # --- round trip 1 of 1: ship requests to owners ----------------
        reqs = lax.all_to_all(routed, axis, 0, 0, tiled=True)
        reqs = reqs.reshape(n_shards, quota)
        # --- memory-side resolution: the compiled gather-chain
        # superoperator (register-chained loads of core/compile) ----------
        live = reqs >= 0
        loff = jnp.where(live, reqs - my * t_shard, 0)
        phys = masked_row_gather(table_l, loff, live)        # chained load 1
        poff = jnp.where(live, phys - my * r_shard, 0)
        rows = masked_row_gather(pool_l, poff, live)         # chained load 2
        # --- reply travels back with the second half of the round trip --
        back = lax.all_to_all(rows, axis, 0, 0, tiled=True)
        back = back.reshape(n_shards * quota, -1)
        out = back[flat_pos] * ok[:, None].astype(back.dtype)
        return out

    fetch = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis, None), P(axis)),
        out_specs=P(axis))

    def run(table, pool, ids):
        return fetch(table, pool, ids)

    return run


def client_side_fetch(table, pool, ids):
    """Baseline: client-side resolution.  Under GSPMD with table/pool
    sharded over the axis, each of the two gathers becomes its own
    collective round (and moves intermediate pointers + gathered data
    across shards)."""
    phys = table[ids]            # round 1: dependent gather on the table
    return pool[phys]            # round 2: dependent gather on the pool


def reference_fetch(table, pool, ids):
    return np.asarray(pool)[np.asarray(table)[np.asarray(ids)]]
