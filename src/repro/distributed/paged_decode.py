"""Sequence-parallel paged decode attention — the Tiara one-round path.

Baseline decode shards the KV pool over the dp axes and lets GSPMD handle
``k_pages[block_tables]``; XLA falls back to masked gathers + full
all-reduces of the gathered KV (PB-scale collectives at 32k, see
EXPERIMENTS.md §Perf cell 1).  This module applies the paper's move —
*ship the request to the memory, not the memory to the request*:

  * pages are sharded over ALL mesh axes (each chip owns pool/chips
    whole pages and never sends them anywhere);
  * every chip resolves the block table against its own pages
    (register-chained load: table entry -> local page) and computes a
    partial flash-attention over the tokens it owns;
  * partials merge with one tiny online-softmax reduction
    (pmax/psum of (B, H, D) accumulators) — the only collective.

Per layer the wire carries O(B x QH x D) floats instead of O(KV bytes).
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.compile import masked_row_gather
from repro.jaxcompat import axis_size as _axis_size

NEG = -1e30


def _partial_paged_attention(
        q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
        bt_local: jax.Array, lengths: jax.Array, *,
        base_page: jax.Array, scale: float
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Flash partial over the locally-owned pages.

    q (B, KVH, G, D); k/v_pages (pp_local, page, KVH, D); bt_local
    (B, maxp) GLOBAL page ids; returns unnormalized (acc, m, l)."""
    b, kvh, group, hd = q.shape
    pp_local, page, _, _ = k_pages.shape
    maxp = bt_local.shape[1]

    loff = bt_local - base_page
    mine = (loff >= 0) & (loff < pp_local)
    # the compiled gather-chain superoperator: block table -> local pages
    k = masked_row_gather(k_pages, loff)     # (B, maxp, page, KVH, D)
    v = masked_row_gather(v_pages, loff)
    s = jnp.einsum("bhgd,bmphd->bhgmp", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    pos = (jnp.arange(maxp)[:, None] * page
           + jnp.arange(page)[None, :])[None]            # (1, maxp, page)
    valid = (pos < lengths[:, None, None]) & mine[..., None]
    s = jnp.where(valid[:, None, None], s, NEG)
    m = jnp.max(s, axis=(-2, -1))                        # (B, KVH, G)
    p = jnp.exp(s - m[..., None, None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=(-2, -1))
    acc = jnp.einsum("bhgmp,bmphd->bhgd", p, v.astype(jnp.float32))
    return acc, m, l


def _partial_paged_attention_sliced(
        q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
        bt: jax.Array, lengths: jax.Array, *,
        base_page: jax.Array, base_local: jax.Array, maxp: int,
        scale: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Contiguous-slab variant.  With the pool laid out (dp-major,
    model-minor) and per-sequence page slabs, each model rank's
    ``pp_local`` pages form one contiguous chunk of exactly ONE local
    sequence (requires B_local <= model size, true for the assigned decode
    shapes).  The rank attends its own pages against that sequence's
    query only — zero redundant HBM traffic — and contributes
    -inf/0 partials for every other sequence."""
    b, kvh, group, hd = q.shape
    pp_local, page, _, _ = k_pages.shape
    assert pp_local <= maxp and maxp % pp_local == 0, \
        "contiguous decode requires B_local <= model-axis size"
    seq_local = base_local // maxp                       # traced scalar
    col0 = base_local % maxp
    btrow = lax.dynamic_index_in_dim(bt, seq_local, 0, keepdims=False)
    cols = lax.dynamic_slice_in_dim(btrow, col0, pp_local, 0)
    loff = cols - base_page
    mine = (loff >= 0) & (loff < pp_local)
    k = masked_row_gather(k_pages, loff)           # (pp, page, KVH, D)
    v = masked_row_gather(v_pages, loff)
    qrow = lax.dynamic_index_in_dim(q, seq_local, 0, keepdims=False)
    length = lax.dynamic_index_in_dim(lengths, seq_local, 0,
                                      keepdims=False)
    s = jnp.einsum("hgd,mphd->hgmp", qrow.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    pos = ((col0 + jnp.arange(pp_local))[:, None] * page
           + jnp.arange(page)[None, :])
    valid = (pos < length) & mine[:, None]
    s = jnp.where(valid[None, None], s, NEG)
    m1 = jnp.max(s, axis=(-2, -1))                        # (KVH, G)
    p = jnp.exp(s - m1[..., None, None])
    p = jnp.where(valid[None, None], p, 0.0)
    l1 = jnp.sum(p, axis=(-2, -1))
    acc1 = jnp.einsum("hgmp,mphd->hgd", p, v.astype(jnp.float32))
    # scatter the single-sequence partial into the (B_local, ...) slots
    onehot = (jnp.arange(b) == seq_local)
    m = jnp.where(onehot[:, None, None], m1[None], NEG)
    l = jnp.where(onehot[:, None, None], l1[None], 0.0)
    acc = jnp.where(onehot[:, None, None, None], acc1[None], 0.0)
    return acc, m, l


def sharded_paged_attention(mesh: Mesh, dp_axes: Tuple[str, ...],
                            model_axis: str = "model", *,
                            contiguous: bool = False,
                            batch_sharded: bool = True) -> Callable[..., Any]:
    """Builds fn(q, k_pages, v_pages, new_k, new_v, bt, lengths) -> (out,
    k_pages, v_pages): appends the new token's KV to its owning chip and
    attends, all pages staying local.

    q: (B, QH, D); pages: (pool, page, KVH, D) sharded over
    (dp..., model) on the pool dim; bt: (B, maxp); lengths: (B,).

    ``contiguous`` (§Perf cell 1, iteration 2): the serving allocator
    gives every sequence a per-rank-contiguous page slab (identity layout:
    model rank m owns block-table columns [m*maxp/M, (m+1)*maxp/M)), so
    each rank slices its own 1/M of the table instead of materializing a
    masked gather over all maxp pages — 16x less HBM traffic."""
    all_axes = tuple(dp_axes) + (model_axis,)

    def local(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
              new_k: jax.Array, new_v: jax.Array, bt: jax.Array,
              lengths: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        # linear rank over (dp..., model); pool is laid out in the same
        # axis order so contiguous page ranges land per rank
        rank = 0
        for a in all_axes:
            rank = rank * _axis_size(a) + lax.axis_index(a)
        pp_local = k_pages.shape[0]
        base = rank * pp_local
        b, qh, hd = q.shape
        kvh = k_pages.shape[2]
        group = qh // kvh
        page = k_pages.shape[1]

        # -- append the new token's KV on the owning chip ---------------
        pidx = jnp.take_along_axis(
            bt, (lengths // page)[:, None].astype(jnp.int32), axis=1)[:, 0]
        poff = (lengths % page).astype(jnp.int32)
        lp = pidx - base
        own = (lp >= 0) & (lp < pp_local)
        lp_safe = jnp.clip(lp, 0, pp_local - 1)
        cur_k = k_pages[lp_safe, poff]
        cur_v = v_pages[lp_safe, poff]
        k_pages = k_pages.at[lp_safe, poff].set(
            jnp.where(own[:, None, None], new_k.astype(k_pages.dtype),
                      cur_k))
        v_pages = v_pages.at[lp_safe, poff].set(
            jnp.where(own[:, None, None], new_v.astype(v_pages.dtype),
                      cur_v))

        # -- partial attention over owned pages --------------------------
        qg = q.reshape(b, kvh, group, hd)
        if contiguous:
            midx = lax.axis_index(model_axis)
            maxp = bt.shape[1]
            # offset of this rank's pool slice within ITS batch rows: when
            # the batch is dp-sharded the dp part of `base` aligns with the
            # local rows; when replicated (B < dp, e.g. long_500k B=1) the
            # global base indexes the single shared sequence directly
            base_local = midx * pp_local if batch_sharded else base
            acc, m, l = _partial_paged_attention_sliced(
                qg, k_pages, v_pages, bt,
                (lengths + 1).astype(jnp.int32), base_page=base,
                base_local=base_local, maxp=maxp, scale=hd ** -0.5)
        else:
            acc, m, l = _partial_paged_attention(
                qg, k_pages, v_pages, bt, (lengths + 1).astype(jnp.int32),
                base_page=base, scale=hd ** -0.5)

        # -- one-round combine: online-softmax merge across the axes that
        # hold partials (model always; the dp axes too when the batch is
        # replicated and its pages are spread over dp) -------------------
        merge_axes = (model_axis,) if batch_sharded \
            else tuple(dp_axes) + (model_axis,)
        mg = m
        for ax in merge_axes:
            mg = lax.pmax(mg, ax)
        w = jnp.exp(m - mg)
        acc = lax.psum(acc * w[..., None], merge_axes)
        l = lax.psum(l * w, merge_axes)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, qh, hd).astype(q.dtype), k_pages, v_pages

    dp = tuple(dp_axes) if batch_sharded else None
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None),                # q (replicated/model)
                  P(all_axes, None, None, None),    # k_pages
                  P(all_axes, None, None, None),    # v_pages
                  P(dp, None, None),                # new_k (B, KVH, D)
                  P(dp, None, None),                # new_v
                  P(dp, None),                      # block tables
                  P(dp)),                           # lengths
        out_specs=(P(dp, None, None),
                   P(all_axes, None, None, None),
                   P(all_axes, None, None, None)),
        check_rep=False)
