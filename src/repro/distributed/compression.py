"""Compressed cross-pod gradient all-reduce.

The multi-pod mesh's ``pod`` axis is pure DP: its single inter-pod
collective is the gradient all-reduce, which crosses the slow data-center
interconnect.  ``int8_psum`` quantizes each gradient leaf blockwise to
int8 (per-block absmax scales in f32), all-reduces codes and scales, and
dequantizes — 4x less inter-pod traffic for <1% relative error (validated
in tests).  Applied via ``make_grad_compressor`` as the train step's
``grad_compressor`` hook; the within-pod FSDP reduction stays full
precision.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

QBLOCK = 256


def _quant(x):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % QBLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    return codes.astype(jnp.int8), scale


def _dequant(codes, scale, shape):
    vals = codes.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return vals.reshape(-1)[:n].reshape(shape)


def int8_psum(x: jax.Array, axis: str) -> jax.Array:
    """Quantize -> all-reduce int32 -> dequantize, over ``axis``.

    Codes are summed exactly in int32 (no overflow: <= 2^7 * axis size),
    scales are averaged implicitly by summing scaled contributions."""
    codes, scale = _quant(x)
    # each participant contributes codes*its scale; sum of scaled codes ==
    # sum of (approximated) gradients.  Sum scaled in f32 per block:
    contrib = codes.astype(jnp.float32) * scale[:, None]
    total = lax.psum(contrib, axis)
    n = 1
    for d in x.shape:
        n *= d
    return total.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def int8_psum_wire(x: jax.Array, axis: str) -> jax.Array:
    """Wire-faithful variant: the int8 codes themselves cross the link
    (psum over int32-cast codes) plus the tiny scale vector — this is the
    version whose HLO shows the 4x traffic cut; ``int8_psum`` above is the
    numerically identical f32 formulation kept for clarity."""
    codes, scale = _quant(x)
    summed_codes = lax.psum(codes.astype(jnp.int32), axis)   # int traffic
    # scales must travel too; sum of per-peer scaled codes needs per-peer
    # scales — approximate with the mean scale (error bounded by scale
    # dispersion across pods, small for gradients of the same step)
    mean_scale = lax.pmean(scale, axis)
    n_peers = lax.psum(jnp.ones((), jnp.float32), axis)
    del n_peers
    vals = summed_codes.astype(jnp.float32) * mean_scale[:, None]
    nel = 1
    for d in x.shape:
        nel *= d
    return vals.reshape(-1)[:nel].reshape(x.shape).astype(x.dtype)


def make_grad_compressor(mesh: Mesh, axis: str = "pod", *,
                         wire: bool = False):
    """Returns fn(grads)->grads performing the compressed cross-pod
    all-reduce inside shard_map (other axes untouched)."""
    if axis not in mesh.axis_names:
        return None
    op = int8_psum_wire if wire else int8_psum

    def compress(grads):
        def leaf(g):
            other = tuple(a for a in mesh.axis_names if a != axis)

            def local(gl):
                return op(gl, axis)

            return shard_map(
                local, mesh=mesh,
                in_specs=P(*((None,) * g.ndim)),
                out_specs=P(*((None,) * g.ndim)),
                check_rep=False)(g)

        return jax.tree_util.tree_map(leaf, grads)

    return compress
