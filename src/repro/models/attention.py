"""GQA attention: training/prefill (flash path) and paged decode (Tiara path).

Weights: wq (D, QH*hd) / wk,wv (D, KVH*hd) sharded TP-on-heads x FSDP-on-D;
wo transposed.  Decode attends against the paged KV pool through the block
table — the Pallas kernel on TPU resolves the table in-kernel (DESIGN.md
§2); prefill scatters its KV into the same pages so decode can continue.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.models.layers import apply_mrope, apply_rope
from repro.models.param import ParamDef


def attn_defs(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int):
    return {
        "wq": ParamDef((d_model, n_heads * head_dim), P("data", "model")),
        "wk": ParamDef((d_model, n_kv_heads * head_dim), P("data", "model")),
        "wv": ParamDef((d_model, n_kv_heads * head_dim), P("data", "model")),
        "wo": ParamDef((n_heads * head_dim, d_model), P("model", "data"),
                       fan_in=n_heads * head_dim),
    }


def _qkv(params, x, n_heads, n_kv_heads, head_dim):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ params["wv"]).reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


def _position_encode(q, k, cfg, positions, positions3):
    if cfg.rope == "mrope":
        q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attention_full(params, x, cfg, *, positions=None, positions3=None,
                   lengths=None, causal=True,
                   kv_override: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Training / prefill attention over the whole sequence.

    Returns (out, (k, v)) with k/v in (B, S, KVH, hd) layout (post-RoPE) so
    the caller can page them for serving.  ``kv_override`` supplies
    precomputed cross-attention KV (encoder-decoder)."""
    b, s, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = _qkv(params, x, nh, nkv, hd)
    if kv_override is not None:
        # cross-attention: precomputed encoder KV, no rotary on either side
        # (seamless/NLLB style uses learned/sinusoidal positions upstream)
        k, v = kv_override
    else:
        q, k = _position_encode(q, k, cfg, positions, positions3)
    out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), lengths,
                          causal=causal, impl=cfg.attn_impl)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    return out @ params["wo"], (k, v)


class PagedKV(NamedTuple):
    """Per-attention-layer paged KV pool (the disaggregated memory region)."""
    k_pages: jax.Array    # (P, page, KVH, hd)
    v_pages: jax.Array


def scatter_prefill_kv(kv: PagedKV, k: jax.Array, v: jax.Array,
                       block_tables: jax.Array) -> PagedKV:
    """Write prefill KV (B, S, KVH, hd) into the pages named by the block
    table (S must be maxp * page; the allocator pads)."""
    b, s, nkv, hd = k.shape
    page = kv.k_pages.shape[1]
    maxp = block_tables.shape[1]
    assert s <= maxp * page, (s, maxp, page)
    if s < maxp * page:                       # pad to whole pages; padded
        pad = maxp * page - s                 # positions are never attended
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    flat_idx = block_tables.reshape(-1)
    k_r = k.reshape(b * maxp, page, nkv, hd)
    v_r = v.reshape(b * maxp, page, nkv, hd)
    return PagedKV(kv.k_pages.at[flat_idx].set(k_r.astype(kv.k_pages.dtype)),
                   kv.v_pages.at[flat_idx].set(v_r.astype(kv.v_pages.dtype)))


def attention_decode(params, x, cfg, kv: PagedKV, block_tables, lengths, *,
                     positions3=None) -> Tuple[jax.Array, PagedKV]:
    """One-token decode: append this token's KV to its page, then attend
    over lengths+1 tokens through the block table."""
    b, s, _ = x.shape
    assert s == 1, "decode is one token per sequence"
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    page = kv.k_pages.shape[1]
    q, k, v = _qkv(params, x, nh, nkv, hd)
    positions = lengths[:, None].astype(jnp.int32)
    q, k = _position_encode(q, k, cfg, positions, positions3)

    if getattr(cfg, "paged_attn_fn", None) is not None:
        # one-round sequence-parallel path (distributed/paged_decode):
        # pages never move; the request ships to their owners
        out, k_pages, v_pages = cfg.paged_attn_fn(
            q[:, 0], kv.k_pages, kv.v_pages, k[:, 0], v[:, 0],
            block_tables, lengths.astype(jnp.int32))
        out = out.reshape(b, 1, nh * hd)
        return out @ params["wo"], PagedKV(k_pages, v_pages)

    page_idx = jnp.take_along_axis(
        block_tables, (lengths // page)[:, None].astype(jnp.int32), axis=1
    )[:, 0]
    page_off = (lengths % page).astype(jnp.int32)
    k_pages = kv.k_pages.at[page_idx, page_off].set(
        k[:, 0].astype(kv.k_pages.dtype))
    v_pages = kv.v_pages.at[page_idx, page_off].set(
        v[:, 0].astype(kv.v_pages.dtype))
    new_kv = PagedKV(k_pages, v_pages)

    out = paged_attention(q[:, 0], k_pages, v_pages, block_tables,
                          (lengths + 1).astype(jnp.int32),
                          impl=cfg.attn_impl)
    out = out.reshape(b, 1, nh * hd)
    return out @ params["wo"], new_kv
