"""Parameter descriptors: shape + sharding spec + initializer, as one tree.

Modules describe their parameters as ``ParamDef`` pytrees; ``materialize``
turns a def-tree into an array-tree and ``spec_tree`` extracts the
``PartitionSpec`` tree the distributed runtime feeds to pjit.  Keeping the
spec next to the shape is what makes every architecture shardable on the
production mesh by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P = P()
    init: str = "normal"          # normal | zeros | ones | embed
    fan_in: Optional[int] = None  # overrides shape[-2] for scaled init

    def instantiate(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "embed":
            scale = 1.0
        else:
            fan = self.fan_in if self.fan_in is not None else (
                self.shape[-2] if len(self.shape) >= 2 else self.shape[-1])
            scale = fan ** -0.5
        return (jax.random.normal(key, self.shape, jnp.float32)
                * scale).astype(dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def materialize(defs, key, dtype=jnp.float32):
    """Instantiate a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrays = [d.instantiate(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def spec_tree(defs):
    return jax.tree_util.tree_map(lambda d: d.spec, defs, is_leaf=is_def)


def shape_tree(defs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def stack_defs(defs, n: int):
    """Prepend a stacking dim of ``n`` (layer repeats) to every def."""
    def bump(d: ParamDef) -> ParamDef:
        return ParamDef(shape=(n,) + tuple(d.shape),
                        spec=P(*((None,) + tuple(d.spec))),
                        init=d.init,
                        fan_in=d.fan_in if d.fan_in is not None else (
                            d.shape[-2] if len(d.shape) >= 2 else None))
    return jax.tree_util.tree_map(bump, defs, is_leaf=is_def)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(tree))
