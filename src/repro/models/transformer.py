"""Pattern-scanned transformer covering all assigned architectures.

The repeating layer pattern (DESIGN.md §4) is stacked per pattern element
and executed with one ``lax.scan`` over repetitions — HLO size and compile
time stay flat in depth (jamba: period-8 pattern x 4; maverick: period-2
x 24; uniform archs: period-1 x L).  Optional encoder stack for
encoder-decoder archs (seamless); modality frontends are stubs that supply
precomputed embeddings (assignment spec).

Three modes share one code path:
  train    full-sequence forward, no caches;
  prefill  full-sequence forward that fills paged KV / recurrent state;
  decode   one token per sequence against the paged pool (Tiara path).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ArchConfig, LayerSpec
from repro.models.blocks import block_apply, block_defs, init_block_cache
from repro.models.layers import (apply_norm, embed, embed_defs, norm_defs,
                                 unembed)
from repro.models.param import (materialize, shape_tree, spec_tree,
                                stack_defs)

ENC_SPEC = LayerSpec(kind="attn", mlp="gelu")


def _hint(x, cfg: ArchConfig, *tail):
    """Activation sharding constraint: batch over cfg.dp_spec (launcher-
    provided), remaining dims per ``tail``.  No-op outside a mesh."""
    if cfg.dp_spec is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(tuple(cfg.dp_spec), *tail))


def model_defs(cfg: ArchConfig):
    defs: Dict[str, Any] = {
        "embed": embed_defs(cfg.vocab_padded, cfg.d_model,
                            cfg.tie_embeddings),
        "final_norm": norm_defs(cfg.norm, cfg.d_model),
        "blocks": tuple(
            stack_defs(block_defs(cfg, spec, cross=cfg.enc_dec),
                       cfg.n_repeat)
            for spec in cfg.pattern),
    }
    if cfg.enc_dec:
        defs["encoder"] = {
            "blocks": stack_defs(block_defs(cfg, ENC_SPEC),
                                 cfg.n_enc_layers),
            "final_norm": norm_defs(cfg.norm, cfg.d_model),
        }
    return defs


def init_params(cfg: ArchConfig, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return materialize(model_defs(cfg), key, dtype)


def param_specs(cfg: ArchConfig):
    return spec_tree(model_defs(cfg))


def param_shapes(cfg: ArchConfig, dtype=None):
    return shape_tree(model_defs(cfg), dtype or jnp.dtype(cfg.param_dtype))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_pages: int, *,
                dtype=None, cross_len: int = 0):
    """One stacked BlockCache per pattern element.  The paged pool gives
    each (layer, sequence) its own pages: pool = batch * max_pages."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    pool = batch * max_pages
    caches = []
    for spec in cfg.pattern:
        base = init_block_cache(cfg, spec, batch, pool, dtype,
                                cross_len=cross_len if cfg.enc_dec else 0)
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.n_repeat,) + a.shape, a.dtype), base)
        caches.append(stacked)
    return tuple(caches)


def default_block_tables(cfg: ArchConfig, batch: int, max_pages: int):
    """Identity allocation: sequence b owns pages [b*maxp, (b+1)*maxp)."""
    return (jnp.arange(batch, dtype=jnp.int32)[:, None] * max_pages
            + jnp.arange(max_pages, dtype=jnp.int32)[None, :])


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


class ModelOutput(NamedTuple):
    logits: jax.Array
    caches: Optional[Tuple]
    aux_loss: jax.Array


def _run_encoder(params, cfg: ArchConfig, enc_embeds, enc_lengths):
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    s = x.shape[1]
    ctx = {"causal": False,
           "positions": jnp.arange(s, dtype=jnp.int32)[None, :],
           "lengths": enc_lengths}

    def body(carry, bp):
        h, aux = carry
        h, _, a = block_apply(bp, h, cfg, ENC_SPEC, mode="train", ctx=ctx)
        return (h, aux + a), None

    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                           params["encoder"]["blocks"])
    return apply_norm(cfg.norm, params["encoder"]["final_norm"], x), aux


def apply_model(params, cfg: ArchConfig, batch: Dict[str, Any], *,
                mode: str = "train") -> ModelOutput:
    """batch keys: tokens (B,S) int32; optional embeds (B,S,D) added to the
    token embeddings (modality stub); positions (B,S); positions3 (3,B,S);
    enc_embeds/enc_lengths (encoder-decoder); caches, block_tables,
    lengths (prefill/decode)."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, dtype)
    if batch.get("embeds") is not None:
        x = x + batch["embeds"].astype(dtype)
    x = _hint(x, cfg, None, None)

    ctx: Dict[str, Any] = {
        "positions": batch.get("positions"),
        "positions3": batch.get("positions3"),
        "causal": True,
        "block_tables": batch.get("block_tables"),
        "lengths": batch.get("lengths"),
    }
    if cfg.enc_dec:
        if mode == "decode":
            ctx["enc_lengths"] = batch.get("enc_lengths")
        else:
            enc_out, enc_aux = _run_encoder(params, cfg,
                                            batch["enc_embeds"],
                                            batch.get("enc_lengths"))
            ctx["enc_out"] = enc_out
            ctx["enc_lengths"] = batch.get("enc_lengths")

    caches = batch.get("caches")
    aux0 = jnp.zeros((), jnp.float32)

    if caches is None:
        remat_on = mode == "train" and cfg.remat != "none"
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots"
                  else jax.checkpoint_policies.nothing_saveable)

        def one_layer(bp_j, h, j):
            h, _, a = block_apply(bp_j, h, cfg, cfg.pattern[j], mode=mode,
                                  ctx=ctx)
            return h, a

        if remat_on and cfg.remat_unit == "layer":
            # per-layer checkpointing: backward recompute peak is ONE
            # layer, not one whole pattern period (§Perf cell 3)
            one_layer = jax.checkpoint(one_layer, policy=policy,
                                       prevent_cse=False,
                                       static_argnums=(2,))

        def body(carry, bp):
            h, aux = carry
            h = _hint(h, cfg, None, None)
            for j in range(len(cfg.pattern)):
                h, a = one_layer(bp[j], h, j)
                aux = aux + a
            return (h, aux), None

        if remat_on and cfg.remat_unit != "layer":
            body = jax.checkpoint(body, policy=policy,
                                  prevent_cse=False)
        if cfg.scan_layers:
            (x, aux), _ = lax.scan(body, (x, aux0), params["blocks"])
        else:
            aux = aux0
            for r in range(cfg.n_repeat):
                bp = jax.tree_util.tree_map(lambda a, r=r: a[r],
                                            params["blocks"])
                (x, aux), _ = body((x, aux), bp)
        new_caches = None
    else:
        def body(carry, xs):
            h, aux = carry
            bp, bc = xs
            h = _hint(h, cfg, None, None)
            ncs = []
            for j, spec in enumerate(cfg.pattern):
                h, nc, a = block_apply(bp[j], h, cfg, spec, mode=mode,
                                       ctx=ctx, cache=bc[j])
                aux = aux + a
                ncs.append(nc)
            return (h, aux), tuple(ncs)

        if cfg.scan_layers:
            (x, aux), new_caches = lax.scan(body, (x, aux0),
                                            (params["blocks"], caches))
        else:
            aux = aux0
            per_repeat = []
            for r in range(cfg.n_repeat):
                bp = jax.tree_util.tree_map(lambda a, r=r: a[r],
                                            params["blocks"])
                bc = jax.tree_util.tree_map(lambda a, r=r: a[r], caches)
                (x, aux), ncs = body((x, aux), (bp, bc))
                per_repeat.append(ncs)
            new_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per_repeat)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg.vocab)
    logits = _hint(logits, cfg, None, "model")   # vocab-sharded loss
    if cfg.enc_dec and mode != "decode":
        aux = aux + enc_aux
    return ModelOutput(logits=logits, caches=new_caches, aux_loss=aux)


def train_forward(params, cfg: ArchConfig, batch):
    return apply_model(params, cfg, batch, mode="train")


def prefill(params, cfg: ArchConfig, batch):
    return apply_model(params, cfg, batch, mode="prefill")


def decode_step(params, cfg: ArchConfig, batch):
    return apply_model(params, cfg, batch, mode="decode")
