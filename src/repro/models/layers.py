"""Shared layers: norms, MLPs, rotary embeddings (RoPE + M-RoPE), embed."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamDef

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_defs(d: int):
    return {"scale": ParamDef((d,), P(None), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


def layernorm_defs(d: int):
    return {"scale": ParamDef((d,), P(None), init="ones"),
            "bias": ParamDef((d,), P(None), init="zeros")}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) \
        + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def norm_defs(kind: str, d: int):
    return rmsnorm_defs(d) if kind == "rmsnorm" else layernorm_defs(d)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------


def mlp_defs(d_model: int, d_ff: int, kind: str):
    if kind == "swiglu":
        return {"wi": ParamDef((d_model, d_ff), P("data", "model")),
                "wg": ParamDef((d_model, d_ff), P("data", "model")),
                "wo": ParamDef((d_ff, d_model), P("model", "data"))}
    # relu2 (squared ReLU, Nemotron-4) and gelu share the 2-matrix shape
    return {"wi": ParamDef((d_model, d_ff), P("data", "model")),
            "wo": ParamDef((d_ff, d_model), P("model", "data"))}


def mlp(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ params["wi"]))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ params["wi"])
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    if x.ndim == ang.ndim + 1:                                # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections: Tuple[int, int, int],
                theta: float = 1_000_000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions3: (3, B, S) — temporal/height/width position
    ids (the vision stub supplies them precomputed).  The half-dim rotary
    frequency bands are split into ``sections`` (t, h, w), each rotated by
    its own position stream; text tokens carry identical t/h/w ids, which
    makes this collapse to standard RoPE for pure text.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    # (3, B, S, half)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs
    parts = []
    start = 0
    for sec_i, sec in enumerate(sections):
        parts.append(ang_all[sec_i, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                     # (B, S, half)
    ang = ang[..., None, :]                                   # head dim
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_defs(vocab_padded: int, d_model: int, tie: bool):
    defs = {"tokens": ParamDef((vocab_padded, d_model), P("model", "data"),
                               init="embed")}
    if not tie:
        defs["head"] = ParamDef((d_model, vocab_padded), P("data", "model"))
    return defs


def embed(params, tokens: jax.Array, dtype) -> jax.Array:
    return params["tokens"].astype(dtype)[tokens]


def unembed(params, x: jax.Array, vocab: Optional[int] = None) -> jax.Array:
    if "head" in params:
        logits = (x @ params["head"]).astype(jnp.float32)
    else:
        logits = (x @ params["tokens"].T.astype(x.dtype)).astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        # mask padding rows so the softmax never sees them
        pad_mask = jnp.arange(logits.shape[-1]) >= vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits
