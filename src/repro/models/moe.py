"""Mixture-of-Experts layer with capacity-based dispatch and EP sharding.

Experts live as stacked (E, ...) tensors sharded over the ``model`` axis
(expert parallelism) and FSDP-sharded over ``data`` on the d_model dim.
Dispatch is the GShard-style capacity scheme expressed as dense scatters,
which GSPMD partitions cleanly (an all_to_all-based path is evaluated as a
§Perf hillclimb alternative in the distributed runtime).

The expert-id -> slab translation this layer performs at serving time is
the paper's §4.5 workload; the serving offload path resolves it with the
tiara_gather kernel / the NIC operator instead of a host round trip.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamDef


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False     # Llama-4 style always-on shared expert
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    balance_coef: float = 1e-2


def moe_defs(d_model: int, spec: MoESpec):
    e, f = spec.n_experts, spec.d_ff_expert
    defs = {
        "router": ParamDef((d_model, e), P("data", None)),
        "wi": ParamDef((e, d_model, f), P("model", "data", None)),
        "wg": ParamDef((e, d_model, f), P("model", "data", None)),
        "wo": ParamDef((e, f, d_model), P("model", None, "data"), fan_in=f),
    }
    if spec.shared_expert:
        defs["shared"] = {
            "wi": ParamDef((d_model, f), P("data", "model")),
            "wg": ParamDef((d_model, f), P("data", "model")),
            "wo": ParamDef((f, d_model), P("model", "data")),
        }
    return defs


def _capacity(n_tokens: int, spec: MoESpec) -> int:
    cap = int(n_tokens * spec.top_k * spec.capacity_factor
              / spec.n_experts)
    return max(8, (cap + 3) // 4 * 4)


def moe_apply(params, x: jax.Array, spec: MoESpec, *,
              hints: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    ``hints``: explicit EP shardings on the dispatch/expert buffers so
    GSPMD routes tokens with one gather per direction instead of
    replicating the buffers (§Perf cell 2); requires an ambient mesh with
    ("data", "model") axes."""
    def hint(t, *axes):
        if not hints:
            return t
        return jax.lax.with_sharding_constraint(t, P(*axes))

    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    e, k = spec.n_experts, spec.top_k
    cap = _capacity(t, spec)

    logits = (xf @ params["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                      # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each assignment within its expert (drop beyond capacity)
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)          # (T, K, E)
    flat_oh = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat_oh, axis=0) - flat_oh                # (T*K, E)
    pos = jnp.sum(pos * flat_oh, axis=-1)                      # (T*K,)
    eflat = eidx.reshape(t * k)
    keep = (pos < cap).astype(xf.dtype)
    slot = jnp.minimum(pos, cap - 1)

    # dispatch: (E, C, D) buffers (dropped tokens contribute zeros)
    disp = jnp.zeros((e, cap, d), xf.dtype)
    x_rep = jnp.repeat(xf, k, axis=0) * keep[:, None]
    x_rep = hint(x_rep, ("data",), None)
    disp = disp.at[eflat, slot].add(x_rep)
    disp = hint(disp, "model", None, "data")

    # expert FFN (SwiGLU), EP-sharded einsums
    h = jnp.einsum("ecd,edf->ecf", disp, params["wi"].astype(xf.dtype))
    g = jnp.einsum("ecd,edf->ecf", disp, params["wg"].astype(xf.dtype))
    h = jax.nn.silu(hint(g, "model", None, None)) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(xf.dtype))
    out_buf = hint(out_buf, "model", None, "data")

    # combine
    y = out_buf[eflat, slot] * keep[:, None]                   # (T*K, D)
    y = hint(y, ("data",), None)
    y = (y.reshape(t, k, d)
         * gate[..., None].astype(xf.dtype)).sum(axis=1)

    if spec.shared_expert:
        sh = params["shared"]
        hs = jax.nn.silu(xf @ sh["wg"]) * (xf @ sh["wi"])
        y = y + hs @ sh["wo"]

    # aux losses: load balance (Switch) + router z-loss
    me = probs.mean(axis=0)                                    # (E,)
    ce = (onehot.sum(axis=1).astype(jnp.float32)).mean(axis=0)  # (E,)
    balance = spec.balance_coef * e * jnp.sum(me * ce) / k
    zloss = spec.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y.reshape(b, s, d), balance + zloss
