"""Layer blocks: norm + mixer (attn/mamba/rwkv) + norm + FFN (mlp/moe/cm).

One ``block_defs``/``block_apply`` pair covers every assigned architecture;
the repeating-pattern transformer stacks these per pattern element and
``lax.scan``s over repetitions (transformer.py).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, LayerSpec
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.attention import PagedKV
from repro.models.layers import apply_norm, mlp, mlp_defs, norm_defs
from repro.models.moe import moe_apply, moe_defs


def block_defs(cfg: ArchConfig, spec: LayerSpec, *, cross: bool = False):
    d = cfg.d_model
    defs: Dict[str, Any] = {"norm1": norm_defs(cfg.norm, d)}
    if spec.kind == "attn":
        defs["mix"] = attn_mod.attn_defs(d, cfg.n_heads, cfg.n_kv_heads,
                                         cfg.head_dim)
    elif spec.kind == "mamba":
        defs["mix"] = mamba_mod.mamba_defs(d, cfg.mamba)
    elif spec.kind == "rwkv":
        defs["mix"] = rwkv_mod.rwkv_time_defs(d, cfg.rwkv)
    else:
        raise ValueError(spec.kind)
    if cross:
        defs["norm_x"] = norm_defs(cfg.norm, d)
        defs["cross"] = attn_mod.attn_defs(d, cfg.n_heads, cfg.n_kv_heads,
                                           cfg.head_dim)
    defs["norm2"] = norm_defs(cfg.norm, d)
    if spec.mlp == "moe":
        defs["ffn"] = moe_defs(d, spec.moe)
    elif spec.mlp == "rwkv_cm":
        defs["ffn"] = rwkv_mod.rwkv_channel_defs(d, cfg.d_ff)
    else:
        defs["ffn"] = mlp_defs(d, cfg.d_ff, spec.mlp)
    return defs


class BlockCache(NamedTuple):
    """Union cache for one layer; unused fields are size-0 placeholders so
    the pytree structure is uniform across layer kinds (scan-friendly)."""
    paged: Optional[PagedKV] = None
    mamba: Optional[mamba_mod.MambaCache] = None
    rwkv: Optional[rwkv_mod.RWKVCache] = None
    cross_k: Optional[jax.Array] = None
    cross_v: Optional[jax.Array] = None


def init_block_cache(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     pages_per_layer: int, dtype, *,
                     cross_len: int = 0) -> BlockCache:
    paged = mamba_c = rwkv_c = cross_k = cross_v = None
    if spec.kind == "attn":
        shape = (pages_per_layer, cfg.page_size, cfg.n_kv_heads,
                 cfg.head_dim)
        paged = PagedKV(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    elif spec.kind == "mamba":
        mamba_c = mamba_mod.init_mamba_cache(batch, cfg.d_model, cfg.mamba,
                                             dtype)
    elif spec.kind == "rwkv":
        rwkv_c = rwkv_mod.init_rwkv_cache(batch, cfg.d_model, cfg.rwkv,
                                          dtype)
    if cross_len:
        cshape = (batch, cross_len, cfg.n_kv_heads, cfg.head_dim)
        cross_k, cross_v = jnp.zeros(cshape, dtype), jnp.zeros(cshape, dtype)
    return BlockCache(paged=paged, mamba=mamba_c, rwkv=rwkv_c,
                      cross_k=cross_k, cross_v=cross_v)


def block_apply(params, x: jax.Array, cfg: ArchConfig, spec: LayerSpec, *,
                mode: str,                     # train | prefill | decode
                ctx: Dict[str, Any],
                cache: Optional[BlockCache] = None,
                ) -> Tuple[jax.Array, Optional[BlockCache], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new = cache._asdict() if cache is not None else None
    h = apply_norm(cfg.norm, params["norm1"], x)

    if spec.kind == "attn":
        if mode == "decode":
            out, paged = attn_mod.attention_decode(
                params["mix"], h, cfg, cache.paged, ctx["block_tables"],
                ctx["lengths"], positions3=ctx.get("positions3"))
            new["paged"] = paged
        else:
            out, (k, v) = attn_mod.attention_full(
                params["mix"], h, cfg, positions=ctx.get("positions"),
                positions3=ctx.get("positions3"),
                causal=ctx.get("causal", True))
            if mode == "prefill" and cache is not None:
                new["paged"] = attn_mod.scatter_prefill_kv(
                    cache.paged, k, v, ctx["block_tables"])
    elif spec.kind == "mamba":
        out, mc = mamba_mod.mamba_forward(
            params["mix"], h, cfg.mamba,
            cache.mamba if cache is not None else None,
            lengths=ctx.get("lengths") if mode == "prefill" else None)
        if cache is not None:
            new["mamba"] = mc
    elif spec.kind == "rwkv":
        out, (state, last_x) = rwkv_mod.rwkv_time_mix(
            params["mix"], h, cfg.rwkv,
            cache.rwkv if cache is not None else None,
            lengths=ctx.get("lengths") if mode == "prefill" else None)
        if cache is not None:
            new["rwkv"] = cache.rwkv._replace(state=state, x_time=last_x)
    else:
        raise ValueError(spec.kind)
    x = x + out

    if "cross" in params:
        hx = apply_norm(cfg.norm, params["norm_x"], x)
        if mode == "decode":
            kv = (cache.cross_k, cache.cross_v)
        else:
            # project encoder output to this layer's cross KV
            enc = ctx["enc_out"]
            b, se, _ = enc.shape
            k = (enc @ params["cross"]["wk"]).reshape(
                b, se, cfg.n_kv_heads, cfg.head_dim)
            v = (enc @ params["cross"]["wv"]).reshape(
                b, se, cfg.n_kv_heads, cfg.head_dim)
            kv = (k, v)
            if cache is not None:
                new["cross_k"] = k.astype(cache.cross_k.dtype)
                new["cross_v"] = v.astype(cache.cross_v.dtype)
        out, _ = attn_mod.attention_full(
            params["cross"], hx, cfg, causal=False,
            lengths=ctx.get("enc_lengths"), kv_override=kv)
        x = x + out

    h = apply_norm(cfg.norm, params["norm2"], x)
    if spec.mlp == "moe":
        if getattr(cfg, "moe_fn", None) is not None:
            out, aux = cfg.moe_fn(params["ffn"], h)
        else:
            out, aux = moe_apply(params["ffn"], h, spec.moe,
                                 hints=getattr(cfg, "moe_hints", False))
    elif spec.mlp == "rwkv_cm":
        prev = cache.rwkv.x_chan if (cache is not None and
                                     cache.rwkv is not None) else None
        out, last_c = rwkv_mod.rwkv_channel_mix(
            params["ffn"], h, prev,
            lengths=ctx.get("lengths") if mode == "prefill" else None)
        if cache is not None and new.get("rwkv") is not None:
            new["rwkv"] = new["rwkv"]._replace(x_chan=last_c)
    else:
        out = mlp(params["ffn"], h, spec.mlp)
    x = x + out

    new_cache = BlockCache(**new) if new is not None else None
    return x, new_cache, aux
