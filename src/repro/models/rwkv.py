"""RWKV-6 (Finch) block — data-dependent decay linear attention.

Time-mix: per-head matrix state S (hd x hd) updated with an
*input-dependent* diagonal decay w_t (the Finch contribution) plus a
first-occurrence bonus u; channel-mix: token-shifted squared-ReLU FFN.
Both recurrences run as ``lax.scan`` over time (compile-size-flat, the
dry-run requirement; see mamba.py for the hardware note).

DESIGN.md §Arch-applicability: attention-free — there is no KV block
table, so the paper's indirection-collapse has nothing to collapse here;
the recurrent state is still registered as a Tiara memory region for the
disaggregated-state example.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamDef


@dataclasses.dataclass(frozen=True)
class RWKVSpec:
    head_size: int = 64
    decay_lora: int = 64


def rwkv_time_defs(d_model: int, spec: RWKVSpec):
    dl = spec.decay_lora
    return {
        # token-shift interpolation coefficients for r/k/v/w/g
        "mu": ParamDef((5, d_model), P(None, None), init="zeros"),
        "wr": ParamDef((d_model, d_model), P("data", "model")),
        "wk": ParamDef((d_model, d_model), P("data", "model")),
        "wv": ParamDef((d_model, d_model), P("data", "model")),
        "wg": ParamDef((d_model, d_model), P("data", "model")),
        "wo": ParamDef((d_model, d_model), P("model", "data")),
        # data-dependent decay LoRA (Finch): w_t = exp(-softplus(...))
        "w_base": ParamDef((d_model,), P("model"), init="zeros"),
        "w1": ParamDef((d_model, dl), P("data", None)),
        "w2": ParamDef((dl, d_model), P(None, "model")),
        "u_bonus": ParamDef((d_model,), P("model"), init="zeros"),
    }


def rwkv_channel_defs(d_model: int, d_ff: int):
    return {
        "mu": ParamDef((2, d_model), P(None, None), init="zeros"),
        "wk": ParamDef((d_model, d_ff), P("data", "model")),
        "wv": ParamDef((d_ff, d_model), P("model", "data")),
        "wr": ParamDef((d_model, d_model), P("data", "model")),
    }


class RWKVCache(NamedTuple):
    state: jax.Array       # (B, H, hd, hd) wkv matrix state
    x_time: jax.Array      # (B, D) last input of the time-mix sublayer
    x_chan: jax.Array      # (B, D) last input of the channel-mix sublayer


def init_rwkv_cache(batch: int, d_model: int, spec: RWKVSpec,
                    dtype=jnp.float32) -> RWKVCache:
    h = d_model // spec.head_size
    return RWKVCache(
        state=jnp.zeros((batch, h, spec.head_size, spec.head_size),
                        jnp.float32),
        x_time=jnp.zeros((batch, d_model), dtype),
        x_chan=jnp.zeros((batch, d_model), dtype))


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """x (B,S,D) -> x shifted right by one (prev fills t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1), x[:, -1]


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * jax.nn.sigmoid(mu).astype(x.dtype)


def _last_valid(x: jax.Array, lengths: Optional[jax.Array]) -> jax.Array:
    """x (B,S,D) -> the entry at position length-1 (or the final one)."""
    if lengths is None:
        return x[:, -1]
    idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def rwkv_time_mix(params, x: jax.Array, spec: RWKVSpec,
                  cache: Optional[RWKVCache] = None,
                  lengths: Optional[jax.Array] = None):
    """x (B,S,D) -> (out, (new_state, last_x)).  ``lengths``: right-padded
    prefill — padded steps leave the state untouched."""
    b, s, d = x.shape
    hs = spec.head_size
    nh = d // hs
    xs, _ = _token_shift(x, cache.x_time if cache else None)
    last_x = _last_valid(x, lengths)
    mu = params["mu"]
    r = _mix(x, xs, mu[0]) @ params["wr"]
    k = _mix(x, xs, mu[1]) @ params["wk"]
    v = _mix(x, xs, mu[2]) @ params["wv"]
    xw = _mix(x, xs, mu[3])
    g = jax.nn.silu(_mix(x, xs, mu[4]) @ params["wg"])
    # data-dependent decay, in (0, 1)
    w = jnp.exp(-jax.nn.softplus(
        (params["w_base"] + (xw @ params["w1"]) @ params["w2"])
        .astype(jnp.float32)))                                 # (B,S,D)
    u = params["u_bonus"].astype(jnp.float32)

    def heads(t):
        return t.reshape(b, s, nh, hs).astype(jnp.float32)

    rh, kh, vh = heads(r), heads(k), heads(v)
    wh = w.reshape(b, s, nh, hs)
    uh = u.reshape(nh, hs)

    if lengths is not None:
        valid = (jnp.arange(s)[None, :] < lengths[:, None])   # (B, S)
    else:
        valid = jnp.ones((b, s), bool)

    def step(state, t):
        r_t, k_t, v_t, w_t, m_t = t                 # (B,H,hs) each
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,hs,hs)
        y = jnp.einsum("bhi,bhij->bhj", r_t,
                       state + uh[None, :, :, None] * kv)
        new_state = w_t[..., :, None] * state + kv
        state = jnp.where(m_t[:, None, None, None], new_state, state)
        return state, y

    def recur(state, t):
        """One chunk; checkpointed so the per-step (B,H,hs,hs) states are
        recomputed, not saved, on backward (TBs at 32k otherwise)."""
        r_c, k_c, v_c, w_c, m_c = t
        return jax.lax.scan(step, state,
                            (r_c.swapaxes(0, 1), k_c.swapaxes(0, 1),
                             v_c.swapaxes(0, 1), w_c.swapaxes(0, 1),
                             m_c.swapaxes(0, 1)))

    s0 = cache.state if cache is not None else jnp.zeros(
        (b, nh, hs, hs), jnp.float32)
    chunk = 256
    if s > chunk and s % chunk == 0:
        n_chunks = s // chunk

        def rsh(t):
            return t.reshape((b, n_chunks, chunk) + t.shape[2:]) \
                    .swapaxes(0, 1)

        sT, ys = jax.lax.scan(jax.checkpoint(recur), s0,
                              (rsh(rh), rsh(kh), rsh(vh), rsh(wh),
                               rsh(valid)))
        y = ys.transpose(2, 0, 1, 3, 4).reshape(b, s, d).astype(x.dtype)
    else:
        sT, ys = recur(s0, (rh, kh, vh, wh, valid))
        y = ys.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    out = (y * g) @ params["wo"]
    return out, (sT, last_x)


def rwkv_channel_mix(params, x: jax.Array,
                     cache_prev: Optional[jax.Array] = None,
                     lengths: Optional[jax.Array] = None):
    xs, _ = _token_shift(x, cache_prev)
    last_x = _last_valid(x, lengths)
    mu = params["mu"]
    k = _mix(x, xs, mu[0]) @ params["wk"]
    kv = jnp.square(jax.nn.relu(k)) @ params["wv"]
    r = jax.nn.sigmoid(_mix(x, xs, mu[1]) @ params["wr"])
    return r * kv, last_x
