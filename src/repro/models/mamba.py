"""Mamba (S6) block — Jamba's attention-free layer.

Selective SSM with input-dependent (dt, B, C): a linear recurrence over
time executed with ``lax.scan`` (state (B, d_inner, d_state) carry).  The
scan keeps HLO size and compile memory flat in sequence length, which is
what the multi-pod dry-run needs; a chunked Pallas selective-scan kernel
is the documented real-hardware fast path (DESIGN.md §Arch-applicability
notes the Tiara technique itself does not apply: state addresses are
affine, there is no indirection to collapse).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.param import ParamDef


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None

    def dims(self, d_model: int) -> Tuple[int, int]:
        d_inner = self.expand * d_model
        dt_rank = self.dt_rank or max(1, d_model // 16)
        return d_inner, dt_rank


def mamba_defs(d_model: int, spec: MambaSpec):
    d_inner, dt_rank = spec.dims(d_model)
    return {
        "in_proj": ParamDef((d_model, 2 * d_inner), P("data", "model")),
        "conv_w": ParamDef((spec.d_conv, d_inner), P(None, "model")),
        "conv_b": ParamDef((d_inner,), P("model"), init="zeros"),
        "x_proj": ParamDef((d_inner, dt_rank + 2 * spec.d_state),
                           P("model", None)),
        "dt_proj": ParamDef((dt_rank, d_inner), P(None, "model")),
        "dt_bias": ParamDef((d_inner,), P("model"), init="zeros"),
        "A_log": ParamDef((d_inner, spec.d_state), P("model", None),
                          init="zeros"),
        "D_skip": ParamDef((d_inner,), P("model"), init="ones"),
        "out_proj": ParamDef((d_inner, d_model), P("model", "data")),
    }


class MambaCache(NamedTuple):
    h: jax.Array         # (B, d_inner, d_state) SSM state
    conv: jax.Array      # (B, d_conv - 1, d_inner) rolling conv window


def init_mamba_cache(batch: int, d_model: int, spec: MambaSpec,
                     dtype=jnp.float32) -> MambaCache:
    d_inner, _ = spec.dims(d_model)
    return MambaCache(
        h=jnp.zeros((batch, d_inner, spec.d_state), jnp.float32),
        conv=jnp.zeros((batch, spec.d_conv - 1, d_inner), dtype))


def _ssm_inputs(params, x_conv, spec: MambaSpec, dt_rank: int):
    """x_conv (B, S, d_inner) -> (dt, bm, cm): small per-step inputs; the
    (B, S, d_inner, d_state) recurrence coefficients are formed lazily
    inside the checkpointed chunks (memory!)."""
    d_state = spec.d_state
    xdb = x_conv @ params["x_proj"]
    dt_r = xdb[..., :dt_rank]
    bm = xdb[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    cm = xdb[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus((dt_r @ params["dt_proj"]
                          + params["dt_bias"]).astype(jnp.float32))
    return dt, bm, cm


def _conv_causal(params, x, spec: MambaSpec, prefix: Optional[jax.Array]):
    """Depthwise causal conv over time. prefix: (B, d_conv-1, d_inner)."""
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], spec.d_conv - 1, x.shape[-1]),
                           x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)
    out = params["conv_b"].astype(x.dtype)
    acc = jnp.zeros_like(x) + out
    s = x.shape[1]
    for j in range(spec.d_conv):
        acc = acc + xp[:, j:j + s] * params["conv_w"][j].astype(x.dtype)
    return jax.nn.silu(acc), xp[:, -(spec.d_conv - 1):] \
        if spec.d_conv > 1 else prefix


def mamba_forward(params, x: jax.Array, spec: MambaSpec,
                  cache: Optional[MambaCache] = None,
                  lengths: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[MambaCache]]:
    """x (B, S, D); returns (out, new_cache if cache given).

    ``lengths`` (prefill with right-padding): positions >= length take an
    identity recurrence step so padding never pollutes the carried state,
    and the conv tail is gathered at the true sequence end."""
    b, s, d_model = x.shape
    d_inner, dt_rank = spec.dims(d_model)
    xz = x @ params["in_proj"]
    x_in, z = xz[..., :d_inner], xz[..., d_inner:]
    x_conv, conv_tail = _conv_causal(params, x_in, spec,
                                     cache.conv if cache else None)
    dt, bm, cm = _ssm_inputs(params, x_conv, spec, dt_rank)
    xcf = x_conv.astype(jnp.float32)
    a_mat = -jnp.exp(params["A_log"].astype(jnp.float32))   # (d_inner, N)

    if lengths is not None:
        valid = (jnp.arange(s)[None, :] < lengths[:, None])  # (B, S)
        if spec.d_conv > 1:
            # conv window ending at the true last token; xp coords offset
            # by (d_conv - 1), so window index = length + j
            xp = jnp.concatenate(
                [cache.conv if cache is not None else
                 jnp.zeros((b, spec.d_conv - 1, d_inner), x_in.dtype),
                 x_in], axis=1)
            idx = jnp.clip(lengths[:, None]
                           + jnp.arange(spec.d_conv - 1)[None, :],
                           0, xp.shape[1] - 1)
            conv_tail = jnp.take_along_axis(xp, idx[..., None], axis=1)
    else:
        valid = jnp.ones((b, s), bool)

    h0 = cache.h if cache is not None else jnp.zeros(
        (b, d_inner, spec.d_state), jnp.float32)

    def recur(h, t):
        """One chunk (or the whole sequence when short).  The (B, C,
        d_inner, N) coefficients live only inside this (checkpointed)
        region; backward recomputes them chunk by chunk."""
        dt_c, xc_c, bm_c, cm_c, v_c = t
        a = jnp.exp(dt_c[..., None] * a_mat)                # (B,C,d,N)
        bx = (dt_c * xc_c)[..., None] * bm_c[..., None, :]
        vm = v_c[..., None, None]
        a = jnp.where(vm, a, 1.0)
        bx = jnp.where(vm, bx, 0.0)

        def step(hh, tt):
            a_t, bx_t, c_t = tt
            hh = a_t * hh + bx_t
            return hh, jnp.einsum("bds,bs->bd", hh, c_t)

        h, ys = jax.lax.scan(step, h,
                             (a.swapaxes(0, 1), bx.swapaxes(0, 1),
                              cm_c.swapaxes(0, 1)))
        return h, ys                                        # ys (C, B, d)

    chunk = 256
    if s > chunk and s % chunk == 0:
        n_chunks = s // chunk

        def rs(t):
            return t.reshape((b, n_chunks, chunk) + t.shape[2:]) \
                    .swapaxes(0, 1)

        hT, ys = jax.lax.scan(jax.checkpoint(recur), h0,
                              (rs(dt), rs(xcf), rs(bm), rs(cm), rs(valid)))
        y = ys.transpose(2, 0, 1, 3).reshape(b, s, d_inner)
    else:
        hT, ys = recur(h0, (dt, xcf, bm, cm, valid))
        y = ys.swapaxes(0, 1)                                # (B,S,d_inner)
    y = y + params["D_skip"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_cache = MambaCache(h=hT, conv=conv_tail) if cache is not None else None
    return out, new_cache
