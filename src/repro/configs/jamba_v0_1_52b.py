"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 with MoE 16e top-2 on every
second layer [arXiv:2403.19887].  Period-8 pattern: one attention layer
per 8, MoE on odd positions."""
from repro.configs import ArchConfig, LayerSpec
from repro.models.mamba import MambaSpec
from repro.models.moe import MoESpec

_MOE = MoESpec(n_experts=16, top_k=2, d_ff_expert=14336,
               shared_expert=False, capacity_factor=1.25)


def _pattern():
    out = []
    for i in range(8):
        kind = "attn" if i == 0 else "mamba"
        if i % 2 == 1:
            out.append(LayerSpec(kind=kind, mlp="moe", moe=_MOE))
        else:
            out.append(LayerSpec(kind=kind, mlp="swiglu"))
    return tuple(out)


CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    pattern=_pattern(),
    norm="rmsnorm", rope="none",     # Jamba uses no positional encoding
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
    sub_quadratic=True,
    source="arXiv:2403.19887",
)
