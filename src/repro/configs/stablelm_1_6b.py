"""StableLM-2 1.6B — dense MHA (kv=heads) [hf:stabilityai/stablelm-2-1_6b].

Deviation note: upstream uses partial (25%) rotary; we apply full-dim RoPE
(recorded in DESIGN.md §8 as a faithfulness boundary)."""
from repro.configs import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352, head_dim=64,
    pattern=(LayerSpec(kind="attn", mlp="swiglu"),),
    norm="layernorm", rope="rope", rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
)
