"""Architecture configs — the 10 assigned architectures + shape suite.

``get_config(name)`` resolves an ``--arch`` id; each architecture lives in
its own module with the exact published numbers.  ``SHAPES`` carries the
assigned input-shape suite; ``cell_applicable`` encodes the long_500k
sub-quadratic rule and encoder/decoder caveats (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

from repro.models.mamba import MambaSpec
from repro.models.moe import MoESpec
from repro.models.rwkv import RWKVSpec


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"            # attn | mamba | rwkv
    mlp: str = "swiglu"           # swiglu | relu2 | gelu | moe | rwkv_cm
    moe: Optional[MoESpec] = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...]
    head_dim: int
    source: str = ""
    norm: str = "rmsnorm"
    rope: str = "rope"            # rope | mrope | none
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = False
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None   # audio | vision (stubs: see DESIGN.md)
    sub_quadratic: bool = False
    mamba: MambaSpec = MambaSpec()
    rwkv: RWKVSpec = RWKVSpec()
    attn_impl: str = "auto"
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    page_size: int = 128          # paged-KV page (tokens)
    remat: str = "full"           # none | dots | full (train-mode scan body)
    scan_layers: bool = True      # False: unroll (dry-run FLOP accounting —
    #                               XLA cost_analysis counts loop bodies once)
    dp_spec: Optional[Tuple[str, ...]] = None   # batch-dim mesh axes for
    #                               explicit activation sharding hints
    #                               (set by the launcher; needs use_mesh)
    paged_attn_fn: Optional[Any] = None   # launcher-injected one-round
    #                               sequence-parallel decode (§Perf cell 1)
    remat_unit: str = "pattern"   # pattern | layer (checkpoint granularity)
    moe_hints: bool = False       # explicit dispatch-buffer shardings
    moe_fn: Optional[Any] = None  # launcher-injected local-dispatch EP MoE

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab dim
        shards evenly on the model axis (padded logits are masked)."""
        return (self.vocab + 255) // 256 * 256

    @property
    def n_repeat(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: {self.n_layers} layers not a multiple of " \
            f"pattern length {len(self.pattern)}"
        return self.n_layers // len(self.pattern)

    @property
    def is_attention_free(self) -> bool:
        return all(s.kind != "attn" for s in self.pattern)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = (
    "internlm2-1.8b",
    "granite-3-8b",
    "stablelm-1.6b",
    "nemotron-4-15b",
    "llama4-scout-17b-a16e",
    "llama4-maverick-400b-a17b",
    "jamba-v0.1-52b",
    "rwkv6-1.6b",
    "seamless-m4t-medium",
    "qwen2-vl-7b",
)

_MODULES = {
    "internlm2-1.8b": "internlm2_1_8b",
    "granite-3-8b": "granite_3_8b",
    "stablelm-1.6b": "stablelm_1_6b",
    "nemotron-4-15b": "nemotron_4_15b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "tiny-lm": "tiny_lm",
}


def get_config(name: str) -> ArchConfig:
    key = name.strip().lower().replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[key]}")
    return mod.CONFIG


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small width, few
    layers (but >= one full pattern period), tiny vocab/experts, preserved
    GQA grouping and layer-kind structure."""
    group = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_kv = 1 if cfg.n_kv_heads < cfg.n_heads else 2
    n_heads = group * n_kv if cfg.n_kv_heads < cfg.n_heads else 2
    head_dim = 32
    d_model = 128
    pattern = []
    for spec in cfg.pattern:
        moe = spec.moe
        if moe is not None:
            # capacity high enough that smoke tests never drop tokens —
            # prefill+decode must match the full forward exactly
            moe = dataclasses.replace(moe, n_experts=min(4, moe.n_experts),
                                      d_ff_expert=64, capacity_factor=8.0)
        pattern.append(dataclasses.replace(spec, moe=moe))
    n_repeat = min(2, cfg.n_repeat)
    return cfg.replace(
        n_layers=len(pattern) * n_repeat,
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim, d_ff=160, vocab=512,
        pattern=tuple(pattern),
        n_enc_layers=min(2, cfg.n_enc_layers),
        mamba=dataclasses.replace(cfg.mamba, d_state=8, dt_rank=8),
        rwkv=dataclasses.replace(cfg.rwkv, head_size=32, decay_lora=16),
        mrope_sections=(4, 6, 6),
        dtype="float32", param_dtype="float32", page_size=8,
    )


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec
                    ) -> Tuple[bool, str]:
    """(runnable, reason) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token dense KV "
                       "decode excluded per assignment (DESIGN.md §4)")
    return True, ""
