"""Granite-3 8B — dense GQA [hf:ibm-granite/granite-3.0-8b-base]."""
from repro.configs import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, head_dim=128,
    pattern=(LayerSpec(kind="attn", mlp="swiglu"),),
    norm="rmsnorm", rope="rope", rope_theta=1e6,
    source="hf:ibm-granite/granite-3.0-2b-base (assigned spec)",
)
