"""RWKV-6 (Finch) 1.6B — attention-free, data-dependent decay
[arXiv:2404.05892].  DESIGN.md §5: the paper's indirection-collapse is
inapplicable (no KV block table); implemented without the technique."""
from repro.configs import ArchConfig, LayerSpec
from repro.models.rwkv import RWKVSpec

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64,
    pattern=(LayerSpec(kind="rwkv", mlp="rwkv_cm"),),
    norm="layernorm", rope="none",
    rwkv=RWKVSpec(head_size=64, decay_lora=64),
    sub_quadratic=True,
    source="arXiv:2404.05892",
)
