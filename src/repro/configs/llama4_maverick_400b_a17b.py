"""Llama-4 Maverick 400B-A17B — MoE 128 experts top-1 + shared expert on
every *second* layer (period-2 dense/MoE interleave, which is what lands
the public 400B total-parameter count) [hf:meta-llama/Llama-4-Maverick]."""
from repro.configs import ArchConfig, LayerSpec
from repro.models.moe import MoESpec

_MOE = MoESpec(n_experts=128, top_k=1, d_ff_expert=8192, shared_expert=True,
               capacity_factor=2.0)

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    pattern=(LayerSpec(kind="attn", mlp="swiglu"),
             LayerSpec(kind="attn", mlp="moe", moe=_MOE)),
    norm="rmsnorm", rope="rope", rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (assigned spec)",
)
