"""Nemotron-4 15B — dense GQA with squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000, head_dim=128,
    pattern=(LayerSpec(kind="attn", mlp="relu2"),),
    norm="layernorm", rope="rope", rope_theta=10000.0,
    source="arXiv:2402.16819",
)
