"""tiny-lm — ~110M dense model for the runnable end-to-end examples."""
from repro.configs import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="tiny-lm", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=32000, head_dim=64,
    pattern=(LayerSpec(kind="attn", mlp="swiglu"),),
    norm="rmsnorm", rope="rope", rope_theta=10000.0,
    dtype="float32", param_dtype="float32", page_size=16,
    source="this repo (examples)",
)
