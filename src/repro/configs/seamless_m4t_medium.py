"""SeamlessM4T-medium — encoder-decoder, multimodal [arXiv:2308.11596].

The audio frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings (B, S_enc, d_model).  Deviation note: we use
RoPE for self-attention in place of upstream relative/sinusoidal positions
(DESIGN.md §8)."""
from repro.configs import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    pattern=(LayerSpec(kind="attn", mlp="gelu"),),
    norm="layernorm", rope="rope", rope_theta=10000.0,
    enc_dec=True, n_enc_layers=12, frontend="audio",
    source="arXiv:2308.11596",
)
