"""Qwen2-VL 7B — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings added to the token embeddings, plus the
3-stream (t/h/w) M-RoPE position ids."""
from repro.configs import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    pattern=(LayerSpec(kind="attn", mlp="swiglu"),),
    norm="rmsnorm", rope="mrope", rope_theta=1e6,
    mrope_sections=(16, 24, 24), frontend="vision",
    source="arXiv:2409.12191",
)
