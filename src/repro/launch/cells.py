"""Dry-run cells: (arch x shape x mesh) -> step fn + arg specs + shardings.

``input_specs()`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, zero allocation); ``make_cell()`` bundles
them with the jitted step function and its in/out shardings so the dry-run
is a pure ``.lower().compile()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig, ShapeSpec, cell_applicable
from repro.models import transformer as tf
from repro.models.attention import PagedKV
from repro.models.blocks import BlockCache
from repro.models.mamba import MambaCache
from repro.models.rwkv import RWKVCache
from repro.launch.mesh import dp_axes, dp_size
from repro.training.optimizer import AdamWConfig, AdamWState, warmup_cosine
from repro.training.train_step import TrainState, make_train_step


from repro.jaxcompat import mesh_context


@dataclasses.dataclass
class Cell:
    cfg: ArchConfig
    shape: ShapeSpec
    fn: Callable                 # step function to jit
    args: Tuple                  # ShapeDtypeStruct pytrees
    in_specs: Tuple              # PartitionSpec pytrees
    out_specs: Any               # PartitionSpec pytree or None (=auto)
    donate: Tuple[int, ...] = ()
    notes: str = ""


def _b(dp, size_b: int, dpsz: int):
    """Batch-dim spec: shard over dp when it divides, else replicate."""
    return dp if size_b % dpsz == 0 and size_b >= dpsz else None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _train_batch(cfg: ArchConfig, shape: ShapeSpec, dp, dpsz):
    b, s = shape.global_batch, shape.seq_len
    bspec = _b(dp, b, dpsz)
    args = {"tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32)}
    specs = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    _add_modality(cfg, args, specs, b, s, bspec)
    return args, specs


def _add_modality(cfg, args, specs, b, s, bspec, *, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.rope == "mrope":
        args["positions3"] = _sds((3, b, s), jnp.int32)
        specs["positions3"] = P(None, bspec, None)
        args["embeds"] = _sds((b, s, cfg.d_model), dtype)
        specs["embeds"] = P(bspec, None, None)
    if cfg.enc_dec:
        args["enc_embeds"] = _sds((b, s, cfg.d_model), dtype)
        specs["enc_embeds"] = P(bspec, None, None)
        args["enc_lengths"] = _sds((b,), jnp.int32)
        specs["enc_lengths"] = P(bspec)


def _cache_structs_and_specs(cfg: ArchConfig, b: int, maxp: int, dp, dpsz,
                             cross_len: int = 0, pool_all_axes=None):
    structs = jax.eval_shape(
        lambda: tf.init_caches(cfg, b, maxp,
                               cross_len=cross_len))
    bspec = _b(dp, b, dpsz)
    pool_spec = dp          # pool = b * maxp, page-granular "memory pool"
    specs = []
    for spec_el, struct_el in zip(cfg.pattern, structs):
        paged = mamba = rwkv = cross_k = cross_v = None
        if struct_el.paged is not None:
            if pool_all_axes is not None:
                # one-round variant: whole pages fully distributed over
                # every mesh axis; they never cross the wire
                pg = P(None, pool_all_axes, None, None, None)
            else:
                # baseline: pool over the dp bundle (pages are batch-
                # owned); the *page* (token-slot) dim over model — it
                # divides for every arch, unlike kv heads (8 or 4 < 16)
                pg = P(None, pool_spec, "model", None, None)
            paged = PagedKV(pg, pg)
        if struct_el.mamba is not None:
            mamba = MambaCache(h=P(None, bspec, "model", None),
                               conv=P(None, bspec, None, "model"))
        if struct_el.rwkv is not None:
            rwkv = RWKVCache(state=P(None, bspec, "model", None, None),
                             x_time=P(None, bspec, None),
                             x_chan=P(None, bspec, None))
        if struct_el.cross_k is not None:
            cross_k = P(None, bspec, None, "model", None)
            cross_v = P(None, bspec, None, "model", None)
        specs.append(BlockCache(paged=paged, mamba=mamba, rwkv=rwkv,
                                cross_k=cross_k, cross_v=cross_v))
    return structs, tuple(specs)


def make_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
              state_bits: int = 32, variant: str = "baseline"
              ) -> Optional[Cell]:
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return None
    dp = dp_axes(mesh)
    dpsz = dp_size(mesh)
    b, s = shape.global_batch, shape.seq_len
    bspec = _b(dp, b, dpsz)
    cfg = cfg.replace(attn_impl="xla",
                      dp_spec=tuple(bspec) if bspec else None)
    pool_all_axes = None
    if variant in ("tiara_decode", "tiara_decode_v2") \
            and shape.kind == "decode":
        from repro.distributed.paged_decode import sharded_paged_attention
        cfg = cfg.replace(
            paged_attn_fn=sharded_paged_attention(
                mesh, dp, "model",
                contiguous=(variant == "tiara_decode_v2"),
                batch_sharded=bspec is not None))
        pool_all_axes = tuple(dp) + ("model",)
    elif variant == "remat_layer":
        cfg = cfg.replace(remat_unit="layer")
    elif variant == "moe_hints":
        cfg = cfg.replace(moe_hints=True)
    elif variant == "remat_layer+moe_hints":
        cfg = cfg.replace(remat_unit="layer", moe_hints=True)
    elif variant in ("moe_ep", "moe_ep+remat_layer"):
        from repro.distributed.moe_ep import make_moe_ep
        moe_specs = {sp.moe for sp in cfg.pattern if sp.moe is not None}
        assert len(moe_specs) == 1, "one MoE spec per arch"
        cfg = cfg.replace(
            moe_fn=make_moe_ep(mesh, dp, next(iter(moe_specs))),
            remat_unit="layer" if "remat" in variant else cfg.remat_unit)
    pspecs = tf.param_specs(cfg)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(lr=warmup_cosine(3e-4, 100, 10_000),
                              state_bits=state_bits)
        init_state, train_step = make_train_step(cfg, opt_cfg)
        state_struct = jax.eval_shape(init_state, jax.random.PRNGKey(0))
        if state_bits == 8:
            def q8spec(ps):
                from repro.training.optimizer import Q8
                # scales mirror the codes' leading-dim sharding (the last
                # dim collapses into per-block scales)
                return jax.tree_util.tree_map(
                    lambda sp: Q8(codes=sp,
                                  scales=P(*sp[:-1], None)
                                  if len(sp) else P()),
                    ps, is_leaf=lambda x: isinstance(x, P))
            mu_spec = q8spec(pspecs)
        else:
            mu_spec = pspecs
        state_spec = TrainState(step=P(), params=pspecs,
                                opt=AdamWState(count=P(), mu=mu_spec,
                                               nu=mu_spec))
        batch_struct, batch_spec = _train_batch(cfg, shape, dp, dpsz)
        metrics_spec = {k: P() for k in
                        ("nll", "aux", "loss", "grad_norm", "lr")}
        return Cell(cfg=cfg, shape=shape, fn=train_step,
                    args=(state_struct, batch_struct),
                    in_specs=(state_spec, batch_spec),
                    out_specs=(state_spec, metrics_spec),
                    donate=(0,))

    # serving shapes
    param_struct = tf.param_shapes(cfg)
    maxp = s // cfg.page_size + (1 if shape.kind == "decode" else 0)
    maxp = (maxp + 63) // 64 * 64      # pool divisibility on the dp bundle
    if pool_all_axes is not None:
        # pool (= b * maxp) must divide the full chip count for the
        # fully-distributed page layout
        import math
        chips = dpsz * mesh.shape["model"]
        need = chips // math.gcd(b, chips)
        maxp = (maxp + need - 1) // need * need
    cross_len = s if cfg.enc_dec else 0
    cache_structs, cache_specs = _cache_structs_and_specs(
        cfg, b, maxp, dp, dpsz, cross_len=cross_len,
        pool_all_axes=pool_all_axes)

    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "block_tables": _sds((b, maxp), jnp.int32),
                 "lengths": _sds((b,), jnp.int32)}
        bsp = {"tokens": P(bspec, None), "block_tables": P(bspec, None),
               "lengths": P(bspec)}
        _add_modality(cfg, batch, bsp, b, s, bspec)

        def prefill_step(params, caches, batch):
            out = tf.apply_model(params, cfg, {**batch, "caches": caches},
                                 mode="prefill")
            idx = jnp.maximum(batch["lengths"] - 1, 0)
            last = jnp.take_along_axis(
                out.logits, idx[:, None, None], axis=1)[:, 0]
            return last, out.caches

        out_specs = (P(bspec, "model"), cache_specs)
        return Cell(cfg=cfg, shape=shape, fn=prefill_step,
                    args=(param_struct, cache_structs, batch),
                    in_specs=(pspecs, cache_specs, bsp),
                    out_specs=out_specs, donate=(1,))

    # decode: one new token against a seq_len-token cache
    batch = {"tokens": _sds((b, 1), jnp.int32),
             "block_tables": _sds((b, maxp), jnp.int32),
             "lengths": _sds((b,), jnp.int32)}
    bsp = {"tokens": P(bspec, None), "block_tables": P(bspec, None),
           "lengths": P(bspec)}
    if cfg.rope == "mrope":
        batch["positions3"] = _sds((3, b, 1), jnp.int32)
        bsp["positions3"] = P(None, bspec, None)
    if cfg.enc_dec:
        batch["enc_lengths"] = _sds((b,), jnp.int32)
        bsp["enc_lengths"] = P(bspec)

    def decode_step(params, caches, batch):
        out = tf.apply_model(params, cfg, {**batch, "caches": caches},
                             mode="decode")
        return out.logits[:, 0], out.caches

    out_specs = (P(bspec, "model"), cache_specs)
    return Cell(cfg=cfg, shape=shape, fn=decode_step,
                args=(param_struct, cache_structs, batch),
                in_specs=(pspecs, cache_specs, bsp),
                out_specs=out_specs, donate=(1,))


def lower_cell(cell: Cell, mesh):
    """jit with explicit shardings and lower — no allocation, no compile.

    The mesh is made ambient so bare-PartitionSpec activation hints inside
    the model (transformer._hint) resolve."""
    def to_sharding(spec_tree_):
        return jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), spec_tree_,
            is_leaf=lambda x: isinstance(x, P))

    in_sh = to_sharding(cell.in_specs)
    out_sh = to_sharding(cell.out_specs) if cell.out_specs is not None \
        else None
    jitted = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=cell.donate)
    with mesh_context(mesh):
        return jitted.lower(*cell.args)
