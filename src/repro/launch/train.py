"""Training launcher: --arch <id> on the local device mesh.

    PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 50

Production note: on a real multi-host pod this entry point runs under
jax.distributed with the same code path; the dry-run (dryrun.py) is the
no-hardware proof of the production mesh configuration.
"""

import argparse

from repro.configs import get_config, reduce_config
from repro.data import DataConfig
from repro.training import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--state-bits", type=int, default=32, choices=[8, 32])
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    tcfg = TrainerConfig(total_steps=args.steps, log_every=5,
                         ckpt_every=max(args.steps // 4, 1),
                         ckpt_dir=args.ckpt, peak_lr=args.lr,
                         warmup=max(args.steps // 10, 1),
                         state_bits=args.state_bits,
                         micro_batches=args.micro_batches)
    state = Trainer(cfg, tcfg, dcfg).run()
    print(f"finished at step {int(state.step)}")


if __name__ == "__main__":
    main()
