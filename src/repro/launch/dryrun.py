import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/collective analysis.

MUST be the process entry point (jax locks the device count on first
init — the XLA_FLAGS line above precedes every other import).

  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k --mesh single
  DRYRUN_DEVICES=32 ... --devices-override 32   # small-mesh smoke mode

Outputs one JSON per cell under --out (default experiments/dryrun/).
"""

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, cell_applicable,  # noqa: E402
                           get_config)
from repro.launch import cells as cells_mod                    # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models import transformer as tf                     # noqa: E402
from repro.roofline import analysis as ra                      # noqa: E402


def make_mesh_for(args, multi_pod: bool):
    if args.devices_override:
        n = args.devices_override
        if multi_pod:
            return jax.make_mesh((2, n // 8, 4), ("pod", "data", "model"))
        return jax.make_mesh((n // 4, 4), ("data", "model"))
    return make_production_mesh(multi_pod=multi_pod)


def _compile_variant(cfg, shape, mesh, state_bits, variant="baseline"):
    cell = cells_mod.make_cell(cfg, shape, mesh, state_bits=state_bits,
                               variant=variant)
    lowered = cells_mod.lower_cell(cell, mesh)
    return lowered.compile()


def _per_device_costs(compiled):
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    raw, per_kind = ra.collective_bytes(text)
    weighted = sum(ra._ALGO_FACTOR[k] * v for k, v in per_kind.items())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(weighted), per_kind, text)


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             out_dir: str, state_bits: int = 32,
             cfg_override=None, variant: str = "baseline") -> dict:
    """Compile the production (scan-over-layers) program for the memory
    proof, plus two small *unrolled* calibration programs (1 and 2 pattern
    repeats) whose per-layer costs extrapolate linearly to full depth —
    XLA's cost analysis counts loop bodies once, so the scan program's
    FLOPs/bytes/collectives must be reconstructed this way (verified in
    EXPERIMENTS.md §Methodology)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mesh_shape": dict(mesh.shape), "status": "skip", "why": why}
    if not ok:
        return rec
    chips = int(len(mesh.devices.flat))
    t0 = time.time()

    # 1) production program: scan over layers — the compile-success proof
    #    and the per-device memory analysis
    full_cfg = cfg.replace(scan_layers=True, attn_impl="xla")
    compiled = _compile_variant(full_cfg, shape, mesh, state_bits, variant)
    t_full = time.time() - t0
    mem = compiled.memory_analysis()
    mem_rec = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
        if mem is not None and hasattr(mem, field):
            mem_rec[field] = int(getattr(mem, field))
    coll_counts = ra.collective_counts(compiled.as_text())

    # 2) calibration: unrolled at 1 and 2 pattern repeats
    p = len(cfg.pattern)

    def cal_cfg(reps):
        kw = dict(n_layers=p * reps, scan_layers=False, attn_impl="xla")
        if cfg.enc_dec:
            kw["n_enc_layers"] = max(1, cfg.n_enc_layers
                                     // cfg.n_repeat * reps)
        return cfg.replace(**kw)

    f1, b1, c1, _, _ = _per_device_costs(
        _compile_variant(cal_cfg(1), shape, mesh, state_bits, variant))
    f2, b2, c2, kinds2, _ = _per_device_costs(
        _compile_variant(cal_cfg(2), shape, mesh, state_bits, variant))
    r = cfg.n_repeat
    flops_dev = f1 + (f2 - f1) * (r - 1)
    bytes_dev = b1 + (b2 - b1) * (r - 1)
    coll_dev = c1 + (c2 - c1) * (r - 1)
    t_cal = time.time() - t0 - t_full

    n_total, n_active = ra.count_active_params(cfg, tf.param_shapes(cfg))
    mf = ra.model_flops(cfg, shape, n_total, n_active)
    roof = ra.Roofline(
        name=f"{arch}/{shape_name}/{mesh_name}", chips=chips,
        hlo_flops=flops_dev * chips, hlo_bytes=bytes_dev * chips,
        coll_bytes=coll_dev * chips, coll_per_kind=kinds2,
        model_flops=mf)
    rec.update(
        status="ok",
        seconds_compile=round(t_full, 2),
        seconds_calibration=round(t_cal, 2),
        memory=mem_rec,
        per_device_bytes=(mem_rec.get("argument_size_in_bytes", 0)
                          + mem_rec.get("temp_size_in_bytes", 0)),
        per_device_flops=flops_dev,
        per_device_coll_bytes=coll_dev,
        n_params_total=n_total, n_params_active=n_active,
        collective_counts=coll_counts,
        roofline=roof.row(),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--state-bits", type=int, default=32)
    ap.add_argument("--devices-override", type=int, default=0,
                    help="small-mesh smoke mode (set DRYRUN_DEVICES too)")
    ap.add_argument("--variant", default="baseline",
                    help="baseline | tiara_decode | remat_layer | "
                         "moe_hints | remat_layer+moe_hints")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for multi in meshes:
        mesh = make_mesh_for(args, multi)
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        if args.devices_override:
            mesh_name += f"_ovr{args.devices_override}"
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}__{shape_name}__{mesh_name}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   args.out, state_bits=args.state_bits,
                                   variant=args.variant)
                except Exception as e:      # noqa: BLE001 — record & go on
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                rec["wall_s"] = round(time.time() - t0, 2)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skip"
                n_fail += st == "fail"
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']} "
                             f"comp={r['compute_s']*1e3:.2f}ms "
                             f"mem={r['memory_s']*1e3:.2f}ms "
                             f"coll={r['collective_s']*1e3:.2f}ms "
                             f"dev={rec['per_device_bytes']/2**30:.2f}GiB")
                elif st == "fail":
                    extra = rec["error"][:120]
                print(f"[{st:4s}] {tag} ({rec['wall_s']}s) {extra}",
                      flush=True)
    print(f"dry-run complete: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
