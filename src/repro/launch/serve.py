"""Serving launcher: --arch <id> through the paged-KV engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm --reduced
"""

import argparse

import numpy as np
import jax

from repro.configs import get_config, reduce_config
from repro.models import transformer as tf
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_slots=args.slots, max_seq=128,
                        temperature=args.temperature, eos_id=-1)
    rng = np.random.default_rng(0)
    handles = [eng.submit(list(rng.integers(1, cfg.vocab, 5)),
                          max_new=args.max_new)
               for _ in range(args.requests)]
    out = eng.run_to_completion()
    for h in handles:
        print(f"seq {h.sid}: {out[h.sid]}")


if __name__ == "__main__":
    main()
