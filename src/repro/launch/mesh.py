"""Production meshes.

Single pod: (data=16, model=16) over 256 chips; multi-pod adds a leading
pure-DP ``pod`` axis (2 x 256 = 512 chips).  Functions, not module-level
constants, so importing this module never touches jax device state (the
dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axis bundle for batch sharding on this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
