"""Token sampling: greedy / temperature / top-k."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperature: float = 0.0,
                  top_k: Optional[int] = None) -> np.ndarray:
    """logits (B, V) -> (B,) int32."""
    logits = jnp.asarray(logits, jnp.float32)
    if temperature <= 0.0:
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    logits = logits / temperature
    if top_k is not None:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return np.asarray(jax.random.categorical(key, logits), np.int32)
