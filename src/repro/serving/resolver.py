"""Tiara-backed KV / expert resolution for the serving engine.

This is the end-to-end disaggregated decode path of paper §4.5–4.6: the
engine's block tables and KV page pool live as *endpoint regions* on a
memory node, and every decode step resolves its paged-KV block table by
posting the stock :class:`~repro.core.operators.PagedKVFetch` operator
from a per-sequence session (queue pair) through the
:class:`~repro.core.serving_loop.ServingLoop` — admission control,
deadlines, QoS, fault semantics, and the registration-time no-conflict
proofs all apply unchanged.  MoE models additionally resolve each step's
expert routes through :class:`~repro.core.operators.MoEExpertGather`.

What travels over the simulated fabric is the *indirection layer*: the
region geometry comes from :meth:`BlockAllocator.region_layout`, the
memory node holds the block table (logical block -> physical page) plus
one descriptor word per KV page / expert slab, and the operator's
remote-reply MEMCPY streams the resolved descriptors straight to the
requesting client's device row — one round trip per step, resolution
chained on the memory side (the paper's disaggregated PagedAttention
configuration, at descriptor granularity so every fetched word is
checkable against the host-resolved truth).

Adaptive re-homing (INDIGO-style): every resolution audits which device
accessed which region (:meth:`TiaraEndpoint.note_access`); every
``rehome_every`` steps the resolver migrates a sequence's regions to its
hottest accessor via the endpoint's control-path
:meth:`~repro.core.endpoint.TiaraEndpoint.rehome`, turning cross-device
reply traffic into home-local traffic while the engine keeps serving.
The same audit feeds the cost model's home-skew EWMA, which
``choose_placement`` prices sharded waves with.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import costmodel as cm
from repro.core import isa
from repro.core import serving_loop as sl
from repro.core.endpoint import (Completion, EndpointError, Session,
                                 TiaraEndpoint)
from repro.core.memory import RegionTable
from repro.core.operators import MoEExpertGather
from repro.serving.allocator import BlockAllocator

#: A resolved slot: the block-table row (ndarray) on success, or the
#: failed Completion (timed out / flushed / faulted / rejected).
ResolvedKV = Union[np.ndarray, Completion]

_KV_REGIONS = ("req", "blocktable", "kvpool", "reply")
_EXP_REGIONS = ("expert_ids", "expert_table", "weights", "reply")


def expert_layout(n_experts: int, *, max_k: int,
                  slab_bytes: int = isa.WORD_BYTES,
                  reply_slots: int = 1) -> MoEExpertGather:
    """The endpoint-registrable layout for an expert routing table: a
    :class:`MoEExpertGather` workload sized for ``n_experts`` experts
    with top-``max_k`` routing.  The serving resolver uses descriptor
    slabs (``slab_bytes=8``, one word per expert) so the route — not
    the weights — crosses the fabric; benches size ``slab_bytes`` up to
    the paper's 8 KB slabs."""
    return MoEExpertGather(
        n_experts=int(n_experts), max_k=int(max_k),
        slab_words=max(1, int(slab_bytes) // isa.WORD_BYTES),
        reply_slots=int(reply_slots))


class TiaraResolver:
    """Per-sequence-session KV/expert resolution over one endpoint.

    One slot = one decode lane of the engine = one tenant (queue pair)
    ``seq<i>`` holding its own req/blocktable/kvpool-descriptor/reply
    regions (plus ``exp<i>`` regions when MoE routing is on).  ``bind``
    writes a sequence's block table to the slot's home device;
    ``resolve_step`` posts one ``paged_kv_fetch`` per active slot (and
    one ``moe_expert_gather`` per expert request) through the serving
    loop, drains, and returns each slot's resolved block-table row read
    from the *client* device the operator's remote reply streamed to.
    """

    def __init__(self, allocator: BlockAllocator, *, max_slots: int,
                 pages_per_seq: int, n_homes: int = 1,
                 moe: Optional[MoEExpertGather] = None,
                 deadline_s: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None,
                 sleep: Optional[Callable[[float], None]] = None,
                 loop_config: Optional[sl.ServingConfig] = None,
                 qos: Optional[Dict[str, sl.TenantQoS]] = None,
                 placement: str = "single",
                 rehome: bool = True, rehome_every: int = 8,
                 min_rehome_share: float = 0.5) -> None:
        self.allocator = allocator
        self.max_slots = int(max_slots)
        self.pages_per_seq = int(pages_per_seq)
        self.n_homes = int(n_homes)
        self.deadline_s = deadline_s
        self.rehome_enabled = bool(rehome)
        self.rehome_every = int(rehome_every)
        self.min_rehome_share = float(min_rehome_share)
        # descriptor-granularity KV geometry: 8-byte blocks, so one pool
        # word per page and the reply row IS the block-table row
        self.kv = allocator.region_layout(
            block_bytes=isa.WORD_BYTES, max_req_blocks=self.pages_per_seq)
        self.moe = moe
        named: List[Tuple[str, RegionTable]] = [
            (self._kv_tenant(s), self.kv.regions())
            for s in range(self.max_slots)]
        if moe is not None:
            named += [(self._exp_tenant(s), moe.regions())
                      for s in range(self.max_slots)]
        kwargs: Dict[str, object] = {}
        if clock is not None:
            kwargs["clock"] = clock
        if sleep is not None:
            kwargs["sleep"] = sleep
        self.ep, sessions = TiaraEndpoint.for_tenants(
            named, n_devices=self.n_homes, **kwargs)
        self.kv_sessions: List[Session] = [
            sessions[self._kv_tenant(s)] for s in range(self.max_slots)]
        self.exp_sessions: List[Session] = [] if moe is None else [
            sessions[self._exp_tenant(s)] for s in range(self.max_slots)]
        for slot, sess in enumerate(self.kv_sessions):
            sess.register(self.kv.build(sess.view, remote_reply=True))
            self._seed_kv(slot, device=0)
        for slot, sess in enumerate(self.exp_sessions):
            assert moe is not None
            sess.register(moe.build(sess.view, remote_reply=True))
            self._seed_exp(slot, device=0)
        posts_per_step = self.max_slots * (2 if moe is not None else 1)
        cfg = loop_config if loop_config is not None else sl.ServingConfig(
            ring_size=max(1, posts_per_step),
            max_inflight_waves=1,
            max_pending=max(64, 2 * posts_per_step),
            placement=placement,
            opportunistic_poll=False)
        self.loop = sl.ServingLoop(self.ep, cfg, qos=qos)
        self.steps = 0
        self.waves = 0
        # modeled fabric time: cost-model prediction per launched wave
        # plus one client->node submit RTT (benches charge this to a
        # virtual clock via ``on_wave``)
        self.fabric_us = 0.0
        self.on_wave: Optional[Callable[[sl.PumpReport], None]] = None

    # -- naming -----------------------------------------------------------

    def _kv_tenant(self, slot: int) -> str:
        return f"seq{slot}"

    def _exp_tenant(self, slot: int) -> str:
        return f"exp{slot}"

    def _kv_region(self, slot: int, name: str) -> str:
        return f"{self._kv_tenant(slot)}{self.ep.sep}{name}"

    def _exp_region(self, slot: int, name: str) -> str:
        return f"{self._exp_tenant(slot)}{self.ep.sep}{name}"

    def client_of(self, slot: int) -> int:
        """The device row slot ``slot``'s decode lane reads replies
        from (the "client GPU" of the disaggregated setup)."""
        return slot % self.n_homes

    def home_of(self, slot: int) -> int:
        """The device row currently homing slot ``slot``'s regions."""
        return self.ep.home_of(self._kv_region(slot, "kvpool"))

    # -- region content (descriptor tables) --------------------------------

    def _seed_kv(self, slot: int, *, device: int) -> None:
        sess = self.kv_sessions[slot]
        bw = self.kv.block_words
        # req: the decode lane always asks for its full logical table
        sess.write_region("req", list(range(self.pages_per_seq)),
                          device=device)
        # kvpool descriptors: word p of the pool names page p, so the
        # operator's gather returns exactly the physical page ids the
        # host-resolved path computes — bit-checkable indirection
        sess.write_region(
            "kvpool",
            [p // bw if p % bw == 0 else 0
             for p in range(self.allocator.n_pages * bw)],
            device=device)

    def _seed_exp(self, slot: int, *, device: int) -> None:
        assert self.moe is not None
        sess = self.exp_sessions[slot]
        sw = self.moe.slab_words
        # identity translation table + slab descriptors (slab e names e)
        sess.write_region(
            "expert_table",
            [e * sw for e in range(self.moe.n_experts)], device=device)
        sess.write_region(
            "weights",
            [w // sw if w % sw == 0 else 0
             for w in range(self.moe.n_experts * sw)], device=device)

    # -- binding -----------------------------------------------------------

    def bind(self, slot: int, pages: Sequence[int]) -> None:
        """Install a sequence's block table on slot ``slot``'s home:
        logical block j -> word offset of physical page ``pages[j]`` in
        the KV pool.  Resets the slot's sessions if a prior sequence
        errored them, and migrates the slot off a failed home device
        first (control-path recovery — the blade's DRAM row is still
        host-readable)."""
        if len(pages) != self.pages_per_seq:
            raise EndpointError(
                f"bind: slot {slot} expects {self.pages_per_seq} pages, "
                f"got {len(pages)}")
        sess = self.kv_sessions[slot]
        if sess.in_error:
            sess.reset()
        if self.exp_sessions and self.exp_sessions[slot].in_error:
            self.exp_sessions[slot].reset()
        home = self.home_of(slot)
        if home in self.ep.failed_devices:
            healthy = sorted(set(range(self.n_homes))
                             - self.ep.failed_devices)
            if not healthy:
                raise EndpointError(
                    f"bind: no healthy device to home slot {slot}")
            home = healthy[slot % len(healthy)]
            self._migrate(slot, home)
        bw = self.kv.block_words
        sess.write_region("blocktable",
                          [int(p) * bw for p in pages], device=home)

    def unbind(self, slot: int) -> None:
        """Release slot ``slot`` (the block-table row is overwritten by
        the next bind; nothing to tear down)."""

    # -- resolution (the per-decode-step data path) ------------------------

    def resolve_step(self, kv_slots: Sequence[int],
                     expert_reqs: Optional[
                         Dict[int, Sequence[int]]] = None
                     ) -> Tuple[Dict[int, ResolvedKV],
                                Dict[int, Optional[Completion]]]:
        """Resolve one decode step: post a ``paged_kv_fetch`` for every
        slot in ``kv_slots`` (and a ``moe_expert_gather`` for every
        ``slot -> expert ids`` entry in ``expert_reqs``) through the
        serving loop, drain, and collect.

        Returns ``(kv, experts)``: ``kv[slot]`` is the resolved
        block-table row (int32 ndarray) or the failed
        :class:`Completion`; ``experts[slot]`` is None on success (the
        gathered slab descriptors matched the requested expert ids) or
        the failed Completion."""
        expert_reqs = dict(expert_reqs or {})
        bw = self.kv.block_words
        # control-path writes strictly precede the wave launch
        for slot, eids in expert_reqs.items():
            self.exp_sessions[slot].write_region(
                "expert_ids", [int(e) for e in eids],
                device=self.home_of(slot))
        kv_posts: Dict[int, Completion] = {}
        exp_posts: Dict[int, Completion] = {}
        for slot in kv_slots:
            home, client = self.home_of(slot), self.client_of(slot)
            kv_posts[slot] = self.loop.submit(
                self._kv_tenant(slot), "paged_kv_fetch",
                [self.pages_per_seq, client], home=home,
                deadline_s=self.deadline_s)
            self.ep.note_access(self._kv_region(slot, "kvpool"), client,
                                self.pages_per_seq * bw)
        for slot, eids in expert_reqs.items():
            home, client = self.home_of(slot), self.client_of(slot)
            assert self.moe is not None
            exp_posts[slot] = self.loop.submit(
                self._exp_tenant(slot), "moe_expert_gather",
                [len(eids), client], home=home,
                deadline_s=self.deadline_s)
            self.ep.note_access(self._exp_region(slot, "weights"), client,
                                len(eids) * self.moe.slab_words)
        self._drain()
        kv_out: Dict[int, ResolvedKV] = {}
        for slot, c in kv_posts.items():
            if not c.ok:
                kv_out[slot] = c
                continue
            reply = self.kv_sessions[slot].read_region(
                "reply", device=self.client_of(slot),
                count=self.pages_per_seq * bw)
            kv_out[slot] = np.asarray(reply[0::bw], dtype=np.int32)
        exp_out: Dict[int, Optional[Completion]] = {}
        for slot, c in exp_posts.items():
            if not c.ok:
                exp_out[slot] = c
                continue
            assert self.moe is not None
            sw = self.moe.slab_words
            eids = [int(e) for e in expert_reqs[slot]]
            reply = self.exp_sessions[slot].read_region(
                "reply", device=self.client_of(slot),
                count=len(eids) * sw)
            got = [int(x) for x in reply[0::sw]]
            if got != eids:
                raise EndpointError(
                    f"expert gather integrity: slot {slot} asked "
                    f"{eids}, fabric returned {got}")
            exp_out[slot] = None
        for sess in self.kv_sessions:
            sess.poll_cq()
        for sess in self.exp_sessions:
            sess.poll_cq()
        self.steps += 1
        if self.rehome_enabled and self.rehome_every > 0 \
                and self.steps % self.rehome_every == 0:
            self.maybe_rehome()
        return kv_out, exp_out

    def _drain(self) -> None:
        """Launch and retire everything submitted this step (stalled
        tenants wait through the endpoint's sleep hook; bounded, never
        hangs)."""
        loop = self.loop
        pumps = 0
        while loop.backlog > 0:
            report = loop.pump(force=True)
            if report.launched:
                self.waves += 1
                self.fabric_us += report.predicted_us \
                    + cm.DEFAULT_HW.rtt_us
                if self.on_wave is not None:
                    self.on_wave(report)
            elif loop.backlog > 0:
                now = self.ep._clock()
                stalls = [u for u in self.ep._stalls.values() if u > now]
                self.ep._sleep((min(stalls) - now) if stalls
                               else loop.config.block_poll_s)
            pumps += 1
            if pumps > 10_000:
                raise RuntimeError(
                    f"resolver drain did not converge "
                    f"(backlog {loop.backlog})")
        self.ep.wait_all()
        self.loop.harvest()

    # -- adaptive re-homing ------------------------------------------------

    def _migrate(self, slot: int, device: int) -> int:
        moved = 0
        for name in _KV_REGIONS:
            moved += self.ep.rehome(self._kv_region(slot, name), device)
        if self.moe is not None:
            for name in _EXP_REGIONS:
                moved += self.ep.rehome(self._exp_region(slot, name),
                                        device)
        return moved

    def maybe_rehome(self) -> int:
        """One migration sweep: move every slot whose access audit shows
        a dominant (``min_rehome_share``) remote accessor to that
        device.  Returns the words migrated."""
        moved = 0
        for slot in range(self.max_slots):
            counts = self.ep.access_counts(self._kv_region(slot, "kvpool"))
            total = int(counts.sum())
            if total <= 0:
                continue
            hot = int(counts.argmax())
            if hot == self.home_of(slot) or hot in self.ep.failed_devices:
                continue
            if int(counts[hot]) < total * self.min_rehome_share:
                continue
            moved += self._migrate(slot, hot)
        return moved

    def audit(self) -> Dict[str, float]:
        """The rehome/traffic audit: migrations performed, words moved,
        cross-device reply words served, the learned home skew, and the
        modeled fabric time."""
        skew = self.ep.cost_model.home_skew()
        return {
            "rehomes": float(self.ep.rehome_count),
            "rehomed_words": float(self.ep.rehomed_words),
            "cross_device_words": float(self.ep.cross_device_words),
            "home_skew": float(skew) if skew is not None else 0.0,
            "fabric_us": float(self.fabric_us),
            "waves": float(self.waves),
        }
