"""Model serving over the paged KV cache.

Public surface (audited ``__all__``): the engine + its completion
handle, the block allocator (with its endpoint region layout export),
the Tiara-backed resolver, and the sampler.
"""

from repro.serving.allocator import BlockAllocator, OutOfPages
from repro.serving.engine import Sequence, SequenceHandle, ServingEngine
from repro.serving.resolver import TiaraResolver, expert_layout
from repro.serving.sampler import sample_tokens

__all__ = [
    "BlockAllocator",
    "OutOfPages",
    "Sequence",
    "SequenceHandle",
    "ServingEngine",
    "TiaraResolver",
    "expert_layout",
    "sample_tokens",
]
