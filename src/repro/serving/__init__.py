from repro.serving.allocator import BlockAllocator, OutOfPages
from repro.serving.engine import ServingEngine
from repro.serving.sampler import sample_tokens

__all__ = ["BlockAllocator", "OutOfPages", "ServingEngine", "sample_tokens"]
