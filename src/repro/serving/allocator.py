"""Paged KV block allocator — vLLM-style free list over the page pool.

This block table is exactly the paper's "block-indirection table": the
engine registers it (and the KV pool) as Tiara memory regions so a remote
node can resolve logical block -> physical page on the *memory side* in
one round trip (see serving/tiara_offload.py and the disaggregated_kv
example)."""

from __future__ import annotations

from typing import Dict, List



class OutOfPages(RuntimeError):
    pass


class BlockAllocator:
    def __init__(self, n_pages: int):
        self.n_pages = int(n_pages)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._owner: Dict[int, int] = {}     # page -> seq id

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int, owner: int) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p in self._owner:
                del self._owner[p]
                self._free.append(p)

    def owned_by(self, owner: int) -> List[int]:
        return [p for p, o in self._owner.items() if o == owner]

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.n_pages
