"""Paged KV block allocator — vLLM-style free list over the page pool.

This block table is exactly the paper's "block-indirection table": the
engine registers it (and the KV pool) as Tiara memory regions so a remote
node can resolve logical block -> physical page on the *memory side* in
one round trip.  :meth:`BlockAllocator.region_layout` is the one place
that maps an allocator's pool geometry to the endpoint region layout the
stock :class:`~repro.core.operators.PagedKVFetch` operator runs against —
the serving resolver (serving/resolver.py), the disaggregated_kv example,
and the paged benchmarks all construct their tables through it, so the
bench path and the serving path cannot drift.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import isa
from repro.core.operators import PagedKVFetch


class OutOfPages(RuntimeError):
    """Pool exhausted.  Carries the structured demand so callers can
    size backpressure decisions (``needed`` pages requested vs ``free``
    pages available) instead of parsing the message."""

    def __init__(self, needed: int, free: int) -> None:
        super().__init__(f"need {needed} pages, {free} free")
        self.needed = int(needed)
        self.free = int(free)


class BlockAllocator:
    def __init__(self, n_pages: int) -> None:
        self.n_pages = int(n_pages)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._owner: Dict[int, int] = {}     # page -> seq id

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int, owner: int) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(n, len(self._free))
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def alloc_many(self, owners: Sequence[Tuple[int, int]]
                   ) -> Dict[int, List[int]]:
        """Batch allocation: ``owners`` is ``[(owner, n_pages), ...]``.
        All-or-nothing — either every owner gets its pages or the pool
        is left untouched and :class:`OutOfPages` carries the *total*
        demand, so a scheduler admitting a batch of sequences never
        half-admits."""
        need = sum(int(n) for _, n in owners)
        if need > len(self._free):
            raise OutOfPages(need, len(self._free))
        return {int(owner): self.alloc(int(n), int(owner))
                for owner, n in owners}

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p in self._owner:
                del self._owner[p]
                self._free.append(p)

    def owned_by(self, owner: int) -> List[int]:
        return [p for p, o in self._owner.items() if o == owner]

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.n_pages

    def region_layout(self, *, block_bytes: int = isa.WORD_BYTES,
                      max_req_blocks: Optional[int] = None,
                      reply_slots: int = 1) -> PagedKVFetch:
        """The endpoint-registrable layout for THIS pool: a
        :class:`~repro.core.operators.PagedKVFetch` workload whose
        block-table and KV-pool regions are sized by the allocator's
        page count.  Callers get ``.regions()`` for registration and
        ``.build()`` for the resolver operator from one object, so the
        region geometry the engine serves against is definitionally the
        geometry the operator was verified against."""
        return PagedKVFetch(
            n_blocks_pool=self.n_pages,
            block_bytes=int(block_bytes),
            max_req_blocks=int(max_req_blocks if max_req_blocks is not None
                               else self.n_pages),
            reply_slots=int(reply_slots))
