"""Continuous-batching serving engine over the paged KV cache.

Decoder-only attention architectures (the vLLM/PagedAttention scenario the
paper targets).  Fixed B decode slots; prompts prefill into a free slot's
pages (bucketed-by-length compilations), then every engine step decodes
all active slots in one batched call through the paged-attention path.

Block-table resolution is pluggable (``resolver=``):

* ``"host"`` (default) — today's local path, bit-for-bit: the engine
  indexes its own ``block_tables`` array.
* ``"tiara"`` — the disaggregated path: block tables, the KV page pool
  and (for MoE archs) the expert routing tables live as regions on a
  :class:`~repro.core.endpoint.TiaraEndpoint`, and every decode step
  resolves them by posting ``PagedKVFetch`` / ``MoEExpertGather``
  operators from per-sequence sessions through the
  :class:`~repro.core.serving_loop.ServingLoop` (see
  ``serving/resolver.py``) — admission, deadlines, fault semantics and
  adaptive region re-homing included.  Decode output is bit-identical
  to ``"host"`` on healthy fabric.

Recurrent/enc-dec archs are served via the transformer API directly (their
state is batch-indexed, not paged); DESIGN.md §5 notes the Tiara technique
has no indirection to collapse there.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core import isa
from repro.core.endpoint import Completion, EndpointError
from repro.models import transformer as tf
from repro.serving.allocator import BlockAllocator
from repro.serving.resolver import TiaraResolver, expert_layout
from repro.serving.sampler import sample_tokens


@dataclasses.dataclass
class Sequence:
    sid: int
    prompt: List[int]
    max_new: int
    slot: Optional[int] = None
    pages: Optional[List[int]] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


_STATUS_NAMES = {
    isa.STATUS_OK: "OK", isa.STATUS_FAIL: "FAIL",
    isa.STATUS_EAGAIN: "EAGAIN", isa.STATUS_TIMEOUT: "TIMEOUT",
    isa.STATUS_FLUSHED: "FLUSHED", isa.STATUS_PROT_FAULT: "PROT_FAULT",
}


@dataclasses.dataclass
class SequenceHandle:
    """One submitted sequence's completion handle — the engine-level
    mirror of :class:`~repro.core.endpoint.Completion`: ``status``
    reuses the ISA's CQE statuses (``STATUS_OK`` / ``STATUS_EAGAIN`` on
    admission reject / ``STATUS_TIMEOUT`` on deadline expiry /
    ``STATUS_PROT_FAULT`` / ``STATUS_FLUSHED`` surfaced from the tiara
    resolver's fabric), ``poll()`` is the non-blocking check, and
    ``result()`` runs the engine until this sequence finishes."""

    sid: int
    tenant: str
    engine: "ServingEngine" = dataclasses.field(repr=False)
    deadline: Optional[float] = None      # absolute engine-clock deadline
    done: bool = False
    status: int = isa.STATUS_OK
    fault: Optional[isa.FaultInfo] = None

    @property
    def ok(self) -> bool:
        return self.done and self.status == isa.STATUS_OK

    @property
    def rejected(self) -> bool:
        return self.done and self.status == isa.STATUS_EAGAIN

    @property
    def timed_out(self) -> bool:
        return self.done and self.status == isa.STATUS_TIMEOUT

    @property
    def faulted(self) -> bool:
        return self.done and self.status == isa.STATUS_PROT_FAULT

    @property
    def flushed(self) -> bool:
        return self.done and self.status == isa.STATUS_FLUSHED

    def poll(self) -> bool:
        """Non-blocking: has this sequence reached a terminal state?"""
        return self.done

    @property
    def tokens(self) -> List[int]:
        """Tokens generated so far (the final output once done)."""
        return self.engine._tokens_of(self.sid)

    def result(self, *, max_steps: int = 10_000,
               check: bool = True) -> List[int]:
        """Run the engine until this sequence finishes and return its
        tokens.  With ``check`` (default), a non-OK terminal status
        raises :class:`~repro.core.endpoint.EndpointError` — mirroring
        ``Completion.result()``."""
        self.engine.run_until(self.sid, max_steps=max_steps)
        if check and not self.ok:
            name = _STATUS_NAMES.get(self.status, str(self.status))
            raise EndpointError(
                f"sequence {self.sid} ({self.tenant}) ended "
                f"{name}" + (f": {self.fault}" if self.fault else ""))
        return self.tokens


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: Any, *,
                 max_slots: int = 4,
                 max_seq: int = 512, n_pages: Optional[int] = None,
                 eos_id: int = 0, temperature: float = 0.0, seed: int = 0,
                 resolver: str = "host", n_homes: int = 1,
                 placement: str = "single",
                 max_pending: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 sleep: Optional[Callable[[float], None]] = None,
                 resolver_deadline_s: Optional[float] = None,
                 rehome: bool = True, rehome_every: int = 8) -> None:
        assert not cfg.enc_dec and all(s.kind == "attn"
                                       for s in cfg.pattern), \
            "engine serves decoder-only attention archs"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.temperature = temperature
        self.pages_per_seq = (max_seq + cfg.page_size - 1) // cfg.page_size
        pool = n_pages or max_slots * self.pages_per_seq
        self.allocator = BlockAllocator(pool)

        # device state; one extra *scratch* page absorbs the KV writes of
        # inactive decode slots (they decode garbage in the batched step —
        # harmless, but they must never touch a live sequence's pages)
        self.scratch_page = pool
        self.caches = tf.init_caches(cfg, max_slots, self.pages_per_seq)
        self.caches = tuple(
            jax.tree_util.tree_map(
                lambda a: (jnp.pad(a, ((0, 0), (0, 1)) + ((0, 0),)
                                   * (a.ndim - 2))
                           if a.ndim >= 2 and a.shape[1] == pool else a),
                c) for c in self.caches)
        self.block_tables = np.full((max_slots, self.pages_per_seq),
                                    self.scratch_page, np.int32)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.active: List[Optional[Sequence]] = [None] * max_slots
        self.waiting: List[Sequence] = []
        self.completed: Dict[int, List[int]] = {}
        self._next_sid = 0
        self._rng = jax.random.PRNGKey(seed)
        self._handles: Dict[int, SequenceHandle] = {}
        self.max_pending = max_pending
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic

        self.resolver_name = str(resolver)
        self._resolver: Optional[TiaraResolver] = None
        self._moe = None
        if resolver == "tiara":
            moe_specs = [s.moe for s in cfg.pattern
                         if s.mlp == "moe" and s.moe is not None]
            if moe_specs:
                spec = moe_specs[0]
                self._moe = expert_layout(
                    spec.n_experts,
                    max_k=min(spec.top_k, spec.n_experts))
            self._resolver = TiaraResolver(
                self.allocator, max_slots=max_slots,
                pages_per_seq=self.pages_per_seq, n_homes=n_homes,
                moe=self._moe, deadline_s=resolver_deadline_s,
                clock=clock, sleep=sleep, placement=placement,
                rehome=rehome, rehome_every=rehome_every)
        elif resolver != "host":
            raise ValueError(
                f"unknown resolver {resolver!r} (use 'host' or 'tiara')")

        self._prefill_jit = jax.jit(
            lambda p, b: tf.apply_model(p, cfg, b, mode="prefill"))
        self._decode_jit = jax.jit(
            lambda p, b: tf.apply_model(p, cfg, b, mode="decode"))

    # -- client API -------------------------------------------------------

    def submit(self, prompt: List[int], *, max_new: int = 32,
               deadline_s: Optional[float] = None,
               tenant: str = "default") -> SequenceHandle:
        """Admit one sequence; returns its :class:`SequenceHandle`
        (``ServingLoop.submit`` semantics: exactly one terminal status
        per submission).  An already-full waiting queue
        (``max_pending``) rejects with ``STATUS_EAGAIN``; a
        ``deadline_s`` that expires before the sequence is admitted to
        a slot times out with ``STATUS_TIMEOUT`` and never prefills.

        The PR-9 deprecated positional form ``submit(prompt, max_new)``
        (which returned the bare ``sid``) is gone; ``max_new`` is
        keyword-only and the old int sid is ``handle.sid``.
        """
        return self._submit(prompt, max_new, deadline_s, tenant)

    def _submit(self, prompt: List[int], max_new: int,
                deadline_s: Optional[float],
                tenant: str) -> SequenceHandle:
        sid = self._next_sid
        self._next_sid += 1
        deadline = None if deadline_s is None \
            else self._clock() + float(deadline_s)
        handle = SequenceHandle(sid=sid, tenant=tenant, engine=self,
                                deadline=deadline)
        self._handles[sid] = handle
        seq = Sequence(sid=sid, prompt=list(prompt), max_new=max_new)
        if self.max_pending is not None \
                and len(self.waiting) >= self.max_pending:
            self.completed[sid] = []
            handle.done, handle.status = True, isa.STATUS_EAGAIN
            return handle
        if deadline is not None and deadline <= self._clock():
            self.completed[sid] = []
            handle.done, handle.status = True, isa.STATUS_TIMEOUT
            return handle
        self.waiting.append(seq)
        return handle

    def handle(self, sid: int) -> SequenceHandle:
        return self._handles[sid]

    def _tokens_of(self, sid: int) -> List[int]:
        if sid in self.completed:
            return list(self.completed[sid])
        for seq in list(self.waiting) + [s for s in self.active if s]:
            if seq.sid == sid:
                return list(seq.output)
        raise KeyError(f"unknown sequence {sid}")

    def finished(self) -> bool:
        return not self.waiting and all(s is None for s in self.active)

    # -- scheduling ---------------------------------------------------------

    def _finish(self, seq: Sequence, *, status: int = isa.STATUS_OK,
                fault: Optional[isa.FaultInfo] = None) -> None:
        """Terminal transition for one sequence: record output, release
        its slot/pages, resolve its handle with ``status``."""
        seq.done = True
        self.completed[seq.sid] = list(seq.output)
        handle = self._handles.get(seq.sid)
        if handle is not None:
            handle.done = True
            handle.status = int(status)
            handle.fault = fault
        if seq.slot is not None:
            slot = seq.slot
            if seq.pages:
                self.allocator.free(seq.pages)
            self.active[slot] = None
            self.lengths[slot] = 0
            self.block_tables[slot] = self.scratch_page
            if self._resolver is not None:
                self._resolver.unbind(slot)

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.active[slot] is not None:
                continue
            while self.waiting:
                seq = self.waiting.pop(0)
                handle = self._handles.get(seq.sid)
                if handle is not None and handle.deadline is not None \
                        and handle.deadline <= self._clock():
                    # expired while queued: times out, never prefills
                    # (the ServingLoop's expired-before-launch rule)
                    self._finish(seq, status=isa.STATUS_TIMEOUT)
                    continue
                need = self.pages_per_seq
                try:
                    pages = self.allocator.alloc(need, seq.sid)
                except Exception:
                    self.waiting.insert(0, seq)
                    return
                seq.slot, seq.pages = slot, pages
                self.block_tables[slot] = np.asarray(pages, np.int32)
                if self._resolver is not None:
                    self._resolver.bind(slot, pages)
                self._prefill(seq)
                self.active[slot] = seq
                break

    def _prefill(self, seq: Sequence) -> None:
        assert seq.slot is not None
        slot = seq.slot
        plen = len(seq.prompt)
        # bucket prompt length to limit compilations
        bucket = max(self.cfg.page_size,
                     1 << int(np.ceil(np.log2(max(plen, 1)))))
        bucket = min(bucket, self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = seq.prompt
        batch = {
            "tokens": jnp.asarray(toks),
            "caches": self._slot_caches(slot),
            "block_tables": jnp.asarray(self.block_tables[slot:slot + 1]),
            "lengths": jnp.asarray([plen], np.int32),
        }
        out = self._prefill_jit(self.params, batch)
        self._merge_slot_caches(slot, out.caches)
        self.lengths[slot] = plen
        logits = np.asarray(out.logits[0, plen - 1])
        self._rng, sub = jax.random.split(self._rng)
        nxt = sample_tokens(logits[None], sub, self.temperature)[0]
        seq.output.append(int(nxt))

    # Per-slot cache views: pages are global (shared pool), so attention
    # caches pass through whole; only batch-indexed leaves (none for
    # attention-only archs) would need slicing.
    def _slot_caches(self, slot: Optional[int]) -> Any:
        return self.caches

    def _merge_slot_caches(self, slot: Optional[int], new_caches: Any
                           ) -> None:
        self.caches = new_caches

    # -- disaggregated resolution (resolver="tiara") -----------------------

    def _expert_request(self, seq: Sequence) -> List[int]:
        """The expert routes this step resolves through the fabric.
        The real router's top-k runs inside the jitted decode; the
        descriptor-level resolution here derives a deterministic route
        from the step's input token, so the *translation layer* (the
        expert-id -> slab indirection of paper §4.5) is exercised and
        audited end to end without forking the jitted compute graph."""
        assert self._moe is not None
        basis = seq.output[-1] if seq.output else \
            (seq.prompt[-1] if seq.prompt else 0)
        return [(int(basis) + j) % self._moe.n_experts
                for j in range(self._moe.max_k)]

    def _resolve_block_tables(self, slots: List[int]) -> np.ndarray:
        """One fabric round trip: resolve every active slot's block
        table (and expert routes) through the endpoint; sequences whose
        resolution fails (timeout / fault / flush / reject) terminate
        with that status through their handles.  Returns the decode
        step's block tables built from the fabric replies."""
        assert self._resolver is not None
        expert_reqs: Dict[int, List[int]] = {}
        if self._moe is not None:
            for slot in slots:
                seq = self.active[slot]
                assert seq is not None
                expert_reqs[slot] = self._expert_request(seq)
        kv, experts = self._resolver.resolve_step(slots, expert_reqs)
        bt = self.block_tables.copy()
        for slot in slots:
            seq = self.active[slot]
            assert seq is not None
            res = kv[slot]
            failed: Optional[Completion] = None
            if isinstance(res, Completion):
                failed = res
            elif experts.get(slot) is not None:
                failed = experts[slot]
            if failed is not None:
                self._finish(seq, status=int(failed.status),
                             fault=failed.fault)
                continue
            bt[slot] = np.asarray(res, np.int32)
        return bt

    # -- engine step -----------------------------------------------------------

    def step(self) -> Dict[int, List[int]]:
        """Admit + decode one token for every active sequence."""
        self._admit()
        slots = [i for i, s in enumerate(self.active) if s is not None]
        if not slots:
            return self.results()
        if self._resolver is not None:
            bt = self._resolve_block_tables(slots)
            slots = [i for i, s in enumerate(self.active)
                     if s is not None]
            if not slots:
                return self.results()
        else:
            bt = self.block_tables
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i, seq in enumerate(self.active):
            if seq is not None and seq.output:
                tokens[i, 0] = seq.output[-1]
        batch = {
            "tokens": jnp.asarray(tokens),
            "caches": self.caches,
            "block_tables": jnp.asarray(bt),
            "lengths": jnp.asarray(self.lengths),
        }
        out = self._decode_jit(self.params, batch)
        self.caches = out.caches
        self._rng, sub = jax.random.split(self._rng)
        nxt = sample_tokens(np.asarray(out.logits[:, 0]), sub,
                            self.temperature)
        for slot in slots:
            seq = self.active[slot]
            assert seq is not None
            self.lengths[slot] += 1
            tok = int(nxt[slot])
            seq.output.append(tok)
            if (tok == self.eos_id
                    or len(seq.output) >= seq.max_new
                    or self.lengths[slot] >= self.max_seq - 1):
                self._finish(seq)
        return self.results()

    def results(self) -> Dict[int, List[int]]:
        out = dict(self.completed)
        for seq in list(self.waiting) + [s for s in self.active if s]:
            out[seq.sid] = list(seq.output)
        return out

    def run_until(self, sid: int, max_steps: int = 10_000
                  ) -> SequenceHandle:
        """Step the engine until sequence ``sid`` reaches a terminal
        state (bounded; raises rather than hangs)."""
        handle = self._handles[sid]
        steps = 0
        while not handle.done and not self.finished():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"sequence {sid} did not finish in "
                    f"{max_steps} steps")
        if not handle.done:
            # engine drained without the sequence reaching a slot
            raise RuntimeError(f"sequence {sid} was never scheduled")
        return handle

    def run_to_completion(self, max_steps: int = 10_000
                          ) -> Dict[int, List[int]]:
        steps = 0
        while not self.finished() and steps < max_steps:
            self.step()
            steps += 1
        return self.results()

    # -- audits -----------------------------------------------------------

    @property
    def resolver(self) -> Optional[TiaraResolver]:
        """The tiara resolver backing this engine (None on the host
        path) — exposed for benches/tests that instrument the fabric
        (``resolver.on_wave``) or drive faults."""
        return self._resolver

    def resolver_audit(self) -> Dict[str, float]:
        """The tiara resolver's rehome/traffic audit (empty dict on the
        host path)."""
        return {} if self._resolver is None else self._resolver.audit()
