"""Continuous-batching serving engine over the paged KV cache.

Decoder-only attention architectures (the vLLM/PagedAttention scenario the
paper targets).  Fixed B decode slots; prompts prefill into a free slot's
pages (bucketed-by-length compilations), then every engine step decodes
all active slots in one batched call through the paged-attention path.

Recurrent/enc-dec archs are served via the transformer API directly (their
state is batch-indexed, not paged); DESIGN.md §5 notes the Tiara technique
has no indirection to collapse there.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import transformer as tf
from repro.serving.allocator import BlockAllocator
from repro.serving.sampler import sample_tokens


@dataclasses.dataclass
class Sequence:
    sid: int
    prompt: List[int]
    max_new: int
    slot: Optional[int] = None
    pages: Optional[List[int]] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_seq: int = 512, n_pages: Optional[int] = None,
                 eos_id: int = 0, temperature: float = 0.0, seed: int = 0):
        assert not cfg.enc_dec and all(s.kind == "attn"
                                       for s in cfg.pattern), \
            "engine serves decoder-only attention archs"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.temperature = temperature
        self.pages_per_seq = (max_seq + cfg.page_size - 1) // cfg.page_size
        pool = n_pages or max_slots * self.pages_per_seq
        self.allocator = BlockAllocator(pool)

        # device state; one extra *scratch* page absorbs the KV writes of
        # inactive decode slots (they decode garbage in the batched step —
        # harmless, but they must never touch a live sequence's pages)
        self.scratch_page = pool
        self.caches = tf.init_caches(cfg, max_slots, self.pages_per_seq)
        self.caches = tuple(
            jax.tree_util.tree_map(
                lambda a: (jnp.pad(a, ((0, 0), (0, 1)) + ((0, 0),)
                                   * (a.ndim - 2))
                           if a.ndim >= 2 and a.shape[1] == pool else a),
                c) for c in self.caches)
        self.block_tables = np.full((max_slots, self.pages_per_seq),
                                    self.scratch_page, np.int32)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.active: List[Optional[Sequence]] = [None] * max_slots
        self.waiting: List[Sequence] = []
        self.completed: Dict[int, List[int]] = {}
        self._next_sid = 0
        self._rng = jax.random.PRNGKey(seed)

        self._prefill_jit = jax.jit(
            lambda p, b: tf.apply_model(p, cfg, b, mode="prefill"))
        self._decode_jit = jax.jit(
            lambda p, b: tf.apply_model(p, cfg, b, mode="decode"))

    # -- client API -------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 32) -> int:
        seq = Sequence(sid=self._next_sid, prompt=list(prompt),
                       max_new=max_new)
        self._next_sid += 1
        self.waiting.append(seq)
        return seq.sid

    def finished(self) -> bool:
        return not self.waiting and all(s is None for s in self.active)

    # -- scheduling ---------------------------------------------------------

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.active[slot] is not None or not self.waiting:
                continue
            seq = self.waiting.pop(0)
            need = self.pages_per_seq
            try:
                pages = self.allocator.alloc(need, seq.sid)
            except Exception:
                self.waiting.insert(0, seq)
                return
            seq.slot, seq.pages = slot, pages
            self.block_tables[slot] = np.asarray(pages, np.int32)
            self._prefill(seq)
            self.active[slot] = seq

    def _prefill(self, seq: Sequence) -> None:
        slot = seq.slot
        plen = len(seq.prompt)
        # bucket prompt length to limit compilations
        bucket = max(self.cfg.page_size,
                     1 << int(np.ceil(np.log2(max(plen, 1)))))
        bucket = min(bucket, self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = seq.prompt
        batch = {
            "tokens": jnp.asarray(toks),
            "caches": self._slot_caches(slot),
            "block_tables": jnp.asarray(self.block_tables[slot:slot + 1]),
            "lengths": jnp.asarray([plen], np.int32),
        }
        out = self._prefill_jit(self.params, batch)
        self._merge_slot_caches(slot, out.caches)
        self.lengths[slot] = plen
        logits = np.asarray(out.logits[0, plen - 1])
        self._rng, sub = jax.random.split(self._rng)
        nxt = sample_tokens(logits[None], sub, self.temperature)[0]
        seq.output.append(int(nxt))

    # Per-slot cache views: pages are global (shared pool), so attention
    # caches pass through whole; only batch-indexed leaves (none for
    # attention-only archs) would need slicing.
    def _slot_caches(self, slot: int):
        return self.caches

    def _merge_slot_caches(self, slot: int, new_caches) -> None:
        self.caches = new_caches

    # -- engine step -----------------------------------------------------------

    def step(self) -> Dict[int, List[int]]:
        """Admit + decode one token for every active sequence."""
        self._admit()
        slots = [i for i, s in enumerate(self.active) if s is not None]
        if not slots:
            return self.results()
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i, seq in enumerate(self.active):
            if seq is not None and seq.output:
                tokens[i, 0] = seq.output[-1]
        batch = {
            "tokens": jnp.asarray(tokens),
            "caches": self.caches,
            "block_tables": jnp.asarray(self.block_tables),
            "lengths": jnp.asarray(self.lengths),
        }
        out = self._decode_jit(self.params, batch)
        self.caches = out.caches
        self._rng, sub = jax.random.split(self._rng)
        nxt = sample_tokens(np.asarray(out.logits[:, 0]), sub,
                            self.temperature)
        for slot in slots:
            seq = self.active[slot]
            self.lengths[slot] += 1
            tok = int(nxt[slot])
            seq.output.append(tok)
            if (tok == self.eos_id
                    or len(seq.output) >= seq.max_new
                    or self.lengths[slot] >= self.max_seq - 1):
                seq.done = True
                self.completed[seq.sid] = list(seq.output)
                self.allocator.free(seq.pages)
                self.active[slot] = None
                self.lengths[slot] = 0
                self.block_tables[slot] = self.scratch_page
        return self.results()

    def results(self) -> Dict[int, List[int]]:
        out = dict(self.completed)
        for seq in list(self.waiting) + [s for s in self.active if s]:
            out[seq.sid] = list(seq.output)
        return out

    def run_to_completion(self, max_steps: int = 10_000
                          ) -> Dict[int, List[int]]:
        steps = 0
        while not self.finished() and steps < max_steps:
            self.step()
            steps += 1
        return self.results()
