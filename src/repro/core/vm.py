"""JAX execution engines for verified Tiara operators.

The paper's NIC pipeline keeps many requests in flight at line rate; the
software analogue is a *batch-parallel* execution engine.  One memory
processor (MP) frontend is modeled as a single ``lax.while_loop`` whose
carry is the architectural state of the paper's Fig. 4 datapath — pc, the
16x64 b register file, the depth-8 loop stack, the in-flight async counter
— for **B independent requests at once**, stepping against one shared
memory pool.  One XLA launch is amortized over the whole batch instead of
paying interpreter dispatch per request.

Execution semantics of a batch (deterministic round-robin interleaving):
each macro-step, every live request executes its current instruction in
request-index order, and request *i* observes all memory effects of
requests ``j < i`` within the same macro-step.  When the requests' memory
footprints are disjoint this is bit-identical to running them one after
another on the ``pyvm`` oracle; under contention (e.g. CAS on a shared
latch) the ordering stays deterministic — the lowest-indexed request wins.

Two step implementations share that semantics:

  * a fully vectorized step (active-mask semantics, every opcode computed
    for every lane and combined with masks, scatters routed through
    out-of-bounds drop lanes) used whenever a cheap per-step conflict
    check proves no request's write window can touch another's read or
    write window, and
  * an exact serialized step — a ``lax.scan`` over the batch of the
    scalar ``lax.switch`` interpreter — used for contended steps so
    atomics (STORE/CAS/CAA) keep pyvm ordering.

``build_vm`` (the single-request entry point every existing caller uses)
is the ``batch=1`` specialization of the same engine.

**Mixed-batch execution model** (the multi-tenant line-rate path): the
engine is built over a *merged instruction store* — every registered
program laid out back to back, exactly the registry's shared BRAM — and
each request additionally carries an ``op_sel`` slot index.  Request ``b``
starts at ``start_pc[op_sel[b]]``, terminates against its own program end
and its own verified step bound, and otherwise participates in the very
same lockstep macro-step: one ``lax.while_loop`` advances B requests
running *different tenants' operators* against the one shared pool, so a
serving wave interleaving GraphWalk, PageTableWalk, KV-fetch and MoE
requests costs one XLA launch instead of one launch per op_id.  The
per-step sweep-line conflict check and the serialized contended fallback
reason per-request from the decoded instruction rows, so mixed batches
compose with them unchanged: contended steps of a mixed batch keep the
deterministic lowest-index-wins ordering.  ``build_batched_vm`` is the
one-program specialization (``op_sel`` pinned to slot 0);
``build_mixed_batched_vm`` / ``invoke_batched_mixed`` expose the full
dispatch-table form.

**Sharded execution model** (the pod-scale fabric): the same lockstep
semantics run over a ``jax.sharding.Mesh`` with the pool's leading
``n_devices`` axis sharded (``shard_map``).  Each device executes the
home-bucketed sub-wave it owns; remote LOAD/MEMCPY lower to collectives
across the mesh axis, and contended macro-steps fall back to a
replicated serialized scan in global *arrival* order, so the
deterministic round-robin contention semantics survive sharding
bit-for-bit (``build_sharded_mixed_vm`` / ``invoke_sharded_mixed``).
The step semantics themselves are emitted once (``_make_scalar_step`` /
``_make_vector_step``) against a small memory-access interface, so the
dense and sharded engines cannot drift apart.

The *verified step bound* is the loop fuel: registration-time verification
proves the VM can never hit it, and the property tests assert exactly that.

Semantics are defined by ``repro.core.pyvm`` — keep the two in lockstep.
All ISA values are int64; because x64 mode is not enabled globally (model
code runs in default 32-bit mode), every entry point here wraps execution
in a local x64 configuration context.
"""

from __future__ import annotations

import contextlib
import dataclasses
import weakref
from typing import Dict, NamedTuple, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import isa
from repro.core.isa import (Alu, Op, FLAG_ASYNC, FLAG_DEV_REG,
                            FLAG_DSTDEV_REG, FLAG_IMMB, FLAG_LEN_REG,
                            FLAG_MREG, FLAG_SRCDEV_REG, FLAG_THR_REG,
                            DEV_LOCAL, ERR_REG)
from repro.core.memory import RegionTable
from repro.core.verifier import VerifiedOperator

_REG_MASK = isa.NUM_REGS - 1


@contextlib.contextmanager
def x64():
    """Locally enable 64-bit mode (the ISA is 64-bit; models stay 32-bit)."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


class ReqState(NamedTuple):
    """Per-request architectural state.  In the batched engine every leaf
    carries a leading batch dimension; the shared memory pool is threaded
    separately so B requests step against one pool."""

    pc: jnp.ndarray          # i64 [B]
    regs: jnp.ndarray        # i64 [B, 16]
    lstack: jnp.ndarray      # i64 [B, 8, 3]  (start, end, remaining)
    lsp: jnp.ndarray         # i64 [B]
    inflight: jnp.ndarray    # i64 [B]
    halted: jnp.ndarray      # bool [B]
    ret: jnp.ndarray         # i64 [B]
    status: jnp.ndarray      # i64 [B]
    steps: jnp.ndarray       # i64 [B]
    ctrl: jnp.ndarray        # i64 [B]: 0 = advance (loop-iterate), 1 = taken jump
    pc_new: jnp.ndarray      # i64 [B]
    fault: jnp.ndarray       # i64 [B, 4]: (pc, opcode, addr, device); pc=-1 none


class VMResult(NamedTuple):
    mem: jnp.ndarray
    ret: jnp.ndarray
    status: jnp.ndarray
    steps: jnp.ndarray
    regs: jnp.ndarray
    fault: jnp.ndarray       # i64 [B, 4] FaultInfo rows (pc=-1 = no fault)


# The "no fault" FaultInfo row every clean lane carries (pc = -1).
NO_FAULT = np.asarray([-1, 0, 0, 0], dtype=np.int64)


def fault_info(row) -> Optional[isa.FaultInfo]:
    """Decode one [4] fault row into a FaultInfo (None when clean)."""
    row = np.asarray(row, dtype=np.int64).reshape(4)
    if int(row[0]) < 0:
        return None
    return isa.FaultInfo(pc=int(row[0]), opcode=int(row[1]),
                         addr=int(row[2]), device=int(row[3]))


def _i64(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.int64)


def _alu_table(a, b):
    """All 16 ALU results for operands ``a``/``b`` (any common shape),
    indexed by ``Alu`` opcode — the single definition both the scalar and
    the vectorized evaluator select from."""
    sh = b & 63
    return [
        a + b, a - b, a * b, a & b, a | b, a ^ b,
        a << sh, lax.shift_right_logical(a, sh),
        (a == b).astype(jnp.int64), (a != b).astype(jnp.int64),
        (a < b).astype(jnp.int64), (a >= b).astype(jnp.int64),
        jnp.minimum(a, b), jnp.maximum(a, b), a, a,
    ]


# ---------------------------------------------------------------------------
# Memory access objects — the one seam between instruction semantics and
# the pool's physical layout.  The step emitters below are written
# against this small interface, so the identical semantics drive both
# the dense single-process pool and a mesh-sharded pool where every
# device holds one row and remote accesses lower to collectives.
# ---------------------------------------------------------------------------


class _DenseOps:
    """Direct access to the full ``(n_devices, pool_words)`` pool — the
    single-process engines."""

    def __init__(self, n_dev: int, pool_words: int):
        self.n_dev = n_dev
        self.P = pool_words

    # -- scalar (one lane; addresses verified in range, except that a
    # faulted lane routes dev to n_dev so the write drops) ---------------
    def read1(self, mem, dev, addr):
        return mem[dev, addr]

    def write1(self, mem, dev, addr, val):
        return mem.at[dev, addr].set(val, mode="drop")

    def read1_win(self, mem, dev, phys):
        return mem[dev, phys]

    def write1_win(self, mem, dev, idx, val):
        return mem.at[dev, idx].set(val, mode="drop")

    # -- vector (B lanes; dead lanes routed to drop targets) -------------
    def readv(self, mem, dev, addr):
        return mem[jnp.clip(dev, 0, self.n_dev - 1),
                   jnp.clip(addr, 0, self.P - 1)]

    def writev(self, mem, dev, addr, val, mask):
        return mem.at[jnp.where(mask, dev, self.n_dev),
                      jnp.where(mask, addr, self.P)].set(val, mode="drop")

    def readv_win(self, mem, dev, phys):
        return mem[jnp.clip(dev, 0, self.n_dev - 1)[:, None],
                   jnp.clip(phys, 0, self.P - 1)]

    def writev_win(self, mem, dev, phys, val, live):
        return mem.at[jnp.where(live, dev[:, None], self.n_dev),
                      jnp.where(live, phys, self.P)].set(val, mode="drop")

    def any_lane(self, flag):
        """Predicate for data-dependent ``lax.cond`` skips."""
        return jnp.any(flag)


class _ShardOps:
    """Collective-routed access to one device's pool shard inside
    ``shard_map``: ``mem`` is this device's ``(pool_words,)`` row of the
    ``(n_devices, pool_words)`` pool.

    Reads are answered by the owning shard (masked contribution +
    ``psum`` across the mesh axis); writes are applied only by the
    owner (non-owners route the scatter out of bounds and drop it).
    The *vector* ops route different per-device requests: indices are
    ``all_gather``-ed across the axis, every shard contributes the words
    it owns, and the ``psum`` carries each answer back — the software
    spelling of the fabric's remote-read round trip.  The *scalar* ops
    are called only from the replicated serialized fallback, where every
    device asks the identical question, so a masked ``psum`` suffices.

    ``any_lane`` returns a globally agreed predicate so data-dependent
    ``lax.cond`` skips take the same branch on every device (collectives
    inside a divergent branch would deadlock the mesh).
    """

    def __init__(self, n_dev: int, pool_words: int, axis: str, me):
        self.n_dev = n_dev
        self.P = pool_words
        self.axis = axis
        self.me = me

    # -- scalar (replicated callers) -------------------------------------
    def read1(self, mem, dev, addr):
        own = jnp.where(dev == self.me,
                        mem[jnp.clip(addr, 0, self.P - 1)], 0)
        return lax.psum(own, self.axis)

    def write1(self, mem, dev, addr, val):
        return mem.at[jnp.where(dev == self.me, addr, self.P)
                      ].set(val, mode="drop")

    def read1_win(self, mem, dev, phys):
        own = jnp.where(dev == self.me,
                        mem[jnp.clip(phys, 0, self.P - 1)], 0)
        return lax.psum(own, self.axis)

    def write1_win(self, mem, dev, idx, val):
        return mem.at[jnp.where(dev == self.me, idx, self.P)
                      ].set(val, mode="drop")

    # -- vector (per-device sub-waves; requests differ across devices) ---
    def readv(self, mem, dev, addr):
        req = lax.all_gather(jnp.stack([dev, addr]), self.axis)
        own = jnp.where(req[:, 0] == self.me,
                        mem[jnp.clip(req[:, 1], 0, self.P - 1)], 0)
        return jnp.take(lax.psum(own, self.axis), self.me, axis=0)

    def writev(self, mem, dev, addr, val, mask):
        pay = lax.all_gather(
            jnp.stack([dev, addr, val, mask.astype(jnp.int64)]), self.axis)
        d, a = pay[:, 0].reshape(-1), pay[:, 1].reshape(-1)
        v, m = pay[:, 2].reshape(-1), pay[:, 3].reshape(-1) != 0
        mine = m & (d == self.me)
        return mem.at[jnp.where(mine, jnp.clip(a, 0, self.P - 1), self.P)
                      ].set(v, mode="drop")

    def readv_win(self, mem, dev, phys):
        reqd = lax.all_gather(dev, self.axis)            # (n_dev, B)
        reqp = lax.all_gather(phys, self.axis)           # (n_dev, B, W)
        own = jnp.where(reqd[:, :, None] == self.me,
                        mem[jnp.clip(reqp, 0, self.P - 1)], 0)
        return jnp.take(lax.psum(own, self.axis), self.me, axis=0)

    def writev_win(self, mem, dev, phys, val, live):
        reqd = lax.all_gather(dev, self.axis)            # (n_dev, B)
        pay = lax.all_gather(
            jnp.stack([phys, val, live.astype(jnp.int64)], axis=0),
            self.axis)                                   # (n_dev, 3, B, W)
        a, v = pay[:, 0], pay[:, 1]
        lv = (pay[:, 2] != 0) & (reqd[:, :, None] == self.me)
        return mem.at[jnp.where(lv, jnp.clip(a, 0, self.P - 1), self.P)
                      ].set(v, mode="drop")

    def any_lane(self, flag):
        return lax.psum(jnp.any(flag).astype(jnp.int32), self.axis) > 0


# ---------------------------------------------------------------------------
# Step emitters, shared by the dense and sharded engines
# ---------------------------------------------------------------------------


def _make_scalar_step(*, base_c, mask_c, failed, n_dev, max_window, depth,
                      ops, protect=True):
    """The scalar (one-request) ``lax.switch`` interpreter — the semantic
    reference every other step implementation must match.  Memory access
    goes through ``ops``, so the same branches drive the dense pool and a
    mesh shard.  Returns ``step_one(s, mem, row, home, act)``.

    ``protect`` bakes in the runtime protection checks (see pyvm): a
    data-dependent device/offset outside the grant, or a word access to a
    failed device, halts the lane with ``STATUS_PROT_FAULT`` and masks
    every effect of the faulting instruction.  With ``protect=False`` the
    checks are not traced at all (legacy wrap semantics)."""

    def dev_of1(regs, home, field, via_reg):
        dreg = regs[field & _REG_MASK]
        d = jnp.where(via_reg, dreg, field)
        return jnp.where(d == DEV_LOCAL, home, jnp.mod(d, n_dev))

    def phys1(rid, off):
        return base_c[rid] + (off & mask_c[rid])

    def alu_eval1(aop, a, b):
        return jnp.stack(_alu_table(a, b))[jnp.clip(aop, 0, 15)]

    def advance(s: ReqState, **kw) -> ReqState:
        return s._replace(ctrl=_i64(0), pc_new=s.pc + 1, **kw)

    # --- runtime protection (scalar) -------------------------------------
    def dev_oob1(regs, field, via_reg):
        """Register-held device that is neither DEV_LOCAL nor a real id."""
        d = regs[field & _REG_MASK]
        return via_reg & (d != DEV_LOCAL) & ((d < 0) | (d >= n_dev))

    def word_fault1(s, home, row):
        """(fault?, FaultInfo row) for LOAD/STORE/CAS/CAA; (None, None)
        when protection is compiled out."""
        if not protect:
            return None, None
        via = (row[isa.F_FLAGS] & FLAG_DEV_REG) != 0
        draw = jnp.where(via, s.regs[row[isa.F_E] & _REG_MASK],
                         row[isa.F_E])
        oob_dev = dev_oob1(s.regs, row[isa.F_E], via)
        dev = dev_of1(s.regs, home, row[isa.F_E], via)
        off = s.regs[row[isa.F_B] & _REG_MASK] + row[isa.F_IMM]
        oob_off = off != (off & mask_c[row[isa.F_A]])
        flt = oob_dev | oob_off | failed[dev]
        frow = jnp.stack([s.pc, row[isa.F_OP], off,
                          jnp.where(oob_dev, draw, dev)])
        return flt, frow

    def prot_halt(s2: ReqState, s: ReqState, flt, frow) -> ReqState:
        """Merge: on fault keep ``s``'s architectural state (regs come
        pre-masked by the branch), halt with PROT_FAULT and latch the
        fault record."""
        if flt is None:
            return s2
        return s2._replace(
            halted=s2.halted | flt,
            status=jnp.where(flt, _i64(isa.STATUS_PROT_FAULT), s2.status),
            inflight=jnp.where(flt, s.inflight, s2.inflight),
            fault=jnp.where(flt, frow, s2.fault))

    # --- one branch per opcode; (s, mem, row, home) -> (s, mem) ----------
    def br_nop(s, mem, row, home):
        return advance(s), mem

    def br_movi(s, mem, row, home):
        return advance(s, regs=s.regs.at[row[isa.F_DST] & _REG_MASK]
                       .set(row[isa.F_IMM])), mem

    def br_alu(s, mem, row, home):
        rhs = jnp.where(row[isa.F_FLAGS] & FLAG_IMMB, row[isa.F_IMM],
                        s.regs[row[isa.F_B] & _REG_MASK])
        val = alu_eval1(row[isa.F_D], s.regs[row[isa.F_A] & _REG_MASK],
                        rhs)
        return advance(s, regs=s.regs.at[row[isa.F_DST] & _REG_MASK]
                       .set(val)), mem

    def br_load(s, mem, row, home):
        flt, frow = word_fault1(s, home, row)
        dev = dev_of1(s.regs, home, row[isa.F_E],
                      (row[isa.F_FLAGS] & FLAG_DEV_REG) != 0)
        addr = phys1(row[isa.F_A],
                     s.regs[row[isa.F_B] & _REG_MASK] + row[isa.F_IMM])
        val = ops.read1(mem, dev, addr)
        regs = s.regs.at[row[isa.F_DST] & _REG_MASK].set(val)
        if flt is not None:
            regs = jnp.where(flt, s.regs, regs)
        return prot_halt(advance(s, regs=regs), s, flt, frow), mem

    def br_store(s, mem, row, home):
        flt, frow = word_fault1(s, home, row)
        dev = dev_of1(s.regs, home, row[isa.F_E],
                      (row[isa.F_FLAGS] & FLAG_DEV_REG) != 0)
        addr = phys1(row[isa.F_A],
                     s.regs[row[isa.F_B] & _REG_MASK] + row[isa.F_IMM])
        val = s.regs[row[isa.F_DST] & _REG_MASK]
        if flt is not None:
            dev = jnp.where(flt, _i64(n_dev), dev)
        return prot_halt(advance(s), s, flt, frow), \
            ops.write1(mem, dev, addr, val)

    def br_memcpy(s, mem, row, home):
        flags = row[isa.F_FLAGS]
        via_d = (flags & FLAG_DSTDEV_REG) != 0
        via_s = (flags & FLAG_SRCDEV_REG) != 0
        ddev = dev_of1(s.regs, home, row[isa.F_DST], via_d)
        sdev = dev_of1(s.regs, home, row[isa.F_C], via_s)
        drid, srid = row[isa.F_A], row[isa.F_D]
        cap = row[isa.F_IMM]
        lnreg = s.regs[row[isa.F_IMM2] & _REG_MASK]
        ln = jnp.where(flags & FLAG_LEN_REG,
                       jnp.clip(lnreg, 0, cap), cap)
        ln = jnp.minimum(jnp.minimum(ln, mask_c[drid] + 1),
                         mask_c[srid] + 1)
        fail = failed[ddev] | failed[sdev]
        soff = s.regs[row[isa.F_E] & _REG_MASK]
        doff = s.regs[row[isa.F_B] & _REG_MASK]
        if protect:
            # Same 4-way priority as pyvm: dst-dev, src-dev, dst window,
            # src window.  Only a copy that would actually move words
            # (post-clamp ln > 0) can fault.
            oob_dd = dev_oob1(s.regs, row[isa.F_DST], via_d)
            oob_sd = dev_oob1(s.regs, row[isa.F_C], via_s)
            d_oob = (doff != (doff & mask_c[drid])) | \
                (doff + ln > mask_c[drid] + 1)
            s_oob = (soff != (soff & mask_c[srid])) | \
                (soff + ln > mask_c[srid] + 1)
            flt = (ln > 0) & (oob_dd | oob_sd | d_oob | s_oob)
            faddr = jnp.where(oob_dd | (~oob_sd & d_oob), doff, soff)
            fdev = jnp.where(
                oob_dd, s.regs[row[isa.F_DST] & _REG_MASK],
                jnp.where(oob_sd, s.regs[row[isa.F_C] & _REG_MASK],
                          jnp.where(d_oob, ddev, sdev)))
            frow = jnp.stack([s.pc, row[isa.F_OP], faddr, fdev])
            fail = fail & ~flt
        else:
            flt, frow = None, None
        ln = jnp.where(fail, 0, ln)
        if flt is not None:
            ln = jnp.where(flt, 0, ln)
        i = jnp.arange(max_window, dtype=jnp.int64)
        sphys = base_c[srid] + ((soff + i) & mask_c[srid])
        dphys = base_c[drid] + ((doff + i) & mask_c[drid])
        svals = ops.read1_win(mem, sdev, sphys)
        live = i < ln
        # Masked lanes all write the lane-0 value to the lane-0 slot so
        # duplicate scatter indices always carry identical values.
        val0 = jnp.where(ln > 0, svals[0], ops.read1(mem, ddev, dphys[0]))
        w_idx = jnp.where(live, dphys, dphys[0])
        w_val = jnp.where(live, svals, val0)
        mem2 = ops.write1_win(mem, ddev, w_idx, w_val)
        err = jnp.where(fail, s.regs[ERR_REG] | 1, s.regs[ERR_REG])
        regs = s.regs.at[ERR_REG].set(err)
        inflight = jnp.where(
            flags & FLAG_ASYNC,
            jnp.minimum(s.inflight + 1, isa.MAX_INFLIGHT), s.inflight)
        return prot_halt(advance(s, regs=regs, inflight=inflight),
                         s, flt, frow), mem2

    def _br_casa(s, mem, row, home, is_cas):
        flt, frow = word_fault1(s, home, row)
        dev = dev_of1(s.regs, home, row[isa.F_E],
                      (row[isa.F_FLAGS] & FLAG_DEV_REG) != 0)
        addr = phys1(row[isa.F_A],
                     s.regs[row[isa.F_B] & _REG_MASK] + row[isa.F_IMM])
        old = ops.read1(mem, dev, addr)
        hit = old == s.regs[row[isa.F_C] & _REG_MASK]
        swp = s.regs[row[isa.F_D] & _REG_MASK]
        new = jnp.where(hit, swp if is_cas else old + swp, old)
        regs = s.regs.at[row[isa.F_DST] & _REG_MASK].set(old)
        if flt is not None:
            regs = jnp.where(flt, s.regs, regs)
            dev = jnp.where(flt, _i64(n_dev), dev)
        return prot_halt(advance(s, regs=regs), s, flt, frow), \
            ops.write1(mem, dev, addr, new)

    def br_cas(s, mem, row, home):
        return _br_casa(s, mem, row, home, True)

    def br_caa(s, mem, row, home):
        return _br_casa(s, mem, row, home, False)

    def br_jump(s, mem, row, home):
        cond = row[isa.F_D]
        lhs = s.regs[row[isa.F_A] & _REG_MASK]
        rhs = jnp.where(row[isa.F_FLAGS] & FLAG_IMMB, row[isa.F_IMM],
                        s.regs[row[isa.F_B] & _REG_MASK])
        take = jnp.where(
            cond == int(Alu.ALWAYS), True,
            jnp.where(cond == int(Alu.EQ), lhs == rhs,
                      jnp.where(cond == int(Alu.NE), lhs != rhs,
                                jnp.where(cond == int(Alu.LT), lhs < rhs,
                                          lhs >= rhs))))
        return s._replace(
            ctrl=jnp.where(take, _i64(1), _i64(0)),
            pc_new=jnp.where(take, s.pc + 1 + row[isa.F_IMM2],
                             s.pc + 1)), mem

    def br_loop(s, mem, row, home):
        cap = row[isa.F_IMM]
        m = jnp.where(row[isa.F_FLAGS] & FLAG_MREG,
                      jnp.clip(s.regs[row[isa.F_B] & _REG_MASK], 0, cap),
                      cap)
        skip = m <= 0
        frame = jnp.stack([s.pc + 1, s.pc + row[isa.F_IMM2], m])
        sp = jnp.clip(s.lsp, 0, depth - 1)
        pushed = s.lstack.at[sp].set(frame)
        return s._replace(
            lstack=jnp.where(skip, s.lstack, pushed),
            lsp=jnp.where(skip, s.lsp, s.lsp + 1),
            ctrl=_i64(0),
            pc_new=jnp.where(skip, s.pc + 1 + row[isa.F_IMM2],
                             s.pc + 1)), mem

    def br_wait(s, mem, row, home):
        thr = jnp.where(row[isa.F_FLAGS] & FLAG_THR_REG,
                        s.regs[row[isa.F_A] & _REG_MASK],
                        row[isa.F_IMM])
        return advance(s, inflight=jnp.minimum(
            s.inflight, jnp.maximum(thr, 0))), mem

    def br_ret(s, mem, row, home):
        return advance(s, halted=jnp.asarray(True),
                       ret=s.regs[row[isa.F_A] & _REG_MASK],
                       status=row[isa.F_IMM]), mem

    branches = [br_nop, br_movi, br_alu, br_load, br_store, br_memcpy,
                br_cas, br_caa, br_jump, br_loop, br_wait, br_ret]

    # --- post-step loop bookkeeping (scalar) -----------------------------
    def loop_fixup1(s: ReqState) -> ReqState:
        # taken jump: pop every frame whose body the jump escaped
        def pop_cond(t):
            lsp, = t
            return (lsp > 0) & (s.lstack[jnp.maximum(lsp - 1, 0), 1]
                                < s.pc_new)

        def pop_body(t):
            lsp, = t
            return (lsp - 1,)

        (pop_lsp,) = lax.while_loop(pop_cond, pop_body, (s.lsp,))

        # normal advance: iterate / pop frames whose body just ended
        def it_cond(t):
            stack, lsp, pcn, done = t
            top_end = stack[jnp.maximum(lsp - 1, 0), 1]
            return (~done) & (lsp > 0) & (pcn == top_end + 1)

        def it_body(t):
            stack, lsp, pcn, done = t
            idx = jnp.maximum(lsp - 1, 0)
            rem = stack[idx, 2] - 1
            cont = rem > 0
            stack2 = stack.at[idx, 2].set(rem)
            return (jnp.where(cont, stack2, stack),
                    jnp.where(cont, lsp, lsp - 1),
                    jnp.where(cont, stack[idx, 0], pcn),
                    cont)

        it_stack, it_lsp, it_pcn, _ = lax.while_loop(
            it_cond, it_body,
            (s.lstack, s.lsp, s.pc_new, jnp.asarray(False)))

        is_jump = s.ctrl == 1
        return s._replace(
            pc=jnp.where(is_jump, s.pc_new, it_pcn),
            lsp=jnp.where(is_jump, pop_lsp, it_lsp),
            lstack=jnp.where(is_jump, s.lstack, it_stack))

    def step_one(s: ReqState, mem, row, home, act):
        """Execute one instruction of one request (if active)."""
        def do(args):
            s, mem = args
            opc = jnp.clip(row[isa.F_OP], 0,
                           len(branches) - 1).astype(jnp.int32)
            s2, mem2 = lax.switch(opc, branches, s, mem, row, home)
            s2 = s2._replace(steps=s2.steps + 1)
            s2 = lax.cond(s2.halted, lambda t: t, loop_fixup1, s2)
            return s2, mem2

        return lax.cond(act, do, lambda a: a, (s, mem))

    return step_one


def _serial_step_fn(step_one):
    """The contention-exact macro-step: requests 0..B-1 each execute one
    instruction in lane order against the shared pool."""
    def serial_step(s: ReqState, mem, rows, homes, active):
        def body(mem, x):
            s1, row, home, act = x
            s2, mem2 = step_one(s1, mem, row, home, act)
            return mem2, s2

        mem2, s2 = lax.scan(body, mem, (s, rows, homes, active))
        return s2, mem2

    return serial_step


def _sweep_conflict(r_lo, r_hi, w_lo, w_hi):
    """Conflict existence over per-lane footprint intervals (see
    ``lane_intervals``): does some lane's write window overlap another
    lane's read or write window?  A sweep line over the sorted interval
    starts with exclusive running maxima of the ends — O(L log L)."""
    big = jnp.int64(1) << 62
    empty_hi = -big
    L = r_lo.shape[0]
    lo = jnp.concatenate([r_lo, w_lo])
    hi = jnp.concatenate([r_hi, w_hi])
    isw = jnp.concatenate([jnp.zeros(L, bool), jnp.ones(L, bool)])
    order = jnp.argsort(lo)
    lo_s, hi_s, w_s = lo[order], hi[order], isw[order]
    hi_w = jnp.where(w_s, hi_s, empty_hi)
    neg1 = jnp.full(1, empty_hi)
    excl_all = jnp.concatenate([neg1, lax.cummax(hi_s)[:-1]])
    excl_w = jnp.concatenate([neg1, lax.cummax(hi_w)[:-1]])
    return jnp.any(excl_w > lo_s) | \
        jnp.any(w_s & (excl_all > lo_s))


def _make_vector_step(*, base_c, mask_c, n_regions, n_dev, pool_words,
                      max_window, depth, B, homes, failed, ops,
                      protect=True):
    """The vectorized macro-step plus the per-lane footprint intervals
    feeding the conflict sweep, parameterized over memory access.
    Returns ``(vector_step, lane_intervals)``.

    Every opcode path is computed for every lane and combined with
    masks; scatters route dead lanes to out-of-bounds drop targets.
    With ``protect`` (the default) the runtime protection checks of the
    scalar reference are decoded per lane and a faulting lane halts with
    ``STATUS_PROT_FAULT``, all channels masked.
    """
    lane16 = jnp.arange(isa.NUM_REGS, dtype=jnp.int64)[None, :]
    lane8 = jnp.arange(depth, dtype=jnp.int64)[None, :]

    def rd(regs, idx):
        """Vector register-file read: regs[b, idx[b] & 15]."""
        return jnp.take_along_axis(
            regs, (idx & _REG_MASK)[:, None], axis=1)[:, 0]

    def dev_of_v(regs, field, via_reg):
        d = jnp.where(via_reg, rd(regs, field), field)
        return jnp.where(d == DEV_LOCAL, homes, jnp.mod(d, n_dev))

    def dev_oob_v(regs, field, via_reg):
        d = rd(regs, field)
        return via_reg & (d != DEV_LOCAL) & ((d < 0) | (d >= n_dev))

    def _decode(s, rows):
        """Shared per-lane decode of memory operands (word ops and
        memcpy windows) used by both the vector step and the conflict
        check."""
        flags = rows[:, isa.F_FLAGS]
        # word ops (LOAD/STORE/CAS/CAA) share the same addressing form
        w_rid = jnp.clip(rows[:, isa.F_A], 0, n_regions - 1)
        w_via = (flags & FLAG_DEV_REG) != 0
        w_dev = dev_of_v(s.regs, rows[:, isa.F_E], w_via)
        w_off = rd(s.regs, rows[:, isa.F_B]) + rows[:, isa.F_IMM]
        w_addr = base_c[w_rid] + (w_off & mask_c[w_rid])
        # memcpy operands
        m_drid = jnp.clip(rows[:, isa.F_A], 0, n_regions - 1)
        m_srid = jnp.clip(rows[:, isa.F_D], 0, n_regions - 1)
        m_via_d = (flags & FLAG_DSTDEV_REG) != 0
        m_via_s = (flags & FLAG_SRCDEV_REG) != 0
        m_ddev = dev_of_v(s.regs, rows[:, isa.F_DST], m_via_d)
        m_sdev = dev_of_v(s.regs, rows[:, isa.F_C], m_via_s)
        cap = rows[:, isa.F_IMM]
        lnreg = rd(s.regs, rows[:, isa.F_IMM2])
        ln = jnp.where((flags & FLAG_LEN_REG) != 0,
                       jnp.clip(lnreg, 0, cap), cap)
        ln = jnp.minimum(jnp.minimum(ln, mask_c[m_drid] + 1),
                         mask_c[m_srid] + 1)
        m_fail = failed[m_ddev] | failed[m_sdev]
        m_soff = rd(s.regs, rows[:, isa.F_E])
        m_doff = rd(s.regs, rows[:, isa.F_B])
        out = dict(flags=flags, w_rid=w_rid, w_dev=w_dev, w_addr=w_addr,
                   m_drid=m_drid, m_srid=m_srid, m_ddev=m_ddev,
                   m_sdev=m_sdev, m_fail=m_fail, m_soff=m_soff,
                   m_doff=m_doff)
        if protect:
            # word-op fault columns (mirrors the scalar word_fault1)
            w_draw = jnp.where(w_via, rd(s.regs, rows[:, isa.F_E]),
                               rows[:, isa.F_E])
            w_oob_dev = dev_oob_v(s.regs, rows[:, isa.F_E], w_via)
            w_flt = w_oob_dev | (w_off != (w_off & mask_c[w_rid])) | \
                failed[w_dev]
            # memcpy fault columns (4-way priority, pre-fail-zero ln)
            oob_dd = dev_oob_v(s.regs, rows[:, isa.F_DST], m_via_d)
            oob_sd = dev_oob_v(s.regs, rows[:, isa.F_C], m_via_s)
            d_oob = (m_doff != (m_doff & mask_c[m_drid])) | \
                (m_doff + ln > mask_c[m_drid] + 1)
            s_oob = (m_soff != (m_soff & mask_c[m_srid])) | \
                (m_soff + ln > mask_c[m_srid] + 1)
            m_flt = (ln > 0) & (oob_dd | oob_sd | d_oob | s_oob)
            m_faddr = jnp.where(oob_dd | (~oob_sd & d_oob), m_doff,
                                m_soff)
            m_fdev = jnp.where(
                oob_dd, rd(s.regs, rows[:, isa.F_DST]),
                jnp.where(oob_sd, rd(s.regs, rows[:, isa.F_C]),
                          jnp.where(d_oob, m_ddev, m_sdev)))
            m_fail = m_fail & ~m_flt
            ln = jnp.where(m_flt, 0, ln)
            out.update(w_flt=w_flt, w_off=w_off,
                       w_fdev=jnp.where(w_oob_dev, w_draw, w_dev),
                       m_flt=m_flt, m_faddr=m_faddr, m_fdev=m_fdev,
                       m_fail=m_fail)
        ln = jnp.where(m_fail, 0, ln)
        out["ln"] = ln
        return out

    def lane_intervals(s, rows, active):
        """Per-lane read/write footprint intervals in flat
        ``dev * pool_words + addr`` coordinates.

        Word ops contribute exact one-word intervals; memcpy its exact
        window when it does not wrap the region mask, else the whole
        region.  An atomic's read is the same word as its write, so it
        contributes one write interval only.  The only false positive is
        a memcpy whose *own* source and destination windows overlap
        (memmove within one request), which merely takes the exact
        serialized path.  Never unsound."""
        d = _decode(s, rows)
        opv = rows[:, isa.F_OP]
        is_load = active & (opv == int(Op.LOAD))
        is_store = active & (opv == int(Op.STORE))
        is_atom = active & ((opv == int(Op.CAS)) | (opv == int(Op.CAA)))
        is_mcpy = active & (opv == int(Op.MEMCPY))
        P = pool_words
        wf = d["w_dev"] * P + d["w_addr"]
        # memcpy source span
        s_size = mask_c[d["m_srid"]] + 1
        s_start = d["m_soff"] & mask_c[d["m_srid"]]
        s_wrap = (s_start + d["ln"]) > s_size
        src_lo = d["m_sdev"] * P + base_c[d["m_srid"]] + \
            jnp.where(s_wrap, 0, s_start)
        src_hi = src_lo + jnp.where(s_wrap, s_size, d["ln"])
        # memcpy destination span
        d_size = mask_c[d["m_drid"]] + 1
        d_start = d["m_doff"] & mask_c[d["m_drid"]]
        d_wrap = (d_start + d["ln"]) > d_size
        dst_lo = d["m_ddev"] * P + base_c[d["m_drid"]] + \
            jnp.where(d_wrap, 0, d_start)
        dst_hi = dst_lo + jnp.where(d_wrap, d_size, d["ln"])

        big = jnp.int64(1) << 62
        empty_lo, empty_hi = big, -big
        r_lo = jnp.where(is_load, wf,
                         jnp.where(is_mcpy, src_lo, empty_lo))
        r_hi = jnp.where(is_load, wf + 1,
                         jnp.where(is_mcpy, src_hi, empty_hi))
        w_lo = jnp.where(is_store | is_atom, wf,
                         jnp.where(is_mcpy, dst_lo, empty_lo))
        w_hi = jnp.where(is_store | is_atom, wf + 1,
                         jnp.where(is_mcpy, dst_hi, empty_hi))
        # zero-length memcpy windows must be empty, not points
        r_hi = jnp.where(r_hi <= r_lo, empty_hi, r_hi)
        w_hi = jnp.where(w_hi <= w_lo, empty_hi, w_hi)
        return r_lo, r_hi, w_lo, w_hi

    def alu_eval_v(aop, a, b):
        stacked = jnp.stack(_alu_table(a, b))      # (16, B)
        return jnp.take_along_axis(
            stacked, jnp.clip(aop, 0, 15)[None, :], axis=0)[0]

    def vector_step(s: ReqState, mem, rows, active):
        d = _decode(s, rows)
        opv = rows[:, isa.F_OP]
        flags = d["flags"]
        imm = rows[:, isa.F_IMM]
        imm2 = rows[:, isa.F_IMM2]

        def is_op(o):
            return active & (opv == int(o))

        is_movi, is_alu = is_op(Op.MOVI), is_op(Op.ALU)
        is_load, is_store = is_op(Op.LOAD), is_op(Op.STORE)
        is_mcpy = is_op(Op.MEMCPY)
        is_cas, is_caa = is_op(Op.CAS), is_op(Op.CAA)
        is_jump, is_loop = is_op(Op.JUMP), is_op(Op.LOOP)
        is_wait, is_ret = is_op(Op.WAIT), is_op(Op.RET)
        is_atom = is_cas | is_caa

        # --- runtime protection faults -----------------------------
        if protect:
            flt = (d["w_flt"] & (is_load | is_store | is_atom)) | \
                (d["m_flt"] & is_mcpy)
            f_addr = jnp.where(is_mcpy, d["m_faddr"], d["w_off"])
            f_dev = jnp.where(is_mcpy, d["m_fdev"], d["w_fdev"])
            frows = jnp.stack(
                [s.pc, opv, f_addr, f_dev], axis=-1)       # (B, 4)
        else:
            flt = jnp.zeros(B, bool)

        # --- ALU / MOVI --------------------------------------------
        alu_rhs = jnp.where((flags & FLAG_IMMB) != 0, imm,
                            rd(s.regs, rows[:, isa.F_B]))
        alu_val = alu_eval_v(rows[:, isa.F_D],
                             rd(s.regs, rows[:, isa.F_A]), alu_rhs)

        # --- LOAD / CAS / CAA reads (step-start memory: the conflict
        # check guarantees no same-step writer touches these words).
        # Gated like the memcpy route: on a macro-step with no live
        # word-memory lane the read values are fully masked out below,
        # so skip the route entirely — in the sharded engine that is an
        # all_gather + psum saved on every compute-only step (the
        # predicate is globally agreed, so the mesh cannot diverge).
        def read_words(m):
            return ops.readv(m, d["w_dev"], d["w_addr"])

        w_old = lax.cond(ops.any_lane(is_load | is_atom), read_words,
                         lambda m: jnp.zeros(B, jnp.int64), mem)
        hit = w_old == rd(s.regs, rows[:, isa.F_C])
        swp = rd(s.regs, rows[:, isa.F_D])
        atom_new = jnp.where(
            hit, jnp.where(is_cas, swp, w_old + swp), w_old)

        # --- register write channel (one per opcode at most) --------
        err_old = s.regs[:, ERR_REG]
        err_new = jnp.where(d["m_fail"], err_old | 1, err_old)
        reg_w_mask = (is_movi | is_alu | is_load | is_atom | is_mcpy) \
            & ~flt
        reg_w_idx = jnp.where(
            is_mcpy, ERR_REG, rows[:, isa.F_DST] & _REG_MASK)
        reg_w_val = jnp.where(
            is_movi, imm,
            jnp.where(is_alu, alu_val,
                      jnp.where(is_load, w_old,
                                jnp.where(is_atom, w_old, err_new))))
        upd = (lane16 == reg_w_idx[:, None]) & reg_w_mask[:, None]
        regs = jnp.where(upd, reg_w_val[:, None], s.regs)

        # --- single-word scatter (STORE / CAS / CAA) -----------------
        sw_mask = (is_store | is_atom) & ~flt
        sw_val = jnp.where(is_store, rd(s.regs, rows[:, isa.F_DST]),
                           atom_new)
        mem = lax.cond(
            ops.any_lane(sw_mask),
            lambda m: ops.writev(m, d["w_dev"], d["w_addr"], sw_val,
                                 sw_mask),
            lambda m: m, mem)

        # --- memcpy window gather + scatter --------------------------
        # The window machinery materializes (B, max_window) gathers —
        # with a merged multi-tenant store max_window is the largest
        # cap of *any* program, so skip it entirely on the (frequent)
        # macro-steps where no live lane is copying.
        def do_memcpy(mem):
            iw = jnp.arange(max_window, dtype=jnp.int64)[None, :]
            sphys = base_c[d["m_srid"]][:, None] + \
                ((d["m_soff"][:, None] + iw)
                 & mask_c[d["m_srid"]][:, None])
            dphys = base_c[d["m_drid"]][:, None] + \
                ((d["m_doff"][:, None] + iw)
                 & mask_c[d["m_drid"]][:, None])
            live = is_mcpy[:, None] & (iw < d["ln"][:, None])
            svals = ops.readv_win(mem, d["m_sdev"], sphys)
            return ops.writev_win(mem, d["m_ddev"], dphys, svals, live)

        mem = lax.cond(ops.any_lane(is_mcpy), do_memcpy, lambda m: m, mem)

        # --- inflight ------------------------------------------------
        inflight = jnp.where(
            is_mcpy & ~flt & ((flags & FLAG_ASYNC) != 0),
            jnp.minimum(s.inflight + 1, isa.MAX_INFLIGHT), s.inflight)
        thr = jnp.where((flags & FLAG_THR_REG) != 0,
                        rd(s.regs, rows[:, isa.F_A]), imm)
        inflight = jnp.where(
            is_wait, jnp.minimum(inflight, jnp.maximum(thr, 0)),
            inflight)

        # --- RET / protection fault ----------------------------------
        halted = s.halted | is_ret | flt
        ret = jnp.where(is_ret, rd(s.regs, rows[:, isa.F_A]), s.ret)
        status = jnp.where(
            is_ret, imm,
            jnp.where(flt, _i64(isa.STATUS_PROT_FAULT), s.status))

        # --- control flow -------------------------------------------
        jcond = rows[:, isa.F_D]
        jlhs = rd(s.regs, rows[:, isa.F_A])
        jrhs = jnp.where((flags & FLAG_IMMB) != 0, imm,
                         rd(s.regs, rows[:, isa.F_B]))
        take = jnp.where(
            jcond == int(Alu.ALWAYS), True,
            jnp.where(jcond == int(Alu.EQ), jlhs == jrhs,
                      jnp.where(jcond == int(Alu.NE), jlhs != jrhs,
                                jnp.where(jcond == int(Alu.LT),
                                          jlhs < jrhs, jlhs >= jrhs))))
        # LOOP push
        cap = imm
        m = jnp.where((flags & FLAG_MREG) != 0,
                      jnp.clip(rd(s.regs, rows[:, isa.F_B]), 0, cap),
                      cap)
        skip = m <= 0
        push = is_loop & ~skip
        frame = jnp.stack([s.pc + 1, s.pc + imm2, m], axis=-1)  # (B, 3)
        sp = jnp.clip(s.lsp, 0, depth - 1)
        push_lane = (lane8 == sp[:, None]) & push[:, None]      # (B, 8)
        lstack = jnp.where(push_lane[:, :, None], frame[:, None, :],
                           s.lstack)
        lsp = jnp.where(push, s.lsp + 1, s.lsp)

        pc_new = jnp.where(
            is_jump & take, s.pc + 1 + imm2,
            jnp.where(is_loop & skip, s.pc + 1 + imm2, s.pc + 1))
        ctrl = jnp.where(is_jump & take, _i64(1), _i64(0))

        # --- loop fixup, vectorized over the batch -------------------
        def top(field, stk, lsp_v):
            idx = jnp.clip(lsp_v - 1, 0, depth - 1)
            return jnp.take_along_axis(
                stk[:, :, field], idx[:, None], axis=1)[:, 0]

        # taken jump: pop every frame whose body the jump escaped
        pop_lsp = lsp
        for _ in range(depth):
            cond = (pop_lsp > 0) & (top(1, lstack, pop_lsp) < pc_new)
            pop_lsp = jnp.where(cond, pop_lsp - 1, pop_lsp)

        # normal advance: iterate / pop frames whose body just ended
        it_stack, it_lsp, it_pcn = lstack, lsp, pc_new
        done = jnp.zeros(B, bool)
        for _ in range(depth):
            idx = jnp.clip(it_lsp - 1, 0, depth - 1)
            t_end = top(1, it_stack, it_lsp)
            cond = (~done) & (it_lsp > 0) & (it_pcn == t_end + 1)
            rem = top(2, it_stack, it_lsp) - 1
            cont = rem > 0
            set_m = cond & cont
            upd2 = (lane8 == idx[:, None]) & set_m[:, None]
            it_stack = jnp.where(
                upd2[:, :, None]
                & (jnp.arange(3) == 2)[None, None, :],
                rem[:, None, None], it_stack)
            it_pcn = jnp.where(set_m, top(0, it_stack, it_lsp), it_pcn)
            it_lsp = jnp.where(cond & ~cont, it_lsp - 1, it_lsp)
            done = done | set_m

        is_jtaken = ctrl == 1
        fix = active & ~is_ret & ~flt
        pc = jnp.where(fix, jnp.where(is_jtaken, pc_new, it_pcn), s.pc)
        lsp_f = jnp.where(fix, jnp.where(is_jtaken, pop_lsp, it_lsp),
                          jnp.where(active, lsp, s.lsp))
        lstack_f = jnp.where(
            fix[:, None, None],
            jnp.where(is_jtaken[:, None, None], lstack, it_stack),
            jnp.where(active[:, None, None], lstack, s.lstack))

        # --- merge, masking out inactive lanes -----------------------
        regs = jnp.where(active[:, None], regs, s.regs)
        fault = s.fault
        if protect:
            fault = jnp.where(flt[:, None], frows, s.fault)
        s2 = ReqState(
            pc=pc, regs=regs, lstack=lstack_f, lsp=lsp_f,
            inflight=jnp.where(active, inflight, s.inflight),
            halted=jnp.where(active, halted, s.halted),
            ret=jnp.where(active, ret, s.ret),
            status=jnp.where(active, status, s.status),
            steps=s.steps + active.astype(jnp.int64),
            ctrl=jnp.where(active, ctrl, s.ctrl),
            pc_new=jnp.where(active, pc_new, s.pc_new),
            fault=fault)
        return s2, mem

    return vector_step, lane_intervals


def _program_statics(codes, fuels):
    """Normalize a merged instruction store: per-slot entry/end/fuel
    vectors plus the static memcpy window — shared by the dense and
    sharded engine builders."""
    codes = [np.asarray(c, dtype=np.int64).reshape(-1, isa.INSTR_WIDTH)
             for c in codes]
    if not codes:
        raise ValueError("engine needs at least one program")
    code_np = np.concatenate(codes, axis=0)
    lens_np = np.asarray([c.shape[0] for c in codes], dtype=np.int64)
    start_np = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(lens_np)[:-1]])
    end_np = start_np + lens_np
    fuel_np = np.asarray([int(f) for f in fuels], dtype=np.int64)
    if fuel_np.shape != (len(codes),):
        raise ValueError("one step bound per program required")
    # Static memcpy window: the largest cap used by any merged program.
    memcpy_caps = [int(r[isa.F_IMM]) for r in code_np
                   if int(r[isa.F_OP]) == int(Op.MEMCPY)]
    max_window = int(min(max(memcpy_caps, default=1), isa.MAX_MEMCPY_WORDS))
    return code_np, start_np, end_np, fuel_np, max_window


def _build_engine(codes: Sequence[np.ndarray], fuels: Sequence[int],
                  regions: RegionTable, n_devices: int, batch: int,
                  protect: bool = True,
                  static_noconflict: bool = False):
    """Build the lockstep engine over a *merged* instruction store.

    ``codes`` holds one program per dispatch-table slot, laid out back to
    back in slot order — the same layout as the registry's shared BRAM
    instruction store, so slot ``i`` starts at the registry's
    ``start_pc[i]``.  Returns jit-compiled
    ``f(mem, params, homes, failed, op_sel) -> VMResult`` where
    ``op_sel``: int64[batch] picks each request's program; the request
    starts at its program's first pc and terminates against its own
    program end and verified step bound (``fuels[op_sel[b]]``).

    ``mem``: int64[n_devices, pool_words] shared by the whole batch;
    ``params``: int64[batch, <=8]; ``homes``: int64[batch] per-request
    executing-host ids; ``failed``: bool[n_devices].  Result fields
    ``ret/status/steps`` are [batch] and ``regs`` is [batch, 16].
    Call under ``vm.x64()`` (or use the ``invoke*`` wrappers).

    ``static_noconflict=True`` builds the engine *without* the per-step
    sweep-line conflict check: every macro-step takes the vectorized
    path (the serialized branch stays compiled in behind a never-true
    predicate — see the note in ``step`` — but the interval
    computation and sort are gone).  The caller must hold a
    registration-time proof (``access.prove_wave_noconflict``) that no
    macro-step of any wave run on this engine can conflict; the engine
    trusts the flag.  Top-footprint waves keep the default build — the
    sweep is the verbatim fallback.
    """
    code_np, start_np, end_np, fuel_np, max_window = \
        _program_statics(codes, fuels)
    n_ops = int(fuel_np.shape[0])
    n_instr = int(code_np.shape[0])
    base_np, mask_np, _ = regions.as_arrays()
    n_regions = int(base_np.shape[0])
    n_dev = int(n_devices)
    B = int(batch)
    depth = isa.LOOP_STACK_DEPTH

    def run(mem, params, homes, failed, op_sel):
        code = jnp.asarray(code_np)
        base_c = jnp.asarray(base_np)
        mask_c = jnp.asarray(mask_np)
        mem = jnp.asarray(mem, jnp.int64)
        homes = jnp.asarray(homes, jnp.int64).reshape(B)
        failed = jnp.asarray(failed, jnp.bool_)
        op_sel = jnp.clip(jnp.asarray(op_sel, jnp.int64).reshape(B),
                          0, n_ops - 1)
        # per-request dispatch: entry pc, program end, and step-bound fuel
        pc0 = jnp.asarray(start_np)[op_sel]
        end_arr = jnp.asarray(end_np)[op_sel]
        fuel_arr = jnp.asarray(fuel_np)[op_sel]
        pool_words = mem.shape[1]

        regs0 = jnp.zeros((B, isa.NUM_REGS), jnp.int64)
        params = jnp.asarray(params, jnp.int64).reshape(B, -1)
        if params.shape[1]:
            regs0 = lax.dynamic_update_slice(regs0, params, (0, 0))

        ops = _DenseOps(n_dev, int(pool_words))
        step_one = _make_scalar_step(
            base_c=base_c, mask_c=mask_c, failed=failed, n_dev=n_dev,
            max_window=max_window, depth=depth, ops=ops, protect=protect)
        serial_step = _serial_step_fn(step_one)
        vector_step, lane_intervals = _make_vector_step(
            base_c=base_c, mask_c=mask_c, n_regions=n_regions,
            n_dev=n_dev, pool_words=int(pool_words),
            max_window=max_window, depth=depth, B=B, homes=homes,
            failed=failed, ops=ops, protect=protect)

        def live_mask(s: ReqState):
            return (~s.halted) & (s.pc < end_arr) & (s.steps < fuel_arr)

        def step(carry):
            s, mem = carry
            active = live_mask(s)
            rows = code[jnp.clip(s.pc, 0, n_instr - 1)]
            if B == 1:
                # single request: the scalar switch interpreter, no
                # conflict machinery — the classic Tiara MP datapath
                s2, mem2 = serial_step(s, mem, rows, homes, active)
            elif static_noconflict:
                # statically proven conflict-free: the per-step sweep
                # (lane_intervals + interval sort) is gone.  The cond
                # and its serialized branch stay: XLA CPU outlines cond
                # branches into their own computations, and inlining
                # vector_step into the while body instead measures ~2x
                # slower at B=1024 (fusion boundaries vanish).  The
                # predicate can never fire (pc is clipped non-negative),
                # and if it somehow did, serial_step is semantically
                # correct — it is the conservative fallback.
                s2, mem2 = lax.cond(
                    jnp.any(s.pc < -1),
                    lambda s_, m_, r_, a_: serial_step(s_, m_, r_, homes,
                                                       a_),
                    vector_step, s, mem, rows, active)
            else:
                s2, mem2 = lax.cond(
                    _sweep_conflict(*lane_intervals(s, rows, active)),
                    lambda s_, m_, r_, a_: serial_step(s_, m_, r_, homes,
                                                       a_),
                    vector_step, s, mem, rows, active)
            return s2, mem2

        def cond(carry):
            s, _ = carry
            return jnp.any(live_mask(s))

        init = ReqState(
            pc=pc0, regs=regs0,
            lstack=jnp.zeros((B, depth, 3), jnp.int64),
            lsp=jnp.zeros(B, jnp.int64),
            inflight=jnp.zeros(B, jnp.int64), halted=jnp.zeros(B, bool),
            ret=jnp.zeros(B, jnp.int64),
            status=jnp.full(B, isa.STATUS_FELL_OFF, jnp.int64),
            steps=jnp.zeros(B, jnp.int64),
            ctrl=jnp.zeros(B, jnp.int64), pc_new=jnp.zeros(B, jnp.int64),
            fault=jnp.tile(jnp.asarray(NO_FAULT), (B, 1)))

        final, mem_f = lax.while_loop(cond, step, (init, mem))
        status = jnp.where(
            final.halted, final.status,
            jnp.where(final.steps >= fuel_arr, _i64(isa.STATUS_FUEL),
                      _i64(isa.STATUS_FELL_OFF)))
        return VMResult(mem=mem_f, ret=final.ret, status=status,
                        steps=final.steps, regs=final.regs,
                        fault=final.fault)

    return jax.jit(run)


def _build_sharded_engine(codes: Sequence[np.ndarray], fuels: Sequence[int],
                          regions: RegionTable, n_devices: int,
                          batch_per_device: int, axis: str = "pool",
                          protect: bool = True,
                          static_noconflict: bool = False):
    """Build the mesh-sharded lockstep engine: the pool's leading
    ``n_devices`` axis is sharded over a 1-D device mesh (``shard_map``),
    each device executes the home-bucketed sub-wave it owns, and remote
    LOAD/MEMCPY/STORE traffic lowers to collectives across the mesh axis
    (``all_gather`` the requests, owning shards answer, ``psum`` routes
    the words back — see :class:`_ShardOps`).

    Semantics are *identical* to the dense mixed engine run over the
    same wave in arrival order: macro-steps stay in lockstep across the
    mesh (the driver condition and the conflict predicate are globally
    agreed each step), conflict-free steps vectorize per device, and a
    contended macro-step falls back to a replicated serialized scan in
    **global arrival order** — the home-bucketed wave order is not the
    arrival order, so each lane carries its arrival rank and the
    fallback sorts by it.  That is what lets deterministic round-robin
    STORE/CAS contention survive sharding bit-for-bit.

    Returns jit-compiled
    ``f(mem, params, homes, failed, op_sel, arrival) -> VMResult`` with
    device-major fields: ``mem`` is ``(n_devices, pool_words)``,
    ``ret/status/steps`` are ``(n_devices, batch_per_device)`` and
    ``regs`` is ``(n_devices, batch_per_device, 16)``.  Lanes with
    ``op_sel < 0`` are padding (sub-waves are ragged) and start halted.
    Call under ``vm.x64()`` (or use :func:`invoke_sharded_mixed`).
    """
    from jax.sharding import PartitionSpec as _P

    from repro import jaxcompat

    code_np, start_np, end_np, fuel_np, max_window = \
        _program_statics(codes, fuels)
    n_ops = int(fuel_np.shape[0])
    n_instr = int(code_np.shape[0])
    base_np, mask_np, _ = regions.as_arrays()
    n_regions = int(base_np.shape[0])
    n_dev = int(n_devices)
    Bp = int(batch_per_device)
    N = n_dev * Bp
    depth = isa.LOOP_STACK_DEPTH
    mesh = jaxcompat.make_device_mesh(n_dev, axis)

    def device_body(mem, params, homes, failed, op_sel, arrival):
        # per-device shards: mem (1, P); params (1, Bp, w); homes /
        # op_sel / arrival (1, Bp); failed (n_devices,) replicated
        me = lax.axis_index(axis)
        code = jnp.asarray(code_np)
        base_c = jnp.asarray(base_np)
        mask_c = jnp.asarray(mask_np)
        shard = jnp.asarray(mem, jnp.int64)[0]
        pool_words = shard.shape[0]
        homes_l = jnp.asarray(homes, jnp.int64).reshape(Bp)
        failed = jnp.asarray(failed, jnp.bool_)
        op_sel_l = jnp.asarray(op_sel, jnp.int64).reshape(Bp)
        arrival_l = jnp.asarray(arrival, jnp.int64).reshape(Bp)
        pad = op_sel_l < 0
        sel = jnp.clip(op_sel_l, 0, n_ops - 1)
        pc0 = jnp.asarray(start_np)[sel]
        end_arr = jnp.asarray(end_np)[sel]
        fuel_arr = jnp.asarray(fuel_np)[sel]
        params_l = jnp.asarray(params, jnp.int64).reshape(Bp, -1)
        regs0 = jnp.zeros((Bp, isa.NUM_REGS), jnp.int64)
        if params_l.shape[1]:
            regs0 = lax.dynamic_update_slice(regs0, params_l, (0, 0))

        ops = _ShardOps(n_dev, int(pool_words), axis, me)
        step_one = _make_scalar_step(
            base_c=base_c, mask_c=mask_c, failed=failed, n_dev=n_dev,
            max_window=max_window, depth=depth, ops=ops, protect=protect)
        vector_step, lane_intervals = _make_vector_step(
            base_c=base_c, mask_c=mask_c, n_regions=n_regions,
            n_dev=n_dev, pool_words=int(pool_words),
            max_window=max_window, depth=depth, B=Bp, homes=homes_l,
            failed=failed, ops=ops, protect=protect)

        def gather(x):
            return lax.all_gather(x, axis).reshape((N,) + x.shape[1:])

        def serial_macro(s, mem, rows, active):
            # Contended macro-step: replicate the whole wave's state on
            # every device and serialize in GLOBAL ARRIVAL order (the
            # home-bucketed wave order is not arrival order).  Register
            # state stays replicated through the scan — reads are
            # psum-routed, so every device computes identical values —
            # and each device applies only its own shard's writes.
            s_all = jax.tree_util.tree_map(gather, s)
            rows_all = gather(rows)
            act_all = gather(active)
            homes_all = gather(homes_l)
            perm = jnp.argsort(gather(arrival_l))

            s_p = jax.tree_util.tree_map(lambda x: x[perm], s_all)

            def body(mem, x):
                s1, row, home, act = x
                s2, mem2 = step_one(s1, mem, row, home, act)
                return mem2, s2

            mem2, s_scan = lax.scan(
                body, mem,
                (s_p, rows_all[perm], homes_all[perm], act_all[perm]))

            def unperm(y):
                return jnp.zeros_like(y).at[perm].set(y)

            s_out = jax.tree_util.tree_map(unperm, s_scan)
            s_mine = jax.tree_util.tree_map(
                lambda x: lax.dynamic_slice_in_dim(x, me * Bp, Bp, 0),
                s_out)
            return s_mine, mem2

        def live_mask(s: ReqState):
            return (~s.halted) & (s.pc < end_arr) & (s.steps < fuel_arr)

        def step(carry):
            s, mem = carry
            active = live_mask(s)
            rows = code[jnp.clip(s.pc, 0, n_instr - 1)]
            if static_noconflict:
                # statically proven conflict-free: skip both the
                # footprint all_gather (a collective per macro-step)
                # and the sweep.  The cond + serial branch stay (same
                # reason as the dense engine: the XLA CPU backend keeps
                # cond branches outlined, and inlining vector_step into
                # the while body compiles measurably worse); the
                # predicate is device-local and identically false on
                # every shard, so the branch-agreement requirement for
                # the collectives inside serial_macro still holds
                return lax.cond(jnp.any(s.pc < -1), serial_macro,
                                vector_step, s, mem, rows, active)
            # conflict existence is a GLOBAL question: gather every
            # device's footprint intervals before the sweep, so all
            # devices agree on the branch (divergence would deadlock
            # the collectives inside)
            iv = lax.all_gather(
                jnp.stack(lane_intervals(s, rows, active)), axis)
            m = jnp.moveaxis(iv, 1, 0).reshape(4, -1)
            conflict = _sweep_conflict(m[0], m[1], m[2], m[3])
            return lax.cond(conflict, serial_macro, vector_step,
                            s, mem, rows, active)

        def cond(carry):
            s, _ = carry
            live = jnp.any(live_mask(s)).astype(jnp.int32)
            return lax.psum(live, axis) > 0

        init = ReqState(
            pc=pc0, regs=regs0,
            lstack=jnp.zeros((Bp, depth, 3), jnp.int64),
            lsp=jnp.zeros(Bp, jnp.int64),
            inflight=jnp.zeros(Bp, jnp.int64),
            halted=pad,                       # padding lanes never run
            ret=jnp.zeros(Bp, jnp.int64),
            status=jnp.full(Bp, isa.STATUS_FELL_OFF, jnp.int64),
            steps=jnp.zeros(Bp, jnp.int64),
            ctrl=jnp.zeros(Bp, jnp.int64),
            pc_new=jnp.zeros(Bp, jnp.int64),
            fault=jnp.tile(jnp.asarray(NO_FAULT), (Bp, 1)))

        final, mem_f = lax.while_loop(cond, step, (init, shard))
        status = jnp.where(
            final.halted, final.status,
            jnp.where(final.steps >= fuel_arr, _i64(isa.STATUS_FUEL),
                      _i64(isa.STATUS_FELL_OFF)))
        return VMResult(mem=mem_f[None, :], ret=final.ret[None],
                        status=status[None], steps=final.steps[None],
                        regs=final.regs[None], fault=final.fault[None])

    sharded = jaxcompat.shard_map(
        device_body, mesh,
        in_specs=(_P(axis, None), _P(axis, None, None), _P(axis, None),
                  _P(None), _P(axis, None), _P(axis, None)),
        out_specs=VMResult(mem=_P(axis, None), ret=_P(axis, None),
                           status=_P(axis, None), steps=_P(axis, None),
                           regs=_P(axis, None, None),
                           fault=_P(axis, None, None)))
    return jax.jit(sharded)


def build_batched_vm(op: VerifiedOperator, regions: RegionTable,
                     n_devices: int, batch: int, protect: bool = True,
                     static_noconflict: bool = False):
    """Returns jit-compiled ``f(mem, params, homes, failed) -> VMResult`` —
    the one-program specialization of :func:`_build_engine` (its merged
    store holds a single program and every request dispatches to slot 0).
    Call under ``vm.x64()`` (or use :func:`invoke` / :func:`invoke_batched`).
    """
    eng = _build_engine([op.code], [op.step_bound], regions, n_devices,
                        batch, protect=protect,
                        static_noconflict=static_noconflict)
    sel0 = np.zeros(int(batch), dtype=np.int64)

    def run(mem, params, homes, failed):
        return eng(mem, params, homes, failed, sel0)

    return run


def build_mixed_batched_vm(ops: Sequence[VerifiedOperator],
                           regions: RegionTable, n_devices: int,
                           batch: int, protect: bool = True,
                           static_noconflict: bool = False):
    """The multi-tenant engine: one lockstep launch executing a batch of
    requests whose per-request ``op_sel`` picks among the ``ops`` programs
    (laid out back to back like the registry's instruction store, so
    ``op_sel`` is exactly the registry ``op_id`` when ``ops`` lists every
    slot in op_id order).  Returns jit-compiled
    ``f(mem, params, homes, failed, op_sel) -> VMResult``."""
    return _build_engine([o.code for o in ops],
                         [o.step_bound for o in ops],
                         regions, n_devices, batch, protect=protect,
                         static_noconflict=static_noconflict)


def build_sharded_mixed_vm(ops: Sequence[VerifiedOperator],
                           regions: RegionTable, n_devices: int,
                           batch_per_device: int, axis: str = "pool",
                           protect: bool = True,
                           static_noconflict: bool = False):
    """The pod-scale engine: the pool's leading axis sharded over a 1-D
    device mesh, one home-bucketed sub-wave per device, cross-device
    LOAD/MEMCPY lowered to collectives (see :func:`_build_sharded_engine`
    for the semantics contract).  Returns jit-compiled
    ``f(mem, params, homes, failed, op_sel, arrival) -> VMResult`` with
    device-major ``(n_devices, batch_per_device)`` result fields."""
    return _build_sharded_engine([o.code for o in ops],
                                 [o.step_bound for o in ops],
                                 regions, n_devices, batch_per_device,
                                 axis, protect=protect,
                                 static_noconflict=static_noconflict)


def build_vm(op: VerifiedOperator, regions: RegionTable, n_devices: int,
             protect: bool = True):
    """Single-request entry point: ``f(mem, params, home, failed)`` —
    the ``batch=1`` specialization of :func:`build_batched_vm` with scalar
    result fields, kept for every existing caller."""
    batched = build_batched_vm(op, regions, n_devices, batch=1,
                               protect=protect)

    def run(mem, params, home, failed):
        params = jnp.asarray(params, jnp.int64).reshape(1, -1)
        homes = jnp.asarray(home, jnp.int64).reshape(1)
        out = batched(mem, params, homes, failed)
        return VMResult(mem=out.mem, ret=out.ret[0], status=out.status[0],
                        steps=out.steps[0], regs=out.regs[0],
                        fault=out.fault[0])

    return run


# Serializing a program's code for its cache key costs tobytes() over the
# whole instruction array; a registry hot path keys the full merged store
# per wave, so memoize per live VerifiedOperator.  Keyed by id() but
# guarded by a weakref identity check — recycled ids miss and recompute,
# and dead entries are purged on the weakref callback.
_CODE_BYTES_MEMO: Dict[int, Tuple[object, bytes]] = {}


def _code_bytes(op: VerifiedOperator) -> bytes:
    ent = _CODE_BYTES_MEMO.get(id(op))
    if ent is not None and ent[0]() is op:
        return ent[1]
    key = id(op)
    b = op.code.tobytes()
    _CODE_BYTES_MEMO[key] = (
        weakref.ref(op, lambda _: _CODE_BYTES_MEMO.pop(key, None)), b)
    return b


def engine_key(op: VerifiedOperator, regions: RegionTable, n_dev: int,
               batch: int, *extra) -> Tuple:
    """Content-addressed cache key for a built engine (object ids recycle
    after GC — never key on id).  Shared with the compiled-path cache."""
    base, mask, _ = regions.as_arrays()
    return (_code_bytes(op), base.tobytes(), mask.tobytes(),
            op.step_bound, n_dev, batch) + extra


def mixed_engine_key(ops: Sequence[VerifiedOperator], regions: RegionTable,
                     n_dev: int, batch: int, *extra) -> Tuple:
    """Content-addressed cache key for a mixed (multi-program) engine."""
    base, mask, _ = regions.as_arrays()
    return (tuple((_code_bytes(o), int(o.step_bound)) for o in ops),
            base.tobytes(), mask.tobytes(), n_dev, batch) + extra


# Engines are cached per (operator, regions, n_devices, batch): a serving
# loop should pad request waves to a few fixed batch sizes (e.g. powers of
# two) so the cache stays small — every new B is a fresh XLA compile.
_VM_CACHE: Dict[Tuple, object] = {}


def engine_cached(op: VerifiedOperator, regions: RegionTable, n_dev: int,
                  batch: int, protect: bool = True,
                  static_noconflict: bool = False) -> bool:
    """True iff the batched interpreter engine for this (op, batch) is
    already built — a cache miss costs an XLA compile, which the
    dispatch cost model charges for."""
    return engine_key(op, regions, n_dev, batch, bool(protect),
                      bool(static_noconflict)) in _VM_CACHE


def mixed_engine_cached(ops: Sequence[VerifiedOperator],
                        regions: RegionTable, n_dev: int,
                        batch: int, protect: bool = True,
                        static_noconflict: bool = False) -> bool:
    return mixed_engine_key(ops, regions, n_dev, batch, bool(protect),
                            bool(static_noconflict)) in _VM_CACHE


def _cached_engine(op: VerifiedOperator, regions: RegionTable, n_dev: int,
                   batch: int, protect: bool = True,
                   static_noconflict: bool = False):
    key = engine_key(op, regions, n_dev, batch, bool(protect),
                     bool(static_noconflict))
    fn = _VM_CACHE.get(key)
    if fn is None:
        fn = build_batched_vm(op, regions, n_dev, batch, protect=protect,
                              static_noconflict=static_noconflict)
        _VM_CACHE[key] = fn
    return fn


def _cached_mixed_engine(ops: Sequence[VerifiedOperator],
                         regions: RegionTable, n_dev: int, batch: int,
                         protect: bool = True,
                         static_noconflict: bool = False):
    key = mixed_engine_key(ops, regions, n_dev, batch, bool(protect),
                           bool(static_noconflict))
    fn = _VM_CACHE.get(key)
    if fn is None:
        fn = build_mixed_batched_vm(ops, regions, n_dev, batch,
                                    protect=protect,
                                    static_noconflict=static_noconflict)
        _VM_CACHE[key] = fn
    return fn


def _sharded_engine_key(ops: Sequence[VerifiedOperator],
                        regions: RegionTable, n_dev: int,
                        batch_per_device: int, axis: str,
                        protect: bool = True,
                        static_noconflict: bool = False) -> Tuple:
    import jax as _jax
    dev_ids = tuple(d.id for d in _jax.devices()[:n_dev])
    return mixed_engine_key(ops, regions, n_dev, batch_per_device,
                            "sharded", axis, dev_ids, bool(protect),
                            bool(static_noconflict))


def sharded_engine_cached(ops: Sequence[VerifiedOperator],
                          regions: RegionTable, n_dev: int,
                          batch_per_device: int,
                          axis: str = "pool",
                          protect: bool = True,
                          static_noconflict: bool = False) -> bool:
    """True iff the sharded mesh engine for this (ops, sub-wave size) is
    already built — a miss costs an XLA compile of the whole shard_map
    program, which the dispatch cost model charges for."""
    return _sharded_engine_key(ops, regions, n_dev, batch_per_device,
                               axis, protect, static_noconflict) in _VM_CACHE


def _cached_sharded_engine(ops: Sequence[VerifiedOperator],
                           regions: RegionTable, n_dev: int,
                           batch_per_device: int, axis: str = "pool",
                           protect: bool = True,
                           static_noconflict: bool = False):
    key = _sharded_engine_key(ops, regions, n_dev, batch_per_device, axis,
                              protect, static_noconflict)
    fn = _VM_CACHE.get(key)
    if fn is None:
        fn = build_sharded_mixed_vm(ops, regions, n_dev, batch_per_device,
                                    axis, protect=protect,
                                    static_noconflict=static_noconflict)
        _VM_CACHE[key] = fn
    return fn


def run_batched_fn(fn, mem: np.ndarray, p: np.ndarray, h: np.ndarray,
                   failed: Optional[Set[int]], *,
                   block: bool = True) -> "BatchedInvokeResult":
    """Execute a built batched engine: numpy in, numpy out, x64 handled.
    Shared by the interpreter and compiled wrappers.

    With ``block=False`` the result fields are left as device arrays —
    XLA's async dispatch keeps computing while the caller goes on
    posting more work (the endpoint's split-phase doorbell); call
    :func:`materialize_result` to retire them to numpy.  The launch
    itself (tracing, validation, cache lookup) still happens eagerly,
    so malformed waves raise here either way."""
    n_dev = int(mem.shape[0])
    with x64():
        out = fn(jnp.asarray(mem, jnp.int64), jnp.asarray(p),
                 jnp.asarray(h), jnp.asarray(_failed_mask(n_dev, failed)))
        if block:
            out = jax.tree_util.tree_map(np.asarray, out)
    return BatchedInvokeResult(mem=out.mem, ret=out.ret, status=out.status,
                               steps=out.steps, regs=out.regs,
                               fault=out.fault)


def materialize_result(res: "BatchedInvokeResult") -> "BatchedInvokeResult":
    """Retire a (possibly deferred) batched result to host numpy arrays.
    Blocks until the launch that produced it completes; a no-op on an
    already-materialized result."""
    return BatchedInvokeResult(
        mem=np.asarray(res.mem), ret=np.asarray(res.ret),
        status=np.asarray(res.status), steps=np.asarray(res.steps),
        regs=np.asarray(res.regs), fault=np.asarray(res.fault))


def result_ready(res: "BatchedInvokeResult") -> bool:
    """Non-blocking readiness probe of a deferred batched result: True
    once every field's device computation has landed (numpy fields are
    trivially ready; jax arrays without ``is_ready`` report ready and
    the subsequent materialization simply blocks)."""
    for f in (res.mem, res.ret, res.status, res.steps, res.regs,
              res.fault):
        probe = getattr(f, "is_ready", None)
        if probe is not None and not probe():
            return False
    return True


def _wrap_param(v) -> np.int64:
    return np.int64(np.uint64(v & (2**64 - 1)).astype(np.uint64)
                    .view(np.int64)) \
        if v > 2**63 - 1 or v < -2**63 else np.int64(v)


def _failed_mask(n_dev: int, failed: Optional[Set[int]]) -> np.ndarray:
    m = np.zeros(n_dev, dtype=bool)
    for f in (failed or ()):
        m[f] = True
    return m


def homes_array(homes: Union[int, Sequence[int]],
                batch: int) -> np.ndarray:
    """Normalize a ``homes`` argument (scalar broadcast or per-request
    sequence) to i64[batch] — the one place that marshalling lives."""
    h = np.full(batch, homes, dtype=np.int64) if np.isscalar(homes) \
        else np.asarray(list(homes), dtype=np.int64)
    if h.shape != (batch,):
        raise ValueError(f"homes shape {h.shape} != ({batch},)")
    return h


def _marshal_batch(params: Sequence[Sequence[int]],
                   homes: Union[int, Sequence[int]]
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Validate and pack a request batch: params -> i64[B, width],
    homes -> i64[B].  Shared by the interpreter and compiled wrappers."""
    batch = len(params)
    if batch == 0:
        raise ValueError("empty request batch")
    width = max(max((len(row) for row in params), default=0), 1)
    p = np.zeros((batch, width), dtype=np.int64)
    for b, row in enumerate(params):
        for i, v in enumerate(row):
            p[b, i] = _wrap_param(v)
    return p, homes_array(homes, batch)


def invoke(op: VerifiedOperator, regions: RegionTable, mem: np.ndarray,
           params: Sequence[int] = (), *, home: int = 0,
           failed: Optional[Set[int]] = None,
           protect: bool = True) -> "InvokeResult":
    """Convenience entry point: numpy in, numpy out, x64 handled."""
    n_dev = int(mem.shape[0])
    with x64():
        fn = _cached_engine(op, regions, n_dev, batch=1, protect=protect)
        p = np.zeros((1, max(len(params), 1)), dtype=np.int64)
        for i, v in enumerate(params):
            p[0, i] = _wrap_param(v)
        out = fn(jnp.asarray(mem, jnp.int64), jnp.asarray(p),
                 jnp.asarray([home], jnp.int64),
                 jnp.asarray(_failed_mask(n_dev, failed)))
        out = jax.tree_util.tree_map(np.asarray, out)
    return InvokeResult(mem=out.mem, ret=int(out.ret[0]),
                        status=int(out.status[0]), steps=int(out.steps[0]),
                        regs=out.regs[0], fault=fault_info(out.fault[0]))


def invoke_batched(op: VerifiedOperator, regions: RegionTable,
                   mem: np.ndarray, params: Sequence[Sequence[int]],
                   *, homes: Union[int, Sequence[int]] = 0,
                   failed: Optional[Set[int]] = None,
                   block: bool = True,
                   protect: bool = True,
                   static_noconflict: bool = False) -> "BatchedInvokeResult":
    """Run a batch of requests against one shared pool: numpy in/out.

    ``params`` is a [B][k] nested sequence (one row per request); ``homes``
    is a scalar (all requests from the same host) or a [B] sequence.
    ``block=False`` defers retirement (see :func:`run_batched_fn`).
    ``static_noconflict=True`` asserts the caller holds a registration-time
    proof that the wave is conflict-free; the engine then skips the
    per-step runtime sweep (see :func:`_build_engine`).
    """
    p, h = _marshal_batch(params, homes)
    fn = _cached_engine(op, regions, int(mem.shape[0]), p.shape[0],
                        protect=protect,
                        static_noconflict=static_noconflict)
    return run_batched_fn(fn, mem, p, h, failed, block=block)


def invoke_batched_mixed(ops: Sequence[VerifiedOperator],
                         regions: RegionTable, mem: np.ndarray,
                         op_sel: Sequence[int],
                         params: Sequence[Sequence[int]], *,
                         homes: Union[int, Sequence[int]] = 0,
                         failed: Optional[Set[int]] = None,
                         block: bool = True,
                         protect: bool = True,
                         static_noconflict: bool = False
                         ) -> "BatchedInvokeResult":
    """Run a *mixed* batch — request ``b`` executes ``ops[op_sel[b]]`` —
    against one shared pool in one lockstep launch: numpy in/out.

    Semantics are the engine's deterministic round-robin interleaving
    across programs: each macro-step, request ``i`` executes the next
    instruction *of its own operator* and observes all same-step memory
    effects of requests ``j < i``.  ``block=False`` defers retirement
    (see :func:`run_batched_fn`).  ``static_noconflict=True`` asserts a
    registration-time proof that the wave is conflict-free; the engine
    then skips the per-step runtime sweep (see :func:`_build_engine`).
    """
    p, h = _marshal_batch(params, homes)
    B = p.shape[0]
    sel = np.asarray(list(op_sel), dtype=np.int64)
    if sel.shape != (B,):
        raise ValueError(f"op_sel shape {sel.shape} != ({B},)")
    if sel.size and (sel.min() < 0 or sel.max() >= len(ops)):
        raise ValueError(
            f"op_sel entries must be in [0, {len(ops)}) for {len(ops)} "
            f"programs; got range [{sel.min()}, {sel.max()}]")
    eng = _cached_mixed_engine(tuple(ops), regions, int(mem.shape[0]), B,
                               protect=protect,
                               static_noconflict=static_noconflict)

    def fn(mem_j, p_j, h_j, failed_j):
        return eng(mem_j, p_j, h_j, failed_j, sel)

    return run_batched_fn(fn, mem, p, h, failed, block=block)


def invoke_sharded_mixed(ops: Sequence[VerifiedOperator],
                         regions: RegionTable, mem: np.ndarray,
                         plan, params: Sequence[Sequence[int]], *,
                         failed: Optional[Set[int]] = None,
                         axis: str = "pool",
                         protect: bool = True,
                         static_noconflict: bool = False
                         ) -> "BatchedInvokeResult":
    """Run a mixed wave on the mesh-sharded engine: numpy in/out.
    ``static_noconflict=True`` asserts a registration-time conflict proof;
    the sharded engine then skips both the per-step footprint all_gather
    and the sweep (see :func:`_build_sharded_engine`).

    ``plan`` is a home-bucketed :class:`~repro.core.compile.MixedPlan`
    (built with ``plan_mixed_batch(op_ids, homes=..., n_devices=...)``):
    its ``order`` lays the wave out device-major, each device's ragged
    sub-wave is padded to ``plan.batch_per_device`` lanes, and results
    scatter back to arrival order through the same permutation.  The
    result is bit-identical to :func:`invoke_batched_mixed` over the
    arrival-order wave (contended STORE/CAS included — the engine's
    serialized fallback sorts by arrival rank)."""
    if getattr(plan, "device_counts", None) is None:
        raise ValueError(
            "plan carries no device placement; build it with "
            "plan_mixed_batch(op_ids, homes=..., n_devices=...)")
    n_dev = int(mem.shape[0])
    if plan.n_devices != n_dev:
        raise ValueError(
            f"plan places {plan.n_devices} devices but the pool has "
            f"{n_dev} rows")
    p, h = _marshal_batch(params, plan.homes)
    B = plan.batch
    if p.shape[0] != B:
        raise ValueError(f"{p.shape[0]} param rows for a {B}-request plan")
    Bp = int(plan.batch_per_device)
    width = p.shape[1]
    # device-major marshal: plan.order is home-bucketed, so device d's
    # sub-wave is one contiguous slice of the sorted batch; pad lanes
    # carry op_sel = -1 (start halted) and arrival ranks past the wave
    sel = np.full((n_dev, Bp), -1, dtype=np.int64)
    pz = np.zeros((n_dev, Bp, width), dtype=np.int64)
    hz = np.zeros((n_dev, Bp), dtype=np.int64)
    az = np.full((n_dev, Bp), B, dtype=np.int64)
    pos = 0
    for d in range(n_dev):
        c = int(plan.device_counts[d])
        lanes = plan.order[pos:pos + c]
        sel[d, :c] = plan.op_ids[lanes]
        pz[d, :c] = p[lanes]
        hz[d, :c] = h[lanes]
        hz[d, c:] = d
        az[d, :c] = lanes            # arrival rank = arrival index
        pos += c
    eng = _cached_sharded_engine(tuple(ops), regions, n_dev, Bp, axis,
                                 protect=protect,
                                 static_noconflict=static_noconflict)
    from repro.core import memory as _memory
    with x64():
        mem_dev = _memory.shard_pool(np.asarray(mem, dtype=np.int64),
                                     axis=axis) \
            if n_dev > 1 else jnp.asarray(mem, jnp.int64)
        out = eng(mem_dev, jnp.asarray(pz), jnp.asarray(hz),
                  jnp.asarray(_failed_mask(n_dev, failed)),
                  jnp.asarray(sel), jnp.asarray(az))
        out = jax.tree_util.tree_map(np.asarray, out)
    ret = np.zeros(B, dtype=np.int64)
    status = np.zeros(B, dtype=np.int64)
    steps = np.zeros(B, dtype=np.int64)
    regs = np.zeros((B, isa.NUM_REGS), dtype=np.int64)
    fault = np.tile(NO_FAULT, (B, 1))
    pos = 0
    for d in range(n_dev):
        c = int(plan.device_counts[d])
        lanes = plan.order[pos:pos + c]
        ret[lanes] = out.ret[d, :c]
        status[lanes] = out.status[d, :c]
        steps[lanes] = out.steps[d, :c]
        regs[lanes] = out.regs[d, :c]
        fault[lanes] = out.fault[d, :c]
        pos += c
    return BatchedInvokeResult(mem=out.mem, ret=ret, status=status,
                               steps=steps, regs=regs, fault=fault)


@dataclasses.dataclass
class InvokeResult:
    mem: np.ndarray
    ret: int
    status: int
    steps: int
    regs: np.ndarray
    fault: Optional[isa.FaultInfo] = None

    @property
    def ok(self) -> bool:
        return self.status == isa.STATUS_OK


@dataclasses.dataclass
class BatchedInvokeResult:
    mem: np.ndarray
    ret: np.ndarray       # i64 [B]
    status: np.ndarray    # i64 [B]
    steps: np.ndarray     # i64 [B]
    regs: np.ndarray      # i64 [B, 16]
    fault: Optional[np.ndarray] = None   # i64 [B, 4] FaultInfo rows

    @property
    def ok(self) -> np.ndarray:
        return self.status == isa.STATUS_OK

    def fault_at(self, b: int) -> Optional[isa.FaultInfo]:
        """The decoded FaultInfo of lane ``b`` (None when clean)."""
        if self.fault is None:
            return None
        return fault_info(np.asarray(self.fault)[b])
