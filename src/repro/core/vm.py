"""JAX execution engine for verified Tiara operators.

One memory processor (MP) is modeled as a ``lax.while_loop`` whose carry is
the architectural state of the paper's Fig. 4 datapath — pc, the 16x64 b
register file, the depth-8 loop stack, the in-flight async counter — plus
the memory pool itself.  Each step decodes ``code[pc]`` (the program is a
compile-time constant: the "BRAM instruction store") and dispatches through
``lax.switch``.

The *verified step bound* is the loop fuel: registration-time verification
proves the VM can never hit it, and the property tests assert exactly that.

Semantics are defined by ``repro.core.pyvm`` — keep the two in lockstep.
All ISA values are int64; because x64 mode is not enabled globally (model
code runs in default 32-bit mode), every entry point here wraps execution
in a local x64 configuration context.
"""

from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Dict, NamedTuple, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import isa
from repro.core.isa import (Alu, Op, FLAG_ASYNC, FLAG_DEV_REG,
                            FLAG_DSTDEV_REG, FLAG_IMMB, FLAG_LEN_REG,
                            FLAG_MREG, FLAG_SRCDEV_REG, FLAG_THR_REG,
                            DEV_LOCAL, ERR_REG)
from repro.core.memory import RegionTable
from repro.core.verifier import VerifiedOperator

_REG_MASK = isa.NUM_REGS - 1


@contextlib.contextmanager
def x64():
    """Locally enable 64-bit mode (the ISA is 64-bit; models stay 32-bit)."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


class VMState(NamedTuple):
    pc: jnp.ndarray          # i64 scalar
    regs: jnp.ndarray        # i64[16]
    lstack: jnp.ndarray      # i64[8, 3]  (start, end, remaining)
    lsp: jnp.ndarray         # i64 scalar
    inflight: jnp.ndarray    # i64 scalar
    mem: jnp.ndarray         # i64[n_dev, pool_words]
    halted: jnp.ndarray      # bool
    ret: jnp.ndarray         # i64
    status: jnp.ndarray      # i64
    steps: jnp.ndarray       # i64
    ctrl: jnp.ndarray        # i64: 0 = advance (loop-iterate check), 1 = taken jump (pop)
    pc_new: jnp.ndarray      # i64


class VMResult(NamedTuple):
    mem: jnp.ndarray
    ret: jnp.ndarray
    status: jnp.ndarray
    steps: jnp.ndarray
    regs: jnp.ndarray


def _i64(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.int64)


def build_vm(op: VerifiedOperator, regions: RegionTable, n_devices: int):
    """Returns a jit-compiled ``f(mem, params, home, failed) -> VMResult``.

    ``mem``: int64[n_devices, pool_words]; ``params``: int64[<=8];
    ``home``: the executing host's device id; ``failed``: bool[n_devices]
    marking unreachable hosts (async Memcpy to them sets the error flag).
    Call under ``vm.x64()`` (or use :func:`invoke`).
    """
    code_np = np.asarray(op.code, dtype=np.int64)
    n_instr = int(code_np.shape[0])
    fuel = int(op.step_bound)
    base_np, mask_np, _ = regions.as_arrays()
    # Static memcpy window: the largest cap used by this program.
    memcpy_caps = [int(r[isa.F_IMM]) for r in code_np
                   if int(r[isa.F_OP]) == int(Op.MEMCPY)]
    max_window = int(min(max(memcpy_caps, default=1), isa.MAX_MEMCPY_WORDS))
    n_dev = int(n_devices)

    def run(mem, params, home, failed):
        code = jnp.asarray(code_np)
        base_c = jnp.asarray(base_np)
        mask_c = jnp.asarray(mask_np)
        home = _i64(home)
        mem = jnp.asarray(mem, jnp.int64)
        failed = jnp.asarray(failed, jnp.bool_)

        regs0 = jnp.zeros(isa.NUM_REGS, jnp.int64)
        params = jnp.asarray(params, jnp.int64).reshape(-1)
        regs0 = lax.dynamic_update_slice(regs0, params, (0,)) \
            if params.shape[0] else regs0

        def dev_of(s: VMState, field, via_reg):
            dreg = s.regs[field & _REG_MASK]
            d = jnp.where(via_reg, dreg, field)
            return jnp.where(d == DEV_LOCAL, home, jnp.mod(d, n_dev))

        def phys(rid, off):
            return base_c[rid] + (off & mask_c[rid])

        def alu_eval(aop, a, b):
            sh = b & 63
            vals = [
                a + b, a - b, a * b, a & b, a | b, a ^ b,
                a << sh, lax.shift_right_logical(a, sh),
                (a == b).astype(jnp.int64), (a != b).astype(jnp.int64),
                (a < b).astype(jnp.int64), (a >= b).astype(jnp.int64),
                jnp.minimum(a, b), jnp.maximum(a, b), a, a,
            ]
            return jnp.stack(vals)[jnp.clip(aop, 0, 15)]

        def advance(s: VMState, **kw) -> VMState:
            return s._replace(ctrl=_i64(0), pc_new=s.pc + 1, **kw)

        # --- one branch per opcode ------------------------------------
        def br_nop(s, row):
            return advance(s)

        def br_movi(s, row):
            return advance(s, regs=s.regs.at[row[isa.F_DST] & _REG_MASK]
                           .set(row[isa.F_IMM]))

        def br_alu(s, row):
            rhs = jnp.where(row[isa.F_FLAGS] & FLAG_IMMB, row[isa.F_IMM],
                            s.regs[row[isa.F_B] & _REG_MASK])
            val = alu_eval(row[isa.F_D], s.regs[row[isa.F_A] & _REG_MASK], rhs)
            return advance(s, regs=s.regs.at[row[isa.F_DST] & _REG_MASK].set(val))

        def br_load(s, row):
            dev = dev_of(s, row[isa.F_E],
                         (row[isa.F_FLAGS] & FLAG_DEV_REG) != 0)
            addr = phys(row[isa.F_A],
                        s.regs[row[isa.F_B] & _REG_MASK] + row[isa.F_IMM])
            val = s.mem[dev, addr]
            return advance(s, regs=s.regs.at[row[isa.F_DST] & _REG_MASK].set(val))

        def br_store(s, row):
            dev = dev_of(s, row[isa.F_E],
                         (row[isa.F_FLAGS] & FLAG_DEV_REG) != 0)
            addr = phys(row[isa.F_A],
                        s.regs[row[isa.F_B] & _REG_MASK] + row[isa.F_IMM])
            val = s.regs[row[isa.F_DST] & _REG_MASK]
            return advance(s, mem=s.mem.at[dev, addr].set(val))

        def br_memcpy(s, row):
            flags = row[isa.F_FLAGS]
            ddev = dev_of(s, row[isa.F_DST], (flags & FLAG_DSTDEV_REG) != 0)
            sdev = dev_of(s, row[isa.F_C], (flags & FLAG_SRCDEV_REG) != 0)
            drid, srid = row[isa.F_A], row[isa.F_D]
            cap = row[isa.F_IMM]
            lnreg = s.regs[row[isa.F_IMM2] & _REG_MASK]
            ln = jnp.where(flags & FLAG_LEN_REG,
                           jnp.clip(lnreg, 0, cap), cap)
            ln = jnp.minimum(jnp.minimum(ln, mask_c[drid] + 1),
                             mask_c[srid] + 1)
            fail = failed[ddev] | failed[sdev]
            ln = jnp.where(fail, 0, ln)
            i = jnp.arange(max_window, dtype=jnp.int64)
            soff = s.regs[row[isa.F_E] & _REG_MASK]
            doff = s.regs[row[isa.F_B] & _REG_MASK]
            sphys = base_c[srid] + ((soff + i) & mask_c[srid])
            dphys = base_c[drid] + ((doff + i) & mask_c[drid])
            svals = s.mem[sdev, sphys]
            live = i < ln
            # Masked lanes all write the lane-0 value to the lane-0 slot so
            # duplicate scatter indices always carry identical values.
            val0 = jnp.where(ln > 0, svals[0], s.mem[ddev, dphys[0]])
            w_idx = jnp.where(live, dphys, dphys[0])
            w_val = jnp.where(live, svals, val0)
            mem = s.mem.at[ddev, w_idx].set(w_val)
            err = jnp.where(fail, s.regs[ERR_REG] | 1, s.regs[ERR_REG])
            regs = s.regs.at[ERR_REG].set(err)
            inflight = jnp.where(
                flags & FLAG_ASYNC,
                jnp.minimum(s.inflight + 1, isa.MAX_INFLIGHT), s.inflight)
            return advance(s, mem=mem, regs=regs, inflight=inflight)

        def _br_casa(s, row, is_cas):
            dev = dev_of(s, row[isa.F_E],
                         (row[isa.F_FLAGS] & FLAG_DEV_REG) != 0)
            addr = phys(row[isa.F_A],
                        s.regs[row[isa.F_B] & _REG_MASK] + row[isa.F_IMM])
            old = s.mem[dev, addr]
            hit = old == s.regs[row[isa.F_C] & _REG_MASK]
            swp = s.regs[row[isa.F_D] & _REG_MASK]
            new = jnp.where(hit, swp if is_cas else old + swp, old)
            return advance(
                s, mem=s.mem.at[dev, addr].set(new),
                regs=s.regs.at[row[isa.F_DST] & _REG_MASK].set(old))

        def br_cas(s, row):
            return _br_casa(s, row, True)

        def br_caa(s, row):
            return _br_casa(s, row, False)

        def br_jump(s, row):
            cond = row[isa.F_D]
            lhs = s.regs[row[isa.F_A] & _REG_MASK]
            rhs = jnp.where(row[isa.F_FLAGS] & FLAG_IMMB, row[isa.F_IMM],
                            s.regs[row[isa.F_B] & _REG_MASK])
            take = jnp.where(
                cond == int(Alu.ALWAYS), True,
                jnp.where(cond == int(Alu.EQ), lhs == rhs,
                          jnp.where(cond == int(Alu.NE), lhs != rhs,
                                    jnp.where(cond == int(Alu.LT), lhs < rhs,
                                              lhs >= rhs))))
            return s._replace(
                ctrl=jnp.where(take, _i64(1), _i64(0)),
                pc_new=jnp.where(take, s.pc + 1 + row[isa.F_IMM2], s.pc + 1))

        def br_loop(s, row):
            cap = row[isa.F_IMM]
            m = jnp.where(row[isa.F_FLAGS] & FLAG_MREG,
                          jnp.clip(s.regs[row[isa.F_B] & _REG_MASK], 0, cap),
                          cap)
            skip = m <= 0
            frame = jnp.stack([s.pc + 1, s.pc + row[isa.F_IMM2], m])
            sp = jnp.clip(s.lsp, 0, isa.LOOP_STACK_DEPTH - 1)
            pushed = s.lstack.at[sp].set(frame)
            return s._replace(
                lstack=jnp.where(skip, s.lstack, pushed),
                lsp=jnp.where(skip, s.lsp, s.lsp + 1),
                ctrl=_i64(0),
                pc_new=jnp.where(skip, s.pc + 1 + row[isa.F_IMM2], s.pc + 1))

        def br_wait(s, row):
            thr = jnp.where(row[isa.F_FLAGS] & FLAG_THR_REG,
                            s.regs[row[isa.F_A] & _REG_MASK], row[isa.F_IMM])
            return advance(s, inflight=jnp.minimum(
                s.inflight, jnp.maximum(thr, 0)))

        def br_ret(s, row):
            return advance(s, halted=jnp.asarray(True),
                           ret=s.regs[row[isa.F_A] & _REG_MASK],
                           status=row[isa.F_IMM])

        branches = [br_nop, br_movi, br_alu, br_load, br_store, br_memcpy,
                    br_cas, br_caa, br_jump, br_loop, br_wait, br_ret]

        # --- post-step loop bookkeeping --------------------------------
        def loop_fixup(s: VMState) -> VMState:
            # taken jump: pop every frame whose body the jump escaped
            def pop_cond(t):
                lsp, = t
                return (lsp > 0) & (s.lstack[jnp.maximum(lsp - 1, 0), 1]
                                    < s.pc_new)

            def pop_body(t):
                lsp, = t
                return (lsp - 1,)

            (pop_lsp,) = lax.while_loop(pop_cond, pop_body, (s.lsp,))

            # normal advance: iterate / pop frames whose body just ended
            def it_cond(t):
                stack, lsp, pcn, done = t
                top_end = stack[jnp.maximum(lsp - 1, 0), 1]
                return (~done) & (lsp > 0) & (pcn == top_end + 1)

            def it_body(t):
                stack, lsp, pcn, done = t
                idx = jnp.maximum(lsp - 1, 0)
                rem = stack[idx, 2] - 1
                cont = rem > 0
                stack2 = stack.at[idx, 2].set(rem)
                return (jnp.where(cont, stack2, stack),
                        jnp.where(cont, lsp, lsp - 1),
                        jnp.where(cont, stack[idx, 0], pcn),
                        cont)

            it_stack, it_lsp, it_pcn, _ = lax.while_loop(
                it_cond, it_body,
                (s.lstack, s.lsp, s.pc_new, jnp.asarray(False)))

            is_jump = s.ctrl == 1
            return s._replace(
                pc=jnp.where(is_jump, s.pc_new, it_pcn),
                lsp=jnp.where(is_jump, pop_lsp, it_lsp),
                lstack=jnp.where(is_jump, s.lstack, it_stack))

        def step(s: VMState) -> VMState:
            row = code[jnp.clip(s.pc, 0, n_instr - 1)]
            opc = jnp.clip(row[isa.F_OP], 0, len(branches) - 1).astype(jnp.int32)
            s2 = lax.switch(opc, branches, s, row)
            s2 = s2._replace(steps=s2.steps + 1)
            return lax.cond(s2.halted, lambda t: t, loop_fixup, s2)

        def cond(s: VMState):
            return (~s.halted) & (s.pc < n_instr) & (s.steps < fuel)

        init = VMState(
            pc=_i64(0), regs=regs0,
            lstack=jnp.zeros((isa.LOOP_STACK_DEPTH, 3), jnp.int64),
            lsp=_i64(0), inflight=_i64(0), mem=mem,
            halted=jnp.asarray(False), ret=_i64(0),
            status=_i64(isa.STATUS_FELL_OFF), steps=_i64(0),
            ctrl=_i64(0), pc_new=_i64(0))

        final = lax.while_loop(cond, step, init)
        status = jnp.where(
            final.halted, final.status,
            jnp.where(final.steps >= fuel, _i64(isa.STATUS_FUEL),
                      _i64(isa.STATUS_FELL_OFF)))
        return VMResult(mem=final.mem, ret=final.ret, status=status,
                        steps=final.steps, regs=final.regs)

    return jax.jit(run, static_argnames=())


_VM_CACHE: Dict[Tuple, object] = {}


def invoke(op: VerifiedOperator, regions: RegionTable, mem: np.ndarray,
           params: Sequence[int] = (), *, home: int = 0,
           failed: Optional[Set[int]] = None) -> "InvokeResult":
    """Convenience entry point: numpy in, numpy out, x64 handled."""
    n_dev = int(mem.shape[0])
    base, mask, _ = regions.as_arrays()
    # content-keyed cache (object ids recycle after GC — never key on id)
    key = (op.code.tobytes(), base.tobytes(), mask.tobytes(),
           op.step_bound, n_dev)
    with x64():
        fn = _VM_CACHE.get(key)
        if fn is None:
            fn = build_vm(op, regions, n_dev)
            _VM_CACHE[key] = fn
        p = np.zeros(max(len(params), 1), dtype=np.int64)
        for i, v in enumerate(params):
            p[i] = np.int64(np.uint64(v & (2**64 - 1)).astype(np.uint64).view(np.int64)) \
                if v > 2**63 - 1 or v < -2**63 else np.int64(v)
        failed_mask = np.zeros(n_dev, dtype=bool)
        for f in (failed or ()):
            failed_mask[f] = True
        out = fn(jnp.asarray(mem, jnp.int64), jnp.asarray(p),
                 np.int64(home), jnp.asarray(failed_mask))
        out = jax.tree_util.tree_map(np.asarray, out)
    return InvokeResult(mem=out.mem, ret=int(out.ret), status=int(out.status),
                        steps=int(out.steps), regs=out.regs)


@dataclasses.dataclass
class InvokeResult:
    mem: np.ndarray
    ret: int
    status: int
    steps: int
    regs: np.ndarray

    @property
    def ok(self) -> bool:
        return self.status == isa.STATUS_OK
