"""Registration-time static verification (paper §3.3).

Two guarantees, both established before an operator ever touches the data
path, so the runtime needs **no per-access checks**:

1. *Termination*: jumps are forward-only and loops have static trip-count
   bounds, so every operator has a statically computable upper bound on
   executed steps.  The verifier computes the exact worst-case bound
   (sum over instructions of the product of enclosing loop bounds) and
   rejects operators above a configurable limit.  The bound doubles as the
   JAX VM's fuel: if the VM ever hits it, that is a *verifier* bug, and a
   hypothesis property test asserts it never happens.

2. *Region isolation*: every memory access names a statically-declared
   region id; the verifier checks the declared set against the tenant's
   grant (read + write separately).  Offsets are data-dependent but are
   masked to the power-of-two region size by the data path, so no reachable
   access can leave a granted region, no matter what the chased pointers
   contain.

Structural rules enforced:
  * jumps strictly forward, targets inside the program;
  * jumps never enter a loop body from outside (they may exit one — that is
    the distributed-lock "break" in Fig. 5 of the paper);
  * loop bodies properly nested, static nesting depth <= 8 (the hardware
    loop stack);
  * the final instruction is Ret (no fall-off-the-end path);
  * register/immediate fields in range; Memcpy lengths capped at the DMA
    burst limit.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Optional, Tuple

import numpy as np

from repro.core import access, isa, wcet
from repro.core.isa import (Alu, Instr, Op, FLAG_DEV_REG, FLAG_DSTDEV_REG,
                            FLAG_IMMB, FLAG_LEN_REG, FLAG_MREG,
                            FLAG_SRCDEV_REG, FLAG_THR_REG)
from repro.core.memory import Grant, RegionTable
from repro.core.program import TiaraProgram

DEFAULT_MAX_STEPS = 1 << 20


class VerificationError(Exception):
    def __init__(self, errors: List[str]):
        self.errors = list(errors)
        super().__init__("; ".join(self.errors))


@dataclasses.dataclass(frozen=True)
class LoopInfo:
    pc: int            # pc of the LOOP instruction
    start: int         # first body pc
    end: int           # last body pc (inclusive)
    bound: int         # static trip-count bound (cap for dynamic counts)


@dataclasses.dataclass(frozen=True)
class VerifiedOperator:
    """The registration artifact: program + proven facts.

    ``footprint`` is the registration-time symbolic access footprint
    (``core/access``): per static access site an affine-in-params
    offset, a trip-scaled loop window, or top (whole region).  It is
    what wave-formation substitutes concrete params into to prove a
    mixed wave conflict-free and skip the runtime sweep.

    ``certificate`` is the registration-time line-rate certificate
    (``core/wcet``): sound upper bounds on worst-case cycles, traffic,
    and per-resource occupancy, derived against the default hardware
    model.  The registry enforces it against its budget, the serving
    loop fail-fasts statically-infeasible deadlines with it, and the
    cost model clamps its learned wave prices to it.
    """

    program: TiaraProgram
    step_bound: int
    loops: Tuple[LoopInfo, ...]
    max_loop_depth: int
    n_async_sites: int
    footprint: Optional[access.OpFootprint] = None
    certificate: Optional[wcet.LineRateCertificate] = None

    @property
    def name(self) -> str:
        return self.program.name

    @property
    def code(self) -> np.ndarray:
        return self.program.code


def _reg_ok(idx: int) -> bool:
    return 0 <= idx < isa.NUM_REGS


def _collect_loops(instrs: List[Instr], errors: List[str]) -> List[LoopInfo]:
    loops: List[LoopInfo] = []
    n = len(instrs)
    for pc, ins in enumerate(instrs):
        if ins.op != Op.LOOP:
            continue
        n_body = ins.imm2
        if n_body < 1:
            errors.append(f"pc {pc}: loop with empty body")
            continue
        if pc + 1 + n_body > n:
            errors.append(f"pc {pc}: loop body extends past program end")
            continue
        bound = int(ins.imm)
        if bound < 0:
            errors.append(f"pc {pc}: negative loop bound")
            continue
        if (ins.flags & FLAG_MREG) and bound < 1:
            errors.append(f"pc {pc}: dynamic loop needs a positive static cap")
            continue
        loops.append(LoopInfo(pc=pc, start=pc + 1, end=pc + n_body, bound=bound))
    return loops


def _check_nesting(loops: List[LoopInfo], errors: List[str]) -> int:
    """Bodies must be disjoint or strictly nested; returns max depth."""
    for i, a in enumerate(loops):
        for b in loops[i + 1:]:
            lo, hi = (a, b) if a.pc < b.pc else (b, a)
            # hi's LOOP instruction sits either inside lo's body or after it.
            if hi.pc <= lo.end:
                if hi.end > lo.end:
                    errors.append(
                        f"loops at pc {lo.pc} and {hi.pc} overlap without nesting")
            # else disjoint — fine.
    max_depth = 0
    for a in loops:
        depth = 1 + sum(1 for b in loops
                        if b.pc != a.pc and b.start <= a.pc and a.end <= b.end)
        max_depth = max(max_depth, depth)
    if max_depth > isa.LOOP_STACK_DEPTH:
        errors.append(f"loop nesting depth {max_depth} exceeds hardware "
                      f"stack of {isa.LOOP_STACK_DEPTH}")
    return max_depth


def _enclosing(loops: List[LoopInfo], pc: int) -> FrozenSet[int]:
    return frozenset(l.pc for l in loops if l.start <= pc <= l.end)


# one multiplier definition for the step bound, the footprint lattice,
# and the line-rate certificate (they must agree for the certificate's
# mp_cycles == step_bound identity to hold)
_multiplier = access.loop_multiplier


def verify(program: TiaraProgram, *, grant: Optional[Grant] = None,
           regions: Optional[RegionTable] = None,
           max_steps: int = DEFAULT_MAX_STEPS) -> VerifiedOperator:
    """Statically verify ``program``; raises VerificationError on failure."""
    errors: List[str] = []
    instrs = isa.decode_program(program.code)
    n = len(instrs)
    if n == 0:
        raise VerificationError([f"{program.name}: empty program"])
    if n > isa.INSTR_STORE_SIZE:
        errors.append(f"program of {n} instructions exceeds the "
                      f"{isa.INSTR_STORE_SIZE}-entry instruction store")
    if not (0 <= program.n_params <= isa.NUM_PARAM_REGS):
        errors.append(f"n_params {program.n_params} out of range")

    n_regions = len(regions) if regions is not None else None

    def check_region(pc: int, rid: int, *, write: bool) -> None:
        if n_regions is not None and not (0 <= rid < n_regions):
            errors.append(f"pc {pc}: region id {rid} not registered")
            return
        if regions is not None and write and not regions[rid].writable:
            errors.append(f"pc {pc}: region {regions[rid].name!r} is read-only")
        if grant is not None:
            if rid not in grant.readable:
                errors.append(f"pc {pc}: region {rid} not readable by tenant "
                              f"{grant.tenant!r}")
            if write and rid not in grant.writable:
                errors.append(f"pc {pc}: region {rid} not writable by tenant "
                              f"{grant.tenant!r}")

    def check_reg(pc: int, idx: int, what: str) -> None:
        if not _reg_ok(idx):
            errors.append(f"pc {pc}: {what} register r{idx} out of range")

    def check_dev(pc: int, field: int, flag_set: bool) -> None:
        if flag_set:
            check_reg(pc, field, "device")
        # Static device ids are masked to the pool size by the data path;
        # DEV_LOCAL (-1) means the executing host.

    loops = _collect_loops(instrs, errors)
    max_depth = _check_nesting(loops, errors)

    n_async = 0
    for pc, ins in enumerate(instrs):
        op = ins.op
        if op in (Op.NOP,):
            continue
        if op == Op.MOVI:
            check_reg(pc, ins.dst, "dst")
        elif op == Op.ALU:
            check_reg(pc, ins.dst, "dst")
            check_reg(pc, ins.a, "a")
            if not (ins.flags & FLAG_IMMB):
                check_reg(pc, ins.b, "b")
            if ins.d not in (int(x) for x in Alu if x != Alu.ALWAYS):
                errors.append(f"pc {pc}: invalid ALU op {ins.d}")
        elif op == Op.LOAD:
            check_reg(pc, ins.dst, "dst")
            check_reg(pc, ins.b, "offset")
            check_dev(pc, ins.e, bool(ins.flags & FLAG_DEV_REG))
            check_region(pc, ins.a, write=False)
        elif op == Op.STORE:
            check_reg(pc, ins.dst, "src")
            check_reg(pc, ins.b, "offset")
            check_dev(pc, ins.e, bool(ins.flags & FLAG_DEV_REG))
            check_region(pc, ins.a, write=True)
        elif op == Op.MEMCPY:
            check_reg(pc, ins.b, "dst offset")
            check_reg(pc, ins.e, "src offset")
            check_dev(pc, ins.dst, bool(ins.flags & FLAG_DSTDEV_REG))
            check_dev(pc, ins.c, bool(ins.flags & FLAG_SRCDEV_REG))
            check_region(pc, ins.a, write=True)
            check_region(pc, ins.d, write=False)
            if not (0 < ins.imm <= isa.MAX_MEMCPY_WORDS):
                errors.append(f"pc {pc}: memcpy length/cap {ins.imm} outside "
                              f"(0, {isa.MAX_MEMCPY_WORDS}]")
            if ins.flags & FLAG_LEN_REG:
                check_reg(pc, ins.imm2, "length")
            if ins.flags & isa.FLAG_ASYNC:
                n_async += 1
        elif op in (Op.CAS, Op.CAA):
            check_reg(pc, ins.dst, "dst")
            check_reg(pc, ins.b, "offset")
            check_reg(pc, ins.c, "cmp")
            check_reg(pc, ins.d, "swap/add")
            check_dev(pc, ins.e, bool(ins.flags & FLAG_DEV_REG))
            check_region(pc, ins.a, write=True)
        elif op == Op.JUMP:
            if ins.d != int(Alu.ALWAYS):
                check_reg(pc, ins.a, "cond lhs")
                if not (ins.flags & FLAG_IMMB):
                    check_reg(pc, ins.b, "cond rhs")
                if ins.d not in (int(Alu.EQ), int(Alu.NE), int(Alu.LT),
                                 int(Alu.GE)):
                    errors.append(f"pc {pc}: invalid jump condition {ins.d}")
            if ins.imm2 < 0:
                errors.append(f"pc {pc}: backward jump")
                continue
            target = pc + 1 + ins.imm2
            if target >= n:
                errors.append(f"pc {pc}: jump target {target} outside program")
                continue
            # May only jump out of (or within) loop bodies, never into one.
            if not _enclosing(loops, target) <= _enclosing(loops, pc):
                errors.append(f"pc {pc}: jump to {target} enters a loop body")
        elif op == Op.LOOP:
            if ins.flags & FLAG_MREG:
                check_reg(pc, ins.b, "trip count")
        elif op == Op.WAIT:
            if ins.flags & FLAG_THR_REG:
                check_reg(pc, ins.a, "threshold")
            elif ins.imm < 0:
                errors.append(f"pc {pc}: negative wait threshold")
        elif op == Op.RET:
            check_reg(pc, ins.a, "return value")
        else:
            errors.append(f"pc {pc}: unknown opcode {int(ins.op)}")

    if instrs and instrs[-1].op != Op.RET:
        errors.append("last instruction must be Ret (no fall-off paths)")

    # Termination bound: sum over instructions of the product of enclosing
    # loop bounds.  Forward jumps can only skip work, so this is sound.
    step_bound = sum(_multiplier(loops, pc) for pc in range(n))
    if step_bound > max_steps:
        errors.append(f"worst-case step bound {step_bound} exceeds the "
                      f"configured limit of {max_steps}")

    if errors:
        # diagnostics carry the operator name so multi-operator
        # registration failures stay attributable
        raise VerificationError(
            [f"{program.name}: {e}" for e in errors])

    return VerifiedOperator(
        program=program,
        step_bound=int(step_bound),
        loops=tuple(loops),
        max_loop_depth=max_depth,
        n_async_sites=n_async,
        footprint=access.analyze(program, loops, regions),
        certificate=wcet.certify(program, loops, regions),
    )
