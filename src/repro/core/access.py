"""Registration-time access analysis — symbolic footprints and static
conflict proofs (ROADMAP open item 2).

The verifier already proves termination and region isolation at
registration; conflict detection, however, is still paid at *runtime*:
every macro-step of every engine runs an O(B log B) sweep-line over the
live lanes' footprints (``vm._sweep_conflict``), and on the sharded
engine that sweep is fed by an ``all_gather`` of every device's
intervals — a static question answered with a collective per step.

This module answers the question once, at registration time.  A small
abstract interpreter walks the verified program and derives, per static
access site, a **symbolic footprint**:

* **affine-in-params** offsets — ``const + sum(coeff_i * param_i)``
  plus a closed interval of slack (the value lattice
  :class:`SymVal`);
* **loop-strided windows** — pure-increment loop counters widen to the
  affine entry value plus ``[cap*d_lo, cap*d_hi]`` slack, so a
  reply-slot cursor stays a *bounded window*, not unknown;
* **top (data-dependent)** — pointer-chased offsets (a LOAD result
  feeding an address) degrade to the *whole region*, which is always
  sound because the datapath masks every offset into its region
  (``pyvm.phys`` / ``vm.lane_intervals`` do the same wrap).

At wave-formation time :func:`prove_wave_noconflict` substitutes each
lane's concrete parameters into its operator's footprint and proves the
wave conflict-free: per-lane merged write/read interval sets, a global
sweep over the merged write spans (any overlap is necessarily
cross-lane), reads checked only against *other* lanes' writes, and a
per-MEMCPY src/dst self-overlap check (the one same-lane case the
runtime sweep flags).  A proof lets ``vm.py`` skip the runtime sweep —
and, sharded, the footprint all_gather — entirely; top footprints keep
the sweep as the verbatim fallback.

Soundness invariants (property-tested in
``tests/test_access_analysis.py``):

* every footprint interval is a **superset** of every runtime
  ``lane_intervals`` window the lane can produce at any macro-step, so
  if the dynamic sweep would flag a wave, the static proof refuses to
  clear it;
* symbolic evaluation tracks a monotone **absolute-magnitude bound**
  (``SymVal.aconst``/``acoeffs``); substitution only trusts the affine
  form when that bound shows no intermediate wrap64 could have fired,
  otherwise the access degrades to the whole region.

No imports from ``pyvm``/``verifier`` (they import us); the few scalar
semantics needed (wrap64, ALU const-folds) are replicated locally.
"""

from __future__ import annotations

import dataclasses
from typing import (Dict, List, Optional, Protocol, Sequence,
                    Tuple)

import numpy as np

from repro.core import isa
from repro.core.isa import Alu, Instr, Op
from repro.core.memory import RegionTable

_U64 = 1 << 64
_S63 = 1 << 63

# collapse pathologically access-heavy programs to per-region summaries
# beyond this many records (keeps proof time linear in B, not program
# unrolling)
MAX_ACCESS_RECORDS = 96


def _wrap64(x: int) -> int:
    """Signed 64-bit two's-complement fold (mirrors ``pyvm.wrap64``)."""
    return ((int(x) + _S63) % _U64) - _S63


# ---------------------------------------------------------------------------
# value lattice
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SymVal:
    """``const + sum(coeff*sym) + [lo, hi]`` with a wrap certificate.

    ``coeffs`` maps symbol index -> integer coefficient (sorted tuple of
    pairs so the value hashes).  Symbols ``0..NUM_PARAM_REGS-1`` are the
    parameter registers; symbols ``NUM_PARAM_REGS + j`` are *auxiliary
    trip counters* — one per dynamically-bounded (``FLAG_MREG``) loop,
    ranging over ``[0, clamp(m)]`` so a reply-slot cursor's window
    scales with the lane's actual trip count, not the static cap.
    ``[lo, hi]`` is inclusive slack — loop widening and unresolved
    comparisons land here.  ``aconst`` / ``acoeffs`` bound the absolute
    magnitude of every intermediate value in the expression's
    computation history:
    ``|any intermediate| <= aconst + sum(ac_i * max|sym_i|)``.  The
    bound only ever *accumulates* (no cancellation), so if it evaluates
    below 2**63 for a concrete symbol vector, no wrap64 fired anywhere
    in the computation and the unbounded affine evaluation equals the
    datapath's wrapped value exactly.
    """

    const: int = 0
    coeffs: Tuple[Tuple[int, int], ...] = ()
    lo: int = 0
    hi: int = 0
    aconst: int = 0
    acoeffs: Tuple[Tuple[int, int], ...] = ()

    # -- constructors ---------------------------------------------------

    @staticmethod
    def exact(v: int) -> "SymVal":
        v = _wrap64(v)
        return SymVal(const=v, aconst=abs(v))

    @staticmethod
    def param(i: int) -> "SymVal":
        return SymVal(coeffs=((i, 1),), aconst=0, acoeffs=((i, 1),))

    @staticmethod
    def sym(i: int, coeff: int = 1) -> "SymVal":
        return SymVal(coeffs=((i, coeff),),
                      acoeffs=((i, abs(coeff)),))

    @staticmethod
    def interval(lo: int, hi: int) -> "SymVal":
        return SymVal(lo=lo, hi=hi, aconst=max(abs(lo), abs(hi)))

    # -- predicates -----------------------------------------------------

    @property
    def is_exact(self) -> bool:
        return not self.coeffs and self.lo == 0 and self.hi == 0

    @property
    def value(self) -> int:
        assert self.is_exact
        return self.const

    # -- arithmetic -----------------------------------------------------

    def _merge(self, other: "SymVal", sign: int) -> "SymVal":
        c: Dict[int, int] = dict(self.coeffs)
        for k, v in other.coeffs:
            c[k] = c.get(k, 0) + sign * v
        a: Dict[int, int] = dict(self.acoeffs)
        for k, v in other.acoeffs:
            a[k] = a.get(k, 0) + v
        lo = self.lo + (other.lo if sign > 0 else -other.hi)
        hi = self.hi + (other.hi if sign > 0 else -other.lo)
        return SymVal(
            const=self.const + sign * other.const,
            coeffs=tuple(sorted((k, v) for k, v in c.items() if v)),
            lo=lo, hi=hi,
            aconst=self.aconst + other.aconst,
            acoeffs=tuple(sorted(a.items())))

    def add(self, other: "SymVal") -> "SymVal":
        return self._merge(other, 1)

    def sub(self, other: "SymVal") -> "SymVal":
        return self._merge(other, -1)

    def scale(self, k: int) -> "SymVal":
        lo, hi = self.lo * k, self.hi * k
        if k < 0:
            lo, hi = hi, lo
        return SymVal(
            const=self.const * k,
            coeffs=tuple((i, c * k) for i, c in self.coeffs if c * k),
            lo=lo, hi=hi,
            aconst=self.aconst * abs(k),
            acoeffs=tuple((i, c * abs(k)) for i, c in self.acoeffs
                          if c * k))

    def widen(self, lo: int, hi: int) -> "SymVal":
        """Add ``[lo, hi]`` slack (loop widening)."""
        return SymVal(const=self.const, coeffs=self.coeffs,
                      lo=self.lo + lo, hi=self.hi + hi,
                      aconst=self.aconst + max(abs(lo), abs(hi)),
                      acoeffs=self.acoeffs)

    def join(self, other: "SymVal") -> Optional["SymVal"]:
        """Least upper bound; ``None`` (top) when affine parts differ."""
        if self.coeffs != other.coeffs:
            return None
        d = other.const - self.const
        a: Dict[int, int] = dict(self.acoeffs)
        for k, v in other.acoeffs:
            a[k] = max(a.get(k, 0), v)
        return SymVal(
            const=self.const, coeffs=self.coeffs,
            lo=min(self.lo, other.lo + d),
            hi=max(self.hi, other.hi + d),
            aconst=max(self.aconst, other.aconst),
            acoeffs=tuple(sorted(a.items())))

    # -- substitution ---------------------------------------------------

    def concrete_range(
            self, syms: Sequence[Tuple[int, int]]
    ) -> Optional[Tuple[int, int]]:
        """``[lo, hi]`` of the value for a concrete symbol vector (each
        symbol an inclusive ``(lo, hi)`` range; params are point
        ranges), or ``None`` when the wrap certificate cannot rule out
        an intermediate wrap64 (caller degrades to the whole region)."""
        absb = self.aconst
        for i, c in self.acoeffs:
            if i < len(syms):
                slo, shi = syms[i]
                absb += c * max(abs(slo), abs(shi))
        if absb >= _S63:
            return None
        vlo = vhi = self.const
        for i, c in self.coeffs:
            slo, shi = syms[i] if i < len(syms) else (0, 0)
            vlo += min(c * slo, c * shi)
            vhi += max(c * slo, c * shi)
        return vlo + self.lo, vhi + self.hi

    @staticmethod
    def _sym_name(i: int) -> str:
        if i < isa.NUM_PARAM_REGS:
            return f"p{i}"
        return f"t{i - isa.NUM_PARAM_REGS}"

    def describe(self) -> str:
        parts: List[str] = []
        if self.const or not (self.coeffs or self.lo != self.hi):
            parts.append(str(self.const))
        for i, c in self.coeffs:
            n = self._sym_name(i)
            parts.append(n if c == 1 else f"{c}*{n}")
        s = "+".join(parts) if parts else "0"
        if self.lo != self.hi or self.lo:
            s += f"+[{self.lo},{self.hi}]"
        return s


# ---------------------------------------------------------------------------
# access records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Access:
    """One static access site's symbolic footprint.

    ``offset is None`` means top: the access may touch any word of the
    region (the datapath masks it in-region, so the whole region is the
    exact upper bound).  ``extent`` is the static window length in
    words (1 for word ops; the imm cap for MEMCPY).  ``dev`` is
    ``isa.DEV_LOCAL`` for the lane's home, a static device id, or
    ``None`` when the device is register-held and unresolved (any
    device).  MEMCPY's two accesses share a ``pair`` id so the
    same-step src/dst self-overlap check can find them.
    """

    rid: int
    write: bool
    offset: Optional[SymVal]
    extent: int
    dev: Optional[int]
    pc: int
    pair: int = -1

    def describe(self, regions: Optional[RegionTable] = None) -> str:
        name = str(self.rid)
        if regions is not None:
            name = regions[self.rid].name
        kind = "w" if self.write else "r"
        off = "⊤" if self.offset is None else self.offset.describe()
        ext = f"×{self.extent}" if self.extent != 1 else ""
        return f"{kind} {name}[{off}]{ext}"


@dataclasses.dataclass(frozen=True)
class OpFootprint:
    """The derived read/write footprint of one verified operator.

    ``aux_trips`` defines the auxiliary trip-count symbols, in
    allocation order: ``(m_expr, cap)`` per dynamically-bounded loop.
    At substitution time symbol ``NUM_PARAM_REGS + j`` ranges over
    ``[0, min(max(m, 0), cap)]`` where ``m`` evaluates ``m_expr``
    against the lane's params (``m_expr is None`` means unresolved —
    the full ``[0, cap]``).
    """

    accesses: Tuple[Access, ...]
    n_params: int
    aux_trips: Tuple[Tuple[Optional[SymVal], int], ...] = ()

    @property
    def exact(self) -> bool:
        """True when no access degraded to top."""
        return all(a.offset is not None for a in self.accesses)

    def lane_syms(self, params: Sequence[int]
                  ) -> List[Tuple[int, int]]:
        """The concrete symbol-range vector for one lane: wrapped
        params as point ranges, then each trip counter's range."""
        syms: List[Tuple[int, int]] = []
        for i in range(isa.NUM_PARAM_REGS):
            v = _wrap64(params[i]) if i < len(params) else 0
            syms.append((v, v))
        for m_expr, cap in self.aux_trips:
            hi = cap
            if m_expr is not None:
                rng = m_expr.concrete_range(syms)
                if rng is not None:
                    hi = min(max(rng[1], 0), cap)
            syms.append((0, hi))
        return syms

    def describe(self, regions: Optional[RegionTable] = None) -> str:
        if not self.accesses:
            return "∅"
        s = " ".join(a.describe(regions) for a in self.accesses)
        if self.aux_trips:
            trips = ",".join(
                f"t{j}≤{'m' if m is None else m.describe()}"
                f"∧{cap}" for j, (m, cap) in enumerate(self.aux_trips))
            s += f"  ({trips})"
        return s


# ---------------------------------------------------------------------------
# abstract interpretation
# ---------------------------------------------------------------------------


_State = List[Optional[SymVal]]

# structural protocol for verifier.LoopInfo without importing it
# (verifier imports this module; ``core/wcet`` consumes the same shape
# for its trip-scaled cost multipliers, so the protocol is public)


class LoopLike(Protocol):
    pc: int
    start: int
    end: int
    bound: int


_LoopLike = LoopLike


def loop_multiplier(loops: Sequence[LoopLike], pc: int) -> int:
    """Product of the loop-trip caps of every loop body enclosing
    ``pc`` — how many times that instruction can execute per
    invocation.  Shared by the verifier's step bound, the footprint
    lattice's trip scaling, and the line-rate certifier's per-pc cost
    attribution (one definition, three consumers)."""
    m = 1
    for l in loops:
        if l.start <= pc <= l.end:
            m *= max(int(l.bound), 0)
    return m


def _copy(state: _State) -> _State:
    return list(state)


def _join_states(a: Optional[_State],
                 b: Optional[_State]) -> Optional[_State]:
    if a is None:
        return None if b is None else _copy(b)
    if b is None:
        return _copy(a)
    out: _State = []
    for x, y in zip(a, b):
        out.append(None if x is None or y is None else x.join(y))
    return out


def _fold_alu(aop: int, a: SymVal, b: SymVal) -> Optional[SymVal]:
    """Abstract ALU transfer.  ``None`` = top."""
    if a.is_exact and b.is_exact:
        # exact const-fold replicating pyvm._alu bit-for-bit
        x, y = a.value, b.value
        if aop == Alu.ADD:
            return SymVal.exact(x + y)
        if aop == Alu.SUB:
            return SymVal.exact(x - y)
        if aop == Alu.MUL:
            return SymVal.exact(x * y)
        if aop == Alu.AND:
            return SymVal.exact(x & y)
        if aop == Alu.OR:
            return SymVal.exact(x | y)
        if aop == Alu.XOR:
            return SymVal.exact(x ^ y)
        if aop == Alu.SHL:
            return SymVal.exact(x << (y & 63))
        if aop == Alu.SHR:
            return SymVal.exact((x % _U64) >> (y & 63))
        if aop == Alu.EQ:
            return SymVal.exact(int(x == y))
        if aop == Alu.NE:
            return SymVal.exact(int(x != y))
        if aop == Alu.LT:
            return SymVal.exact(int(x < y))
        if aop == Alu.GE:
            return SymVal.exact(int(x >= y))
        if aop == Alu.MIN:
            return SymVal.exact(min(x, y))
        if aop == Alu.MAX:
            return SymVal.exact(max(x, y))
        return None
    if aop == Alu.ADD:
        return a.add(b)
    if aop == Alu.SUB:
        return a.sub(b)
    if aop == Alu.MUL:
        if a.is_exact:
            return b.scale(a.value)
        if b.is_exact:
            return a.scale(b.value)
        return None
    if aop == Alu.SHL and b.is_exact and 0 <= (b.value & 63) < 63:
        return a.scale(1 << (b.value & 63))
    if aop in (Alu.EQ, Alu.NE, Alu.LT, Alu.GE):
        return SymVal.interval(0, 1)
    if aop == Alu.AND:
        # a logical AND with a known non-negative mask is bounded by it
        # regardless of the other operand (index-masking idiom)
        for m in (a, b):
            if m.is_exact and m.value >= 0:
                return SymVal.interval(0, m.value)
        return None
    if aop in (Alu.MIN, Alu.MAX) and not a.coeffs and not b.coeffs:
        alo, ahi = a.const + a.lo, a.const + a.hi
        blo, bhi = b.const + b.lo, b.const + b.hi
        if aop == Alu.MIN:
            return SymVal.interval(min(alo, blo), min(ahi, bhi))
        return SymVal.interval(max(alo, blo), max(ahi, bhi))
    return None


def _multiplier_within(loops: Sequence[_LoopLike], outer: _LoopLike,
                       pc: int) -> int:
    """Product of the bounds of loops nested strictly inside ``outer``
    that enclose ``pc`` — how often one outer iteration can run it."""
    m = 1
    for l in loops:
        if l.pc == outer.pc:
            continue
        if outer.start <= l.pc <= outer.end and l.start <= pc <= l.end:
            m *= max(int(l.bound), 0)
    return m


_REG_WRITERS = (Op.MOVI, Op.ALU, Op.LOAD, Op.CAS, Op.CAA)


class _Analyzer:
    def __init__(self, instrs: Sequence[Instr],
                 loops: Sequence[_LoopLike], n_params: int,
                 regions: Optional[RegionTable]):
        self.instrs = instrs
        self.loops = list(loops)
        self.loop_by_pc = {l.pc: l for l in self.loops}
        self.n_params = n_params
        self.regions = regions
        self.accesses: List[Access] = []
        self.joins: Dict[int, Optional[_State]] = {}
        self.n_pairs = 0
        self.aux: List[Tuple[Optional[SymVal], int]] = []

    # -- helpers --------------------------------------------------------

    def _reg(self, state: _State, idx: int) -> Optional[SymVal]:
        return state[int(idx) & (isa.NUM_REGS - 1)]

    def _static_extent(self, ins: Instr) -> int:
        ext = min(int(ins.imm), isa.MAX_MEMCPY_WORDS)
        if self.regions is not None:
            for rid in (int(ins.a), int(ins.d)):
                if 0 <= rid < len(self.regions):
                    ext = min(ext, int(self.regions[rid].size))
        return max(ext, 0)

    def _dev(self, state: _State, field: int, via_reg: bool
             ) -> Optional[int]:
        if not via_reg:
            return int(field)
        v = self._reg(state, field)
        if v is not None and v.is_exact:
            return int(v.value)
        return None

    def _record(self, *, rid: int, write: bool,
                offset: Optional[SymVal], extent: int,
                dev: Optional[int], pc: int, pair: int = -1) -> None:
        self.accesses.append(Access(rid=int(rid), write=write,
                                    offset=offset, extent=int(extent),
                                    dev=dev, pc=int(pc), pair=pair))

    # -- loop widening --------------------------------------------------

    def _widen_loop(self, state: _State, loop: _LoopLike) -> _State:
        """Entry state covering *every* point of every loop iteration
        and the post-loop state after 0..cap trips (MREG early exits
        and jump breaks included).

        Pure-increment registers — every body write is ``ALU ADD/SUB``
        with an immediate and ``dst == a`` — widen to the entry value
        plus a trip-scaled window; every other body-written register
        goes top.  For an MREG loop whose trip register is itself
        affine at entry, the window is scaled by a fresh trip-count
        symbol ``t in [0, clamp(m)]`` so it tracks the lane's *actual*
        trip count; otherwise the verifier-checked static cap bounds
        the window (cap-bounded, never top).
        """
        cap = max(int(loop.bound), 0)
        ins_loop = self.instrs[loop.pc]
        m_val: Optional[SymVal] = None
        if ins_loop.flags & isa.FLAG_MREG:
            m_val = self._reg(state, ins_loop.b)
        t_idx: Optional[int] = None
        written: Dict[int, List[int]] = {}
        for pc in range(loop.start, loop.end + 1):
            ins = self.instrs[pc]
            if ins.op in _REG_WRITERS:
                written.setdefault(int(ins.dst), []).append(pc)
        out = _copy(state)
        for reg, pcs in written.items():
            deltas: List[int] = []
            pure = True
            for pc in pcs:
                ins = self.instrs[pc]
                if (ins.op == Op.ALU and int(ins.d) in (int(Alu.ADD),
                                                        int(Alu.SUB))
                        and (ins.flags & isa.FLAG_IMMB)
                        and int(ins.dst) == int(ins.a)):
                    step = int(ins.imm)
                    if int(ins.d) == int(Alu.SUB):
                        step = -step
                    deltas.append(
                        step * _multiplier_within(self.loops, loop, pc))
                else:
                    pure = False
                    break
            cur = out[reg]
            if not pure or cur is None:
                out[reg] = None
                continue
            d_lo = sum(min(0, d) for d in deltas)
            d_hi = sum(max(0, d) for d in deltas)
            if d_lo == 0 and d_hi == 0:
                out[reg] = cur
                continue
            if m_val is not None and (d_lo == 0 or d_hi == 0):
                # trip-scaled window: one shared symbol per loop
                if t_idx is None:
                    t_idx = isa.NUM_PARAM_REGS + len(self.aux)
                    self.aux.append((m_val, cap))
                coeff = d_hi if d_lo == 0 else d_lo
                out[reg] = cur.add(SymVal.sym(t_idx, coeff))
            else:
                out[reg] = cur.widen(cap * d_lo, cap * d_hi)
        return out

    # -- the walk -------------------------------------------------------

    def walk(self, lo: int, hi: int,
             state: Optional[_State]) -> None:
        pc = lo
        while pc < hi:
            if pc in self.joins:
                state = _join_states(state, self.joins.pop(pc))
            loop = self.loop_by_pc.get(pc)
            if loop is not None:
                body_hi = loop.end + 1
                if state is not None:
                    state = self._widen_loop(state, loop)
                    self.walk(loop.start, body_hi, _copy(state))
                pc = body_hi
                continue
            if state is None:
                pc += 1
                continue
            state = self._transfer(pc, state)
            pc += 1

    def _transfer(self, pc: int, state: _State) -> Optional[_State]:
        ins = self.instrs[pc]
        o = ins.op
        if o == Op.NOP or o == Op.WAIT:
            return state
        if o == Op.MOVI:
            state = _copy(state)
            state[int(ins.dst)] = SymVal.exact(int(ins.imm))
            return state
        if o == Op.ALU:
            a = self._reg(state, ins.a)
            rhs = (SymVal.exact(int(ins.imm))
                   if (ins.flags & isa.FLAG_IMMB)
                   else self._reg(state, ins.b))
            state = _copy(state)
            if a is None or rhs is None:
                # top op of a known non-negative mask still bounds AND
                if (int(ins.d) == int(Alu.AND) and rhs is not None
                        and rhs.is_exact and rhs.value >= 0):
                    state[int(ins.dst)] = SymVal.interval(0, rhs.value)
                elif (int(ins.d) == int(Alu.AND) and a is not None
                        and a.is_exact and a.value >= 0):
                    state[int(ins.dst)] = SymVal.interval(0, a.value)
                elif int(ins.d) in (int(Alu.EQ), int(Alu.NE),
                                    int(Alu.LT), int(Alu.GE)):
                    state[int(ins.dst)] = SymVal.interval(0, 1)
                else:
                    state[int(ins.dst)] = None
            else:
                state[int(ins.dst)] = _fold_alu(int(ins.d), a, rhs)
            return state
        if o in (Op.LOAD, Op.STORE, Op.CAS, Op.CAA):
            base_off = self._reg(state, ins.b)
            off = (None if base_off is None
                   else base_off.add(SymVal.exact(int(ins.imm))))
            dev = self._dev(state, int(ins.e),
                            bool(ins.flags & isa.FLAG_DEV_REG))
            self._record(rid=int(ins.a), write=(o != Op.LOAD),
                         offset=off, extent=1, dev=dev, pc=pc)
            if o != Op.STORE:
                state = _copy(state)
                state[int(ins.dst)] = None   # loaded value: data-dep
            return state
        if o == Op.MEMCPY:
            ext = self._static_extent(ins)
            pair = self.n_pairs
            self.n_pairs += 1
            doff = self._reg(state, ins.b)
            soff = self._reg(state, ins.e)
            ddev = self._dev(state, int(ins.dst),
                             bool(ins.flags & isa.FLAG_DSTDEV_REG))
            sdev = self._dev(state, int(ins.c),
                             bool(ins.flags & isa.FLAG_SRCDEV_REG))
            if ext > 0:
                self._record(rid=int(ins.a), write=True, offset=doff,
                             extent=ext, dev=ddev, pc=pc, pair=pair)
                self._record(rid=int(ins.d), write=False, offset=soff,
                             extent=ext, dev=sdev, pc=pc, pair=pair)
            return state
        if o == Op.JUMP:
            target = pc + 1 + int(ins.imm2)
            taken = _copy(state)
            if target in self.joins:
                self.joins[target] = _join_states(self.joins[target],
                                                  taken)
            else:
                self.joins[target] = taken
            if int(ins.d) == int(Alu.ALWAYS):
                return None
            return state
        if o == Op.RET:
            return None
        if o == Op.LOOP:
            # a LOOP the verifier did not record (malformed) — give up
            # on everything after it conservatively
            return None
        return state


def analyze(program: "object", loops: Sequence[_LoopLike],
            regions: Optional[RegionTable] = None) -> OpFootprint:
    """Derive the symbolic access footprint of a verified program.

    ``program`` is a ``TiaraProgram`` (duck-typed: ``.code`` and
    ``.n_params``); ``loops`` the verifier's ``LoopInfo`` records.
    ``regions`` (optional) tightens MEMCPY extents by region size.
    """
    instrs = isa.decode_program(program.code)          # type: ignore[attr-defined]
    n_params = int(program.n_params)                   # type: ignore[attr-defined]
    state: _State = [SymVal.exact(0)] * isa.NUM_REGS
    # every param register is symbolic: the datapath writes regs[i] for
    # each *provided* param, and an absent param substitutes 0 at proof
    # time — so modelling all of them is exact in both cases
    for i in range(isa.NUM_PARAM_REGS):
        state[i] = SymVal.param(i)
    state[isa.ERR_REG] = None   # mutated by failed-device MEMCPYs
    an = _Analyzer(instrs, loops, n_params, regions)
    an.walk(0, len(instrs), state)
    accesses = an.accesses
    if len(accesses) > MAX_ACCESS_RECORDS:
        # collapse to one whole-region record per (rid, write, dev)
        seen: Dict[Tuple[int, bool, Optional[int]], Access] = {}
        for a in accesses:
            key = (a.rid, a.write, a.dev)
            if key not in seen:
                seen[key] = Access(rid=a.rid, write=a.write, offset=None,
                                   extent=1, dev=a.dev, pc=a.pc)
        accesses = list(seen.values())
    return OpFootprint(accesses=tuple(accesses), n_params=n_params,
                       aux_trips=tuple(an.aux))


# ---------------------------------------------------------------------------
# wave-level conflict proof
# ---------------------------------------------------------------------------


def _lane_intervals(fp: OpFootprint, params: Sequence[int], home: int,
                    base: np.ndarray, sizes: np.ndarray,
                    pool_words: int, n_devices: int
                    ) -> Optional[Tuple[List[Tuple[int, int, int]],
                                        List[Tuple[int, int, int]]]]:
    """Substitute one lane's params into its footprint.

    Returns ``(writes, reads)`` as lists of ``(lo, hi, pair)`` flat
    half-open word intervals (device-major coordinates), or ``None``
    when the lane has a same-site MEMCPY src/dst self-overlap (the one
    same-lane case the runtime sweep flags — cannot be cleared).
    """
    writes: List[Tuple[int, int, int]] = []
    reads: List[Tuple[int, int, int]] = []
    syms = fp.lane_syms(params)
    for a in fp.accesses:
        size = int(sizes[a.rid])
        ext = min(a.extent, size)
        span: Optional[Tuple[int, int]] = None
        if a.offset is not None:
            rng = a.offset.concrete_range(syms)
            if rng is not None:
                vlo, vhi = rng
                if 0 <= vlo and vhi + ext <= size:
                    span = (vlo, vhi + ext)
        if span is None:
            span = (0, size)                    # whole region (masked)
        if a.dev is None:
            devs = list(range(n_devices))
        elif a.dev == isa.DEV_LOCAL:
            devs = [int(home)]
        else:
            devs = [int(a.dev) % n_devices]
        for d in devs:
            off = d * pool_words + int(base[a.rid])
            rec = (off + span[0], off + span[1], a.pair)
            (writes if a.write else reads).append(rec)
    # same-site MEMCPY src/dst self-overlap: the runtime sweep sees both
    # windows in the same macro-step and flags the lane against itself
    for wlo, whi, wp in writes:
        if wp < 0:
            continue
        for rlo, rhi, rp in reads:
            if rp == wp and wlo < rhi and rlo < whi:
                return None
    return writes, reads


def _merge(spans: List[Tuple[int, int, int]]) -> List[Tuple[int, int]]:
    """Merge a lane's intervals into disjoint sorted spans."""
    if not spans:
        return []
    spans.sort()
    out: List[Tuple[int, int]] = []
    clo, chi = spans[0][0], spans[0][1]
    for lo, hi, _ in spans[1:]:
        if lo <= chi:
            chi = max(chi, hi)
        else:
            out.append((clo, chi))
            clo, chi = lo, hi
    out.append((clo, chi))
    return out


def prove_wave_noconflict(
        footprints: Sequence[OpFootprint],
        params: Sequence[Sequence[int]],
        homes: Sequence[int],
        regions: RegionTable,
        n_devices: int = 1) -> bool:
    """Statically prove a wave conflict-free.

    True only when no macro-step of the wave can make the runtime
    sweep (``vm._sweep_conflict`` over ``vm.lane_intervals``) flag a
    conflict: cross-lane write/write and write/read overlaps are ruled
    out on merged per-lane footprint supersets, and each lane's MEMCPY
    sites are src/dst self-disjoint.  A ``False`` is *not* a proof of
    conflict — just "could not prove"; callers fall back to the
    runtime sweep.
    """
    B = len(footprints)
    if B <= 1:
        return True
    base, mask, _ = regions.as_arrays()
    sizes = mask + 1
    pool_words = int(regions.pool_words)
    n_devices = max(int(n_devices), 1)

    w_lo: List[int] = []
    w_hi: List[int] = []
    w_lane: List[int] = []
    r_lo: List[int] = []
    r_hi: List[int] = []
    r_lane: List[int] = []
    for b in range(B):
        lane = _lane_intervals(footprints[b], params[b], int(homes[b]),
                               base, sizes, pool_words, n_devices)
        if lane is None:
            return False
        writes, reads = lane
        for lo, hi in _merge(writes):
            w_lo.append(lo)
            w_hi.append(hi)
            w_lane.append(b)
        for lo, hi in _merge(reads):
            r_lo.append(lo)
            r_hi.append(hi)
            r_lane.append(b)

    if not w_lo:
        return True                      # read-only waves never conflict
    wl = np.asarray(w_lo, dtype=np.int64)
    wh = np.asarray(w_hi, dtype=np.int64)
    wb = np.asarray(w_lane, dtype=np.int64)
    order = np.argsort(wl, kind="stable")
    wl, wh, wb = wl[order], wh[order], wb[order]
    # global write/write sweep: per-lane spans are merged-disjoint, so
    # any overlap here is necessarily cross-lane
    if wl.size > 1:
        run_hi = np.maximum.accumulate(wh)[:-1]
        if bool(np.any(wl[1:] < run_hi)):
            return False
    if r_lo:
        rl = np.asarray(r_lo, dtype=np.int64)
        rh = np.asarray(r_hi, dtype=np.int64)
        rb = np.asarray(r_lane, dtype=np.int64)
        # writes are globally disjoint and sorted: the spans overlapping
        # read [lo, hi) are exactly w[i0:i1]
        i0 = np.searchsorted(wh, rl, side="right")
        i1 = np.searchsorted(wl, rh, side="left")
        hits = np.nonzero(i1 > i0)[0]
        for i in hits:
            if bool(np.any(wb[int(i0[i]):int(i1[i])] != rb[i])):
                return False
    return True


def describe_footprint(fp: Optional[OpFootprint],
                       regions: Optional[RegionTable] = None) -> str:
    if fp is None:
        return "(no footprint)"
    return fp.describe(regions)
