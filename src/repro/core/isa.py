"""Tiara instruction set — encoding and constants.

The paper's Table 2 defines eight instruction families:

    Load/Store   register <-> local/remote memory; a loaded value can be the
                 next address (register-chained loads, the key enabler)
    Memcpy       bulk transfer with unified (device, region, offset)
                 addressing; subsumes RDMA Read/Write
    CAS/CAA      atomic compare-and-swap / compare-and-add
    Jump         forward-only conditional branch
    Loop(M,N)    execute next N ops for M iterations (depth-8 loop stack)
    Wait         block until in-flight async ops <= threshold
    Ret          return result to caller
    ComputeOp    integer arithmetic / logical / shift for address computation

We encode each instruction as a row of ``INSTR_WIDTH`` int64 fields so the
whole operator is a dense ``(n_instr, INSTR_WIDTH)`` int64 array — the JAX
VM bakes it in as a compile-time constant (the "BRAM instruction store"),
and the verifier walks the same array.

Addressing is *region-relative*: every memory operand names a statically
declared ``region_id`` plus a dynamic word offset.  Regions are power-of-two
sized so the hardware masks the offset for free (``off & (size-1)``); the
verifier only has to check the static region set against the tenant grant —
this is how the paper gets isolation "with no runtime checks" even though
the chased pointers themselves are data-dependent (see DESIGN.md §2).

All memory is word-addressed (1 word = 8 bytes), matching the 64-bit
register file of the paper's memory processors.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Machine parameters (paper §3: Fig. 4 and §4.1)
# ---------------------------------------------------------------------------

NUM_REGS = 16              # 16 x 64b register file per memory processor
NUM_PARAM_REGS = 8         # a client invocation carries up to 8 parameters
LOOP_STACK_DEPTH = 8       # depth-8 loop stack
MAX_INFLIGHT = 32          # 32-entry in-flight async counter
INSTR_STORE_SIZE = 1024    # 1024-entry BRAM instruction store
OP_TABLE_SIZE = 256        # 256-entry op_id -> start_pc dispatch table
MAX_MEMCPY_WORDS = 4096    # max words per single Memcpy DMA burst (32 KB)
WORD_BYTES = 8

# Register 15 is the asynchronous error flag register: a Memcpy targeting a
# failed device sets a bit here instead of faulting, so operators can test
# it with Jump and take a fallback path (paper §3.2).
ERR_REG = 15

# Instruction fields -------------------------------------------------------

INSTR_WIDTH = 10
F_OP, F_DST, F_A, F_B, F_C, F_D, F_E, F_FLAGS, F_IMM, F_IMM2 = range(INSTR_WIDTH)


class Op(enum.IntEnum):
    NOP = 0
    MOVI = 1      # dst <- imm
    ALU = 2       # dst <- aluop(regs[a], regs[b] | imm)
    LOAD = 3      # dst <- mem[dev][region(a)][regs[b] + imm]
    STORE = 4     # mem[dev][region(a)][regs[b] + imm] <- regs[dst]
    MEMCPY = 5    # bulk copy, optionally async
    CAS = 6       # dst <- old; if old == regs[c]: mem <- regs[d]
    CAA = 7       # dst <- old; if old == regs[c]: mem <- old + regs[d]
    JUMP = 8      # forward-only: if cond(regs[a], regs[b]|imm): pc += 1 + imm2
    LOOP = 9      # run next imm2 instructions for imm (or min(regs[b], imm)) iters
    WAIT = 10     # block until inflight <= (imm | regs[a])
    RET = 11      # return regs[a] with status imm


class Alu(enum.IntEnum):
    ADD = 0
    SUB = 1
    MUL = 2
    AND = 3
    OR = 4
    XOR = 5
    SHL = 6
    SHR = 7       # logical shift right
    EQ = 8
    NE = 9
    LT = 10       # signed
    GE = 11       # signed
    MIN = 12
    MAX = 13
    ALWAYS = 15   # only meaningful as a JUMP condition


# Flag bits ----------------------------------------------------------------

FLAG_IMMB = 1        # ALU/JUMP: second operand is the immediate, not regs[b]
FLAG_ASYNC = 2       # MEMCPY: asynchronous (counts toward in-flight)
FLAG_DEV_REG = 4     # LOAD/STORE/CAS/CAA: device operand e is a register index
FLAG_LEN_REG = 8     # MEMCPY: length is regs[imm2] capped at imm, else imm
FLAG_MREG = 8        # LOOP: trip count is min(regs[b], imm), else imm
FLAG_DSTDEV_REG = 16  # MEMCPY: dst field is a register index holding the device
FLAG_SRCDEV_REG = 32  # MEMCPY: c field is a register index holding the device
FLAG_THR_REG = 64    # WAIT: threshold is regs[a], else imm

# Device operand value meaning "the executing NIC's own host memory".
DEV_LOCAL = -1

# Return statuses ----------------------------------------------------------

STATUS_OK = 0
STATUS_FAIL = 1          # conventional app-level failure (e.g. lock busy)
STATUS_EAGAIN = 122      # admission reject: SQ full / rate limited / shed
                         # before execution (the RNIC "try again" errno)
STATUS_TIMEOUT = 123     # per-post deadline expired before launch (no run)
STATUS_FLUSHED = 124     # post flushed from an errored session's SQ (no run)
STATUS_PROT_FAULT = 125  # runtime protection fault: data-dependent access
                         # outside the grant/pool (lane halted, writes masked)
STATUS_FELL_OFF = 126    # pc ran past the end without RET (verifier rejects)
STATUS_FUEL = 127        # exceeded the static step bound (must be unreachable)


@dataclasses.dataclass(frozen=True)
class FaultInfo:
    """Where a runtime protection fault hit — the CQE error payload.

    ``addr`` is the *offending* value exactly as the lane computed it
    (the raw word offset for an out-of-bounds access, before region
    masking), and ``device`` the raw device operand (before the
    ``% n_devices`` router) — so a wild pointer is reported as the wild
    value, not as the clamped location it would have silently hit.
    """

    pc: int
    opcode: int
    addr: int
    device: int

    def describe(self) -> str:
        try:
            name = Op(self.opcode).name
        except ValueError:
            name = f"op{self.opcode}"
        return (f"protection fault at pc {self.pc} ({name}): "
                f"offset {self.addr}, device {self.device}")


@dataclasses.dataclass(frozen=True)
class Instr:
    """One decoded instruction; packs to an int64[INSTR_WIDTH] row."""

    op: Op
    dst: int = 0
    a: int = 0
    b: int = 0
    c: int = 0
    d: int = 0
    e: int = 0
    flags: int = 0
    imm: int = 0
    imm2: int = 0

    def encode(self) -> np.ndarray:
        row = np.zeros(INSTR_WIDTH, dtype=np.int64)
        row[F_OP] = int(self.op)
        row[F_DST] = self.dst
        row[F_A] = self.a
        row[F_B] = self.b
        row[F_C] = self.c
        row[F_D] = self.d
        row[F_E] = self.e
        row[F_FLAGS] = self.flags
        row[F_IMM] = self.imm
        row[F_IMM2] = self.imm2
        return row

    @staticmethod
    def decode(row: Sequence[int]) -> "Instr":
        return Instr(
            op=Op(int(row[F_OP])),
            dst=int(row[F_DST]),
            a=int(row[F_A]),
            b=int(row[F_B]),
            c=int(row[F_C]),
            d=int(row[F_D]),
            e=int(row[F_E]),
            flags=int(row[F_FLAGS]),
            imm=int(row[F_IMM]),
            imm2=int(row[F_IMM2]),
        )


def encode_program(instrs: Sequence[Instr]) -> np.ndarray:
    """Pack a list of instructions into the (n, INSTR_WIDTH) int64 store."""
    if not instrs:
        return np.zeros((0, INSTR_WIDTH), dtype=np.int64)
    return np.stack([i.encode() for i in instrs]).astype(np.int64)


def decode_program(code: np.ndarray) -> list:
    return [Instr.decode(code[i]) for i in range(code.shape[0])]


# Pretty-printing (used by the registry's `dump` and by tests) -------------

_ALU_SYM = {
    Alu.ADD: "+", Alu.SUB: "-", Alu.MUL: "*", Alu.AND: "&", Alu.OR: "|",
    Alu.XOR: "^", Alu.SHL: "<<", Alu.SHR: ">>", Alu.EQ: "==", Alu.NE: "!=",
    Alu.LT: "<", Alu.GE: ">=", Alu.MIN: "min", Alu.MAX: "max",
    Alu.ALWAYS: "always",
}


def format_instr(ins: Instr, pc: Optional[int] = None) -> str:
    p = f"{pc:4d}: " if pc is not None else ""
    f = ins.flags
    if ins.op == Op.NOP:
        return f"{p}nop"
    if ins.op == Op.MOVI:
        return f"{p}r{ins.dst} = {ins.imm}"
    if ins.op == Op.ALU:
        rhs = f"{ins.imm}" if f & FLAG_IMMB else f"r{ins.b}"
        sym = _ALU_SYM[Alu(ins.d)]
        return f"{p}r{ins.dst} = r{ins.a} {sym} {rhs}"
    dev = (f"r{ins.e}" if f & FLAG_DEV_REG else
           ("local" if ins.e == DEV_LOCAL else f"dev{ins.e}"))
    if ins.op == Op.LOAD:
        return f"{p}r{ins.dst} = load {dev}:rgn{ins.a}[r{ins.b} + {ins.imm}]"
    if ins.op == Op.STORE:
        return f"{p}store {dev}:rgn{ins.a}[r{ins.b} + {ins.imm}] = r{ins.dst}"
    if ins.op == Op.MEMCPY:
        dd = f"r{ins.dst}" if f & FLAG_DSTDEV_REG else (
            "local" if ins.dst == DEV_LOCAL else f"dev{ins.dst}")
        sd = f"r{ins.c}" if f & FLAG_SRCDEV_REG else (
            "local" if ins.c == DEV_LOCAL else f"dev{ins.c}")
        ln = f"min(r{ins.imm2}, {ins.imm})" if f & FLAG_LEN_REG else f"{ins.imm}"
        a = " async" if f & FLAG_ASYNC else ""
        return (f"{p}memcpy{a} {dd}:rgn{ins.a}[r{ins.b}] <- "
                f"{sd}:rgn{ins.d}[r{ins.e}] x{ln}")
    if ins.op == Op.CAS:
        return (f"{p}r{ins.dst} = cas {dev}:rgn{ins.a}[r{ins.b} + {ins.imm}]"
                f" cmp r{ins.c} swap r{ins.d}")
    if ins.op == Op.CAA:
        return (f"{p}r{ins.dst} = caa {dev}:rgn{ins.a}[r{ins.b} + {ins.imm}]"
                f" cmp r{ins.c} add r{ins.d}")
    if ins.op == Op.JUMP:
        rhs = f"{ins.imm}" if f & FLAG_IMMB else f"r{ins.b}"
        tgt = (pc + 1 + ins.imm2) if pc is not None else f"+{1 + ins.imm2}"
        if Alu(ins.d) == Alu.ALWAYS:
            return f"{p}jump -> {tgt}"
        return f"{p}if r{ins.a} {_ALU_SYM[Alu(ins.d)]} {rhs}: jump -> {tgt}"
    if ins.op == Op.LOOP:
        m = f"min(r{ins.b}, {ins.imm})" if f & FLAG_MREG else f"{ins.imm}"
        return f"{p}loop {m} times over next {ins.imm2} ops"
    if ins.op == Op.WAIT:
        thr = f"r{ins.a}" if f & FLAG_THR_REG else f"{ins.imm}"
        return f"{p}wait inflight <= {thr}"
    if ins.op == Op.RET:
        return f"{p}ret r{ins.a} (status={ins.imm})"
    return f"{p}<op{int(ins.op)}>"


def disassemble(code: np.ndarray) -> str:
    return "\n".join(format_instr(ins, pc)
                     for pc, ins in enumerate(decode_program(code)))
