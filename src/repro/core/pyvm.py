"""Pure-Python reference interpreter — the semantic oracle.

This file *defines* Tiara execution semantics at word level; the JAX VM
(`repro.core.vm`) and the Pallas data-path kernels are validated against
it.  It also emits the executed-instruction trace that the cycle-level MP
simulator (`repro.core.simulator`) charges timing against, playing the
role of the paper's Verilator model.

Semantics notes (shared with the JAX VM — keep in lockstep):
  * all values are 64-bit two's complement; arithmetic wraps;
  * shifts mask the amount to 0..63; SHR is logical;
  * device operands: DEV_LOCAL (-1) resolves to the executing host, any
    other value is taken mod n_devices (the device-id router);
  * offsets are masked to the region size (power of two) — the no-runtime-
    check isolation mechanism;
  * Memcpy reads its whole source window before writing (memmove
    semantics); lengths clamp to the DMA burst limit and to both region
    sizes;
  * an async Memcpy touching a failed device sets the error register
    (r15 |= 1) and performs no writes; execution continues (paper §3.2);
  * with ``protect=True`` (the default) data-dependent accesses are
    checked at runtime: a word op or memcpy whose offset falls outside
    its region, whose register-held device operand is neither DEV_LOCAL
    nor a valid device id, or (word ops only) whose resolved device is
    in the ``failed`` set, raises a *protection fault* — the lane halts
    with ``STATUS_PROT_FAULT`` and a :class:`~repro.core.isa.FaultInfo`,
    the faulting instruction performs no architectural effect, and no
    further writes leak into the pool (containment).  ``protect=False``
    restores the paper's mask-and-wrap data path exactly;
  * Wait(threshold) lowers the in-flight counter (copies are functionally
    applied at issue; *timing* of async completion is the simulator's job);
  * a taken forward jump pops loop frames it escapes (break); normal
    advance past a body end decrements the trip counter and re-enters.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set

import numpy as np

from repro.core import isa
from repro.core.isa import (Alu, Op, FLAG_ASYNC, FLAG_DEV_REG,
                            FLAG_DSTDEV_REG, FLAG_IMMB, FLAG_LEN_REG,
                            FLAG_MREG, FLAG_SRCDEV_REG, FLAG_THR_REG,
                            DEV_LOCAL)
from repro.core.memory import RegionTable
from repro.core.verifier import VerifiedOperator

_U64 = 1 << 64
_S63 = 1 << 63


def wrap64(x: int) -> int:
    """Fold a Python int into signed 64-bit two's complement."""
    return ((int(x) + _S63) % _U64) - _S63


def _alu(op: int, a: int, b: int) -> int:
    if op == Alu.ADD:
        return wrap64(a + b)
    if op == Alu.SUB:
        return wrap64(a - b)
    if op == Alu.MUL:
        return wrap64(a * b)
    if op == Alu.AND:
        return wrap64(a & b)
    if op == Alu.OR:
        return wrap64(a | b)
    if op == Alu.XOR:
        return wrap64(a ^ b)
    if op == Alu.SHL:
        return wrap64(a << (b & 63))
    if op == Alu.SHR:
        return wrap64((a % _U64) >> (b & 63))
    if op == Alu.EQ:
        return int(a == b)
    if op == Alu.NE:
        return int(a != b)
    if op == Alu.LT:
        return int(a < b)
    if op == Alu.GE:
        return int(a >= b)
    if op == Alu.MIN:
        return min(a, b)
    if op == Alu.MAX:
        return max(a, b)
    raise ValueError(f"bad alu op {op}")


@dataclasses.dataclass
class TraceEvent:
    pc: int
    op: Op
    is_async: bool = False
    n_words: int = 0          # memcpy payload
    remote: bool = False      # memcpy/load touching a non-home device
    src_remote: bool = False  # memcpy source on a non-home device
    dst_remote: bool = False  # memcpy destination on a non-home device
    dst_dev: int = -1         # memcpy destination device (for RTT counting)
    wait_thr: int = 0         # WAIT: resolved in-flight threshold


@dataclasses.dataclass
class Result:
    ret: int
    status: int
    steps: int
    regs: List[int]
    mem: np.ndarray
    trace: List[TraceEvent]
    fault: Optional[isa.FaultInfo] = None

    @property
    def ok(self) -> bool:
        return self.status == isa.STATUS_OK

    @property
    def faulted(self) -> bool:
        return self.status == isa.STATUS_PROT_FAULT


def run(op: VerifiedOperator, regions: RegionTable, mem: np.ndarray,
        params: Sequence[int] = (), *, home: int = 0,
        failed: Optional[Set[int]] = None, record_trace: bool = False,
        fuel: Optional[int] = None, protect: bool = True) -> Result:
    """Execute a verified operator against ``mem`` (modified in place)."""
    code = op.code
    base, mask, _ = regions.as_arrays()
    n_dev = int(mem.shape[0])
    failed = failed or set()
    fuel = int(fuel if fuel is not None else op.step_bound)

    regs = [0] * isa.NUM_REGS
    for i, p in enumerate(params):
        regs[i] = wrap64(p)

    # loop stack entries: [start, end, remaining]
    lstack: List[List[int]] = []
    inflight = 0
    pc = 0
    steps = 0
    halted = False
    ret_val = 0
    status = isa.STATUS_FELL_OFF
    fault: Optional[isa.FaultInfo] = None
    trace: List[TraceEvent] = []

    def dev_of(field: int, via_reg: bool) -> int:
        d = regs[field] if via_reg else field
        if d == DEV_LOCAL:
            return home
        return int(d) % n_dev

    def phys(rid: int, off: int) -> int:
        return int(base[rid]) + (wrap64(off) & int(mask[rid]))

    def dev_raw(field: int, via_reg: bool) -> int:
        return regs[field] if via_reg else field

    def dev_invalid(field: int, via_reg: bool) -> bool:
        """A register-held device operand must be DEV_LOCAL or a real
        device id; static fields stay verifier-territory (they wrap)."""
        if not via_reg:
            return False
        d = regs[field]
        return d != DEV_LOCAL and not (0 <= d < n_dev)

    def off_oob(rid: int, off: int) -> bool:
        """In-bounds iff masking is the identity: 0 <= off < size."""
        off = wrap64(off)
        return off != (off & int(mask[rid]))

    def word_fault(rid: int, off: int, field: int,
                   via_reg: bool) -> Optional[isa.FaultInfo]:
        """PROT_FAULT check shared by LOAD/STORE/CAS/CAA: wild device
        register, out-of-region offset, or a failed blade."""
        if not protect:
            return None
        if dev_invalid(field, via_reg):
            return isa.FaultInfo(pc=pc, opcode=int(o), addr=wrap64(off),
                                 device=regs[field])
        dev = dev_of(field, via_reg)
        if off_oob(rid, off) or dev in failed:
            return isa.FaultInfo(pc=pc, opcode=int(o), addr=wrap64(off),
                                 device=dev)
        return None

    n = code.shape[0]
    while not halted and pc < n and steps < fuel:
        row = code[pc]
        o = Op(int(row[isa.F_OP]))
        dst, a, b, c, d, e = (int(row[isa.F_DST]), int(row[isa.F_A]),
                              int(row[isa.F_B]), int(row[isa.F_C]),
                              int(row[isa.F_D]), int(row[isa.F_E]))
        flags, imm, imm2 = (int(row[isa.F_FLAGS]), int(row[isa.F_IMM]),
                            int(row[isa.F_IMM2]))
        steps += 1
        jumped = False
        skipped_to: Optional[int] = None
        flt: Optional[isa.FaultInfo] = None
        ev = TraceEvent(pc=pc, op=o) if record_trace else None

        if o == Op.NOP:
            pass
        elif o == Op.MOVI:
            regs[dst] = wrap64(imm)
        elif o == Op.ALU:
            rhs = imm if (flags & FLAG_IMMB) else regs[b]
            regs[dst] = _alu(d, regs[a], rhs)
        elif o == Op.LOAD:
            flt = word_fault(a, regs[b] + imm, e, bool(flags & FLAG_DEV_REG))
            if flt is None:
                dev = dev_of(e, bool(flags & FLAG_DEV_REG))
                regs[dst] = int(mem[dev, phys(a, regs[b] + imm)])
                if ev:
                    ev.remote = dev != home
        elif o == Op.STORE:
            flt = word_fault(a, regs[b] + imm, e, bool(flags & FLAG_DEV_REG))
            if flt is None:
                dev = dev_of(e, bool(flags & FLAG_DEV_REG))
                mem[dev, phys(a, regs[b] + imm)] = np.int64(regs[dst])
                if ev:
                    ev.remote = dev != home
        elif o == Op.MEMCPY:
            via_d = bool(flags & FLAG_DSTDEV_REG)
            via_s = bool(flags & FLAG_SRCDEV_REG)
            if flags & FLAG_LEN_REG:
                ln = min(max(regs[imm2], 0), imm)
            else:
                ln = imm
            ln = min(ln, isa.MAX_MEMCPY_WORDS,
                     int(mask[a]) + 1, int(mask[d]) + 1)
            is_async = bool(flags & FLAG_ASYNC)
            doff, soff = wrap64(regs[b]), wrap64(regs[e])
            if protect and ln > 0:
                # check order is part of the semantics (engines mirror
                # it): dst device, src device, dst window, src window
                if dev_invalid(dst, via_d):
                    flt = isa.FaultInfo(pc=pc, opcode=int(o), addr=doff,
                                        device=regs[dst])
                elif dev_invalid(c, via_s):
                    flt = isa.FaultInfo(pc=pc, opcode=int(o), addr=soff,
                                        device=regs[c])
                elif off_oob(a, doff) or doff + ln > int(mask[a]) + 1:
                    flt = isa.FaultInfo(pc=pc, opcode=int(o), addr=doff,
                                        device=dev_of(dst, via_d))
                elif off_oob(d, soff) or soff + ln > int(mask[d]) + 1:
                    flt = isa.FaultInfo(pc=pc, opcode=int(o), addr=soff,
                                        device=dev_of(c, via_s))
            if flt is None:
                ddev = dev_of(dst, via_d)
                sdev = dev_of(c, via_s)
                fail = (ddev in failed) or (sdev in failed)
                if fail:
                    regs[isa.ERR_REG] = wrap64(regs[isa.ERR_REG] | 1)
                else:
                    window = [int(mem[sdev, phys(d, soff + i)])
                              for i in range(ln)]
                    for i in range(ln):
                        mem[ddev, phys(a, doff + i)] = np.int64(window[i])
                if is_async:
                    inflight = min(inflight + 1, isa.MAX_INFLIGHT)
                if ev:
                    ev.is_async = is_async
                    ev.n_words = ln
                    ev.src_remote = sdev != home
                    ev.dst_remote = ddev != home
                    ev.remote = ev.src_remote or ev.dst_remote
                    ev.dst_dev = ddev
        elif o in (Op.CAS, Op.CAA):
            flt = word_fault(a, regs[b] + imm, e, bool(flags & FLAG_DEV_REG))
            if flt is None:
                dev = dev_of(e, bool(flags & FLAG_DEV_REG))
                addr = phys(a, regs[b] + imm)
                old = int(mem[dev, addr])
                if old == regs[c]:
                    new = regs[d] if o == Op.CAS else wrap64(old + regs[d])
                    mem[dev, addr] = np.int64(new)
                regs[dst] = old
                if ev:
                    ev.remote = dev != home
        elif o == Op.JUMP:
            cond = int(d)
            if cond == Alu.ALWAYS:
                take = True
            else:
                rhs = imm if (flags & FLAG_IMMB) else regs[b]
                take = bool(_alu(cond, regs[a], rhs))
            if take:
                pc_new = pc + 1 + imm2
                while lstack and lstack[-1][1] < pc_new:
                    lstack.pop()       # break out of escaped loops
                pc = pc_new
                jumped = True
        elif o == Op.LOOP:
            m = min(max(regs[b], 0), imm) if (flags & FLAG_MREG) else imm
            if m <= 0:
                skipped_to = pc + 1 + imm2
            else:
                assert len(lstack) < isa.LOOP_STACK_DEPTH, "verifier bug"
                lstack.append([pc + 1, pc + imm2, m])
        elif o == Op.WAIT:
            thr = regs[a] if (flags & FLAG_THR_REG) else imm
            inflight = min(inflight, max(int(thr), 0))
            if ev:
                ev.wait_thr = max(int(thr), 0)
        elif o == Op.RET:
            halted = True
            ret_val = regs[a]
            status = imm
        else:
            raise ValueError(f"pc {pc}: bad opcode {o}")

        if flt is not None:
            # protection fault: the lane halts with zero architectural
            # effect from the faulting instruction (containment) — the
            # step itself is counted (the MP fetched and killed it)
            halted = True
            status = isa.STATUS_PROT_FAULT
            fault = flt
        if record_trace:
            trace.append(ev)
        if halted:
            break
        if not jumped:
            pc_new = skipped_to if skipped_to is not None else pc + 1
            # normal advance: iterate / pop loops whose body just ended
            while lstack and pc_new == lstack[-1][1] + 1:
                lstack[-1][2] -= 1
                if lstack[-1][2] > 0:
                    pc_new = lstack[-1][0]
                    break
                lstack.pop()
            pc = pc_new

    if not halted and steps >= fuel:
        status = isa.STATUS_FUEL
    return Result(ret=ret_val, status=status, steps=steps, regs=regs,
                  mem=mem, trace=trace, fault=fault)
