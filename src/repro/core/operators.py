"""Stock Tiara operators — the paper's workload suite (Table 1).

Each workload bundles:
  * a region layout (`regions()`),
  * the operator program (`build()`), written against the builder DSL the
    way the paper's OpenCL-C frontend would emit it,
  * a memory populator for tests/benchmarks (`populate()`),
  * a numpy reference model (`reference()`).

Word-level layouts:
  graph traversal   64 B nodes = 8 words: [key, next_off, payload x6]
  page-table walk   three 8 B-entry levels; entries hold word offsets into
                    the next level / the data region; 4 KB pages
  distributed lock  region "lock": [latch, state, ...]; replicas hold the
                    same layout on other hosts
  paged KV fetch    block table: bid -> word offset into the KV pool;
                    blocks are ``block_bytes`` big (multiple DMA bursts if
                    > 32 KB, like a real DMA engine segmenting a transfer)
  MoE gather        expert table: expert id -> word offset of an 8 KB slab
  NSA select        score-then-select: fetch block i iff score[i] >= thr
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import isa, memory
from repro.core.isa import Alu
from repro.core.memory import RegionTable
from repro.core.program import OperatorBuilder, TiaraProgram

NODE_WORDS = 8                 # 64-byte graph nodes
PAGE_WORDS = 512               # 4 KB pages
MOE_SLAB_WORDS = 1024          # 8 KB expert slabs


def _chunks(total_words: int) -> List[int]:
    """Split a transfer into DMA bursts of at most MAX_MEMCPY_WORDS."""
    out = []
    left = int(total_words)
    while left > 0:
        c = min(left, isa.MAX_MEMCPY_WORDS)
        out.append(c)
        left -= c
    return out


# ===========================================================================
# 1. Graph traversal (pointer chasing) — paper §4.2
# ===========================================================================

@dataclasses.dataclass
class GraphWalk:
    n_nodes: int = 4096
    max_depth: int = 64
    reply_words: int = 64      # widen for batched serving (one slot/request)

    def regions(self) -> RegionTable:
        return memory.packed_table([("graph", self.n_nodes * NODE_WORDS),
                                    ("reply", self.reply_words)])

    def build(self, rt: RegionTable, *,
              reply_param: bool = False) -> TiaraProgram:
        """params: r0 = start node offset (words), r1 = depth; with
        ``reply_param``, r2 = reply word offset — batched requests write
        disjoint reply slots instead of all landing on slot 0."""
        b = OperatorBuilder("graph_walk", n_params=3 if reply_param else 2,
                            regions=rt)
        cur = b.mov(b.reg(), b.param(0))
        nxt = b.reg()
        with b.loop((b.param(1), self.max_depth)):
            b.load(nxt, "graph", cur, 1)       # register-chained load
            b.mov(cur, nxt)
        key = b.load(b.reg(), "graph", cur, 0)
        dst = b.param(2) if reply_param else b.const(0)
        b.memcpy(dst_region="reply", dst_off=dst,
                 src_region="graph", src_off=cur, n_words=NODE_WORDS)
        b.ret(key)
        return b.build()

    def populate(self, mem: np.ndarray, rt: RegionTable, *, device: int = 0,
                 seed: int = 0) -> np.ndarray:
        """Random ring permutation; returns the node order (offsets/8)."""
        rng = np.random.default_rng(seed)
        order = rng.permutation(self.n_nodes)
        graph = np.zeros(self.n_nodes * NODE_WORDS, dtype=np.int64)
        for i in range(self.n_nodes):
            node, nxt = order[i], order[(i + 1) % self.n_nodes]
            graph[node * NODE_WORDS + 0] = 10_000 + node
            graph[node * NODE_WORDS + 1] = nxt * NODE_WORDS
            graph[node * NODE_WORDS + 2: node * NODE_WORDS + 8] = \
                rng.integers(0, 1 << 32, size=6)
        memory.write_region(mem, rt, device, "graph", graph)
        return order

    def reference(self, order: np.ndarray, start_idx: int, depth: int) -> int:
        i = int(np.where(order == start_idx)[0][0])
        node = order[(i + depth) % self.n_nodes]
        return 10_000 + int(node)


# ===========================================================================
# 2. Three-level page-table walk — paper §4.3
# ===========================================================================

@dataclasses.dataclass
class PageTableWalk:
    """Block-indirection table over a disaggregated pool (paper §2.1).

    VA layout (word-addressed): [i1 : i2 : i3 : page offset], fanout
    entries per level, 4 KB (512-word) pages.
    """

    fanout: int = 64
    n_pages: int = 256
    reply_pages: int = 1       # widen for batched serving (one page/request)

    def __post_init__(self):
        self.page_shift = int(np.log2(PAGE_WORDS))
        self.bits = int(np.log2(self.fanout))

    def regions(self) -> RegionTable:
        return memory.packed_table([
            ("pt1", self.fanout),
            ("pt2", self.fanout * self.fanout),
            ("pt3", max(self.fanout ** 3 // 64, self.fanout ** 2)),
            ("data", self.n_pages * PAGE_WORDS),
            ("reply", PAGE_WORDS * self.reply_pages),
        ])

    def build_translate_only(self, rt: RegionTable) -> TiaraProgram:
        """Translation without the data fetch — the paper's Fig. 8
        throughput experiment ('each translation is one network message')."""
        b = OperatorBuilder("ptw3_translate", n_params=1, regions=rt)
        va = b.param(0)
        s1 = self.page_shift + 2 * self.bits
        s2 = self.page_shift + self.bits
        m = self.fanout - 1
        i1 = b.band(b.reg(), b.shr(b.reg(), va, s1), m)
        l2 = b.load(b.reg(), "pt1", i1)
        i2 = b.band(b.reg(), b.shr(b.reg(), va, s2), m)
        e2 = b.load(b.reg(), "pt2", b.add(b.reg(), l2, i2))
        i3 = b.band(b.reg(), b.shr(i2, va, self.page_shift), m)
        ppage = b.load(b.reg(), "pt3", b.add(l2, e2, i3))
        b.ret(ppage)
        return b.build()

    def build(self, rt: RegionTable, *,
              reply_param: bool = False) -> TiaraProgram:
        """params: r0 = virtual address (words). Returns physical page base.
        With ``reply_param``, r1 = reply word offset so batched requests
        stream their pages into disjoint reply slots."""
        b = OperatorBuilder("ptw3", n_params=2 if reply_param else 1,
                            regions=rt)
        va = b.param(0)
        s1 = self.page_shift + 2 * self.bits
        s2 = self.page_shift + self.bits
        m = self.fanout - 1
        i1 = b.band(b.reg(), b.shr(b.reg(), va, s1), m)
        l2 = b.load(b.reg(), "pt1", i1)              # chained loads: the
        i2 = b.band(b.reg(), b.shr(b.reg(), va, s2), m)
        e2 = b.load(b.reg(), "pt2", b.add(b.reg(), l2, i2))   # loaded value
        i3 = b.band(b.reg(), b.shr(i2, va, self.page_shift), m)
        ppage = b.load(b.reg(), "pt3", b.add(l2, e2, i3))     # is the next
        dst = b.param(1) if reply_param else b.movi(i2, 0)    # address
        b.memcpy(dst_region="reply", dst_off=dst,
                 src_region="data", src_off=ppage, n_words=PAGE_WORDS)
        b.ret(ppage)
        return b.build()

    def populate(self, mem: np.ndarray, rt: RegionTable, *, device: int = 0,
                 seed: int = 0) -> Dict[int, int]:
        """Maps ``n_pages`` random VAs; returns {va_words: phys_page_off}."""
        rng = np.random.default_rng(seed)
        f = self.fanout
        pt1 = np.zeros(f, dtype=np.int64)
        pt2 = np.zeros(f * f, dtype=np.int64)
        pt3 = np.zeros(rt["pt3"].size, dtype=np.int64)
        l2_alloc = 0
        l3_alloc = 0
        l2_of: Dict[int, int] = {}
        l3_of: Dict[Tuple[int, int], int] = {}
        va_map: Dict[int, int] = {}
        phys = rng.permutation(self.n_pages)
        for p in range(self.n_pages):
            i1, i2, i3 = (rng.integers(0, f), rng.integers(0, f),
                          rng.integers(0, f))
            if i1 not in l2_of:
                l2_of[i1] = l2_alloc * f
                pt1[i1] = l2_of[i1]
                l2_alloc += 1
            if (i1, i2) in l3_of:
                l3b = l3_of[(i1, i2)]
            else:
                l3b = l3_alloc * f
                if l3b + f > pt3.size:
                    continue
                l3_of[(i1, i2)] = l3b
                pt2[l2_of[i1] + i2] = l3b
                l3_alloc += 1
            ppage = int(phys[p]) * PAGE_WORDS
            pt3[l3b + i3] = ppage
            va = (int(i1) << (self.page_shift + 2 * self.bits)) | \
                 (int(i2) << (self.page_shift + self.bits)) | \
                 (int(i3) << self.page_shift)
            va_map[va] = ppage
        memory.write_region(mem, rt, device, "pt1", pt1)
        memory.write_region(mem, rt, device, "pt2", pt2)
        memory.write_region(mem, rt, device, "pt3", pt3)
        data = rng.integers(0, 1 << 40, size=self.n_pages * PAGE_WORDS)
        memory.write_region(mem, rt, device, "data", data.astype(np.int64))
        return va_map


# ===========================================================================
# 3. Distributed lock with replication — paper §4.4, Fig. 5
# ===========================================================================

@dataclasses.dataclass
class DistLock:
    max_retries: int = 8

    def regions(self) -> RegionTable:
        return memory.packed_table([("lock", 64)])   # [latch, state, ...]

    def build(self, rt: RegionTable) -> TiaraProgram:
        """params (Fig. 5): r0=latch_off, r1=state_off, r2=newVal,
        r3=replica1 dev, r4=replica1 off, r5=replica2 dev, r6=replica2 off."""
        b = OperatorBuilder("dist_lock", n_params=7, regions=rt)
        latch, state, new_val = b.param(0), b.param(1), b.param(2)
        r1d, r1o, r2d, r2o = (b.param(3), b.param(4), b.param(5), b.param(6))
        zero, one = b.const(0), b.const(1)
        ok = b.reg()
        acquired = b.mklabel("acquired")
        with b.loop(self.max_retries):                 # bounded CAS retry
            b.cas(ok, "lock", latch, cmp=zero, swap=one)
            b.jump(acquired, ok, Alu.EQ, 0)
        b.ret(ok, status=isa.STATUS_FAIL)              # Ret(FAIL)
        b.bind(acquired)
        old = b.load(b.reg(), "lock", state)
        b.store(new_val, "lock", state)
        b.memcpy(dst_region="lock", dst_off=r1o, dst_dev=r1d,   # async
                 src_region="lock", src_off=state, n_words=1, is_async=True)
        b.memcpy(dst_region="lock", dst_off=r2o, dst_dev=r2d,   # async
                 src_region="lock", src_off=state, n_words=1, is_async=True)
        b.wait(0)                                      # both replicas ACK
        b.store(zero, "lock", latch)                   # release
        b.ret(old)
        return b.build()


# ===========================================================================
# 4. Disaggregated PagedAttention KV fetch — paper §4.6
# ===========================================================================

@dataclasses.dataclass
class PagedKVFetch:
    """Resolve block ids through the Block Table and gather KV blocks.

    Layout: "req" holds the request's logical block-id list; "blocktable"
    maps logical block id -> word offset in "kvpool"; the operator streams
    each block to "reply" with async Memcpy, pipelining resolution with
    transfer (paper §3.4), and returns the block count.
    """

    n_blocks_pool: int = 512
    block_bytes: int = 8192
    max_req_blocks: int = 64
    reply_slots: int = 1       # widen for batched serving (slot/request)

    @property
    def block_words(self) -> int:
        return self.block_bytes // isa.WORD_BYTES

    def regions(self) -> RegionTable:
        return memory.packed_table([
            ("req", max(self.max_req_blocks, 64)),
            ("blocktable", max(self.n_blocks_pool, 64)),
            ("kvpool", self.n_blocks_pool * self.block_words),
            ("reply",
             self.max_req_blocks * self.block_words * self.reply_slots),
        ])

    def build(self, rt: RegionTable, *, remote_reply: bool = False,
              reply_param: bool = False) -> TiaraProgram:
        """params: r0 = n_blocks (dynamic, capped); with ``remote_reply``,
        r1 = the requester's device id and every KV block streams straight
        to the caller's reply region (an RDMA write per block) — no local
        staging copy, the deployment configuration of paper §4.6.  With
        ``reply_param``, the next param is the reply word offset so
        batched requests stream into disjoint reply slots."""
        n_params = 1 + int(remote_reply) + int(reply_param)
        b = OperatorBuilder("paged_kv_fetch", n_params=n_params,
                            regions=rt)
        n = b.param(0)
        client = b.param(1) if remote_reply else None
        i = b.const(0)
        bid = b.reg()
        paddr = b.reg()
        dst = b.mov(b.reg(), b.param(n_params - 1)) if reply_param \
            else b.const(0)
        with b.loop((n, self.max_req_blocks)):
            b.load(bid, "req", i)                      # logical block id
            b.load(paddr, "blocktable", bid)           # chained: id -> phys
            prev = 0
            for c in _chunks(self.block_words):
                # segment large blocks into DMA bursts; all async —
                # resolution of block i+1 overlaps transfer of block i
                if prev:
                    b.add(paddr, paddr, prev)
                if remote_reply:
                    b.memcpy(dst_region="reply", dst_off=dst, dst_dev=client,
                             src_region="kvpool", src_off=paddr,
                             n_words=c, is_async=True)
                else:
                    b.memcpy(dst_region="reply", dst_off=dst,
                             src_region="kvpool", src_off=paddr,
                             n_words=c, is_async=True)
                b.add(dst, dst, c)
                prev = c
            b.add(i, i, 1)
        b.wait(0)
        b.ret(n)
        return b.build()

    def populate(self, mem: np.ndarray, rt: RegionTable, *, device: int = 0,
                 seed: int = 0) -> np.ndarray:
        """Shuffled block table; returns the table (logical -> word offset)."""
        rng = np.random.default_rng(seed)
        table = rng.permutation(self.n_blocks_pool) * self.block_words
        memory.write_region(mem, rt, device, "blocktable",
                            table.astype(np.int64))
        pool = rng.integers(0, 1 << 40,
                            size=self.n_blocks_pool * self.block_words)
        memory.write_region(mem, rt, device, "kvpool", pool.astype(np.int64))
        return table.astype(np.int64)

    def make_request(self, mem: np.ndarray, rt: RegionTable,
                     block_ids: Sequence[int], *, device: int = 0) -> None:
        memory.write_region(mem, rt, device, "req",
                            np.asarray(block_ids, dtype=np.int64))

    def reference(self, mem_before: np.ndarray, rt: RegionTable,
                  table: np.ndarray, block_ids: Sequence[int],
                  *, device: int = 0) -> np.ndarray:
        pool = memory.read_region(mem_before, rt, device, "kvpool")
        out = [pool[int(table[int(b)]): int(table[int(b)]) + self.block_words]
               for b in block_ids]
        return np.concatenate(out) if out else np.zeros(0, dtype=np.int64)


# ===========================================================================
# 5. MoE expert gather — paper §4.5
# ===========================================================================

@dataclasses.dataclass
class MoEExpertGather:
    """Fetch k expert-weight slabs through a translation table."""

    n_experts: int = 256
    max_k: int = 64
    slab_words: int = MOE_SLAB_WORDS   # 8 KB slabs by default
    reply_slots: int = 1       # widen for batched serving (slot/request)

    def regions(self) -> RegionTable:
        return memory.packed_table([
            ("expert_ids", max(self.max_k, 64)),
            ("expert_table", max(self.n_experts, 64)),
            ("weights", self.n_experts * self.slab_words),
            ("reply", self.max_k * self.slab_words * self.reply_slots),
        ])

    def build(self, rt: RegionTable, *, remote_reply: bool = False,
              reply_param: bool = False) -> TiaraProgram:
        """params: r0 = k (dynamic, capped); with ``remote_reply``, r1 = the
        requester's device and slabs stream straight to the caller.  With
        ``reply_param``, the next param is the reply word offset (disjoint
        slots for batched serving)."""
        n_params = 1 + int(remote_reply) + int(reply_param)
        b = OperatorBuilder("moe_expert_gather",
                            n_params=n_params, regions=rt)
        k = b.param(0)
        client = b.param(1) if remote_reply else None
        i = b.const(0)
        eid, paddr = b.reg(), b.reg()
        dst = b.mov(b.reg(), b.param(n_params - 1)) if reply_param \
            else b.const(0)
        with b.loop((k, self.max_k)):
            b.load(eid, "expert_ids", i)
            b.load(paddr, "expert_table", eid)          # paged translation
            if remote_reply:
                b.memcpy(dst_region="reply", dst_off=dst, dst_dev=client,
                         src_region="weights", src_off=paddr,
                         n_words=self.slab_words, is_async=True)
            else:
                b.memcpy(dst_region="reply", dst_off=dst,
                         src_region="weights", src_off=paddr,
                         n_words=self.slab_words, is_async=True)
            b.add(dst, dst, self.slab_words)
            b.add(i, i, 1)
        b.wait(0)
        b.ret(k)
        return b.build()

    def populate(self, mem: np.ndarray, rt: RegionTable, *, device: int = 0,
                 seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        table = rng.permutation(self.n_experts) * self.slab_words
        memory.write_region(mem, rt, device, "expert_table",
                            table.astype(np.int64))
        w = rng.integers(0, 1 << 40, size=self.n_experts * self.slab_words)
        memory.write_region(mem, rt, device, "weights", w.astype(np.int64))
        return table.astype(np.int64)


# ===========================================================================
# 6. NSA score-then-select — paper §2.1 (Table 1)
# ===========================================================================

@dataclasses.dataclass
class NSASelect:
    """Fetch KV block i iff its compressed-key score clears a threshold —
    the decision of *what to read* depends on remote data."""

    n_scores: int = 64
    block_words: int = 512

    def regions(self) -> RegionTable:
        return memory.packed_table([
            ("scores", max(self.n_scores, 64)),
            ("blockmap", max(self.n_scores, 64)),
            ("kvpool", self.n_scores * self.block_words),
            ("reply", self.n_scores * self.block_words),
        ])

    def build(self, rt: RegionTable) -> TiaraProgram:
        """params: r0 = n (capped), r1 = threshold. Returns count fetched."""
        b = OperatorBuilder("nsa_select", n_params=2, regions=rt)
        n, thr = b.param(0), b.param(1)
        i, cnt = b.const(0), b.const(0)
        s, paddr, dst = b.reg(), b.reg(), b.reg()
        with b.loop((n, self.n_scores)):
            skip = b.mklabel("skip")
            b.load(s, "scores", i)
            b.jump(skip, s, Alu.LT, thr)                # score < thr: skip
            b.load(paddr, "blockmap", i)
            b.mul(dst, cnt, self.block_words)
            b.memcpy(dst_region="reply", dst_off=dst,
                     src_region="kvpool", src_off=paddr,
                     n_words=self.block_words, is_async=True)
            b.add(cnt, cnt, 1)
            b.bind(skip)
            b.add(i, i, 1)
        b.wait(0)
        b.ret(cnt)
        return b.build()

    def populate(self, mem: np.ndarray, rt: RegionTable, *, device: int = 0,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(seed)
        scores = rng.integers(0, 100, size=self.n_scores).astype(np.int64)
        blockmap = (rng.permutation(self.n_scores)
                    * self.block_words).astype(np.int64)
        memory.write_region(mem, rt, device, "scores", scores)
        memory.write_region(mem, rt, device, "blockmap", blockmap)
        pool = rng.integers(0, 1 << 40, size=self.n_scores * self.block_words)
        memory.write_region(mem, rt, device, "kvpool", pool.astype(np.int64))
        return scores, blockmap


ALL_WORKLOADS = {
    "graph_walk": GraphWalk,
    "ptw3": PageTableWalk,
    "dist_lock": DistLock,
    "paged_kv_fetch": PagedKVFetch,
    "moe_expert_gather": MoEExpertGather,
    "nsa_select": NSASelect,
}
