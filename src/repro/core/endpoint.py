"""Queue-pair endpoint — the one way to invoke Tiara operators.

The paper's execution model is an RNIC, not a function call: clients
*post* pre-registered operator invocations to per-tenant queue pairs and
poll completions, while the NIC decides how to batch whatever is sitting
in the queues.  This module is that surface in software:

  * :class:`TiaraEndpoint` models one NIC plus its attached memory blade.
    It owns the region table, the ``(n_devices, pool_words)`` pool, and
    the operator registry — callers never thread a raw numpy pool
    through invocations again.
  * :meth:`TiaraEndpoint.connect` admits a tenant: the tenant's region
    layout is re-registered under its namespace in the shared pool, a
    :class:`~repro.core.memory.RegionView` and a full
    :class:`~repro.core.memory.Grant` over exactly those regions are
    wired automatically, and the tenant gets back a :class:`Session` —
    its queue pair.
  * :meth:`Session.post` enqueues one operator invocation on the send
    queue and returns a :class:`Completion` handle immediately; nothing
    executes yet.
  * :meth:`TiaraEndpoint.doorbell` drains *all* sessions' outstanding
    posts into one wave in global arrival order and runs it through the
    mixed-batch planner + dispatch cost model (one XLA launch for the
    whole multi-tenant wave in the common case).  Results retire into
    per-session completion queues in per-session FIFO order; contended
    STORE/CAS posts keep the engines' deterministic
    lowest-arrival-index-wins semantics because the wave *is* the
    arrival order.
  * **Split-phase completion** (the paper's async MEMCPY + WAIT pair at
    the API level): ``doorbell(wait=False)`` *launches* the wave and
    returns an in-flight :class:`WaveHandle` immediately — XLA's async
    dispatch keeps computing while the caller posts the next wave
    against the in-flight pool (the device array chains the data
    dependency), so post -> doorbell -> post -> poll pipelines.
    Completions retire on :meth:`Session.poll_cq` (non-blocking, ready
    waves only), :meth:`TiaraEndpoint.wait_any` (block for the oldest
    wave), :meth:`TiaraEndpoint.wait_all`, :meth:`Completion.wait`, or
    :meth:`WaveHandle.wait`.  Waves retire strictly in launch order, so
    per-session FIFO survives any number of waves in flight; each
    retired CQE carries a frozen :class:`CompletionEvent` with status,
    return value, and retire timestamp.
  * :meth:`Session.poll_cq` / :meth:`Completion.result` are the receive
    side.  ``result()`` rings the doorbell on demand, so single-request
    control-path code stays one line.

An optional ``flush_watermark`` auto-rings the doorbell once that many
posts are outstanding across all sessions — the NIC analogue of a
doorbell-batching driver.  Watermark rings are split-phase
(``doorbell(wait=False)``): the triggering ``post()`` returns as soon as
the wave is *launched*, so posts keep pipelining through an auto-ring.

Overload semantics (the serving-loop substrate — see
``core/serving_loop.py``): a ``max_sq_depth`` bounds each session's send
queue — a post to a full SQ retires immediately with ``STATUS_EAGAIN``
(the RNIC "queue full" errno) and never executes; a per-post
``deadline_s`` is enforced at admission and again when the doorbell
drains the queues — an expired post retires ``STATUS_TIMEOUT`` instead
of joining the wave (the ``STATUS_FLUSHED`` retirement machinery from
the QP error path, generalized).  An optional ``admission`` hook
rejects posts before they are enqueued.  Time is injectable
(``clock``/``sleep`` constructor hooks), so retry backoff, deadlines,
and the fault harness's stall/delay injections run deterministically
under a virtual clock.

The PR-3 deprecated ``registry.invoke*`` shims are gone; this surface is
the only way to invoke operators.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import faults, isa, memory, pyvm, vm, wcet
from repro.core import registry as _registry
from repro.core.costmodel import DispatchCostModel
from repro.core.memory import Grant, RegionTable, RegionView
from repro.core.program import TiaraProgram
from repro.core.registry import OperatorRegistry

# the wave/engine mode vocabularies are the registry's — one source of
# truth, the endpoint only adds the single-request "interp" spelling
_WAVE_MODES = _registry._MIXED_MODES
_SINGLE_OP_MODES = tuple(m for m in _registry._BATCHED_MODES
                         if m != "auto")
_SINGLE_REQ_MODES = ("interp",)
DOORBELL_MODES = _WAVE_MODES + _SINGLE_OP_MODES + _SINGLE_REQ_MODES


class EndpointError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class CompletionEvent:
    """One retired CQE, frozen at retirement time: what completed, with
    what result, in which wave, and when it retired (so callers can
    attribute latency to the wave that hid it)."""

    seq: int              # global arrival index of the post
    op_name: str
    ret: int
    status: int
    steps: int
    wave: int             # doorbell wave id the post retired with
    retired_at: float     # endpoint clock at retirement
    fault: Optional[isa.FaultInfo] = None   # set iff STATUS_PROT_FAULT

    @property
    def ok(self) -> bool:
        return self.status == isa.STATUS_OK

    @property
    def faulted(self) -> bool:
        return self.status == isa.STATUS_PROT_FAULT

    @property
    def flushed(self) -> bool:
        return self.status == isa.STATUS_FLUSHED

    @property
    def timed_out(self) -> bool:
        return self.status == isa.STATUS_TIMEOUT

    @property
    def rejected(self) -> bool:
        return self.status == isa.STATUS_EAGAIN


@dataclasses.dataclass(eq=False)
class Completion:
    """Handle for one posted invocation (one CQE once retired).
    Identity equality: two handles are the same completion only if they
    are the same object (value comparison over the regs array would be
    meaningless for a handle).

    ``seq`` is the global arrival index — the deterministic position of
    this post in the next wave.  Until :attr:`done`, the result fields
    hold zeros; :meth:`result` rings the owning endpoint's doorbell on
    demand so callers never have to flush by hand.  Once the post is
    *in flight* (its wave launched with ``doorbell(wait=False)``),
    :attr:`wave_handle` points at the wave and :meth:`wait` /
    :meth:`result` retire through it; at retirement :attr:`event` holds
    the frozen :class:`CompletionEvent`.
    """

    session: "Session" = dataclasses.field(repr=False)
    seq: int
    op_id: int
    op_name: str
    params: Tuple[int, ...]
    home: int
    deadline: Optional[float] = None    # absolute endpoint-clock deadline
    done: bool = False
    ret: int = 0
    status: int = 0
    steps: int = 0
    regs: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    wave_handle: Optional["WaveHandle"] = dataclasses.field(
        default=None, repr=False)
    event: Optional[CompletionEvent] = None
    fault: Optional[isa.FaultInfo] = None   # set iff STATUS_PROT_FAULT

    @property
    def ok(self) -> bool:
        return self.done and self.status == isa.STATUS_OK

    @property
    def faulted(self) -> bool:
        return self.done and self.status == isa.STATUS_PROT_FAULT

    @property
    def flushed(self) -> bool:
        return self.done and self.status == isa.STATUS_FLUSHED

    @property
    def timed_out(self) -> bool:
        """Deadline expired before launch (``STATUS_TIMEOUT``, no run)."""
        return self.done and self.status == isa.STATUS_TIMEOUT

    @property
    def rejected(self) -> bool:
        """Refused at admission (``STATUS_EAGAIN``: SQ full, rate
        limited, or load shed — no run; safe to re-post later)."""
        return self.done and self.status == isa.STATUS_EAGAIN

    @property
    def in_flight(self) -> bool:
        """Launched but not yet retired."""
        return not self.done and self.wave_handle is not None

    def wait(self) -> "Completion":
        """Block until this post retires: an in-flight post retires its
        wave (and, FIFO, every earlier wave); a post still sitting in
        the send queue rings the doorbell first.  Returns ``self``."""
        if not self.done:
            if self.wave_handle is not None:
                self.session.endpoint._retire_through(self.wave_handle)
            else:
                self.session.endpoint.doorbell()
        return self

    def result(self, *, flush: bool = True, check: bool = True) -> int:
        """The operator's return value, ringing the doorbell if this
        post is still outstanding (``flush=False`` raises instead; an
        already *launched* post never needs a flush — it just retires
        its in-flight wave).

        With ``check=True`` (default) a non-OK status raises — like an
        RNIC CQE error — so failures can't masquerade as values; pass
        ``check=False`` (or read ``.ret``/``.status``/``.ok`` directly)
        for operators whose failure status is an expected outcome
        (e.g. a busy lock)."""
        if not self.done:
            if self.wave_handle is not None:
                self.session.endpoint._retire_through(self.wave_handle)
            elif not flush:
                raise EndpointError(
                    f"completion for {self.op_name!r} (seq {self.seq}) "
                    f"still outstanding; ring doorbell() first")
            else:
                self.session.endpoint.doorbell()
        # result() is a consuming read: drop this CQE from the session's
        # completion queue so a later poll_cq() doesn't deliver it twice.
        # Membership is identity (eq=False), so an already-polled handle
        # is simply absent — no exception to swallow, and engine errors
        # from the retire path above propagate untouched.
        cq = self.session._cq
        for i, c in enumerate(cq):
            if c is self:
                del cq[i]
                break
        if check and self.status != isa.STATUS_OK:
            detail = f" [{self.fault.describe()}]" if self.fault else ""
            raise EndpointError(
                f"op {self.op_name!r} (seq {self.seq}) completed with "
                f"status {self.status} (ret {self.ret}){detail}; use "
                f"result(check=False) or .ret/.status for expected "
                f"failures")
        return self.ret


class WaveHandle:
    """One launched-but-unretired doorbell wave (``doorbell(wait=False)``).

    The engine launch has been *issued* — XLA's async dispatch computes
    in the background while the caller posts more work — but no CQE has
    been delivered: per-session FIFO requires waves to retire strictly
    in launch order, which :meth:`TiaraEndpoint._retire_through`
    enforces.  ``completions`` lists the wave's posts in global arrival
    order."""

    def __init__(self, endpoint: "TiaraEndpoint", wave_id: int,
                 completions: Sequence[Completion], res):
        self.endpoint = endpoint
        self.wave_id = wave_id
        self.completions = tuple(completions)
        self._res = res
        self.done = False
        # launch metadata for online cost-model calibration: _retire
        # feeds (measured wall clock, batch, steps) back into
        # DispatchCostModel.observe_dispatch.  obs_mode is None for
        # waves the model has no closed form for (sharded, interp).
        self.launched_at = 0.0
        self.obs_key: Optional[int] = None
        self.obs_mode: Optional[str] = None
        self.obs_steps = 0
        self.obs_chain = 0
        self.obs_contention = 0.0

    def __len__(self) -> int:
        return len(self.completions)

    def __repr__(self) -> str:
        state = "retired" if self.done else "in-flight"
        return (f"WaveHandle(wave={self.wave_id}, "
                f"n={len(self.completions)}, {state})")

    @property
    def ready(self) -> bool:
        """Non-blocking: has the launch landed on device?  (Retirement
        still only happens on a poll/wait call, and only in wave
        order.)"""
        return self.done or vm.result_ready(self._res)

    def wait(self) -> List[Completion]:
        """Block until this wave (and, FIFO, every earlier one) retires;
        returns the wave's completions in arrival order."""
        self.endpoint._retire_through(self)
        return list(self.completions)


class Session:
    """One tenant's queue pair: a send queue of posted invocations and a
    completion queue of retired ones, both FIFO in post order."""

    def __init__(self, endpoint: "TiaraEndpoint", tenant: str,
                 view: RegionView, grant: Grant):
        self.endpoint = endpoint
        self.tenant = tenant
        self.view = view
        self.grant = grant
        self._ops: Dict[str, int] = {}
        self._sq: List[Completion] = []      # posted, not yet drained
        self._cq: List[Completion] = []      # retired, not yet polled
        self._error: Optional[isa.FaultInfo] = None   # QP error state

    # -- error state (RNIC QP semantics) ---------------------------------

    @property
    def in_error(self) -> bool:
        """True once a post of this session took a runtime protection
        fault.  While in error, new posts (and posts still sitting in
        the send queue at retirement time) retire immediately with
        ``STATUS_FLUSHED`` and never execute — the RNIC QP error state.
        Posts that were already *launched* in a wave are concurrent with
        the faulting one and retire with their real results."""
        return self._error is not None

    @property
    def error(self) -> Optional[isa.FaultInfo]:
        """The fault that errored this session (None when healthy)."""
        return self._error

    def reset(self) -> "Session":
        """Clear the error state (the QP reset->init transition); posts
        flow again.  Flushed CQEs already delivered stay delivered."""
        self._error = None
        return self

    # -- control path ---------------------------------------------------

    def register(self, program: TiaraProgram) -> int:
        """Register an operator (compile output -> verify against this
        tenant's grant -> op_id); remembered by ``program.name`` so posts
        can use the name."""
        op_id = self.endpoint.registry.register(self.tenant, program)
        self._ops[program.name] = op_id
        return op_id

    def op_id(self, name: str) -> int:
        return self._ops[name]

    @property
    def pool(self) -> np.ndarray:
        """The endpoint's pool, writable — for host-side (control path)
        population through this tenant's :attr:`view`.

        Do NOT hold the returned array across a doorbell: every wave
        rebinds the endpoint's pool to the engine's output, so a stale
        reference reads/writes an orphaned copy.  Re-fetch ``.pool``
        (or use :meth:`write_region`/:meth:`read_region`) after each
        doorbell."""
        return self.endpoint.host_mem()

    def write_region(self, region: str, data: Sequence[int], *,
                     device: int = 0, offset: int = 0) -> None:
        memory.write_region(self.endpoint.host_mem(), self.view, device,
                            region, data, offset=offset)

    def read_region(self, region: str, *, device: int = 0, offset: int = 0,
                    count: Optional[int] = None) -> np.ndarray:
        return memory.read_region(self.endpoint._host_view(), self.view,
                                  device, region, offset=offset,
                                  count=count)

    # -- data path ------------------------------------------------------

    def _resolve(self, op: Union[str, int]) -> Tuple[int, str]:
        """Name or op_id -> (op_id, name), rejecting other tenants' slots:
        a queue pair may only post operators registered through it."""
        if isinstance(op, str):
            return self._ops[op], op
        op_id = int(op)
        slot = self.endpoint.registry[op_id]
        if slot.tenant != self.tenant:
            raise EndpointError(
                f"op {op_id} belongs to tenant {slot.tenant!r}; session "
                f"{self.tenant!r} cannot post it")
        return op_id, slot.verified.program.name

    def _make(self, op: Union[str, int], params: Sequence[int] = (), *,
              home: int = 0,
              deadline_s: Optional[float] = None) -> Completion:
        """Build (and sequence) one invocation handle WITHOUT enqueueing
        it — the serving loop's admission path, which holds posts in its
        own per-tenant queues until wave formation.  ``deadline_s`` is
        relative; the handle carries the absolute endpoint-clock
        deadline."""
        op_id, name = self._resolve(op)
        deadline = None if deadline_s is None else \
            self.endpoint._clock() + float(deadline_s)
        return Completion(session=self, seq=self.endpoint._next_seq(),
                          op_id=op_id, op_name=name,
                          params=tuple(int(p) for p in params),
                          home=int(home), deadline=deadline)

    def post(self, op: Union[str, int], params: Sequence[int] = (), *,
             home: int = 0,
             deadline_s: Optional[float] = None) -> Completion:
        """Enqueue one invocation; returns its completion handle.  No
        execution happens until a doorbell (explicit, watermark, or
        ``Completion.result()``).

        Admission order (each reject retires exactly one CQE, never
        executes): a session in error flushes (``STATUS_FLUSHED``); an
        already-expired ``deadline_s`` times out (``STATUS_TIMEOUT``);
        the endpoint's ``admission`` hook may refuse with any status;
        a full bounded SQ rejects with ``STATUS_EAGAIN`` — the
        backpressure signal a caller handles by draining completions or
        re-posting later.  A live deadline travels with the post and is
        re-checked when the doorbell drains the queue."""
        ep = self.endpoint
        c = self._make(op, params, home=home, deadline_s=deadline_s)
        if self._error is not None:
            # QP in error: the post is flushed, never enqueued/executed
            ep._retire_immediate(c, isa.STATUS_FLUSHED)
            return c
        if c.deadline is not None and c.deadline <= ep._clock():
            ep._retire_immediate(c, isa.STATUS_TIMEOUT)
            return c
        if ep.admission is not None:
            status = ep.admission(c)
            if status is not None:
                ep._retire_immediate(c, int(status))
                return c
        if ep.max_sq_depth is not None and len(self._sq) >= ep.max_sq_depth:
            ep._retire_immediate(c, isa.STATUS_EAGAIN)
            return c
        self._sq.append(c)
        ep._posted(c)
        return c

    @property
    def outstanding(self) -> int:
        return len(self._sq)

    def poll_cq(self, n: Optional[int] = None) -> List[Completion]:
        """Pop up to ``n`` retired completions (all of them by default)
        in per-session FIFO order.

        Polling first retires any in-flight waves whose launches have
        landed (in wave order, never blocking): the split-phase receive
        path — post, ring ``doorbell(wait=False)``, keep working, poll
        until the CQEs appear."""
        self.endpoint._retire_ready()
        n = len(self._cq) if n is None else \
            max(0, min(int(n), len(self._cq)))
        out, self._cq = self._cq[:n], self._cq[n:]
        return out

    # -- oracle / simulator path ----------------------------------------

    def trace(self, op: Union[str, int], params: Sequence[int] = (), *,
              home: int = 0, record_trace: bool = True) -> pyvm.Result:
        """Run one invocation on the ``pyvm`` oracle against the
        endpoint's pool (in place), recording the event trace the cycle
        simulator replays.  This is the control-path debugging/timing
        entry point; the data path is :meth:`post` + doorbell."""
        op_id, _ = self._resolve(op)
        slot = self.endpoint.registry[op_id]
        return pyvm.run(slot.verified, self.endpoint.regions,
                        self.endpoint.host_mem(), list(params), home=home,
                        record_trace=record_trace)


class TiaraEndpoint:
    """One NIC + memory blade: region table, pool, registry, doorbell.

    ``pool_words`` is the capacity of the attached DRAM; tenants carve
    regions out of it at :meth:`connect` time (registration order, each
    region naturally aligned).  ``flush_watermark`` auto-rings the
    doorbell when that many posts are outstanding across all sessions.
    """

    def __init__(self, pool_words: int, *, n_devices: int = 1,
                 flush_watermark: Optional[int] = None,
                 max_steps: Optional[int] = None,
                 cost_model: Optional[DispatchCostModel] = None,
                 retry_limit: int = 3, retry_backoff_s: float = 0.001,
                 retry_jitter: float = 0.0,
                 retry_jitter_seed: Optional[int] = None,
                 max_sq_depth: Optional[int] = None,
                 admission: Optional[
                     Callable[[Completion], Optional[int]]] = None,
                 clock: Optional[Callable[[], float]] = None,
                 sleep: Optional[Callable[[float], None]] = None,
                 budget: Optional[wcet.Budget] = wcet.DEFAULT_BUDGET,
                 sep: str = "/"):
        self.regions = RegionTable(pool_words)
        self.registry = OperatorRegistry(self.regions, n_devices=n_devices,
                                         max_steps=max_steps,
                                         cost_model=cost_model,
                                         budget=budget)
        self.n_devices = int(n_devices)
        self.mem = memory.make_pool(n_devices, self.regions)
        self.flush_watermark = flush_watermark
        self.retry_limit = int(retry_limit)       # transient-launch retries
        self.retry_backoff_s = float(retry_backoff_s)
        # retry backoff jitter: a seeded rng makes chaos runs
        # reproducible — the same seed sleeps the same sequence
        self.retry_jitter = float(retry_jitter)
        self._retry_rng = np.random.default_rng(retry_jitter_seed)
        # bounded per-session SQ + admission hook (overload semantics —
        # see the module docstring); None = unbounded / admit everything
        self.max_sq_depth = None if max_sq_depth is None \
            else int(max_sq_depth)
        self.admission = admission
        # injectable time: every timestamp, deadline check, backoff and
        # injected delay goes through these, so tests and benches swap
        # in a virtual clock and never real-sleep
        self._clock = clock if clock is not None else time.monotonic
        self._sleep = sleep if sleep is not None else time.sleep
        self._last_retire_t = self._clock()
        self.sep = sep
        self._sessions: Dict[str, Session] = {}
        self._seq = 0
        self._outstanding = 0
        self._inflight: List[WaveHandle] = []
        self._wave_seq = 0
        # fault-injection state (see core/faults.py); failed_devices is
        # also the live health set threaded into every engine dispatch
        self.failed_devices: set = set()
        self._pending_corrupt: List[Tuple[int, int, int]] = []
        self._transient_left = 0
        self._poison_left = 0
        self._pending_delays: List[float] = []
        self._stalls: Dict[str, float] = {}      # tenant -> stalled until
        # adaptive re-homing state (INDIGO-style, see note_access /
        # rehome): which device row holds each region's live copy, the
        # per-region per-device access audit, and the migration audit
        self._region_home: Dict[str, int] = {}
        self._region_access: Dict[str, np.ndarray] = {}
        self._dev_access = np.zeros(self.n_devices, dtype=np.int64)
        self.cross_device_words = 0      # words served home != accessor
        self.rehome_count = 0
        self.rehomed_words = 0

    @classmethod
    def for_tenants(cls, named: Sequence[Tuple[str, RegionTable]], *,
                    n_devices: int = 1, sep: str = "/", **kwargs
                    ) -> Tuple["TiaraEndpoint", Dict[str, Session]]:
        """Build an endpoint sized exactly for the given per-tenant
        region layouts and connect every tenant — the one-call setup for
        examples, benchmarks, and tests."""
        cursor = 0
        for _, table in named:
            cursor = memory.aligned_end(cursor, table)
        ep = cls(max(cursor, 1), n_devices=n_devices, sep=sep, **kwargs)
        sessions = {tenant: ep.connect(tenant, table)
                    for tenant, table in named}
        return ep, sessions

    # -- tenants --------------------------------------------------------

    def connect(self, tenant: str, regions: RegionTable) -> Session:
        """Admit a tenant: re-register its region layout under
        ``tenant/<name>`` in the shared pool, wire up its view + grant,
        and hand back its queue pair."""
        if self.sep in tenant:
            raise EndpointError(
                f"tenant name {tenant!r} must not contain {self.sep!r}")
        if tenant in self._sessions:
            raise EndpointError(f"tenant {tenant!r} already connected")
        # admission is all-or-nothing: check capacity BEFORE registering
        # anything (RegionTable has no unregister, so a mid-layout
        # failure would leak the tenant's earlier regions forever)
        need = memory.aligned_end(self.regions.high_water, regions)
        if need > self.regions.pool_words:
            raise EndpointError(
                f"cannot admit tenant {tenant!r}: layout needs "
                f"{need} words, pool has {self.regions.pool_words}")
        for r in regions:
            try:
                self.regions.register(f"{tenant}{self.sep}{r.name}",
                                      r.size, writable=r.writable)
            except ValueError as e:
                raise EndpointError(
                    f"cannot admit tenant {tenant!r}: {e}") from e
        view = RegionView(self.regions, f"{tenant}{self.sep}")
        grant = Grant.all_of(view, tenant)
        self.registry.add_tenant(grant)
        session = Session(self, tenant, view, grant)
        self._sessions[tenant] = session
        return session

    def session(self, tenant: str) -> Session:
        return self._sessions[tenant]

    def _host_view(self) -> np.ndarray:
        """Host-side (possibly read-only) view of the pool.  While waves
        are in flight the pool is a device future; viewing it blocks
        until the last launched wave lands — a read must observe every
        launched wave, in-flight or not."""
        if not isinstance(self.mem, np.ndarray):
            self.mem = np.asarray(self.mem)
        return self.mem

    def host_mem(self) -> np.ndarray:
        """The pool, guaranteed host-writable for control-path access.

        After a doorbell the pool may be a read-only view of the last
        launch's device buffer (or, split-phase, a device future not yet
        landed); the block + copy happen lazily here, so the data path
        never pays for them."""
        mem = self._host_view()
        if not mem.flags.writeable:
            self.mem = mem = mem.copy()
        return mem

    @property
    def sessions(self) -> Dict[str, Session]:
        return dict(self._sessions)

    # -- fault injection (see core/faults.py) -----------------------------

    def inject(self, plan: "faults.FaultPlan") -> None:
        """Apply a :class:`~repro.core.faults.FaultPlan`: device
        failures take effect on the next dispatch, corruptions before
        the next wave, transient/poison counters accumulate."""
        self.failed_devices |= set(plan.fail_devices)
        for d, w, _ in plan.corrupt:
            if not (0 <= d < self.n_devices
                    and 0 <= w < self.regions.pool_words):
                raise EndpointError(
                    f"corruption target (dev {d}, word {w}) outside the "
                    f"{self.n_devices}x{self.regions.pool_words} pool")
        self._pending_corrupt.extend(plan.corrupt)
        self._transient_left += plan.transient_launch_failures
        self._poison_left += plan.poison_materialize
        self._pending_delays.extend(plan.delay_waves)
        now = self._clock()
        for tenant, seconds in plan.stall_tenants:
            if tenant not in self._sessions:
                raise EndpointError(
                    f"cannot stall unknown tenant {tenant!r}")
            until = now + seconds
            self._stalls[tenant] = max(self._stalls.get(tenant, 0.0),
                                       until)

    def revive(self, *devices: int) -> None:
        """Bring failed devices back (all of them with no argument)."""
        if devices:
            self.failed_devices -= set(int(d) for d in devices)
        else:
            self.failed_devices.clear()

    def clear_faults(self) -> None:
        """Drop every pending injection, including device failures."""
        self.failed_devices.clear()
        self._pending_corrupt.clear()
        self._transient_left = 0
        self._poison_left = 0
        self._pending_delays.clear()
        self._stalls.clear()

    def stalled(self, tenant: str) -> bool:
        """Is the tenant's SQ currently withheld from doorbell drains
        (an injected ``stall_tenant`` still in effect)?"""
        return self._stalls.get(tenant, 0.0) > self._clock()

    # -- adaptive re-homing (INDIGO-style access audit + control-path
    #    migration) --------------------------------------------------------
    #
    # A region's *home* is the device row that holds its live copy —
    # posts against it execute there, and an accessor on another device
    # pays cross-device reply traffic.  The endpoint keeps a per-region
    # per-device access audit (``note_access``, fed by serving-side
    # resolvers per post), and ``rehome`` migrates a region's content
    # between device rows on the control path — between doorbells, never
    # under an in-flight wave.  The audit also feeds the cost model's
    # home-skew EWMA, so ``choose_placement`` prices the hot home's
    # sub-wave as the sharded critical path.

    def home_of(self, region: str) -> int:
        """The device row holding ``region``'s live copy (0 until the
        first ``rehome``)."""
        return self._region_home.get(region, 0)

    def note_access(self, region: str, device: int, words: int = 1) -> None:
        """Audit one access of ``words`` pool words against ``region``
        from ``device`` (the accessor's device — e.g. the client a reply
        streams to).  Accumulates the per-region and per-device counts,
        charges ``cross_device_words`` when the accessor is not the
        region's home, and feeds the cost model's home-skew EWMA."""
        try:
            self.regions[region]
        except KeyError:
            raise EndpointError(
                f"note_access: unknown region {region!r}") from None
        if not 0 <= int(device) < self.n_devices:
            raise EndpointError(
                f"note_access: device {device} outside mesh of "
                f"{self.n_devices}")
        counts = self._region_access.get(region)
        if counts is None:
            counts = self._region_access[region] = np.zeros(
                self.n_devices, dtype=np.int64)
        counts[int(device)] += int(words)
        self._dev_access[int(device)] += int(words)
        if int(device) != self.home_of(region):
            self.cross_device_words += int(words)
        self.cost_model.observe_home_access(self._dev_access)

    def access_counts(self, region: str) -> np.ndarray:
        """Per-device access-word counts for ``region`` since its last
        rehome (a copy; zeros before any access)."""
        counts = self._region_access.get(region)
        if counts is None:
            return np.zeros(self.n_devices, dtype=np.int64)
        return counts.copy()

    def rehome(self, region: str, device: int) -> int:
        """Control-path migration: copy ``region``'s content from its
        current home row to ``device``'s row and make that the home.
        Returns the words moved (0 when already home).  The access
        window resets, so the next rehome decision is made on fresh
        traffic.  Raises on unknown regions, out-of-mesh or failed
        target devices, and while waves are in flight (migration is a
        between-doorbells operation, like fault injection)."""
        try:
            r = self.regions[region]
        except KeyError:
            raise EndpointError(
                f"rehome: unknown region {region!r}") from None
        if not 0 <= int(device) < self.n_devices:
            raise EndpointError(
                f"rehome: device {device} outside mesh of "
                f"{self.n_devices}")
        if int(device) in self.failed_devices:
            raise EndpointError(
                f"rehome: target device {device} is failed")
        if self._inflight:
            raise EndpointError(
                "rehome: waves in flight — retire them first "
                "(wait_all) before migrating regions")
        src = self.home_of(region)
        self._region_access.pop(region, None)
        if src == int(device):
            return 0
        mem = self.host_mem()
        mem[int(device), r.base:r.base + r.size] = \
            mem[src, r.base:r.base + r.size]
        self._region_home[region] = int(device)
        self.rehome_count += 1
        self.rehomed_words += int(r.size)
        return int(r.size)

    def _retire_immediate(self, c: Completion, status: int) -> None:
        """Retire a post immediately with the given no-execution status
        (``event.wave == -1``): the flushed-WQE path of a session in
        error (``STATUS_FLUSHED``), an expired deadline
        (``STATUS_TIMEOUT``), or an admission reject / load shed
        (``STATUS_EAGAIN``).  Exactly one CQE is delivered either way —
        overload degrades a post's status, never loses its completion."""
        c.ret, c.status, c.steps = 0, int(status), 0
        c.regs = np.zeros(isa.NUM_REGS, dtype=np.int64)
        c.event = CompletionEvent(
            seq=c.seq, op_name=c.op_name, ret=0, status=c.status,
            steps=0, wave=-1, retired_at=self._clock())
        c.done = True
        c.session._cq.append(c)

    # -- doorbell (the data path) ----------------------------------------

    def _next_seq(self) -> int:
        s = self._seq
        self._seq += 1
        return s

    def _posted(self, c: Completion) -> None:
        self._outstanding += 1
        if self.flush_watermark is not None and \
                self._outstanding >= self.flush_watermark:
            try:
                # split-phase auto-ring: the watermark *launches* the
                # wave but does not block the triggering post() on its
                # retirement — posts keep pipelining through the ring
                # and the CQEs arrive on the normal poll/wait paths
                self.doorbell(wait=False)
            except BaseException:
                # post() must be atomic: if the auto-ring fails, cancel
                # the triggering post (the doorbell failure path already
                # re-queued the wave, including it) so the caller, who
                # gets the exception instead of a handle, can re-post
                # without risking double execution
                c.session._sq.remove(c)
                self._outstanding -= 1
                raise

    def _enqueue(self, c: Completion) -> None:
        """Move an already-sequenced (``Session._make``) post into its
        session's SQ without triggering the watermark auto-ring — the
        serving loop's wave-formation path, which rings its own doorbell
        immediately after selecting the wave."""
        c.session._sq.append(c)
        self._outstanding += 1

    @property
    def outstanding(self) -> int:
        return self._outstanding

    @property
    def last_noconflict(self) -> Optional[bool]:
        """Did the last doorbell wave carry a static no-conflict proof
        (registration-time footprints with that wave's concrete params —
        ``registry.prove_wave_noconflict``)?  ``True`` means the engines
        ran with the runtime sweep compiled out; ``None`` before any
        wave."""
        return self.registry.last_noconflict

    def doorbell(self, *, mode: str = "auto",
                 contention_rate: float = 0.0,
                 placement: str = "single",
                 wait: bool = True) -> Union[int, "WaveHandle"]:
        """Drain every session's outstanding posts into one wave (global
        arrival order), launch it, and — with ``wait=True`` — retire the
        results into per-session CQs, returning the number of
        completions retired.

        **Split phase**: ``wait=False`` returns an in-flight
        :class:`WaveHandle` as soon as the launch is *issued* — before
        any (possibly slow, async-MEMCPY-heavy) work retires.  The pool
        binding becomes a device future, so further posts and doorbells
        pipeline against the in-flight wave (XLA chains the data
        dependency); completions retire later via ``poll_cq`` /
        ``wait_any`` / ``wait_all`` / ``Completion.wait()``, always in
        wave order so per-session FIFO holds.  Modes that cannot defer
        (sharded placement, "interp") still compute eagerly but retire
        on the same split-phase path.

        ``mode`` picks the wave engine: the mixed-dispatch set
        ("auto"/"mixed"/"segmented"/"serial") for any wave, "batched"/
        "compiled"/"compiled_dbuf" for single-op waves, "interp" for a
        single-request wave — which makes the endpoint the one surface
        that can drive every engine (the benchmarks rely on this).

        ``placement`` decides *where* the wave executes — placement is a
        doorbell concern, invisible to :meth:`Session.post` callers:
        "single" (default) runs on one chip; "sharded" shards the pool
        over a device mesh and buckets the wave by each post's ``home``
        into per-device sub-waves (requires a wave mode, "auto" or
        "mixed"); "auto" lets the dispatch cost model pick (audited via
        ``registry.last_placement``).  Results are bit-identical across
        placements — contended STORE/CAS waves keep the deterministic
        arrival-order round-robin semantics on the mesh."""
        if mode not in DOORBELL_MODES:
            raise ValueError(
                f"unknown mode {mode!r}; expected one of "
                f"{list(DOORBELL_MODES)}")
        if placement not in _registry._PLACEMENTS:
            raise ValueError(
                f"unknown placement {placement!r}; expected one of "
                f"{list(_registry._PLACEMENTS)}")
        if placement != "single" and mode not in ("auto", "mixed"):
            raise EndpointError(
                f"placement {placement!r} needs a wave mode ('auto' or "
                f"'mixed'); got mode {mode!r}")
        if self._pending_corrupt:
            # injected pre-wave corruption (stale translations, torn
            # pointers) lands in the pool before any request sees it
            mem = self.host_mem()
            for d, w, v in self._pending_corrupt:
                mem[d, w] = v
            self._pending_corrupt = []
        now = self._clock()
        wave: List[Completion] = []
        held = 0
        for name, s in self._sessions.items():
            if s._error is not None:
                # QP in error: anything still queued (enqueued before
                # the fault retired, e.g. by the serving loop) flushes
                # at the drain — it must never execute
                flushed, s._sq = s._sq, []
                for c in flushed:
                    self._retire_immediate(c, isa.STATUS_FLUSHED)
                continue
            if self._stalls.get(name, 0.0) > now:
                # injected tenant stall: its posts stay queued (and
                # aging — deadlines still apply at the next drain)
                held += len(s._sq)
                continue
            wave.extend(s._sq)
            s._sq = []
        self._outstanding = held
        # deadline enforcement at wave formation: an expired post never
        # executes — it retires STATUS_TIMEOUT right here, in seq order,
        # and the wave launches without it
        expired = [c for c in wave
                   if c.deadline is not None and c.deadline <= now]
        if expired:
            wave = [c for c in wave
                    if not (c.deadline is not None and c.deadline <= now)]
            for c in sorted(expired, key=lambda c: c.seq):
                self._retire_immediate(c, isa.STATUS_TIMEOUT)
        n_expired = len(expired)
        if not wave:
            if wait:
                return n_expired
            empty = WaveHandle(self, self._wave_seq, (),
                               None)  # nothing launched, nothing to wait
            empty.done = True
            self._wave_seq += 1
            return empty
        wave.sort(key=lambda c: c.seq)
        if self._pending_delays:
            # injected launch delay (slow NIC / congested launch queue):
            # charged through the sleep hook so virtual clocks advance
            self._sleep(self._pending_delays.pop(0))
        ids = [c.op_id for c in wave]
        params = [list(c.params) for c in wave]
        homes = [c.home for c in wave]
        reg = self.registry
        block = wait  # split-phase doorbells defer result retirement
        failed = set(self.failed_devices) or None
        attempt = 0
        while True:
            try:
                if self._transient_left > 0:
                    self._transient_left -= 1
                    raise faults.TransientError(
                        "injected transient launch failure")
                if mode in _WAVE_MODES:
                    res = reg._invoke_mixed(ids, self.mem, params,
                                            homes=homes, mode=mode,
                                            contention_rate=contention_rate,
                                            failed=failed,
                                            placement=placement, block=block)
                elif mode in _SINGLE_OP_MODES:
                    if len(set(ids)) != 1:
                        raise EndpointError(
                            f"mode {mode!r} needs a single-op wave; got "
                            f"op_ids {sorted(set(ids))}")
                    res = reg._invoke_batched(ids[0], self.mem, params,
                                              homes=homes, mode=mode,
                                              failed=failed, block=block)
                else:  # "interp"
                    if len(wave) != 1:
                        raise EndpointError(
                            f"mode 'interp' needs a single-request wave; "
                            f"got {len(wave)} posts")
                    r = reg._invoke(ids[0], self.mem, params[0],
                                    home=homes[0], failed=failed,
                                    mode="interp")
                    frow = (np.asarray([r.fault.pc, r.fault.opcode,
                                        r.fault.addr, r.fault.device],
                                       dtype=np.int64)
                            if r.fault is not None else vm.NO_FAULT)
                    res = vm.BatchedInvokeResult(
                        mem=r.mem, ret=np.asarray([r.ret], dtype=np.int64),
                        status=np.asarray([r.status], dtype=np.int64),
                        steps=np.asarray([r.steps], dtype=np.int64),
                        regs=np.asarray(r.regs, dtype=np.int64)[None, :],
                        fault=np.asarray(frow, dtype=np.int64)[None, :])
                break
            except faults.TransientError:
                # bounded retry-with-backoff: a lost doorbell is cured by
                # ringing again, not by dropping the wave
                attempt += 1
                if attempt > self.retry_limit:
                    for c in wave:
                        c.session._sq.append(c)
                    self._outstanding += len(wave)
                    raise
                backoff = self.retry_backoff_s * (1 << (attempt - 1))
                if self.retry_jitter > 0.0:
                    # seeded, deterministic de-synchronization jitter
                    backoff *= 1.0 + self.retry_jitter * float(
                        self._retry_rng.random())
                self._sleep(backoff)
            except BaseException:
                # a failed doorbell must not drop the send queues: re-post
                # the wave untouched (it is seq-sorted, and nothing can
                # have posted concurrently), so the caller can ring again
                for c in wave:
                    c.session._sq.append(c)
                self._outstanding += len(wave)
                raise
        self.mem = res.mem
        handle = WaveHandle(self, self._wave_seq, wave, res)
        self._wave_seq += 1
        # launch metadata for the online cost-model feed (_retire):
        # single-op waves calibrate their slot's scale, mixed waves the
        # wave-global bucket; modes without a closed analytical form
        # (sharded placement, interp) observe nothing
        handle.launched_at = self._clock()
        if placement == "single" and mode != "interp":
            uniq = sorted(set(ids))
            slots = [reg[i] for i in uniq]
            handle.obs_steps = max(s.verified.step_bound for s in slots)
            handle.obs_contention = contention_rate
            eff_mode = mode
            if mode == "auto":
                d = reg.last_decision
                eff_mode = d.mode if d is not None else None
            if len(uniq) == 1:
                handle.obs_key = uniq[0]
                handle.obs_chain = slots[0].chain_iters
                # a single-op wave through the wave planner runs the
                # mixed engine degenerately; observe it as "batched"
                handle.obs_mode = "batched" if eff_mode == "mixed" \
                    else eff_mode
            else:
                handle.obs_key = None
                handle.obs_mode = eff_mode
        for c in wave:
            c.wave_handle = handle
        self._inflight.append(handle)
        if wait:
            self._retire_through(handle)
            return len(wave) + n_expired
        return handle

    # -- completion retirement (the receive side) -------------------------

    def _retire(self, handle: WaveHandle) -> None:
        """Deliver one wave's CQEs: materialize the (possibly deferred)
        engine result, fill the completion handles, and append them to
        their sessions' CQs in global arrival order.  Only
        :meth:`_retire_through` / :meth:`_retire_ready` call this, and
        only in wave order."""
        if self._poison_left > 0:
            # injected deferred engine failure: raise BEFORE any CQE is
            # delivered; _retire_through leaves the wave queued, so the
            # next wait retries materialization (no lost completions)
            self._poison_left -= 1
            raise faults.InjectedEngineError(
                "injected materialization failure")
        res = vm.materialize_result(handle._res)
        if self.mem is handle._res.mem:
            # the pool still points at this wave's output: keep the
            # materialized host view so later reads don't re-block
            self.mem = res.mem
        # drop the result: a user-held Completion must not pin a whole
        # pool snapshot (the per-request fields are copied out below)
        handle._res = None
        now = self._clock()
        if handle.obs_mode is not None and handle.completions:
            # online calibration feed: this wave's measured wall clock
            # (from launch, or from the previous retirement when waves
            # pipelined and overlapped) updates the cost model's
            # per-slot EWMA scales, so mode="auto" and the serving
            # loop's formation policy adapt to the running host
            start = max(handle.launched_at, self._last_retire_t)
            measured_us = (now - start) * 1e6
            if measured_us > 0.0:
                self.cost_model.observe_dispatch(
                    handle.obs_key, handle.obs_mode,
                    batch=len(handle.completions),
                    step_bound=handle.obs_steps,
                    measured_us=measured_us,
                    contention_rate=handle.obs_contention,
                    chain_iters=handle.obs_chain)
        self._last_retire_t = now
        errored: List[Session] = []
        for i, c in enumerate(handle.completions):
            c.ret = int(res.ret[i])
            c.status = int(res.status[i])
            c.steps = int(res.steps[i])
            c.regs = np.asarray(res.regs[i])
            if c.status == isa.STATUS_PROT_FAULT:
                c.fault = res.fault_at(i)
                if c.session._error is None:
                    # RNIC QP semantics: first protection fault moves
                    # the owning session into the error state
                    c.session._error = c.fault
                    errored.append(c.session)
            c.event = CompletionEvent(
                seq=c.seq, op_name=c.op_name, ret=c.ret, status=c.status,
                steps=c.steps, wave=handle.wave_id, retired_at=now,
                fault=c.fault)
            c.done = True
            c.session._cq.append(c)
        handle.done = True
        # flush the errored sessions' not-yet-launched posts: they were
        # posted after the faulting wave launched and must not execute
        for s in errored:
            flushed, s._sq = s._sq, []
            self._outstanding -= len(flushed)
            for c in flushed:
                self._retire_immediate(c, isa.STATUS_FLUSHED)

    def _retire_through(self, handle: WaveHandle) -> None:
        """Retire every in-flight wave up to and including ``handle``
        (strict launch order — per-session FIFO depends on it).  A wave
        is only popped once its retirement succeeded, so a
        materialization error leaves it queued for a retry instead of
        silently losing it (and draining every later wave looking for
        it)."""
        if handle.done:
            return
        while self._inflight:
            h = self._inflight[0]
            self._retire(h)
            self._inflight.pop(0)
            if h is handle:
                break

    def _retire_ready(self) -> int:
        """Retire in-flight waves whose launches have landed, oldest
        first, stopping at the first one still computing (never
        blocks).  Returns the number of completions retired."""
        n = 0
        while self._inflight and self._inflight[0].ready:
            h = self._inflight[0]
            self._retire(h)
            self._inflight.pop(0)
            n += len(h)
        return n

    def wait_all(self) -> int:
        """Block until every in-flight wave retires; returns the number
        of completions retired."""
        n = self.in_flight
        if self._inflight:
            self._retire_through(self._inflight[-1])
        return n

    def wait_any(self) -> List[Completion]:
        """Block until at least one in-flight wave retires (the oldest —
        waves retire in launch order) and return its completions in
        arrival order; ``[]`` when nothing is in flight."""
        if not self._inflight:
            return []
        h = self._inflight[0]
        self._retire_through(h)
        return list(h.completions)

    @property
    def in_flight(self) -> int:
        """Posts launched but not yet retired."""
        return sum(len(h) for h in self._inflight)

    @property
    def in_flight_waves(self) -> int:
        return len(self._inflight)

    @property
    def cost_model(self) -> DispatchCostModel:
        """The registry's dispatch cost model — also the sink for the
        endpoint's online wall-clock observations and the serving
        loop's conflict-rate feed."""
        return self.registry.cost_model

    @property
    def last_decision(self):
        """The wave-level dispatch decision of the most recent doorbell
        that went through the cost model (audit hook)."""
        return self.registry.last_decision

    @property
    def last_placement(self):
        """The placement decision of the most recent
        ``doorbell(placement="auto")`` (audit hook)."""
        return self.registry.last_placement

    def dump(self) -> str:
        lines = [f"endpoint: {len(self._sessions)} sessions, "
                 f"{len(self.registry)} ops, pool "
                 f"{self.n_devices}x{self.regions.pool_words} words, "
                 f"{self._outstanding} outstanding"]
        lines.append(self.registry.dump())
        return "\n".join(lines)
