"""Operator builder — the assembler-level programming interface.

This is the layer the compiler frontend (`repro.core.frontend`) lowers
into; it can also be used directly, like writing eBPF assembly by hand.
It tracks register allocation, forward-label patching, and the Loop(M,N)
body-length back-patching, and records the *static* region declarations
the verifier will check against the tenant grant.

Shape of an operator (paper §3.1): up to 8 parameters arrive in r0..r7;
temporaries live in r8..r14; r15 is the async error-flag register.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Set, Tuple, Union

import numpy as np

from repro.core import isa
from repro.core.isa import (Alu, Instr, Op, FLAG_ASYNC, FLAG_DEV_REG,
                            FLAG_DSTDEV_REG, FLAG_IMMB, FLAG_LEN_REG,
                            FLAG_MREG, FLAG_SRCDEV_REG, FLAG_THR_REG,
                            DEV_LOCAL)
from repro.core.memory import RegionTable


@dataclasses.dataclass(frozen=True)
class Reg:
    """A register handle; operators never touch raw indices."""

    idx: int

    def __post_init__(self):
        if not (0 <= self.idx < isa.NUM_REGS):
            raise ValueError(f"register index {self.idx} out of range")


Operand = Union[Reg, int]
Device = Union[Reg, int]


@dataclasses.dataclass(frozen=True)
class TiaraProgram:
    """A compiled (but not yet verified/registered) operator."""

    name: str
    code: np.ndarray                    # (n, INSTR_WIDTH) int64
    n_params: int
    regions_read: Tuple[int, ...]       # statically declared region ids
    regions_written: Tuple[int, ...]
    region_names: Tuple[str, ...] = ()  # for diagnostics

    @property
    def n_instr(self) -> int:
        return int(self.code.shape[0])

    def disassemble(self) -> str:
        return isa.disassemble(self.code)


class Label:
    def __init__(self, name: str):
        self.name = name
        self.pc: Optional[int] = None
        self.pending: List[int] = []    # pcs of jumps waiting for this label


class _LoopCtx:
    def __init__(self, builder: "OperatorBuilder", pc: int):
        self.builder = builder
        self.pc = pc

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.builder._close_loop(self.pc)
        return False


class OperatorBuilder:
    """Incremental assembler with labels, loops, and region tracking."""

    def __init__(self, name: str, *, n_params: int,
                 regions: Optional[RegionTable] = None):
        if not (0 <= n_params <= isa.NUM_PARAM_REGS):
            raise ValueError(f"n_params must be in [0, {isa.NUM_PARAM_REGS}]")
        self.name = name
        self.n_params = n_params
        self.regions = regions
        self._instrs: List[Instr] = []
        self._next_reg = max(n_params, 0)
        self._labels: List[Label] = []
        self._open_loops: List[int] = []
        self._regions_read: Set[int] = set()
        self._regions_written: Set[int] = set()

    # -- registers ----------------------------------------------------

    def param(self, i: int) -> Reg:
        if not (0 <= i < self.n_params):
            raise ValueError(f"operator has {self.n_params} params")
        return Reg(i)

    @property
    def params(self) -> List[Reg]:
        return [Reg(i) for i in range(self.n_params)]

    def reg(self) -> Reg:
        """Allocate a fresh temporary register."""
        if self._next_reg >= isa.ERR_REG:
            raise RuntimeError("out of registers (r8..r14 are temporaries)")
        r = Reg(self._next_reg)
        self._next_reg += 1
        return r

    @property
    def err(self) -> Reg:
        return Reg(isa.ERR_REG)

    # -- region bookkeeping --------------------------------------------

    def _rid(self, region: Union[int, str], *, write: bool) -> int:
        if isinstance(region, str):
            if self.regions is None:
                raise ValueError("string region names need a RegionTable")
            rid = self.regions.rid(region)
        else:
            rid = int(region)
        self._regions_read.add(rid)
        if write:
            self._regions_written.add(rid)
        return rid

    # -- emit helpers ---------------------------------------------------

    def _emit(self, ins: Instr) -> int:
        pc = len(self._instrs)
        if pc >= isa.INSTR_STORE_SIZE:
            raise RuntimeError("operator exceeds the 1024-entry instruction store")
        self._instrs.append(ins)
        return pc

    @staticmethod
    def _dev(dev: Device) -> Tuple[int, int]:
        """Returns (field_value, extra_flags) for a device operand."""
        if isinstance(dev, Reg):
            return dev.idx, FLAG_DEV_REG
        return int(dev), 0

    # -- instructions ----------------------------------------------------

    def nop(self) -> None:
        self._emit(Instr(Op.NOP))

    def movi(self, dst: Reg, imm: int) -> Reg:
        self._emit(Instr(Op.MOVI, dst=dst.idx, imm=int(imm)))
        return dst

    def const(self, imm: int) -> Reg:
        """Materialize a constant in a fresh register."""
        return self.movi(self.reg(), imm)

    def alu(self, dst: Reg, a: Reg, op: Alu, b: Operand) -> Reg:
        if isinstance(b, Reg):
            self._emit(Instr(Op.ALU, dst=dst.idx, a=a.idx, b=b.idx, d=int(op)))
        else:
            self._emit(Instr(Op.ALU, dst=dst.idx, a=a.idx, d=int(op),
                             flags=FLAG_IMMB, imm=int(b)))
        return dst

    # common sugar
    def add(self, dst, a, b):
        return self.alu(dst, a, Alu.ADD, b)

    def sub(self, dst, a, b):
        return self.alu(dst, a, Alu.SUB, b)

    def mul(self, dst, a, b):
        return self.alu(dst, a, Alu.MUL, b)

    def shl(self, dst, a, b):
        return self.alu(dst, a, Alu.SHL, b)

    def shr(self, dst, a, b):
        return self.alu(dst, a, Alu.SHR, b)

    def band(self, dst, a, b):
        return self.alu(dst, a, Alu.AND, b)

    def mov(self, dst: Reg, src: Reg) -> Reg:
        return self.alu(dst, src, Alu.ADD, 0)

    def load(self, dst: Reg, region: Union[int, str], off: Reg,
             disp: int = 0, dev: Device = DEV_LOCAL) -> Reg:
        rid = self._rid(region, write=False)
        devf, fl = self._dev(dev)
        self._emit(Instr(Op.LOAD, dst=dst.idx, a=rid, b=off.idx, e=devf,
                         flags=fl, imm=int(disp)))
        return dst

    def store(self, src: Reg, region: Union[int, str], off: Reg,
              disp: int = 0, dev: Device = DEV_LOCAL) -> None:
        rid = self._rid(region, write=True)
        devf, fl = self._dev(dev)
        self._emit(Instr(Op.STORE, dst=src.idx, a=rid, b=off.idx, e=devf,
                         flags=fl, imm=int(disp)))

    def memcpy(self, *, dst_region: Union[int, str], dst_off: Reg,
               src_region: Union[int, str], src_off: Reg,
               n_words: Union[int, Tuple[Reg, int]],
               dst_dev: Device = DEV_LOCAL, src_dev: Device = DEV_LOCAL,
               is_async: bool = False) -> None:
        """Bulk copy. ``n_words`` is either a static word count, or a
        ``(reg, cap)`` pair — a dynamic count statically capped at ``cap``
        (the cap is what the verifier bounds against)."""
        drid = self._rid(dst_region, write=True)
        srid = self._rid(src_region, write=False)
        flags = FLAG_ASYNC if is_async else 0
        if isinstance(dst_dev, Reg):
            dfield, flags = dst_dev.idx, flags | FLAG_DSTDEV_REG
        else:
            dfield = int(dst_dev)
        if isinstance(src_dev, Reg):
            sfield, flags = src_dev.idx, flags | FLAG_SRCDEV_REG
        else:
            sfield = int(src_dev)
        if isinstance(n_words, tuple):
            len_reg, cap = n_words
            if not (0 < cap <= isa.MAX_MEMCPY_WORDS):
                raise ValueError(f"memcpy cap {cap} out of range")
            self._emit(Instr(Op.MEMCPY, dst=dfield, a=drid, b=dst_off.idx,
                             c=sfield, d=srid, e=src_off.idx,
                             flags=flags | FLAG_LEN_REG, imm=int(cap),
                             imm2=len_reg.idx))
        else:
            if not (0 < int(n_words) <= isa.MAX_MEMCPY_WORDS):
                raise ValueError(f"memcpy length {n_words} out of range")
            self._emit(Instr(Op.MEMCPY, dst=dfield, a=drid, b=dst_off.idx,
                             c=sfield, d=srid, e=src_off.idx, flags=flags,
                             imm=int(n_words)))

    def cas(self, dst: Reg, region: Union[int, str], off: Reg, cmp: Reg,
            swap: Reg, disp: int = 0, dev: Device = DEV_LOCAL) -> Reg:
        rid = self._rid(region, write=True)
        devf, fl = self._dev(dev)
        self._emit(Instr(Op.CAS, dst=dst.idx, a=rid, b=off.idx, c=cmp.idx,
                         d=swap.idx, e=devf, flags=fl, imm=int(disp)))
        return dst

    def caa(self, dst: Reg, region: Union[int, str], off: Reg, cmp: Reg,
            addend: Reg, disp: int = 0, dev: Device = DEV_LOCAL) -> Reg:
        rid = self._rid(region, write=True)
        devf, fl = self._dev(dev)
        self._emit(Instr(Op.CAA, dst=dst.idx, a=rid, b=off.idx, c=cmp.idx,
                         d=addend.idx, e=devf, flags=fl, imm=int(disp)))
        return dst

    # -- control flow -----------------------------------------------------

    def mklabel(self, name: str = "L") -> Label:
        lbl = Label(f"{name}{len(self._labels)}")
        self._labels.append(lbl)
        return lbl

    def bind(self, label: Label) -> None:
        if label.pc is not None:
            raise ValueError(f"label {label.name} already bound")
        label.pc = len(self._instrs)
        for jpc in label.pending:
            self._patch_jump(jpc, label.pc)
        label.pending.clear()

    def _patch_jump(self, jpc: int, target_pc: int) -> None:
        delta = target_pc - jpc - 1
        # delta == 0 (target = pc+1) is meaningful: a taken jump pops loop
        # frames it escapes (break), while fall-through iterates the loop.
        if delta < 0:
            raise ValueError(
                f"jump at pc {jpc} to pc {target_pc} goes backward")
        ins = self._instrs[jpc]
        self._instrs[jpc] = dataclasses.replace(ins, imm2=delta)

    def jump(self, label: Label, a: Optional[Reg] = None,
             cond: Alu = Alu.ALWAYS, b: Operand = 0) -> None:
        """Forward-only (conditionally) jump to ``label``."""
        if cond != Alu.ALWAYS and a is None:
            raise ValueError("conditional jump needs a register operand")
        if isinstance(b, Reg):
            ins = Instr(Op.JUMP, a=a.idx if a else 0, b=b.idx, d=int(cond))
        else:
            ins = Instr(Op.JUMP, a=a.idx if a else 0, d=int(cond),
                        flags=FLAG_IMMB, imm=int(b))
        jpc = self._emit(ins)
        if label.pc is not None:
            self._patch_jump(jpc, label.pc)
        else:
            label.pending.append(jpc)

    def loop(self, m: Union[int, Tuple[Reg, int]]) -> _LoopCtx:
        """``with b.loop(M):`` — body length is back-patched on exit.

        ``m`` is a static trip count, or ``(reg, cap)`` for a dynamic count
        statically capped at ``cap`` (the verifier bounds with ``cap``).
        """
        if isinstance(m, tuple):
            mreg, cap = m
            if cap <= 0:
                raise ValueError("loop cap must be positive")
            pc = self._emit(Instr(Op.LOOP, b=mreg.idx, flags=FLAG_MREG,
                                  imm=int(cap)))
        else:
            if int(m) < 0:
                raise ValueError("loop trip count must be >= 0")
            pc = self._emit(Instr(Op.LOOP, imm=int(m)))
        self._open_loops.append(pc)
        return _LoopCtx(self, pc)

    def _close_loop(self, loop_pc: int) -> None:
        if not self._open_loops or self._open_loops[-1] != loop_pc:
            raise RuntimeError("mismatched loop close")
        self._open_loops.pop()
        n_body = len(self._instrs) - loop_pc - 1
        if n_body < 1:
            raise ValueError("empty loop body")
        ins = self._instrs[loop_pc]
        self._instrs[loop_pc] = dataclasses.replace(ins, imm2=n_body)

    def wait(self, threshold: Operand = 0) -> None:
        if isinstance(threshold, Reg):
            self._emit(Instr(Op.WAIT, a=threshold.idx, flags=FLAG_THR_REG))
        else:
            self._emit(Instr(Op.WAIT, imm=int(threshold)))

    def ret(self, value: Optional[Reg] = None, status: int = isa.STATUS_OK) -> None:
        self._emit(Instr(Op.RET, a=value.idx if value is not None else 0,
                         imm=int(status)))

    # -- finalize ----------------------------------------------------------

    def build(self) -> TiaraProgram:
        if self._open_loops:
            raise RuntimeError("unclosed loop at build time")
        unbound = [l.name for l in self._labels if l.pending]
        if unbound:
            raise RuntimeError(f"unbound labels with pending jumps: {unbound}")
        names: Tuple[str, ...] = ()
        if self.regions is not None:
            names = tuple(self.regions.names())
        return TiaraProgram(
            name=self.name,
            code=isa.encode_program(self._instrs),
            n_params=self.n_params,
            regions_read=tuple(sorted(self._regions_read)),
            regions_written=tuple(sorted(self._regions_written)),
            region_names=names,
        )
