"""Restricted-Python frontend — the paper's "restricted OpenCL C" analogue.

Paper §3.3: operators are written in a source subset whose control flow is
a Static Control Part (SCoP), making termination and resource bounds
decidable at compile time; an LLVM backend lowers to Tiara instructions.
Here the source subset is restricted *Python* and the backend is
``repro.core.program.OperatorBuilder``; the output goes through the same
registration-time verifier as hand-written programs.

Supported subset (anything else is a compile error):

  * integer parameters and integer local variables;
  * arithmetic / logical / shift binary operators, integer constants;
  * ``for i in range(CONST)``            — static trip count
  * ``for i in bounded(expr, CAP)``      — dynamic count, static cap CAP
  * ``if <cmp>: ... [else: ...]``        — forward control flow only
  * ``break``                            — exits the innermost loop
  * ``return expr`` / ``return fail(expr)``
  * intrinsics: ``load(region, off, dev=?)``, ``store(region, off, val,
    dev=?)``, ``memcpy(dst_region, dst_off, src_region, src_off, n,
    dst_dev=?, src_dev=?, is_async=?)`` (n static, or ``(expr, CAP)``),
    ``cas(region, off, cmp, new, dev=?)``, ``caa(region, off, cmp, add,
    dev=?)``, ``wait(thr)``, ``err()``.

Example::

    def walk(start, depth):
        cur = start
        for _ in bounded(depth, 16):
            cur = load("graph", cur + 1)
        return load("graph", cur)

    program = compile_operator(walk, regions=rt)
"""

from __future__ import annotations

import ast
import inspect
import textwrap
from typing import Callable, Dict, List, Optional, Union

from repro.core.isa import Alu, DEV_LOCAL
from repro.core.memory import RegionTable
from repro.core.program import Label, OperatorBuilder, Reg, TiaraProgram


class TiaraCompileError(Exception):
    def __init__(self, msg: str, node: Optional[ast.AST] = None):
        if node is not None and hasattr(node, "lineno"):
            msg = f"line {node.lineno}: {msg}"
        super().__init__(msg)


_BINOPS = {
    ast.Add: Alu.ADD, ast.Sub: Alu.SUB, ast.Mult: Alu.MUL,
    ast.BitAnd: Alu.AND, ast.BitOr: Alu.OR, ast.BitXor: Alu.XOR,
    ast.LShift: Alu.SHL, ast.RShift: Alu.SHR,
}

_CMPS = {ast.Eq: Alu.EQ, ast.NotEq: Alu.NE, ast.Lt: Alu.LT, ast.GtE: Alu.GE}
# negation for jump-over-body lowering
_NEG = {Alu.EQ: Alu.NE, Alu.NE: Alu.EQ, Alu.LT: Alu.GE, Alu.GE: Alu.LT}


class _Compiler:
    def __init__(self, name: str, arg_names: List[str],
                 regions: Optional[RegionTable], consts: Dict[str, int]):
        self.b = OperatorBuilder(name, n_params=len(arg_names),
                                 regions=regions)
        self.vars: Dict[str, Reg] = {
            a: self.b.param(i) for i, a in enumerate(arg_names)}
        self.consts = consts
        self._free_temps: List[Reg] = []
        self._break_labels: List[Label] = []

    # -- register management ----------------------------------------------

    def _temp(self) -> Reg:
        return self._free_temps.pop() if self._free_temps else self.b.reg()

    def _release(self, r: Reg) -> None:
        if r.idx >= self.b.n_params and r not in self.vars.values() \
                and r.idx < 15:
            self._free_temps.append(r)

    def _var(self, name: str, node: ast.AST) -> Reg:
        if name not in self.vars:
            self.vars[name] = self.b.reg()
        return self.vars[name]

    # -- expressions --------------------------------------------------------

    def _const_value(self, node: ast.AST) -> Optional[int]:
        """Fold to a Python int if statically known."""
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return int(node.value)
        if isinstance(node, ast.Name) and node.id in self.consts:
            return int(self.consts[node.id])
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self._const_value(node.operand)
            return -v if v is not None else None
        if isinstance(node, ast.BinOp) and type(node.op) in _BINOPS:
            lv, rv = self._const_value(node.left), self._const_value(node.right)
            if lv is not None and rv is not None:
                op = _BINOPS[type(node.op)]
                return {
                    Alu.ADD: lv + rv, Alu.SUB: lv - rv, Alu.MUL: lv * rv,
                    Alu.AND: lv & rv, Alu.OR: lv | rv, Alu.XOR: lv ^ rv,
                    Alu.SHL: lv << rv, Alu.SHR: (lv % (1 << 64)) >> rv,
                }[op]
        return None

    def expr(self, node: ast.AST, out: Optional[Reg] = None) -> Reg:
        """Compile ``node``; result lands in ``out`` (or a temp)."""
        cv = self._const_value(node)
        if cv is not None:
            dst = out or self._temp()
            return self.b.movi(dst, cv)
        if isinstance(node, ast.Name):
            if node.id not in self.vars:
                raise TiaraCompileError(f"unknown variable {node.id!r}", node)
            src = self.vars[node.id]
            if out is not None and out != src:
                return self.b.mov(out, src)
            return src
        if isinstance(node, ast.BinOp):
            if type(node.op) not in _BINOPS:
                raise TiaraCompileError(
                    f"operator {type(node.op).__name__} not in the subset", node)
            alu = _BINOPS[type(node.op)]
            a = self.expr(node.left)
            rv = self._const_value(node.right)
            dst = out or self._temp()
            if rv is not None:
                self.b.alu(dst, a, alu, rv)
            else:
                breg = self.expr(node.right)
                self.b.alu(dst, a, alu, breg)
                if breg != dst:
                    self._release(breg)
            if a != dst:
                self._release(a)
            return dst
        if isinstance(node, ast.Call):
            return self._call(node, out)
        raise TiaraCompileError(
            f"expression {ast.dump(node)[:60]} not in the subset", node)

    # -- intrinsic calls -----------------------------------------------------

    def _region_arg(self, node: ast.AST) -> str:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value
        raise TiaraCompileError("region must be a string literal", node)

    def _dev_kw(self, kws, key: str):
        for kw in kws:
            if kw.arg == key:
                cv = self._const_value(kw.value)
                if cv is not None:
                    return cv
                return self.expr(kw.value)
        return DEV_LOCAL

    def _call(self, node: ast.Call, out: Optional[Reg]) -> Reg:
        if not isinstance(node.func, ast.Name):
            raise TiaraCompileError("only intrinsic calls allowed", node)
        fn = node.func.id
        if fn == "load":
            region = self._region_arg(node.args[0])
            off = self.expr(node.args[1])
            dev = self._dev_kw(node.keywords, "dev")
            dst = out or self._temp()
            self.b.load(dst, region, off, dev=dev)
            if off != dst:
                self._release(off)
            return dst
        if fn in ("cas", "caa"):
            region = self._region_arg(node.args[0])
            off = self.expr(node.args[1])
            cmp_ = self.expr(node.args[2])
            swp = self.expr(node.args[3])
            dev = self._dev_kw(node.keywords, "dev")
            dst = out or self._temp()
            m = self.b.cas if fn == "cas" else self.b.caa
            m(dst, region, off, cmp_, swp, dev=dev)
            for r in (off, cmp_, swp):
                if r != dst:
                    self._release(r)
            return dst
        if fn == "err":
            return self.b.err
        raise TiaraCompileError(f"unknown intrinsic {fn!r} in expression", node)

    def _stmt_call(self, node: ast.Call) -> None:
        fn = node.func.id if isinstance(node.func, ast.Name) else None
        if fn == "store":
            region = self._region_arg(node.args[0])
            off = self.expr(node.args[1])
            val = self.expr(node.args[2])
            dev = self._dev_kw(node.keywords, "dev")
            self.b.store(val, region, off, dev=dev)
            self._release(off)
            self._release(val)
            return
        if fn == "memcpy":
            dreg = self._region_arg(node.args[0])
            doff = self.expr(node.args[1])
            sreg = self._region_arg(node.args[2])
            soff = self.expr(node.args[3])
            nnode = node.args[4]
            if isinstance(nnode, ast.Tuple):           # (expr, CAP)
                nreg = self.expr(nnode.elts[0])
                cap = self._const_value(nnode.elts[1])
                if cap is None:
                    raise TiaraCompileError("memcpy cap must be static", node)
                n: Union[int, tuple] = (nreg, cap)
            else:
                nv = self._const_value(nnode)
                if nv is None:
                    raise TiaraCompileError(
                        "memcpy length must be static or (expr, CAP)", node)
                n = nv
            ddev = self._dev_kw(node.keywords, "dst_dev")
            sdev = self._dev_kw(node.keywords, "src_dev")
            is_async = False
            for kw in node.keywords:
                if kw.arg == "is_async":
                    if not isinstance(kw.value, ast.Constant):
                        raise TiaraCompileError("is_async must be literal", node)
                    is_async = bool(kw.value.value)
            self.b.memcpy(dst_region=dreg, dst_off=doff, src_region=sreg,
                          src_off=soff, n_words=n, dst_dev=ddev,
                          src_dev=sdev, is_async=is_async)
            self._release(doff)
            self._release(soff)
            return
        if fn == "wait":
            tv = self._const_value(node.args[0])
            if tv is not None:
                self.b.wait(tv)
            else:
                self.b.wait(self.expr(node.args[0]))
            return
        # expression-position intrinsics used as statements (result dropped)
        r = self._call(node, None)
        self._release(r)

    # -- statements -----------------------------------------------------------

    def _compare(self, test: ast.AST, target: Label, *, negate: bool) -> None:
        """Emit a conditional jump to ``target`` on (negated) ``test``."""
        if not (isinstance(test, ast.Compare) and len(test.ops) == 1):
            raise TiaraCompileError("test must be a single comparison", test)
        op_node, rhs_node = test.ops[0], test.comparators[0]
        lhs_node = test.left
        # normalize > and <= by swapping operands
        if isinstance(op_node, ast.Gt):
            op_node, lhs_node, rhs_node = ast.Lt(), rhs_node, lhs_node
        elif isinstance(op_node, ast.LtE):
            op_node, lhs_node, rhs_node = ast.GtE(), rhs_node, lhs_node
        if type(op_node) not in _CMPS:
            raise TiaraCompileError("comparison not in the subset", test)
        cond = _CMPS[type(op_node)]
        if negate:
            cond = _NEG[cond]
        lhs = self.expr(lhs_node)
        rv = self._const_value(rhs_node)
        if rv is not None:
            self.b.jump(target, lhs, cond, rv)
        else:
            rhs = self.expr(rhs_node)
            self.b.jump(target, lhs, cond, rhs)
            self._release(rhs)
        self._release(lhs)

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, ast.Assign):
            if len(node.targets) != 1 or not isinstance(node.targets[0], ast.Name):
                raise TiaraCompileError("only simple assignment", node)
            dst = self._var(node.targets[0].id, node)
            self.expr(node.value, out=dst)
            return
        if isinstance(node, ast.AugAssign):
            if not isinstance(node.target, ast.Name):
                raise TiaraCompileError("only simple targets", node)
            dst = self._var(node.target.id, node)
            if type(node.op) not in _BINOPS:
                raise TiaraCompileError("augmented op not in subset", node)
            alu = _BINOPS[type(node.op)]
            rv = self._const_value(node.value)
            if rv is not None:
                self.b.alu(dst, dst, alu, rv)
            else:
                r = self.expr(node.value)
                self.b.alu(dst, dst, alu, r)
                self._release(r)
            return
        if isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            self._stmt_call(node.value)
            return
        if isinstance(node, ast.If):
            else_lbl = self.b.mklabel("else")
            end_lbl = self.b.mklabel("endif") if node.orelse else else_lbl
            self._compare(node.test, else_lbl, negate=True)
            for s in node.body:
                self.stmt(s)
            if node.orelse:
                self.b.jump(end_lbl)
                self.b.bind(else_lbl)
                for s in node.orelse:
                    self.stmt(s)
                self.b.bind(end_lbl)
            else:
                self.b.bind(else_lbl)
            return
        if isinstance(node, ast.For):
            self._for(node)
            return
        if isinstance(node, ast.Break):
            if not self._break_labels:
                raise TiaraCompileError("break outside loop", node)
            self.b.jump(self._break_labels[-1])
            return
        if isinstance(node, ast.Return):
            self._return(node)
            return
        if isinstance(node, ast.Pass):
            return
        raise TiaraCompileError(
            f"statement {type(node).__name__} not in the subset", node)

    def _for(self, node: ast.For) -> None:
        if not (isinstance(node.iter, ast.Call)
                and isinstance(node.iter.func, ast.Name)
                and node.iter.func.id in ("range", "bounded")):
            raise TiaraCompileError(
                "loops must be `for i in range(CONST)` or "
                "`for i in bounded(expr, CAP)`", node)
        if node.orelse:
            raise TiaraCompileError("for-else not supported", node)
        kind = node.iter.func.id
        if kind == "range":
            if len(node.iter.args) != 1:
                raise TiaraCompileError("range() takes one static arg", node)
            m = self._const_value(node.iter.args[0])
            if m is None:
                raise TiaraCompileError(
                    "range() bound must be static; use bounded(expr, CAP)",
                    node)
            loop_arg: Union[int, tuple] = m
        else:
            cnt = self.expr(node.iter.args[0])
            cap = self._const_value(node.iter.args[1])
            if cap is None:
                raise TiaraCompileError("bounded() cap must be static", node)
            loop_arg = (cnt, cap)
        idx_name = node.target.id if isinstance(node.target, ast.Name) else "_"
        idx: Optional[Reg] = None
        if idx_name != "_":
            idx = self._var(idx_name, node)
            self.b.movi(idx, 0)
        brk = self.b.mklabel("break")
        self._break_labels.append(brk)
        with self.b.loop(loop_arg):
            for s in node.body:
                self.stmt(s)
            if idx is not None:
                self.b.alu(idx, idx, Alu.ADD, 1)
            # If an if-join label binds at the body end, a jump to it would
            # land at end+1 and read as a *break* (frame pop).  Pad with a
            # NOP so intra-iteration joins stay inside the body and fall
            # through to the loop-iterate check.
            if any(l.pc == len(self.b._instrs) for l in self.b._labels):
                self.b.nop()
        self._break_labels.pop()
        self.b.bind(brk)
        if kind == "bounded" and isinstance(loop_arg, tuple):
            self._release(loop_arg[0])

    def _return(self, node: ast.Return) -> None:
        status = 0
        value = node.value
        if (isinstance(value, ast.Call) and isinstance(value.func, ast.Name)
                and value.func.id == "fail"):
            status = 1
            value = value.args[0] if value.args else None
        if value is None:
            self.b.ret(None, status=status)
        else:
            r = self.expr(value)
            self.b.ret(r, status=status)
            self._release(r)


def compile_source(src: str, *, regions: Optional[RegionTable] = None,
                   consts: Optional[Dict[str, int]] = None,
                   name: Optional[str] = None) -> TiaraProgram:
    """Compile restricted-Python source text into a TiaraProgram."""
    return _compile_tree(ast.parse(textwrap.dedent(src)), regions=regions,
                         consts=consts, name=name)


def compile_operator(fn: Callable, *, regions: Optional[RegionTable] = None,
                     consts: Optional[Dict[str, int]] = None,
                     name: Optional[str] = None) -> TiaraProgram:
    """Compile a restricted-Python function into a TiaraProgram."""
    src = textwrap.dedent(inspect.getsource(fn))
    closure_consts = dict(consts or {})
    try:
        cv = inspect.getclosurevars(fn)
        for k, v in {**cv.nonlocals, **cv.globals}.items():
            if isinstance(v, int) and not isinstance(v, bool):
                closure_consts.setdefault(k, v)
    except TypeError:
        pass
    return _compile_tree(ast.parse(src), regions=regions,
                         consts=closure_consts, name=name)


def _compile_tree(tree: ast.Module, *, regions, consts, name) -> TiaraProgram:
    fdef = tree.body[0]
    if not isinstance(fdef, ast.FunctionDef):
        raise TiaraCompileError("expected a function definition")
    args = [a.arg for a in fdef.args.args]
    if len(args) > 8:
        raise TiaraCompileError("operators take at most 8 parameters")
    c = _Compiler(name or fdef.name, args, regions, dict(consts or {}))
    for s in fdef.body:
        # skip the docstring
        if isinstance(s, ast.Expr) and isinstance(s.value, ast.Constant) \
                and isinstance(s.value.value, str):
            continue
        c.stmt(s)
    # ensure a trailing Ret for straight-line fallthrough
    from repro.core.isa import Op
    if c.b._instrs and c.b._instrs[-1].op != Op.RET:
        c.b.ret(None, status=0)
    return c.b.build()
