"""Region-based disaggregated memory model.

A memory *pool* is the flat word-addressed DRAM of one host (one row of the
``(n_devices, pool_words)`` int64 array the VM executes against).  Hosts in
a Tiara deployment register *regions* — power-of-two-sized windows — and
grant sets of regions to tenants.  Operators address memory exclusively as
``(device, region_id, offset)``; the region id must be statically declared
(verified at registration), and the offset is masked by the region size, so
the data path performs no bounds check (DESIGN.md §2).

The same layout is shared by every host in the pool (a common simplification
for symmetric memory blades); per-host private layouts would only change the
bookkeeping here, not the ISA or the VM.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import isa


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def align_base(cursor: int, size: int) -> int:
    """First naturally aligned base >= ``cursor`` for a region of
    ``size`` words — THE alignment rule; every allocation walk
    (``RegionTable.register``, ``packed_table``, ``aligned_end``)
    shares it so capacity pre-checks can never diverge from the
    allocator."""
    if size <= 0:
        return cursor
    return (cursor + size - 1) & ~(size - 1)


@dataclasses.dataclass(frozen=True)
class Region:
    """A registered memory window (word granularity, power-of-two size)."""

    rid: int
    name: str
    base: int           # word offset within the host pool
    size: int           # words, power of two
    writable: bool = True

    def __post_init__(self):
        if not _is_pow2(self.size):
            raise ValueError(f"region {self.name}: size {self.size} not a power of two")
        if self.base < 0:
            raise ValueError(f"region {self.name}: negative base")

    @property
    def mask(self) -> int:
        return self.size - 1

    @property
    def end(self) -> int:
        return self.base + self.size


class RegionTable:
    """Host-side region registry; the static side of the memory subsystem.

    The table compiles to three dense int64 vectors (base/mask/writable)
    which the VM closes over as compile-time constants — region metadata
    never travels on the data path.
    """

    def __init__(self, pool_words: int):
        if pool_words <= 0:
            raise ValueError("pool must be non-empty")
        self.pool_words = int(pool_words)
        self._regions: List[Region] = []
        self._by_name: Dict[str, Region] = {}

    def register(self, name: str, size_words: int, *, base: Optional[int] = None,
                 writable: bool = True, align: bool = True) -> Region:
        """Register a region; allocates after the current high-water mark."""
        if name in self._by_name:
            raise ValueError(f"region {name!r} already registered")
        if base is None:
            base = self.high_water
            if align:
                # Align the base to the region size so wrapped offsets stay
                # inside naturally aligned hardware pages.
                base = align_base(base, size_words)
        region = Region(rid=len(self._regions), name=name, base=base,
                        size=size_words, writable=writable)
        if region.end > self.pool_words:
            raise ValueError(
                f"region {name!r} [{region.base}, {region.end}) exceeds pool "
                f"of {self.pool_words} words")
        for other in self._regions:
            if region.base < other.end and other.base < region.end:
                raise ValueError(f"region {name!r} overlaps {other.name!r}")
        self._regions.append(region)
        self._by_name[name] = region
        return region

    @property
    def high_water(self) -> int:
        return max((r.end for r in self._regions), default=0)

    def __len__(self) -> int:
        return len(self._regions)

    def __iter__(self):
        return iter(self._regions)

    def __getitem__(self, key) -> Region:
        if isinstance(key, str):
            return self._by_name[key]
        return self._regions[key]

    def rid(self, name: str) -> int:
        return self._by_name[name].rid

    def names(self) -> List[str]:
        return [r.name for r in self._regions]

    def as_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(base, mask, writable) int64 vectors, one entry per region."""
        n = len(self._regions)
        base = np.zeros(n, dtype=np.int64)
        mask = np.zeros(n, dtype=np.int64)
        writable = np.zeros(n, dtype=np.int64)
        for r in self._regions:
            base[r.rid] = r.base
            mask[r.rid] = r.mask
            writable[r.rid] = int(r.writable)
        return base, mask, writable


@dataclasses.dataclass(frozen=True)
class Grant:
    """A tenant's capability: which regions it may read / write.

    The verifier checks an operator's statically declared region accesses
    against its tenant's grant at registration time; after that the data
    path runs with no per-access check (the paper's multi-tenant story).
    """

    tenant: str
    readable: frozenset
    writable: frozenset

    @staticmethod
    def of(tenant: str, readable: Iterable[int],
           writable: Iterable[int] = ()) -> "Grant":
        readable = frozenset(int(r) for r in readable)
        writable = frozenset(int(w) for w in writable)
        return Grant(tenant=tenant, readable=readable | writable,
                     writable=writable)

    @staticmethod
    def all_of(table: RegionTable, tenant: str = "root") -> "Grant":
        rids = [r.rid for r in table]
        wids = [r.rid for r in table if r.writable]
        return Grant.of(tenant, rids, wids)


class RegionView:
    """A tenant-namespaced view of a shared :class:`RegionTable`.

    In a multi-tenant deployment every tenant's regions live in the *one*
    host pool behind the NIC, but each tenant programs against its own
    region names.  A view resolves name ``n`` to ``prefix + n`` in the
    backing table, so a stock workload builder (which hardcodes names like
    ``"reply"``) can target its slice of a combined table unmodified.
    Region ids stay global — programs built through a view carry the
    combined table's rids, which is exactly what the verifier checks a
    tenant grant against.  Iteration yields only the tenant's regions, so
    ``Grant.all_of(view)`` is the tenant's capability, not the pool's.
    """

    def __init__(self, table: RegionTable, prefix: str = ""):
        self._table = table
        self.prefix = prefix

    @property
    def table(self) -> RegionTable:
        return self._table

    @property
    def pool_words(self) -> int:
        return self._table.pool_words

    def rid(self, name: str) -> int:
        return self._table.rid(self.prefix + name)

    def __getitem__(self, key) -> Region:
        if isinstance(key, str):
            return self._table[self.prefix + key]
        return self._table[key]

    def __iter__(self):
        return (r for r in self._table if r.name.startswith(self.prefix))

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def names(self) -> List[str]:
        return [r.name for r in self]

    def as_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The *backing table's* arrays: rids in programs built through a
        view are global, so engines must see the full table."""
        return self._table.as_arrays()


def merge_tables(named: Sequence[Tuple[str, RegionTable]], *,
                 sep: str = "/") -> Tuple[RegionTable, Dict[str, RegionView]]:
    """Pack several per-tenant region layouts into one shared pool.

    ``named`` is ``[(tenant, table), ...]``; each tenant's regions are
    re-registered as ``tenant/sep/name`` in one combined table (packed, in
    order).  Returns the combined table plus per-tenant views — the setup
    a multi-tenant registry wants: register operators built against the
    views, grant each tenant ``Grant.all_of(view)``, and run every
    tenant's requests against one ``make_pool(n, combined)``.

    Tenant names must be unique and must not contain ``sep``: the view
    prefix is the isolation boundary, so a name like ``"a/b"`` next to
    tenant ``"a"`` would leak ``a/b``'s regions into ``a``'s grant.
    """
    seen = set()
    for tenant, _ in named:
        if sep in tenant:
            raise ValueError(
                f"tenant name {tenant!r} must not contain {sep!r} "
                f"(it would collide with another tenant's namespace)")
        if tenant in seen:
            raise ValueError(f"duplicate tenant name {tenant!r}")
        seen.add(tenant)
    specs: List[Tuple[str, int]] = []
    for tenant, table in named:
        for r in table:
            specs.append((f"{tenant}{sep}{r.name}", r.size))
    combined = packed_table(specs)
    views = {tenant: RegionView(combined, f"{tenant}{sep}")
             for tenant, _ in named}
    return combined, views


def aligned_end(cursor: int, regions: Iterable[Region]) -> int:
    """Pool end after appending ``regions`` at ``cursor`` with the same
    naturally-aligned walk :meth:`RegionTable.register` performs (every
    :class:`Region` size is a power of two >= 1 by construction).  The
    one place capacity pre-checks (e.g. endpoint tenant admission) and
    the allocator share the alignment rule."""
    for r in regions:
        cursor = align_base(cursor, r.size) + r.size
    return cursor


def next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def packed_table(specs: Sequence[Tuple[str, int]], *,
                 extra_words: int = 0) -> RegionTable:
    """Build a RegionTable sized exactly for ``specs`` (name, size_words
    rounded up to a power of two), accounting for natural alignment."""
    cursor = 0
    layout = []
    specs = [(name, next_pow2(size)) for name, size in specs]
    for name, size in specs:
        base = align_base(cursor, size)
        layout.append((name, base, size))
        cursor = base + size
    rt = RegionTable(pool_words=cursor + extra_words)
    for name, base, size in layout:
        rt.register(name, size, base=base)
    return rt


def make_pool(n_devices: int, table: RegionTable,
              fill: int = 0) -> np.ndarray:
    """Allocate the (n_devices, pool_words) int64 backing store."""
    mem = np.full((n_devices, table.pool_words), fill, dtype=np.int64)
    return mem


def pool_sharding(mesh, axis: str = "pool"):
    """The pool's mesh placement: the leading ``n_devices`` axis sharded
    over the 1-D device mesh (device ``d`` holds row ``d`` — its blade's
    DRAM), words replicated along no other axis."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec(axis, None))


def shard_pool(mem: np.ndarray, mesh=None, axis: str = "pool"):
    """Place a ``(n_devices, pool_words)`` pool on a device mesh with
    :func:`pool_sharding` — shard-aware pool construction for the
    sharded VM engine (device ``d`` owns ``mem[d]``).  With no ``mesh``
    a 1-D mesh over the first ``n_devices`` local devices is built
    (raises when the host exposes fewer)."""
    import jax

    from repro import jaxcompat
    if mesh is None:
        mesh = jaxcompat.make_device_mesh(int(mem.shape[0]), axis)
    return jax.device_put(np.asarray(mem, dtype=np.int64),
                          pool_sharding(mesh, axis))


def write_region(mem: np.ndarray, table: RegionTable, device: int,
                 region: str, data: Sequence[int], offset: int = 0) -> None:
    """Host-side (control path) helper to populate a region."""
    r = table[region]
    data = np.asarray(data, dtype=np.int64)
    if offset + data.size > r.size:
        raise ValueError(f"write of {data.size} words at {offset} exceeds "
                         f"region {region!r} ({r.size} words)")
    mem[device, r.base + offset: r.base + offset + data.size] = data


def read_region(mem: np.ndarray, table: RegionTable, device: int,
                region: str, offset: int = 0,
                count: Optional[int] = None) -> np.ndarray:
    r = table[region]
    if count is None:
        count = r.size - offset
    if offset + count > r.size:
        raise ValueError("read exceeds region")
    return np.asarray(mem[device, r.base + offset: r.base + offset + count])


def bytes_to_words(n_bytes: int) -> int:
    return (n_bytes + isa.WORD_BYTES - 1) // isa.WORD_BYTES
