"""Overload-safe continuous serving loop over a :class:`TiaraEndpoint`.

The endpoint is a wave-at-a-time executor: callers ring ``doorbell()``
by hand and any number of waves pile up in flight.  A serving fabric
cannot fail open like that — under overload every resource must stay
bounded and every degradation must be deterministic.  This module is
that discipline, in the shape the serving literature converged on
(RedN's chained asynchronously-retired work requests for throughput,
EDM's bounded fabric queueing for tail latency — see PAPERS.md):

  * **Bounded in-flight waves.**  Wave formation never launches past
    ``max_inflight_waves``; at the bound it blocks in
    :meth:`TiaraEndpoint.wait_any` for the oldest wave (the PR-5
    watermark carry-over), so split-phase pipelining is capped, not
    unbounded.
  * **Continuous batcher.**  :meth:`ServingLoop.pump` forms waves like
    a serving engine's continuous batcher: ring when the batch hits
    ``ring_size``, when the oldest admitted post ages past
    ``ring_age_s``, or when the cost model's
    :meth:`~repro.core.costmodel.DispatchCostModel.launch_efficiency`
    says the launch already amortizes well enough
    (``min_efficiency``) — the estimate adapts online through the
    endpoint's per-slot EWMA wall-clock feed.
  * **Admission control & backpressure.**  Each tenant has a token
    bucket (``TenantQoS.rate``/``burst``) and a bounded admitted queue
    (``max_pending``); :meth:`ServingLoop.submit` either blocks with a
    timeout (pumping the loop while it waits) or rejects immediately
    with a ``STATUS_EAGAIN`` CQE.  Rejected work never executes but
    always retires exactly one completion.
  * **Weighted fair queueing.**  Admitted posts carry virtual finish
    tags (``F = max(V, F_tenant) + 1/weight``); wave formation selects
    the globally smallest tags, which is automatically a per-tenant
    FIFO prefix — per-session FIFO survives fair scheduling.
  * **Deadlines.**  A per-post ``deadline_s`` is enforced at admission,
    at every pump, and again when the doorbell drains the queues; an
    expired post retires ``STATUS_TIMEOUT`` and never executes.
  * **Load shedding.**  Expired work is always dropped first (the
    deadline sweep precedes shedding); past ``shed_watermark`` total
    backlog the loop drops the lowest-weight tenants' newest work with
    ``STATUS_EAGAIN`` until the backlog fits — sustained overload
    degrades the cheapest work deterministically instead of growing
    queues without bound.

Determinism: every decision reads the endpoint's injectable clock, so
a :class:`VirtualClock` makes an entire overload run — arrivals,
deadlines, sheds, fairness — exactly reproducible from a seed while
the waves still execute for real (`tests/test_serving_loop.py` holds
bit-parity with the pyvm oracle under chaos).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import isa
from repro.core.endpoint import Completion, Session, TiaraEndpoint


class VirtualClock:
    """A deterministic clock + sleep pair for the endpoint's
    ``clock``/``sleep`` hooks: ``sleep`` *advances* the clock instead of
    blocking, so overload scenarios (backoff, injected delays, aging
    deadlines) run in microseconds of wall time and are exactly
    reproducible."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def sleep(self, seconds: float) -> None:
        self.now += max(float(seconds), 0.0)

    def advance(self, seconds: float) -> float:
        self.now += max(float(seconds), 0.0)
        return self.now

    def advance_to(self, t: float) -> float:
        self.now = max(self.now, float(t))
        return self.now


@dataclasses.dataclass(frozen=True)
class TenantQoS:
    """Per-tenant service contract.

    ``rate`` is the token-bucket refill in posts/second (None =
    unlimited), ``burst`` the bucket depth, ``weight`` the WFQ share —
    a weight-2 tenant gets twice the wave slots of a weight-1 tenant
    when both have backlog."""

    rate: Optional[float] = None
    burst: int = 32
    weight: float = 1.0

    def __post_init__(self):
        if self.rate is not None and self.rate <= 0:
            raise ValueError("rate must be positive (or None)")
        if self.burst < 1:
            raise ValueError("burst must be >= 1")
        if self.weight <= 0:
            raise ValueError("weight must be positive")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Serving-loop policy knobs (see module docstring)."""

    max_inflight_waves: int = 2     # split-phase pipelining bound
    max_pending: int = 64           # per-tenant admitted-queue bound
    ring_size: int = 32             # formation: ring at this batch
    ring_age_s: float = 0.005       # formation: ring at this head age
    min_efficiency: float = 0.5     # formation: ring at this cost-model
                                    # launch efficiency
    shed_watermark: Optional[int] = None   # total backlog triggering
                                           # load shedding (None = off)
    block_timeout_s: float = 0.0    # submit(block=True) budget
    block_poll_s: float = 0.0005    # sleep step while blocked
    default_deadline_s: Optional[float] = None
    admission_wcet: bool = True     # fail-fast posts whose operator's
                                    # certified WCET already exceeds the
                                    # remaining deadline (statically
                                    # infeasible: never queued, never
                                    # launched, still exactly one CQE)
    mode: str = "auto"              # doorbell engine mode
    placement: str = "single"       # doorbell placement
    opportunistic_poll: bool = True  # retire landed waves every pump
                                     # (False = only the in-flight
                                     # bound retires — deterministic
                                     # retirement points under a
                                     # virtual clock)

    def __post_init__(self):
        if self.max_inflight_waves < 1:
            raise ValueError("max_inflight_waves must be >= 1")
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        if self.ring_size < 1:
            raise ValueError("ring_size must be >= 1")


@dataclasses.dataclass
class ServingStats:
    """Counters + latency reservoir; one CQE retires per submitted post
    across these buckets (``submitted == sum of terminal outcomes``
    once the loop drains)."""

    submitted: int = 0
    admitted: int = 0
    launched: int = 0
    executed: int = 0        # retired with a real engine result
    ok: int = 0
    faulted: int = 0
    flushed: int = 0
    timed_out: int = 0
    rejected: int = 0        # STATUS_EAGAIN at admission
    shed: int = 0            # STATUS_EAGAIN from load shedding
    per_tenant: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)
    latencies: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list, repr=False)

    def bump(self, tenant: str, field: str, n: int = 1) -> None:
        setattr(self, field, getattr(self, field) + n)
        t = self.per_tenant.setdefault(tenant, {})
        t[field] = t.get(field, 0) + n

    def latency_percentile(self, q: float) -> float:
        """Submit-to-retire latency percentile over executed posts
        (seconds; 0.0 with no samples)."""
        if not self.latencies:
            return 0.0
        xs = sorted(lat for _, lat in self.latencies)
        i = min(int(q * len(xs)), len(xs) - 1)
        return xs[i]

    @property
    def p50_s(self) -> float:
        return self.latency_percentile(0.50)

    @property
    def p99_s(self) -> float:
        return self.latency_percentile(0.99)


@dataclasses.dataclass(frozen=True)
class PumpReport:
    """What one :meth:`ServingLoop.pump` turn did."""

    launched: int = 0        # posts launched in a new wave (0 = no ring)
    wave_id: int = -1
    predicted_us: float = 0.0   # cost-model estimate for the new wave
    retired: int = 0
    timed_out: int = 0
    shed: int = 0
    flushed: int = 0


class ServingLoop:
    """The continuous serving loop: admit -> queue fairly -> form waves
    -> launch split-phase -> retire, with every stage bounded.

    Typical use::

        loop = ServingLoop(ep, ServingConfig(max_inflight_waves=2),
                           qos={"a": TenantQoS(weight=2.0)})
        c = loop.submit("a", "walk", [start, 12], deadline_s=0.1)
        loop.pump()          # call from the serving thread's main turn
        ...
        loop.drain()         # flush everything at shutdown
    """

    def __init__(self, endpoint: TiaraEndpoint,
                 config: Optional[ServingConfig] = None,
                 qos: Optional[Dict[str, TenantQoS]] = None):
        self.ep = endpoint
        self.config = config or ServingConfig()
        self._qos: Dict[str, TenantQoS] = dict(qos or {})
        self._pending: Dict[str, Deque[Completion]] = {}
        self._tokens: Dict[str, float] = {}
        self._token_t: Dict[str, float] = {}
        self._tags: Dict[int, float] = {}       # seq -> WFQ finish tag
        self._submit_t: Dict[int, float] = {}   # seq -> admission time
        self._vtime = 0.0                       # WFQ virtual time
        self._vfinish: Dict[str, float] = {}    # tenant -> last tag
        self._launched: List[Completion] = []   # awaiting harvest
        self.stats = ServingStats()

    # -- QoS --------------------------------------------------------------

    def qos(self, tenant: str) -> TenantQoS:
        return self._qos.get(tenant, TenantQoS())

    def set_qos(self, tenant: str, qos: TenantQoS) -> None:
        self._qos[tenant] = qos

    # -- admission --------------------------------------------------------

    def _refill(self, tenant: str, now: float) -> None:
        q = self.qos(tenant)
        if q.rate is None:
            return
        last = self._token_t.get(tenant)
        if last is None:
            self._tokens[tenant] = float(q.burst)
        else:
            self._tokens[tenant] = min(
                float(q.burst),
                self._tokens.get(tenant, 0.0) + (now - last) * q.rate)
        self._token_t[tenant] = now

    def _admissible(self, tenant: str, now: float) -> bool:
        self._refill(tenant, now)
        q = self.qos(tenant)
        if q.rate is not None and self._tokens.get(tenant, 0.0) < 1.0:
            return False
        queue = self._pending.get(tenant)
        return queue is None or len(queue) < self.config.max_pending

    def submit(self, tenant: str, op: Union[str, int],
               params: Sequence[int] = (), *, home: int = 0,
               deadline_s: Optional[float] = None,
               contention: float = 0.0,
               block: bool = False) -> Completion:
        """Admit one invocation for ``tenant`` (exactly one CQE retires
        whatever happens).  Admission order: an errored session flushes
        (``STATUS_FLUSHED``); an already-expired deadline times out
        (``STATUS_TIMEOUT``); an empty token bucket or a full admitted
        queue rejects with ``STATUS_EAGAIN`` — or, with ``block=True``,
        pumps the loop for up to ``block_timeout_s`` first (the
        backpressure path: the caller is slowed to the rate the fabric
        sustains).  ``contention`` is the caller's conflict hint for
        this post's operator; the loop EWMAs it per slot
        (:meth:`~repro.core.costmodel.DispatchCostModel
        .observe_conflicts`) and prices future waves with the learned
        rate."""
        ep = self.ep
        sess: Session = ep.session(tenant)
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        c = sess._make(op, params, home=home, deadline_s=deadline_s)
        self.stats.bump(tenant, "submitted")
        ep.cost_model.observe_conflicts(c.op_id, contention)
        if sess.in_error:
            ep._retire_immediate(c, isa.STATUS_FLUSHED)
            self.stats.bump(tenant, "flushed")
            return c
        now = ep._clock()
        if c.deadline is not None and c.deadline <= now:
            ep._retire_immediate(c, isa.STATUS_TIMEOUT)
            self.stats.bump(tenant, "timed_out")
            return c
        if self._wcet_infeasible(c, now):
            # statically infeasible deadline: the certificate already
            # proves the worst case overruns it — fail fast instead of
            # queueing work that could only expire after launch
            ep._retire_immediate(c, isa.STATUS_TIMEOUT)
            self.stats.bump(tenant, "timed_out")
            return c
        if not self._admissible(tenant, now):
            gave_up = True
            if block and self.config.block_timeout_s > 0.0:
                give_up_at = now + self.config.block_timeout_s
                while True:
                    self.pump()
                    ep._sleep(self.config.block_poll_s)
                    now = ep._clock()
                    if self._admissible(tenant, now):
                        gave_up = False
                        break
                    if now >= give_up_at:
                        break
                # the post may have expired (or its remaining window
                # shrunk below the certified WCET) while it waited
                if not gave_up and ((c.deadline is not None
                                     and c.deadline <= now)
                                    or self._wcet_infeasible(c, now)):
                    ep._retire_immediate(c, isa.STATUS_TIMEOUT)
                    self.stats.bump(tenant, "timed_out")
                    return c
            if gave_up:
                ep._retire_immediate(c, isa.STATUS_EAGAIN)
                self.stats.bump(tenant, "rejected")
                return c
        q = self.qos(tenant)
        if q.rate is not None:
            self._tokens[tenant] -= 1.0
        # WFQ finish tag: monotone within a tenant, so selecting the
        # globally smallest tags always takes per-tenant FIFO prefixes
        tag = max(self._vtime, self._vfinish.get(tenant, 0.0)) \
            + 1.0 / q.weight
        self._vfinish[tenant] = tag
        self._tags[c.seq] = tag
        self._submit_t[c.seq] = now
        self._pending.setdefault(tenant, deque()).append(c)
        self.stats.bump(tenant, "admitted")
        return c

    def _wcet_infeasible(self, c: Completion, now: float) -> bool:
        """True when the post's deadline is *statically* infeasible:
        the operator's certified worst-case latency
        (:class:`~repro.core.wcet.LineRateCertificate`) already
        overruns the time remaining, so queueing or launching could
        only burn fabric work before the same ``STATUS_TIMEOUT``
        retires.  Admission retires it immediately instead."""
        if not self.config.admission_wcet or c.deadline is None:
            return False
        cert = self.ep.registry[c.op_id].certificate
        if cert is None:
            return False
        return now + cert.wcet_latency_us * 1e-6 > c.deadline

    # -- backlog maintenance ----------------------------------------------

    def _drop(self, tenant: str, c: Completion, status: int,
              field: str) -> None:
        tag_c = self._tags.pop(c.seq, None)
        self._submit_t.pop(c.seq, None)
        # WFQ never charges for unserved work: give the dropped post's
        # virtual slot back by shifting the tenant's later queued tags
        # (and its finish tag) down one service quantum.  Without the
        # refund, a tenant losing work to deadlines or sheds keeps
        # paying for service it never received — its head tag drifts
        # above everyone else's and it starves in a feedback loop
        # (expire -> fall behind -> expire).  The uniform shift keeps
        # per-tenant tags monotone, so wave formation still selects
        # FIFO prefixes.
        if tag_c is not None:
            quantum = 1.0 / self.qos(tenant).weight
            for d in self._pending.get(tenant, ()):
                if d.seq in self._tags and self._tags[d.seq] > tag_c:
                    self._tags[d.seq] -= quantum
            self._vfinish[tenant] = \
                self._vfinish.get(tenant, 0.0) - quantum
        self.ep._retire_immediate(c, status)
        self.stats.bump(tenant, field)

    def _flush_errored(self) -> int:
        n = 0
        for tenant, queue in self._pending.items():
            if queue and self.ep.session(tenant).in_error:
                while queue:
                    self._drop(tenant, queue.popleft(),
                               isa.STATUS_FLUSHED, "flushed")
                    n += 1
        return n

    def _expire(self, now: float) -> int:
        n = 0
        for tenant, queue in self._pending.items():
            live = deque()
            for c in queue:
                if c.deadline is not None and c.deadline <= now:
                    self._drop(tenant, c, isa.STATUS_TIMEOUT, "timed_out")
                    n += 1
                else:
                    live.append(c)
            self._pending[tenant] = live
        return n

    def _shed(self) -> int:
        """Past the watermark, drop the lowest-weight tenants' newest
        admitted work (LIFO within a tenant, so the survivors keep their
        FIFO prefix) until the backlog fits.  Ties on weight shed from
        the longest backlog first, so equal-weight tenants share the
        pain instead of the first-connected tenant absorbing every
        drop.  Runs after the deadline sweep, so expired work is always
        shed first."""
        wm = self.config.shed_watermark
        if wm is None:
            return 0
        backlog = sum(len(q) for q in self._pending.values())
        n = 0
        while backlog > wm:
            victim = min(
                (t for t, q in self._pending.items() if q),
                key=lambda t: (self.qos(t).weight,
                               -len(self._pending[t]), t))
            self._drop(victim, self._pending[victim].pop(),
                       isa.STATUS_EAGAIN, "shed")
            backlog -= 1
            n += 1
        return n

    def _harvest(self) -> int:
        """Collect stats for launched posts that have retired."""
        still: List[Completion] = []
        n = 0
        for c in self._launched:
            if not c.done:
                still.append(c)
                continue
            n += 1
            tenant = c.session.tenant
            t0 = self._submit_t.pop(c.seq, None)
            if c.status == isa.STATUS_TIMEOUT:
                # expired at the doorbell drain (never executed)
                self.stats.bump(tenant, "timed_out")
            elif c.status == isa.STATUS_FLUSHED:
                self.stats.bump(tenant, "flushed")
            else:
                self.stats.bump(tenant, "executed")
                if c.ok:
                    self.stats.bump(tenant, "ok")
                elif c.faulted:
                    self.stats.bump(tenant, "faulted")
                if t0 is not None and c.event is not None:
                    self.stats.latencies.append(
                        (tenant, c.event.retired_at - t0))
        self._launched = still
        return n

    def harvest(self) -> int:
        """Public harvest: collect stats/latencies for launched posts
        that have retired since the last pump.  For callers that drive
        retirement themselves (``ep.wait_all()`` between their own
        pumps) instead of going through :meth:`drain`."""
        return self._harvest()

    # -- wave formation ---------------------------------------------------

    def _selectable(self) -> List[Tuple[float, Completion]]:
        """(tag, post) for every pending post of a non-stalled tenant,
        smallest (= most entitled) tags first."""
        out: List[Tuple[float, Completion]] = []
        for tenant, queue in self._pending.items():
            if queue and self.ep.stalled(tenant):
                continue        # injected stall: age toward the deadline
            for c in queue:
                out.append((self._tags[c.seq], c))
        out.sort(key=lambda tc: (tc[0], tc[1].seq))
        return out

    def _should_ring(self, picked: List[Completion], now: float) -> bool:
        cfg = self.config
        if len(picked) >= cfg.ring_size:
            return True
        oldest = min(self._submit_t.get(c.seq, now) for c in picked)
        if now - oldest >= cfg.ring_age_s:
            return True
        key, steps, contention = self._wave_profile(picked)
        eff = self.ep.cost_model.launch_efficiency(
            batch=len(picked), step_bound=steps, key=key,
            contention_rate=contention)
        return eff >= cfg.min_efficiency

    def _wave_profile(self, picked: Sequence[Completion]
                      ) -> Tuple[Optional[int], int, float]:
        """(cost-model key, step bound, learned contention) for a
        candidate wave: the slot id for single-op waves (per-slot EWMA
        scales apply), the wave-global bucket otherwise; contention is
        the max of the selected slots' learned conflict rates — any
        contended slot pins the wave to the conflict-exact engine.

        A static no-conflict proof over the candidate's concrete params
        (``registry.prove_wave_noconflict``) overrides the learned rate
        with 0.0: the EWMA is a guess about past waves, the proof is a
        fact about this one — so a provably-disjoint wave forms and
        prices as conflict-free even on a slot with a contended
        history."""
        reg = self.ep.registry
        ids = sorted({c.op_id for c in picked})
        steps = max(reg[i].verified.step_bound for i in ids)
        contention = max(self.ep.cost_model.conflict_hint(i) for i in ids)
        if contention > 0.0 and reg.prove_wave_noconflict(
                [c.op_id for c in picked],
                [list(c.params) for c in picked],
                [c.home for c in picked]):
            contention = 0.0
        key = ids[0] if len(ids) == 1 else None
        return key, steps, contention

    def pump(self, force: bool = False) -> PumpReport:
        """One serving turn: retire what landed, flush/expire/shed the
        backlog, and launch one wave if the formation policy rings
        (``force=True`` rings on any non-empty backlog — the drain
        path).  Never launches past ``max_inflight_waves``: at the
        bound it first blocks for the oldest in-flight wave."""
        ep = self.ep
        cfg = self.config
        if cfg.opportunistic_poll:
            ep._retire_ready()
        flushed = self._flush_errored()
        now = ep._clock()
        timed_out = self._expire(now)
        shed = self._shed()
        retired = self._harvest()
        launched = 0
        wave_id = -1
        predicted_us = 0.0
        picked_all = self._selectable()
        if picked_all:
            tag_of = {c.seq: tag for tag, c in picked_all}
            picked = [c for _, c in picked_all[:cfg.ring_size]]
            ring = force or self._should_ring(picked, now)
            if ring and ep.in_flight_waves >= cfg.max_inflight_waves:
                ep.wait_any()           # the watermark-triggered bound
                retired += self._harvest()
                # the retired wave may have faulted a session whose
                # posts we just selected — flush them, never launch
                flushed += self._flush_errored()
                picked = [c for c in picked if not c.done]
            if ring and picked:
                key, steps, contention = self._wave_profile(picked)
                for c in picked:
                    queue = self._pending[c.session.tenant]
                    assert queue[0] is c, "WFQ must select FIFO prefixes"
                    queue.popleft()
                    self._tags.pop(c.seq, None)
                    ep._enqueue(c)
                self._vtime = max(self._vtime,
                                  max(tag_of[c.seq] for c in picked))
                # the wave's certified cost ceiling: no wave can cost
                # more than the sum of its members' certified worst
                # cases, so no EWMA prediction may price it above that
                certs = [ep.registry[c.op_id].certificate for c in picked]
                ceiling = (sum(x.wcet_latency_us for x in certs)
                           if all(x is not None for x in certs) else None)
                predicted_us = ep.cost_model.wave_us(
                    batch=len(picked), step_bound=steps, key=key,
                    mode="mixed", contention_rate=contention,
                    cert_ceiling_us=ceiling)
                if cfg.placement != "single" and ep.n_devices > 1:
                    # non-single placements: price the wave through the
                    # placement model (the learned home-skew EWMA sets
                    # batch_per_device when no plan is supplied), not
                    # the one-chip mixed engine
                    decision = ep.cost_model.choose_placement(
                        batch=len(picked), n_devices=ep.n_devices,
                        step_bound=steps, contention_rate=contention)
                    if cfg.placement == "sharded":
                        predicted_us = decision.costs.get(
                            "sharded", predicted_us)
                    else:                       # "auto": the pick's cost
                        predicted_us = decision.costs[decision.mode]
                    if ceiling is not None:
                        predicted_us = min(predicted_us, ceiling)
                handle = ep.doorbell(mode=cfg.mode,
                                     placement=cfg.placement,
                                     contention_rate=contention,
                                     wait=False)
                wave_id = handle.wave_id
                launched = len(picked)
                for c in picked:
                    self.stats.bump(c.session.tenant, "launched")
                self._launched.extend(picked)
        return PumpReport(launched=launched, wave_id=wave_id,
                          predicted_us=predicted_us, retired=retired,
                          timed_out=timed_out, shed=shed, flushed=flushed)

    # -- shutdown ---------------------------------------------------------

    @property
    def backlog(self) -> int:
        """Admitted posts not yet launched."""
        return sum(len(q) for q in self._pending.values())

    def drain(self, *, max_pumps: int = 10_000) -> ServingStats:
        """Launch everything admitted (stalled tenants wait for their
        stalls through the sleep hook), retire every in-flight wave,
        and harvest; returns the final stats."""
        pumps = 0
        while self.backlog > 0:
            report = self.pump(force=True)
            if report.launched == 0 and self.backlog > 0:
                # backlog but nothing selectable: stalled tenants —
                # sleep to the earliest stall expiry and retry
                now = self.ep._clock()
                stalls = [u for u in self.ep._stalls.values() if u > now]
                self.ep._sleep((min(stalls) - now) if stalls
                               else self.config.block_poll_s)
            pumps += 1
            if pumps > max_pumps:
                raise RuntimeError(
                    f"drain did not converge in {max_pumps} pumps "
                    f"(backlog {self.backlog})")
        self.ep.wait_all()
        self._harvest()
        return self.stats
