"""Cycle-level memory-processor simulator (the paper's Verilator stand-in).

The paper measures Tiara on a cycle-accurate model of the Alveo U50 build
(5 ns clock, 150-cycle PCIe DMA, 500-cycle RDMA RTT) and derives saturated
throughput from latency at 8 MPs x 12 outstanding tasks.  We replay the
*executed instruction trace* of a verified operator (from the pyvm oracle,
so timing follows the exact data-dependent path) against the same machine
parameters:

  * each instruction costs one MP cycle (sequential scalar FSM);
  * Load/Store/CAS issue a small PCIe DMA: ``dma_issue_cycles`` of channel
    occupancy, ``pcie_dma_cycles`` of latency;
  * Memcpy moves payload at the PCIe bulk rate (local) or wire rate
    (remote, plus one RTT for the write+ack);
  * async Memcpy is a true split-phase transfer: issue charges only the
    channel/wire *occupancy* (the port is busy for the transfer's
    duration), the MP keeps executing, and the copy retires in the
    background at its completion time;
  * Wait(thr) blocks the MP only until the in-flight count drops to
    ``thr`` — completions retire in completion-time order, so a
    double-buffered chain (``Wait(1)`` between chunks) overlaps chunk
    k+1's resolution with chunk k's transfer; completions that have
    already landed by the time Wait executes cost nothing;
  * the reply and request each cross half an RTT plus wire serialization
    (any still-outstanding async copy joins implicitly before the reply).

Two MP variants (DESIGN.md discusses the calibration):
  * ``pipelined=False`` — FPGA-faithful: every load stalls the FSM for the
    full DMA latency (register-chained loads are made correct by stalling
    fetch until writeback).
  * ``pipelined=True``  — the production-ASIC/software-pipelined model the
    paper's §4.6 numbers imply: loads inside a loop body whose iterations
    are *independent* (no loop-carried address chain — PagedAttention and
    MoE gather, NOT pointer chasing) hide their latency behind previous
    iterations after the first (pipeline fill), costing only channel
    occupancy.  The caller asserts independence via ``serial_chain``.

Saturated throughput uses operational bottleneck analysis, which is exact
for the steady state of identical tasks: the slowest of
{MP issue, DMA channel, wire, dispatcher-slot residency} binds.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Set

from repro.core import isa
from repro.core.costmodel import HW, DEFAULT_HW
from repro.core.isa import Op
from repro.core.pyvm import TraceEvent
from repro.core.verifier import VerifiedOperator
# The per-transfer DMA setup cost and wire header sizes are shared with
# the static line-rate certifier (the certificate must charge exactly
# what this simulator charges); ``core/wcet`` is their single source of
# truth and this module re-exports them for its existing callers.
from repro.core.wcet import (DMA_SETUP_CYCLES, REPLY_BYTES,  # noqa: F401
                             REQUEST_BYTES)


@dataclasses.dataclass
class TaskSim:
    """Timing of one operator invocation."""

    latency_us: float          # client-observed end-to-end
    nic_resident_us: float     # dispatcher-slot occupancy
    mp_cycles: int
    dma_channel_cycles: int    # PCIe small-request + bulk occupancy
    dma_small_reqs: int
    dma_bulk_bytes: int
    wire_bytes: int            # request + reply + remote Memcpy payload
    n_instr_executed: int
    async_issued: int = 0      # split-phase Memcpys issued
    wait_stall_cycles: float = 0.0   # cycles the MP blocked in WAIT /
    #                                # the implicit pre-reply join
    failed_transfers: int = 0  # injected mid-flight Memcpy aborts


def simulate_task(vop: VerifiedOperator, trace: Sequence[TraceEvent],
                  hw: HW = DEFAULT_HW, *, pipelined: bool = False,
                  serial_chain: bool = True,
                  reply_payload_bytes: int = 0,
                  serialize_async: bool = False,
                  fail_memcpy_at: Sequence[int] = ()) -> TaskSim:
    """Charge cycle costs along one executed trace.

    ``reply_payload_bytes``: data returned to the caller beyond the status
    word (e.g. the gathered KV blocks), serialized onto the wire.

    ``serialize_async=True`` treats every async Memcpy as synchronous —
    the no-overlap timeline a split-phase operator is compared against
    (``bench_async_overlap`` reports the ratio).

    ``fail_memcpy_at``: fault-injection hook — the i-th Memcpy (0-based
    issue index, sync or async) aborts halfway through its transfer: it
    occupies its port for half the full occupancy, delivers half the
    payload, and retires (with an error CQE on real hardware) at the
    abort time.  ``TaskSim.failed_transfers`` counts them; WAIT still
    joins an aborted async copy at its abort time, so the timing of the
    paper's degraded-mode fallback path (test ERR_REG, re-issue) can be
    simulated against the same trace.
    """
    clk = hw.clk_ns
    dma_lat = hw.pcie_dma_cycles
    rtt_cy = hw.rdma_rtt_cycles
    wire_bpc = hw.wire_eff_gbs * clk            # bytes per cycle
    pcie_bpc = hw.pcie_gbs * clk

    loop_pcs: Set[int] = set()
    for l in vop.loops:
        loop_pcs.update(range(l.start, l.end + 1))
    can_pipeline = pipelined and not serial_chain

    t = float(hw.dispatch_cycles)     # cycles since dispatch
    mp_cycles = 0
    chan = 0.0
    small = 0
    bulk_bytes = 0
    wire_bytes = REQUEST_BYTES + REPLY_BYTES + reply_payload_bytes
    outstanding: List[float] = []     # completion times of in-flight copies
    async_issued = 0
    wait_stall = 0.0
    seen_pcs: Set[int] = set()
    # serializing shared resources (per-NIC): the PCIe channel and the
    # network port — async transfers queue on them, which is what makes a
    # pipelined gather line-rate-bound rather than latency-bound
    chan_free = 0.0
    wire_free = 0.0
    fail_at = set(int(i) for i in fail_memcpy_at)
    memcpy_idx = 0
    failed_transfers = 0

    for ev in trace:
        mp_cycles += 1
        t += hw.instr_cycles
        if ev.op in (Op.LOAD, Op.STORE, Op.CAS, Op.CAA):
            small += 1
            chan += hw.dma_issue_cycles
            if ev.remote:
                t += rtt_cy
                wire_bytes += 2 * 32       # small RDMA read/write + ack
            else:
                start = max(t, chan_free)
                chan_free = start + hw.dma_issue_cycles
                if can_pipeline and ev.pc in loop_pcs and ev.pc in seen_pcs:
                    t = start + hw.dma_issue_cycles  # latency pipelined away
                else:
                    t = start + dma_lat
                    seen_pcs.add(ev.pc)
        elif ev.op == Op.MEMCPY:
            nbytes = ev.n_words * isa.WORD_BYTES
            if memcpy_idx in fail_at:
                # mid-flight abort: half the payload crossed before the
                # port errored; the completion (a NAK) still pays the
                # latency leg below
                nbytes //= 2
                failed_transfers += 1
            memcpy_idx += 1
            if ev.remote:
                # one side is usually the local pool: the stream crosses
                # PCIe *and* the wire (cut-through at the slower rate)
                local_side = not (ev.src_remote and ev.dst_remote)
                eff_bpc = min(wire_bpc, pcie_bpc) if local_side else wire_bpc
                start = max(t, wire_free, chan_free if local_side else 0.0)
                occ = DMA_SETUP_CYCLES + nbytes / eff_bpc
                wire_free = start + occ
                if local_side:
                    chan_free = start + occ
                    chan += occ
                done = start + occ + rtt_cy            # write + ack
                wire_bytes += nbytes + 32
            else:
                start = max(t, chan_free)
                occ = DMA_SETUP_CYCLES + nbytes / pcie_bpc
                chan_free = start + occ
                done = start + dma_lat + occ
                chan += occ
                bulk_bytes += nbytes
            if ev.is_async and not serialize_async:
                # split-phase: the port occupancy is charged above, the
                # MP moves on; the transfer retires at `done`
                outstanding.append(done)
                async_issued += 1
            else:
                t = done
        elif ev.op == Op.WAIT:
            # completions retire in completion-time order; Wait(thr)
            # blocks only until at most `thr` transfers remain in flight
            outstanding = [d for d in outstanding if d > t]
            thr = max(int(getattr(ev, "wait_thr", 0)), 0)
            if len(outstanding) > thr:
                outstanding.sort()
                t_new = outstanding[len(outstanding) - thr - 1]
                wait_stall += max(t_new - t, 0.0)
                t = max(t, t_new)
                outstanding = [d for d in outstanding if d > t]
        # NOP/MOVI/ALU/JUMP/LOOP/RET: 1 MP cycle, already charged

    if outstanding:                    # implicit completion before reply
        t_new = max(outstanding)
        wait_stall += max(t_new - t, 0.0)
        t = max(t, t_new)

    nic_resident_us = t * clk / 1e3
    latency_us = (hw.rtt_us / 2                      # request flight
                  + REQUEST_BYTES / (wire_bpc) * clk / 1e3
                  + nic_resident_us
                  + hw.rtt_us / 2                    # reply flight
                  + (REPLY_BYTES + reply_payload_bytes) / wire_bpc * clk / 1e3)
    return TaskSim(latency_us=latency_us, nic_resident_us=nic_resident_us,
                   mp_cycles=mp_cycles, dma_channel_cycles=int(chan),
                   dma_small_reqs=small, dma_bulk_bytes=bulk_bytes,
                   wire_bytes=wire_bytes, n_instr_executed=len(trace),
                   async_issued=async_issued, wait_stall_cycles=wait_stall,
                   failed_transfers=failed_transfers)


def overlap_speedup(vop: VerifiedOperator, trace: Sequence[TraceEvent],
                    hw: HW = DEFAULT_HW, **kwargs: Any) -> float:
    """NIC-residency ratio of the serialized timeline (every Memcpy
    synchronous) over the split-phase one — how much latency the async
    issue + deferred retirement actually hides for this trace."""
    asyn = simulate_task(vop, trace, hw, **kwargs)
    sync = simulate_task(vop, trace, hw, serialize_async=True, **kwargs)
    return sync.nic_resident_us / max(asyn.nic_resident_us, 1e-12)


def saturated_throughput_mops(sim: TaskSim, hw: HW = DEFAULT_HW) -> float:
    """Bottleneck law over shared resources, in Mops."""
    clk_us = hw.clk_ns / 1e3
    demands_us: Dict[str, float] = {
        "mp": sim.mp_cycles * clk_us / hw.n_mps,
        "dma_channel": sim.dma_channel_cycles * clk_us,
        "wire": sim.wire_bytes / hw.wire_bytes_per_us,
        "slots": sim.nic_resident_us / hw.slots,
    }
    return 1.0 / max(demands_us.values())


def bottleneck(sim: TaskSim, hw: HW = DEFAULT_HW) -> str:
    clk_us = hw.clk_ns / 1e3
    demands_us: Dict[str, float] = {
        "mp": sim.mp_cycles * clk_us / hw.n_mps,
        "dma_channel": sim.dma_channel_cycles * clk_us,
        "wire": sim.wire_bytes / hw.wire_bytes_per_us,
        "slots": sim.nic_resident_us / hw.slots,
    }
    return max(demands_us, key=lambda k: demands_us[k])


def effective_gather_gbs(sim: TaskSim, payload_bytes: int,
                         hw: HW = DEFAULT_HW) -> float:
    """Fig. 10 metric: payload delivered / end-to-end latency."""
    return payload_bytes / sim.latency_us / 1e3
