"""Operator registry — the NIC's control plane.

Models one Tiara NIC: a region table over the host pool, per-tenant grants,
and the 256-entry ``op_id -> start_pc`` dispatch table (paper §3).
``register()`` is the eBPF-load moment: compile output goes through the
static verifier against the *tenant's* grant; only then does the operator
get a slot.  Registration is also the trace-compile moment: the slot
records whether the operator's CFG admits the interpreter-free fast path
(``core/compile``), so the data path can dispatch with no further checks.

``invoke()`` is the single-request data path — O(1) dispatch, no checks.
``invoke_batched()`` is the line-rate path: B requests share one XLA
launch, dispatched to the trace-compiled superoperator when the slot has
one and to the batch-parallel interpreter otherwise.

The instruction stores are per-MP BRAMs of 1024 entries; we model one
shared store and enforce the aggregate capacity.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Set, Union

import numpy as np

from repro.core import compile as tcompile
from repro.core import isa, vm
from repro.core.memory import Grant, RegionTable
from repro.core.program import TiaraProgram
from repro.core.verifier import VerifiedOperator, verify


class RegistrationError(Exception):
    pass


@dataclasses.dataclass
class Slot:
    """One dispatch-table entry with its three entry points:

    ``interp``   single-request lax.while_loop interpreter (always there);
    ``batched``  batch-parallel interpreter, B requests per XLA launch;
    ``compiled`` trace-compiled straight-line fast path (when the CFG
                 admits one — ``compilable`` / ``compile_reason``).
    """

    op_id: int
    tenant: str
    verified: VerifiedOperator
    start_pc: int
    regions: RegionTable
    compile_reason: Optional[str] = None
    n_gather_chains: int = 0

    @property
    def compilable(self) -> bool:
        return self.compile_reason is None

    def interp(self, mem: np.ndarray, params: Sequence[int] = (), *,
               home: int = 0,
               failed: Optional[Set[int]] = None) -> vm.InvokeResult:
        return vm.invoke(self.verified, self.regions, mem, params,
                         home=home, failed=failed)

    def batched(self, mem: np.ndarray, params: Sequence[Sequence[int]], *,
                homes: Union[int, Sequence[int]] = 0,
                failed: Optional[Set[int]] = None
                ) -> vm.BatchedInvokeResult:
        return vm.invoke_batched(self.verified, self.regions, mem, params,
                                 homes=homes, failed=failed)

    def compiled(self, mem: np.ndarray, params: Sequence[Sequence[int]], *,
                 homes: Union[int, Sequence[int]] = 0,
                 failed: Optional[Set[int]] = None,
                 impl: str = "xla") -> vm.BatchedInvokeResult:
        if not self.compilable:
            raise ValueError(
                f"op {self.op_id} has no compiled entry point: "
                f"{self.compile_reason}")
        return tcompile.invoke_compiled(self.verified, self.regions, mem,
                                        params, homes=homes, failed=failed,
                                        impl=impl)


class OperatorRegistry:
    def __init__(self, regions: RegionTable, *, n_devices: int = 1,
                 max_steps: Optional[int] = None):
        self.regions = regions
        self.n_devices = int(n_devices)
        self.max_steps = max_steps
        self._grants: Dict[str, Grant] = {}
        self._slots: Dict[int, Slot] = {}
        self._by_name: Dict[str, int] = {}
        self._store_used = 0

    # -- tenants --------------------------------------------------------

    def add_tenant(self, grant: Grant) -> None:
        self._grants[grant.tenant] = grant

    def grant_of(self, tenant: str) -> Grant:
        if tenant not in self._grants:
            raise RegistrationError(f"unknown tenant {tenant!r}")
        return self._grants[tenant]

    # -- registration (control path) -------------------------------------

    def register(self, tenant: str, program: TiaraProgram) -> int:
        grant = self.grant_of(tenant)
        kwargs = {}
        if self.max_steps is not None:
            kwargs["max_steps"] = self.max_steps
        verified = verify(program, grant=grant, regions=self.regions,
                          **kwargs)
        if len(self._slots) >= isa.OP_TABLE_SIZE:
            raise RegistrationError("op_id table full (256 entries)")
        if self._store_used + program.n_instr > isa.INSTR_STORE_SIZE:
            raise RegistrationError(
                f"instruction store full: {self._store_used} + "
                f"{program.n_instr} > {isa.INSTR_STORE_SIZE}")
        op_id = len(self._slots)
        self._slots[op_id] = Slot(
            op_id=op_id, tenant=tenant, verified=verified,
            start_pc=self._store_used, regions=self.regions,
            compile_reason=tcompile.why_not_compilable(verified),
            n_gather_chains=len(tcompile.find_gather_chains(verified)))
        self._store_used += program.n_instr
        self._by_name[f"{tenant}/{program.name}"] = op_id
        return op_id

    def lookup(self, tenant: str, name: str) -> int:
        return self._by_name[f"{tenant}/{name}"]

    def __getitem__(self, op_id: int) -> Slot:
        return self._slots[op_id]

    def __len__(self) -> int:
        return len(self._slots)

    @property
    def store_used(self) -> int:
        return self._store_used

    def dispatch_table(self) -> np.ndarray:
        """The 256-entry op_id -> start_pc hardware table (-1 = empty)."""
        t = np.full(isa.OP_TABLE_SIZE, -1, dtype=np.int64)
        for op_id, slot in self._slots.items():
            t[op_id] = slot.start_pc
        return t

    # -- invocation (data path) -------------------------------------------

    def invoke(self, op_id: int, mem: np.ndarray,
               params: Sequence[int] = (), *, home: int = 0,
               failed: Optional[Set[int]] = None,
               mode: str = "interp") -> vm.InvokeResult:
        """Single-request dispatch.  ``mode``: "interp" (default — the
        classic MP datapath), "compiled" (trace-compiled fast path), or
        "auto" (compiled when the slot has one, interpreter fallback)."""
        slot = self._slots[op_id]
        if mode == "auto":
            mode = "compiled" if slot.compilable else "interp"
        if mode == "interp":
            return slot.interp(mem, params, home=home, failed=failed)
        if mode == "compiled":
            r = slot.compiled(mem, [list(params)], homes=home, failed=failed)
            return vm.InvokeResult(mem=r.mem, ret=int(r.ret[0]),
                                   status=int(r.status[0]),
                                   steps=int(r.steps[0]), regs=r.regs[0])
        raise ValueError(f"unknown mode {mode!r}")

    def invoke_batched(self, op_id: int, mem: np.ndarray,
                       params: Sequence[Sequence[int]], *,
                       homes: Union[int, Sequence[int]] = 0,
                       failed: Optional[Set[int]] = None,
                       mode: str = "auto") -> vm.BatchedInvokeResult:
        """Line-rate dispatch: B requests, one XLA launch.  ``mode``:
        "auto" (compiled fast path when available, batched interpreter
        fallback), "batched" (force the interpreter), or "compiled"."""
        slot = self._slots[op_id]
        if mode == "auto":
            mode = "compiled" if slot.compilable else "batched"
        if mode == "batched":
            return slot.batched(mem, params, homes=homes, failed=failed)
        if mode == "compiled":
            return slot.compiled(mem, params, homes=homes, failed=failed)
        raise ValueError(f"unknown mode {mode!r}")

    def dump(self) -> str:
        lines = []
        for op_id, slot in sorted(self._slots.items()):
            p = slot.verified.program
            fast = "compiled" if slot.compilable else "interp-only"
            chains = f" gather-chains={slot.n_gather_chains}" \
                if slot.n_gather_chains else ""
            lines.append(
                f"op {op_id:3d}  tenant={slot.tenant:<12s} "
                f"{p.name:<20s} {p.n_instr:3d} instrs  "
                f"bound={slot.verified.step_bound:<8d} "
                f"regions r={p.regions_read} w={p.regions_written} "
                f"[{fast}{chains}]")
        return "\n".join(lines)
