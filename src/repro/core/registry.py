"""Operator registry — the NIC's control plane.

Models one Tiara NIC: a region table over the host pool, per-tenant grants,
and the 256-entry ``op_id -> start_pc`` dispatch table (paper §3).
``register()`` is the eBPF-load moment: compile output goes through the
static verifier against the *tenant's* grant; only then does the operator
get a slot.  Registration is also the trace-compile moment: the slot
records whether the operator's CFG admits the interpreter-free fast path
(``core/compile``), so the data path can dispatch with no further checks.

The data path is *internal* engine plumbing behind the queue-pair
endpoint surface (``core/endpoint``): ``_invoke()`` is single-request
O(1) dispatch, ``_invoke_batched()`` the line-rate path (B requests, one
XLA launch), and ``_invoke_mixed()`` the *multi-tenant* line-rate path: a
wave whose requests carry per-request op_ids runs either through the
mixed lockstep engine (one launch over the merged instruction store, each
request entering at its slot's ``start_pc`` — the hardware dispatch
table in software) or stable-sorted into same-op segments through the
compiled traces, with per-request outputs scattered back to arrival
order.  All ``mode="auto"`` choices go through the analytical
:class:`~repro.core.costmodel.DispatchCostModel` — engine choice is a
function of batch size, trace length, op-mix entropy, and the caller's
contention-rate hint, not a hardcoded preference.

There is no public invocation surface here: the PR-3 deprecated
``invoke``/``invoke_batched``/``invoke_mixed`` shims have been removed
after their one-release window.  All invocation goes through a
:class:`~repro.core.endpoint.Session` and
:meth:`~repro.core.endpoint.TiaraEndpoint.doorbell`, which owns the pool
and calls the internal engines here.

The instruction stores are per-MP BRAMs of 1024 entries; we model one
shared store and enforce the aggregate capacity.
"""

from __future__ import annotations

import dataclasses
from typing import (Dict, Iterator, List, Optional, Sequence, Set, Tuple,
                    Union)

import numpy as np

from repro.core import access
from repro.core import compile as tcompile
from repro.core import isa, vm, wcet
from repro.core.costmodel import (DispatchCostModel, DispatchDecision,
                                  SegmentStats)
from repro.core.memory import Grant, RegionTable
from repro.core.program import TiaraProgram
from repro.core.verifier import VerifiedOperator, verify

_SINGLE_MODES = ("auto", "interp", "compiled")
_BATCHED_MODES = ("auto", "batched", "compiled", "compiled_dbuf")
_MIXED_MODES = ("auto", "mixed", "segmented", "serial")
_PLACEMENTS = ("single", "sharded", "auto")


class RegistrationError(Exception):
    pass


@dataclasses.dataclass
class Slot:
    """One dispatch-table entry with its three entry points:

    ``interp``   single-request lax.while_loop interpreter (always there);
    ``batched``  batch-parallel interpreter, B requests per XLA launch;
    ``compiled`` trace-compiled straight-line fast path (when the CFG
                 admits one — ``compilable`` / ``compile_reason``).
    """

    op_id: int
    tenant: str
    verified: VerifiedOperator
    start_pc: int
    regions: RegionTable
    compile_reason: Optional[str] = None
    n_gather_chains: int = 0
    # Summed static caps of the *double-bufferable* gather chains only
    # (cap > compile.DBUF_CHUNK — the engine chunks per chain, so a
    # chain that fits one chunk must not count toward the overlap
    # discount or the dbuf candidate would price a win that the emitted
    # schedule cannot deliver).
    chain_iters: int = 0
    # Registration-time introspection (see ``tcompile.superop_report``):
    # every (superop kind, loop pc) the trace compiler will fuse, and —
    # when some loop matched nothing — the first structural reason the
    # gather-chain matcher bailed on it.
    superops: Tuple[Tuple[str, int], ...] = ()
    superop_near_miss: Optional[str] = None

    @property
    def compilable(self) -> bool:
        return self.compile_reason is None

    @property
    def footprint(self) -> Optional[access.OpFootprint]:
        """The operator's registration-time symbolic access footprint."""
        return self.verified.footprint

    @property
    def certificate(self) -> Optional[wcet.LineRateCertificate]:
        """The operator's registration-time line-rate certificate."""
        return self.verified.certificate

    def describe_analysis(self) -> str:
        """One-line summary of the static analysis artifacts: derived
        footprint, line-rate certificate, matched superoperators, and
        the nearest superop miss."""
        bits = ["footprint: "
                + access.describe_footprint(self.footprint, self.regions)]
        if self.certificate is not None:
            bits.append("certificate: " + self.certificate.describe())
        if self.superops:
            bits.append("superops: " + ", ".join(
                f"{kind}@pc{pc}" for kind, pc in self.superops))
        if self.superop_near_miss is not None:
            bits.append(f"superop near-miss: {self.superop_near_miss}")
        return "; ".join(bits)

    def interp(self, mem: np.ndarray, params: Sequence[int] = (), *,
               home: int = 0,
               failed: Optional[Set[int]] = None) -> vm.InvokeResult:
        return vm.invoke(self.verified, self.regions, mem, params,
                         home=home, failed=failed)

    def batched(self, mem: np.ndarray, params: Sequence[Sequence[int]], *,
                homes: Union[int, Sequence[int]] = 0,
                failed: Optional[Set[int]] = None,
                block: bool = True,
                static_noconflict: bool = False) -> vm.BatchedInvokeResult:
        return vm.invoke_batched(self.verified, self.regions, mem, params,
                                 homes=homes, failed=failed, block=block,
                                 static_noconflict=static_noconflict)

    def compiled(self, mem: np.ndarray, params: Sequence[Sequence[int]], *,
                 homes: Union[int, Sequence[int]] = 0,
                 failed: Optional[Set[int]] = None,
                 impl: str = "xla", double_buffer: bool = False,
                 block: bool = True,
                 static_noconflict: bool = False) -> vm.BatchedInvokeResult:
        if not self.compilable:
            raise ValueError(
                f"op {self.op_id} has no compiled entry point: "
                f"{self.compile_reason}")
        return tcompile.invoke_compiled(self.verified, self.regions, mem,
                                        params, homes=homes, failed=failed,
                                        impl=impl,
                                        double_buffer=double_buffer,
                                        noconflict=static_noconflict,
                                        block=block)


_PROOF_CACHE_MAX = 512


class OperatorRegistry:
    def __init__(self, regions: RegionTable, *, n_devices: int = 1,
                 max_steps: Optional[int] = None,
                 cost_model: Optional[DispatchCostModel] = None,
                 static_analysis: bool = True,
                 budget: Optional[wcet.Budget] = wcet.DEFAULT_BUDGET):
        self.regions = regions
        self.n_devices = int(n_devices)
        self.max_steps = max_steps
        self.cost_model = cost_model or DispatchCostModel()
        # Line-rate admission budget: registration rejects operators
        # whose certificate exceeds it (None disables enforcement —
        # certificates are still derived and reported).
        self.budget = budget
        # static_analysis=False disables the registration-time conflict
        # proofs at dispatch: every wave runs with the runtime sweep,
        # exactly the pre-analysis behaviour (escape hatch + A/B lever
        # for benchmarks).
        self.static_analysis = bool(static_analysis)
        self.last_decision: Optional[DispatchDecision] = None
        self.last_placement: Optional[DispatchDecision] = None
        # Audit hooks: did the last wave carry a static no-conflict
        # proof, and which segmented-wave op groups were coalesced into
        # one launch because their programs are bit-identical.
        self.last_noconflict: Optional[bool] = None
        self.last_fused_groups: Optional[List[List[int]]] = None
        self._grants: Dict[str, Grant] = {}
        self._slots: Dict[int, Slot] = {}
        self._by_name: Dict[str, int] = {}
        self._store_used = 0
        # Bounded memo of wave-proof verdicts: the serving loop re-forms
        # near-identical waves, and the proof is pure in
        # (op_ids, params, homes, n_devices).
        self._proof_cache: Dict[tuple, bool] = {}

    # -- tenants --------------------------------------------------------

    def add_tenant(self, grant: Grant) -> None:
        self._grants[grant.tenant] = grant

    def grant_of(self, tenant: str) -> Grant:
        if tenant not in self._grants:
            raise RegistrationError(f"unknown tenant {tenant!r}")
        return self._grants[tenant]

    # -- registration (control path) -------------------------------------

    def register(self, tenant: str, program: TiaraProgram) -> int:
        grant = self.grant_of(tenant)
        key = f"{tenant}/{program.name}"
        if key in self._by_name:
            raise RegistrationError(
                f"operator {key!r} already registered as op "
                f"{self._by_name[key]}")
        kwargs = {}
        if self.max_steps is not None:
            kwargs["max_steps"] = self.max_steps
        verified = verify(program, grant=grant, regions=self.regions,
                          **kwargs)
        # the eBPF-load budget check: an operator whose *certified*
        # worst case exceeds the NIC's line-rate budget never gets a
        # slot, and the error names the offending pc and resource
        if self.budget is not None and verified.certificate is not None:
            violations = self.budget.violations(verified.certificate)
            if violations:
                raise RegistrationError(
                    f"{program.name}: " + "; ".join(violations))
        if len(self._slots) >= isa.OP_TABLE_SIZE:
            raise RegistrationError("op_id table full (256 entries)")
        if self._store_used + program.n_instr > isa.INSTR_STORE_SIZE:
            raise RegistrationError(
                f"instruction store full: {self._store_used} + "
                f"{program.n_instr} > {isa.INSTR_STORE_SIZE}")
        op_id = len(self._slots)
        chains = tcompile.find_gather_chains(verified)
        report = tcompile.superop_report(verified)
        matched = tuple(report["matched"])  # type: ignore[arg-type]
        near_miss = report["near_miss"]
        reason = tcompile.why_not_compilable(verified)
        if reason is not None:
            # interp-only slots surface the full analysis in the reason
            # itself — the one string a "why is this slow" caller reads
            extra = ["footprint: "
                     + access.describe_footprint(verified.footprint,
                                                 self.regions)]
            if matched:
                extra.append("superops: " + ", ".join(
                    f"{kind}@pc{pc}" for kind, pc in matched))
            if near_miss is not None:
                extra.append(f"superop near-miss: {near_miss}")
            reason = "; ".join([reason] + extra)
        self._slots[op_id] = Slot(
            op_id=op_id, tenant=tenant, verified=verified,
            start_pc=self._store_used, regions=self.regions,
            compile_reason=reason,
            n_gather_chains=len(chains),
            chain_iters=sum(g.cap for g in chains
                            if g.cap > tcompile.DBUF_CHUNK),
            superops=matched,
            superop_near_miss=near_miss)
        self._store_used += program.n_instr
        self._by_name[f"{tenant}/{program.name}"] = op_id
        return op_id

    def lookup(self, tenant: str, name: str) -> int:
        return self._by_name[f"{tenant}/{name}"]

    def __getitem__(self, op_id: int) -> Slot:
        return self._slots[op_id]

    def __len__(self) -> int:
        return len(self._slots)

    @property
    def store_used(self) -> int:
        return self._store_used

    def dispatch_table(self) -> np.ndarray:
        """The 256-entry op_id -> start_pc hardware table (-1 = empty)."""
        t = np.full(isa.OP_TABLE_SIZE, -1, dtype=np.int64)
        for op_id, slot in self._slots.items():
            t[op_id] = slot.start_pc
        return t

    # -- static conflict proofs (wave formation) --------------------------

    def prove_wave_noconflict(self, op_ids: Sequence[int],
                              params: Sequence[Sequence[int]],
                              homes: Union[int, Sequence[int]] = 0, *,
                              n_devices: Optional[int] = None) -> bool:
        """Substitute the wave's concrete params into the registration-time
        footprints and try to prove the wave conflict-free.

        ``True`` is a proof: no macro-step of this wave can make the
        runtime sweep flag a conflict, so the lockstep engines may run
        with the sweep (and the sharded footprint all_gather) compiled
        out.  ``False`` is *not* a disproof — it just means "could not
        prove" (a ⊤ footprint, a disabled analysis, an unregistered
        footprint) and the engines keep the runtime sweep.  Verdicts are
        memoized; the serving loop re-forms near-identical waves.
        """
        if not self.static_analysis:
            return False
        ids = np.asarray(list(op_ids), dtype=np.int64)
        B = int(ids.size)
        if B != len(params):
            raise ValueError(f"{B} op_ids for {len(params)} param rows")
        if B == 0:
            return True
        # (B == 1 still runs the proof: a lone lane's MEMCPY sites must
        # be src/dst self-disjoint or the sweep would flag them)
        n_dev = self.n_devices if n_devices is None else int(n_devices)
        h = vm.homes_array(homes, B)
        key = (ids.tobytes(), h.tobytes(), n_dev,
               tuple(tuple(int(x) for x in row) for row in params))
        hit = self._proof_cache.get(key)
        if hit is not None:
            return hit
        fps = []
        for i in ids:
            fp = self._slots[int(i)].verified.footprint
            if fp is None:
                return False
            fps.append(fp)
        verdict = access.prove_wave_noconflict(fps, params, h, self.regions,
                                               n_devices=n_dev)
        if len(self._proof_cache) >= _PROOF_CACHE_MAX:
            self._proof_cache.pop(next(iter(self._proof_cache)))
        self._proof_cache[key] = verdict
        return verdict

    # -- invocation (data path) -------------------------------------------

    @staticmethod
    def _check_mode(mode: str, allowed: Sequence[str]) -> None:
        if mode not in allowed:
            raise ValueError(
                f"unknown mode {mode!r}; expected one of {list(allowed)}")

    def _invoke(self, op_id: int, mem: np.ndarray,
                params: Sequence[int] = (), *, home: int = 0,
                failed: Optional[Set[int]] = None,
                mode: str = "interp") -> vm.InvokeResult:
        """Single-request dispatch.  ``mode``: "interp" (default — the
        classic MP datapath), "compiled" (trace-compiled fast path), or
        "auto" (cost-model pick between the two)."""
        self._check_mode(mode, _SINGLE_MODES)
        slot = self._slots[op_id]
        if mode == "auto":
            n_dev = int(mem.shape[0])
            decision = self.cost_model.choose_batched(
                batch=1, step_bound=slot.verified.step_bound,
                compilable=slot.compilable, key=op_id,
                batched_cached=vm.engine_cached(
                    slot.verified, self.regions, n_dev, 1),
                compiled_cached=tcompile.compiled_cached(
                    slot.verified, self.regions, n_dev, 1))
            self.last_decision = decision
            # at B=1 the batched lockstep engine *is* the scalar datapath
            mode = "compiled" if decision.mode == "compiled" else "interp"
        if mode == "interp":
            return slot.interp(mem, params, home=home, failed=failed)
        r = slot.compiled(mem, [list(params)], homes=home, failed=failed)
        return vm.InvokeResult(mem=r.mem, ret=int(r.ret[0]),
                               status=int(r.status[0]),
                               steps=int(r.steps[0]), regs=r.regs[0],
                               fault=r.fault_at(0))

    def _invoke_batched(self, op_id: int, mem: np.ndarray,
                        params: Sequence[Sequence[int]], *,
                        homes: Union[int, Sequence[int]] = 0,
                        failed: Optional[Set[int]] = None,
                        mode: str = "auto",
                        contention_rate: float = 0.0,
                        block: bool = True,
                        static_noconflict: Optional[bool] = None
                        ) -> vm.BatchedInvokeResult:
        """Line-rate dispatch: B requests, one XLA launch.  ``mode``:
        "auto" (cost-model pick), "batched" (force the lockstep
        interpreter — always exact, even under contention), "compiled"
        (force the straight-line trace), or "compiled_dbuf" (force the
        double-buffered gather-chain schedule).  ``contention_rate``
        is the caller's estimate of the fraction of macro-steps whose
        footprints collide; any positive value steers "auto" to the
        interpreter, whose per-step conflict check serializes exactly.
        ``block=False`` defers result retirement (the endpoint's
        split-phase doorbell).

        ``static_noconflict``: None (default) derives the wave's static
        conflict proof from the registered footprints; an explicit bool
        is a caller-supplied verdict (a mixed wave's proof covers each
        of its segments).  A proven wave runs the engines with the
        runtime sweep compiled out and overrides ``contention_rate``."""
        self._check_mode(mode, _BATCHED_MODES)
        slot = self._slots[op_id]
        n_dev = int(mem.shape[0])
        B = len(params)
        nc = static_noconflict
        if nc is None:
            nc = B > 1 and self.prove_wave_noconflict(
                np.full(B, op_id, dtype=np.int64), params, homes,
                n_devices=n_dev)
        nc = bool(nc)
        self.last_noconflict = nc
        if mode == "auto":
            decision = self.cost_model.choose_batched(
                batch=B, step_bound=slot.verified.step_bound,
                compilable=slot.compilable, key=op_id,
                contention_rate=contention_rate,
                chain_iters=slot.chain_iters,
                static_noconflict=nc,
                batched_cached=vm.engine_cached(
                    slot.verified, self.regions, n_dev, B,
                    static_noconflict=nc),
                compiled_cached=tcompile.compiled_cached(
                    slot.verified, self.regions, n_dev, B, noconflict=nc),
                # only worth a cache-key hash when the dbuf candidate
                # can actually be priced (the op has gather chains)
                dbuf_cached=(slot.chain_iters > 0
                             and tcompile.compiled_cached(
                                 slot.verified, self.regions, n_dev, B,
                                 double_buffer=True, noconflict=nc)))
            self.last_decision = decision
            mode = decision.mode
        if mode == "batched":
            return slot.batched(mem, params, homes=homes, failed=failed,
                                block=block, static_noconflict=nc)
        return slot.compiled(mem, params, homes=homes, failed=failed,
                             double_buffer=(mode == "compiled_dbuf"),
                             block=block, static_noconflict=nc)

    # -- mixed-op invocation (the multi-tenant line-rate path) -------------

    def store_ops(self) -> List[VerifiedOperator]:
        """Every registered operator in op_id order — the programs of the
        shared instruction store.  Concatenated in this order their entry
        offsets reproduce :meth:`dispatch_table` exactly, which is what
        the mixed engine dispatches ``op_id`` against.  op_ids are
        assigned densely in registration order, so this is just the slots
        in insertion order (dicts preserve it)."""
        return [s.verified for s in self._slots.values()]

    def _segment_stats(self, plan: "tcompile.MixedPlan",
                       n_dev: int) -> List[SegmentStats]:
        out = []
        for seg in plan.segments:
            v = self._slots[seg.op_id].verified
            out.append(SegmentStats(
                size=seg.size, step_bound=v.step_bound,
                compilable=self._slots[seg.op_id].compilable,
                batched_cached=vm.engine_cached(v, self.regions, n_dev,
                                                seg.size),
                compiled_cached=tcompile.compiled_cached(
                    v, self.regions, n_dev, seg.size)))
        return out

    def _invoke_mixed(self, op_ids: Sequence[int], mem: np.ndarray,
                      params: Sequence[Sequence[int]], *,
                      homes: Union[int, Sequence[int]] = 0,
                      failed: Optional[Set[int]] = None,
                      mode: str = "auto",
                      contention_rate: float = 0.0,
                      placement: str = "single",
                      block: bool = True) -> vm.BatchedInvokeResult:
        """Dispatch a wave whose requests carry *per-request* op_ids.

        ``mode``:
          "mixed"      one lockstep launch over the merged instruction
                       store; request ``b`` enters at
                       ``dispatch_table()[op_ids[b]]``.  Exact round-robin
                       semantics, contended steps serialize per request
                       index — the reference mixed execution.
          "segmented"  stable-sort by op_id, run each same-op segment on
                       its best engine (compiled trace when the slot has
                       one), scatter outputs back to arrival order.
                       Matches "mixed" whenever cross-segment footprints
                       are disjoint (the normal serving case).
          "serial"     arrival-order baseline: one ``invoke_batched``
                       launch per *contiguous* same-op run — what a
                       dispatcher without mixed batching must do; a fully
                       interleaved wave degenerates to one launch per
                       request.
          "auto"       single-op waves delegate to
                       :meth:`_invoke_batched`; genuinely mixed waves go
                       to the cost model.

        ``placement``:
          "single"     the wave runs on one chip against the whole pool
                       (every mode above).
          "sharded"    the pool's leading axis is sharded over a device
                       mesh: the planner buckets the wave by ``home``
                       into per-device sub-waves and the mesh executes
                       them in lockstep, remote traffic on collectives
                       (``vm.invoke_sharded_mixed``) — bit-identical to
                       the "mixed" engine over the arrival-order wave.
                       Requires ``mode`` "auto" or "mixed".
          "auto"       :meth:`DispatchCostModel.choose_placement`
                       decides (recorded in :attr:`last_placement`).
        """
        self._check_mode(mode, _MIXED_MODES)
        if placement not in _PLACEMENTS:
            raise ValueError(
                f"unknown placement {placement!r}; expected one of "
                f"{list(_PLACEMENTS)}")
        if placement != "auto":
            # no placement decision this wave: clear the audit hook so
            # an earlier auto wave's pick cannot look current
            self.last_placement = None
        ids = np.asarray(list(op_ids), dtype=np.int64)
        if ids.ndim != 1 or ids.size != len(params):
            raise ValueError(
                f"op_ids shape {ids.shape} does not match "
                f"{len(params)} requests")
        for i in np.unique(ids):
            if int(i) not in self._slots:
                raise KeyError(f"op_id {int(i)} not registered")
        self.last_fused_groups = None
        # Static conflict proof over the whole wave, formed once at plan
        # time: a True lets every engine below (dense mixed, segmented
        # sub-launches, the sharded mesh) run with the runtime sweep —
        # and the mesh's footprint all_gather — compiled out.
        nc = ids.size > 1 and self.prove_wave_noconflict(
            ids, params, homes, n_devices=int(mem.shape[0]))
        if placement != "single":
            out = self._invoke_placed(ids, mem, params, homes=homes,
                                      failed=failed, mode=mode,
                                      contention_rate=contention_rate,
                                      placement=placement,
                                      static_noconflict=nc)
            if out is not None:
                # the wave ran on the mesh: no engine-mode decision was
                # made, so clear the audit hook rather than leave an
                # earlier wave's pick looking current
                self.last_decision = None
                self.last_noconflict = nc
                return out
        plan = tcompile.plan_mixed_batch(ids)
        decision = None
        if mode == "auto":
            if plan.n_segments == 1:
                return self._invoke_batched(
                    int(ids[0]), mem, params, homes=homes, failed=failed,
                    mode="auto", contention_rate=contention_rate,
                    block=block, static_noconflict=nc)
            n_dev = int(mem.shape[0])
            decision = self.cost_model.choose_mixed(
                segments=self._segment_stats(plan, n_dev),
                contention_rate=contention_rate,
                static_noconflict=nc,
                mixed_cached=vm.mixed_engine_cached(
                    self.store_ops(), self.regions, n_dev, plan.batch,
                    static_noconflict=nc))
            mode = decision.mode
        if mode == "mixed":
            out = vm.invoke_batched_mixed(
                self.store_ops(), self.regions, mem, ids, params,
                homes=homes, failed=failed, block=block,
                static_noconflict=nc)
        elif mode == "segmented":
            out = self._invoke_groups(
                self._coalesced_segments(plan),
                mem, params, homes=homes, failed=failed,
                contention_rate=contention_rate, block=block,
                static_noconflict=nc)
        else:
            out = self._invoke_groups(
                self._arrival_runs(ids), mem, params, homes=homes,
                failed=failed, contention_rate=contention_rate,
                block=block, static_noconflict=nc)
        if decision is not None:
            # nested per-group dispatches recorded their own decisions;
            # the wave-level pick is what callers audit
            self.last_decision = decision
        self.last_noconflict = nc
        return out

    def _invoke_placed(self, ids: np.ndarray, mem: np.ndarray,
                       params: Sequence[Sequence[int]], *,
                       homes: Union[int, Sequence[int]],
                       failed: Optional[Set[int]],
                       mode: str, contention_rate: float,
                       placement: str,
                       static_noconflict: bool = False
                       ) -> Optional[vm.BatchedInvokeResult]:
        """Resolve a non-"single" placement: run the wave on the sharded
        mesh engine, or return None when the cost model sends an "auto"
        wave back to single-chip execution.  A statically-proven wave
        (``static_noconflict``) runs the mesh step without the footprint
        all_gather or the sweep, and is priced accordingly."""
        if mode not in ("auto", "mixed"):
            raise ValueError(
                f"placement={placement!r} executes the mixed lockstep "
                f"engine over the mesh; mode must be 'auto' or 'mixed', "
                f"not {mode!r}")
        from repro import jaxcompat
        n_dev = int(mem.shape[0])
        h = vm.homes_array(homes, len(params))
        plan = tcompile.plan_mixed_batch(ids, homes=h, n_devices=n_dev)
        if placement == "auto":
            bound = max(self._slots[int(i)].verified.step_bound
                        for i in np.unique(ids))
            # the dense (no-homes) plan's segment stats price the best
            # *single-chip* dispatch — mixed or segmented — so a wave
            # whose best local plan is segmented is no longer routed to
            # the mesh prematurely (the old choose_placement scope gap)
            dense_plan = tcompile.plan_mixed_batch(ids)
            decision = self.cost_model.choose_placement(
                batch=int(ids.size), n_devices=n_dev, step_bound=bound,
                contention_rate=contention_rate,
                batch_per_device=plan.batch_per_device,
                # a pool can model more homes than the process exposes
                # devices; "auto" must degrade to "single" there, not
                # pick a placement whose mesh cannot build.  Likewise a
                # mesh with a failed member: the single-chip engines
                # model failed devices exactly, the mesh would compute
                # through the dead chip
                sharded_feasible=(jaxcompat.device_count() >= n_dev
                                  and not failed),
                mixed_cached=vm.mixed_engine_cached(
                    self.store_ops(), self.regions, n_dev, int(ids.size),
                    static_noconflict=static_noconflict),
                sharded_cached=vm.sharded_engine_cached(
                    self.store_ops(), self.regions, n_dev,
                    plan.batch_per_device,
                    static_noconflict=static_noconflict),
                segments=self._segment_stats(dense_plan, n_dev),
                static_noconflict=static_noconflict)
            self.last_placement = decision
            if decision.mode != "sharded":
                return None
        return vm.invoke_sharded_mixed(self.store_ops(), self.regions,
                                       mem, plan, params, failed=failed,
                                       static_noconflict=static_noconflict)

    def _coalesced_segments(self, plan: "tcompile.MixedPlan"
                            ) -> Iterator[Tuple[int, np.ndarray]]:
        """Cross-op fusion for the segmented path: plan segments whose
        slots hold *bit-identical* programs (two tenants registering the
        same gather-chain kernel get distinct op_ids but the same code)
        coalesce into one engine launch on the first op_id — identical
        code means identical semantics, and the merged arrival indices
        scatter each request's outputs back exactly as before.  Fused
        groups (>1 op_id per launch) are recorded in
        :attr:`last_fused_groups` for auditing."""
        buckets: Dict[bytes, Tuple[int, List[np.ndarray], List[int]]] = {}
        for seg in plan.segments:
            code = vm._code_bytes(self._slots[seg.op_id].verified)
            if code not in buckets:
                buckets[code] = (seg.op_id, [], [])
            buckets[code][1].append(plan.segment_indices(seg))
            buckets[code][2].append(seg.op_id)
        self.last_fused_groups = [ops for _, _, ops in buckets.values()
                                  if len(ops) > 1]
        for rep_op, idx_lists, _ in buckets.values():
            yield rep_op, np.concatenate(idx_lists)

    @staticmethod
    def _arrival_runs(ids: np.ndarray):
        """Contiguous same-op runs in arrival order — the grouping a
        dispatcher without mixed batching is stuck with."""
        lo, B = 0, int(ids.size)
        while lo < B:
            hi = lo + 1
            while hi < B and ids[hi] == ids[lo]:
                hi += 1
            yield int(ids[lo]), np.arange(lo, hi)
            lo = hi

    def _invoke_groups(self, groups, mem: np.ndarray,
                       params: Sequence[Sequence[int]], *,
                       homes: Union[int, Sequence[int]],
                       failed: Optional[Set[int]],
                       contention_rate: float = 0.0,
                       block: bool = True,
                       static_noconflict: Optional[bool] = None
                       ) -> vm.BatchedInvokeResult:
        """Launch each ``(op_id, arrival_indices)`` group on its own
        (best-engine auto dispatch), threading the pool through in group
        order and scattering per-request outputs back to arrival order.

        With ``block=False`` the per-group launches stay deferred: the
        pool threads through as device futures and the arrival-order
        scatter happens on device, so the whole multi-launch chain
        retires later in one materialization."""
        import contextlib

        import jax.numpy as jnp

        B = len(params)
        h = vm.homes_array(homes, B)
        ret = np.zeros(B, dtype=np.int64)
        status = np.zeros(B, dtype=np.int64)
        steps = np.zeros(B, dtype=np.int64)
        regs = np.zeros((B, isa.NUM_REGS), dtype=np.int64)
        fault = np.tile(vm.NO_FAULT, (B, 1))
        mem_cur = mem
        # the deferred path scatters on device: int64 conversions there
        # need 64-bit mode, same as the engine launches themselves
        with vm.x64() if not block else contextlib.nullcontext():
            if not block:
                ret, status = jnp.asarray(ret), jnp.asarray(status)
                steps, regs = jnp.asarray(steps), jnp.asarray(regs)
                fault = jnp.asarray(fault)
            for op_id, idx in groups:
                idx = np.asarray(idx)
                r = self._invoke_batched(
                    int(op_id), mem_cur, [list(params[i]) for i in idx],
                    homes=[int(h[i]) for i in idx], failed=failed,
                    mode="auto", contention_rate=contention_rate,
                    block=block, static_noconflict=static_noconflict)
                mem_cur = r.mem
                if block:
                    ret[idx], status[idx] = r.ret, r.status
                    steps[idx], regs[idx] = r.steps, r.regs
                    fault[idx] = r.fault
                else:
                    ret = ret.at[idx].set(r.ret)
                    status = status.at[idx].set(r.status)
                    steps = steps.at[idx].set(r.steps)
                    regs = regs.at[idx].set(r.regs)
                    fault = fault.at[idx].set(r.fault)
        return vm.BatchedInvokeResult(mem=mem_cur, ret=ret, status=status,
                                      steps=steps, regs=regs, fault=fault)

    def dump(self) -> str:
        lines = []
        for op_id, slot in sorted(self._slots.items()):
            p = slot.verified.program
            fast = "compiled" if slot.compilable else "interp-only"
            chains = f" gather-chains={slot.n_gather_chains}" \
                if slot.n_gather_chains else ""
            lines.append(
                f"op {op_id:3d}  tenant={slot.tenant:<12s} "
                f"{p.name:<20s} {p.n_instr:3d} instrs  "
                f"bound={slot.verified.step_bound:<8d} "
                f"regions r={p.regions_read} w={p.regions_written} "
                f"[{fast}{chains}]")
            # registration-time analysis artifacts: derived footprint,
            # matched superoperators, nearest superop near-miss
            lines.append("         " + slot.describe_analysis())
        return "\n".join(lines)
