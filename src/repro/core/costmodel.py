"""Hardware constants and analytical baseline models (paper §4.1).

The paper's own methodology: Tiara latencies are cycle-accurate on a
calibrated simulator (5 ns clock, 150-cycle PCIe DMA, 500-cycle RDMA RTT);
the non-Tiara baselines are *analytical models* with published constants.
This module carries those constants and the baseline models; the Tiara
side is `repro.core.simulator` (trace-driven, cycle-level).

Every constant is either quoted directly from the paper (marked [paper])
or calibrated to reproduce a number the paper reports (marked [calib],
with the anchor).  Benchmarks print derived vs. paper-claimed side by side.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HW:
    # --- clock & fabric [paper §4.1] -----------------------------------
    clk_ns: float = 5.0                  # 200 MHz MP clock
    pcie_dma_cycles: int = 150           # 0.75 us host DRAM access via PCIe
    rdma_rtt_cycles: int = 500           # 2.5 us RDMA Read RTT
    n_mps: int = 8
    tasks_per_mp: int = 12               # 96 dispatcher slots
    # --- bandwidths ------------------------------------------------------
    wire_gbps: float = 100.0             # 100 GbE
    wire_eff_gbs: float = 12.0           # effective line rate [paper §4.6]
    pcie_gbs: float = 12.8               # PCIe 3 x16 effective bulk
    # PCIe small-request channel: one outstanding DMA issued per
    # ``dma_issue_cycles`` (random 64 B read rate ~100 M/s) [calib:
    # anchors Tiara graph throughput ~29.5 Mops at depth 3]
    dma_issue_cycles: int = 2
    # --- MP micro-costs ---------------------------------------------------
    instr_cycles: int = 1                # scalar FSM, 1 op/cycle
    dispatch_cycles: int = 4             # task setup: op_id lookup + regs
    # --- baseline systems -------------------------------------------------
    rtt_us: float = 2.5                  # [paper]
    rpc_dispatch_us: float = 1.5         # [paper]
    rpc_hop_us: float = 0.17             # cached-DRAM hop [paper]
    rpc_core_rate_mops: float = 0.222    # [calib: 16 cores = 3.55 Mops §4.2]
    rpc_cores: int = 16                  # paper's RPC baseline core count
    rpc_cores_sat: int = 22              # saturation configuration
    redn_wr_us: float = 1.1              # per chained WR [paper]
    prism_hop_us: float = 0.5            # [paper]
    rdma_verb_mops: float = 26.0         # [calib: RedN "26x below RDMA at
    #                                       depth 1" with RedN ~1 Mops §4.2]
    client_wr_build_us: float = 1.2      # client-side WR construction
    #                                    # [calib: batched RDMA 2.7 GB/s at
    #                                    #  4 KB and ~4.3 GB/s at 8 KB, Fig 10]
    rpc_per_expert_us: float = 1.225     # [calib: RPC 41.7 us at k=32 §4.5]

    @property
    def dma_us(self) -> float:
        return self.pcie_dma_cycles * self.clk_ns / 1e3

    @property
    def slots(self) -> int:
        return self.n_mps * self.tasks_per_mp

    @property
    def wire_bytes_per_us(self) -> float:
        return self.wire_eff_gbs * 1e3

    @property
    def pcie_bytes_per_us(self) -> float:
        return self.pcie_gbs * 1e3


DEFAULT_HW = HW()


# =============================================================================
# Analytical baselines — one-sided RDMA, RPC, RedN, PRISM
# =============================================================================

def rdma_chain_latency_us(depth: int, hw: HW = DEFAULT_HW) -> float:
    """Dependent chain of ``depth`` one-sided reads: depth x RTT."""
    return depth * hw.rtt_us


def rdma_chain_throughput_mops(depth: int, hw: HW = DEFAULT_HW) -> float:
    """Verb rate divided across the ``depth`` verbs each op needs."""
    return hw.rdma_verb_mops / max(depth, 1)


def rpc_latency_us(hops: int, hw: HW = DEFAULT_HW) -> float:
    """One RTT + dispatch + node-local cached-DRAM hops."""
    return hw.rtt_us + hw.rpc_dispatch_us + hops * hw.rpc_hop_us


def rpc_throughput_mops(hops: int, hw: HW = DEFAULT_HW,
                        cores: int = 0) -> float:
    del hops  # the paper's RPC rate is message-rate-bound, not hop-bound
    return (cores or hw.rpc_cores) * hw.rpc_core_rate_mops


def redn_latency_us(wrs: int, hw: HW = DEFAULT_HW) -> float:
    """Doorbell-ordered WR chain on the memory-side NIC: 1 RTT + per-WR
    fetch cost (RedN's throughput killer, paper §2.2)."""
    return hw.rtt_us + wrs * hw.redn_wr_us


def redn_throughput_mops(wrs: int, hw: HW = DEFAULT_HW) -> float:
    """8 processing units serialized by doorbell ordering."""
    return min(hw.n_mps / (wrs * hw.redn_wr_us), 1.0)


def prism_latency_us(hops: int, hw: HW = DEFAULT_HW) -> float:
    return hw.rtt_us + hops * hw.prism_hop_us


def prism_throughput_mops(hops: int, hw: HW = DEFAULT_HW) -> float:
    """PRISM tracks RDMA (NIC-native, no doorbell ordering) [paper §4.2]."""
    return rdma_chain_throughput_mops(hops, hw)


# --- workload-specific baselines -------------------------------------------

def rdma_ptw_latency_us(levels: int = 3, hw: HW = DEFAULT_HW) -> float:
    """k levels + final data fetch: (k+1) RTTs (Table 1)."""
    return (levels + 1) * hw.rtt_us


def rdma_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    """CAS + read + 2 replica writes + release: 5 sequential RTTs."""
    return 5 * hw.rtt_us


def tiara_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    """Client->primary, local CAS + parallel replica writes, ack: 2 RTTs."""
    return 2 * hw.rtt_us


def redn_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    """1 RTT but ~6 WRs of doorbell-ordered chain."""
    return hw.rtt_us + 6 * hw.redn_wr_us


def rpc_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    return 2 * hw.rtt_us + hw.rpc_dispatch_us + 4 * hw.rpc_hop_us


# Contention scaling factors, calibrated to Fig. 9's reported degradations
# between 1 and 16 clients (RDMA 2.5x, RedN 4.9x, RPC ~1.2x; Tiara read off
# the figure at ~1.9x).  latency(c) = latency(1) * (1 + alpha * (c - 1)).
LOCK_CONTENTION_ALPHA = {
    "rdma": (2.5 - 1) / 15,
    "redn": (4.9 - 1) / 15,
    "rpc": (1.2 - 1) / 15,
    "tiara": (1.94 - 1) / 15,
}


def lock_latency_contended_us(system: str, clients: int,
                              hw: HW = DEFAULT_HW) -> float:
    base = {
        "rdma": rdma_lock_latency_us(hw),
        "redn": redn_lock_latency_us(hw),
        "rpc": rpc_lock_latency_us(hw),
        "tiara": tiara_lock_latency_us(hw),
    }[system]
    return base * (1 + LOCK_CONTENTION_ALPHA[system] * (clients - 1))


# --- PagedAttention / bulk gather baselines (Fig. 10) ------------------------

def batched_rdma_gather_gbs(total_bytes: int, block_bytes: int,
                            hw: HW = DEFAULT_HW) -> float:
    """Optimally batched RDMA: 1 RTT for the block table, then the client
    builds one WR per block and posts the batch (Table 1 footnote).  WR
    construction happens before the second round can complete, so it
    serializes with the transfer — this is what keeps batched RDMA at
    2.7 GB/s for 4 KB blocks in Fig. 10."""
    n = max(total_bytes // block_bytes, 1)
    build_us = n * hw.client_wr_build_us
    transfer_us = total_bytes / hw.wire_bytes_per_us
    lat = 2 * hw.rtt_us + build_us + transfer_us
    return total_bytes / lat / 1e3  # GB/s

def rpc_gather_gbs(total_bytes: int, block_bytes: int,
                   hw: HW = DEFAULT_HW) -> float:
    """Server-side RPC resolves and streams; per-block touch cost on the
    server CPU plus wire time."""
    n = max(total_bytes // block_bytes, 1)
    per_block_us = hw.rpc_hop_us * 2
    lat = hw.rtt_us + hw.rpc_dispatch_us + max(n * per_block_us,
                                               total_bytes / hw.wire_bytes_per_us)
    return total_bytes / lat / 1e3


def redn_gather_gbs(total_bytes: int, block_bytes: int,
                    hw: HW = DEFAULT_HW) -> float:
    """WR chain per block: doorbell ordering costs ~1.1 us per block."""
    n = max(total_bytes // block_bytes, 1)
    lat = hw.rtt_us + max(n * hw.redn_wr_us,
                          total_bytes / hw.wire_bytes_per_us)
    return total_bytes / lat / 1e3


# --- MoE expert gather (§4.5) ------------------------------------------------

def rdma_moe_latency_us(k: int, slab_bytes: int = 8192,
                        hw: HW = DEFAULT_HW) -> float:
    """2 RTTs (table read, then batched slab reads) + wire serialization.
    [calib: the paper's 26.7 us at k=32 is exactly 2xRTT + 256 KB/12 GB/s,
    i.e. it charges no WR-build cost here, unlike Fig. 10.]"""
    return 2 * hw.rtt_us + k * slab_bytes / hw.wire_bytes_per_us


def rpc_moe_latency_us(k: int, slab_bytes: int = 8192,
                       hw: HW = DEFAULT_HW) -> float:
    """Per-expert dispatch dominates as k grows (paper §4.5).
    [calib: 41.7 us at k=32 = RTT + 32 x 1.225 us.]"""
    del slab_bytes
    return hw.rtt_us + k * hw.rpc_per_expert_us


# --- offload crossover model (Figs. 2 & 3) -----------------------------------

def offload_chain_latency_us(host_mem_us: float, depth: int,
                             hw: HW = DEFAULT_HW) -> float:
    """Generic memory-side offload: 1 RTT + depth x host-memory accesses.
    Offloading beats client-side RDMA iff host_mem_us < RTT (Fig. 3)."""
    return hw.rtt_us + depth * host_mem_us


BF2_HOST_ACCESS_US = 1.7      # BlueField-2 internal RDMA hop [paper §2.2]
BF3_DPA_HOST_ACCESS_US = 0.85  # BF-3 DPA datasheet [paper §2.2]
TIARA_HOST_ACCESS_US = 0.75    # PCIe DMA [paper]
BF2_CABLE_RTT_US = 1.9         # back-to-back DAC cable [paper §2.2]
