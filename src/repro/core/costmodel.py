"""Hardware constants and analytical baseline models (paper §4.1).

The paper's own methodology: Tiara latencies are cycle-accurate on a
calibrated simulator (5 ns clock, 150-cycle PCIe DMA, 500-cycle RDMA RTT);
the non-Tiara baselines are *analytical models* with published constants.
This module carries those constants and the baseline models; the Tiara
side is `repro.core.simulator` (trace-driven, cycle-level).

Every constant is either quoted directly from the paper (marked [paper])
or calibrated to reproduce a number the paper reports (marked [calib],
with the anchor).  Benchmarks print derived vs. paper-claimed side by side.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HW:
    # --- clock & fabric [paper §4.1] -----------------------------------
    clk_ns: float = 5.0                  # 200 MHz MP clock
    pcie_dma_cycles: int = 150           # 0.75 us host DRAM access via PCIe
    rdma_rtt_cycles: int = 500           # 2.5 us RDMA Read RTT
    n_mps: int = 8
    tasks_per_mp: int = 12               # 96 dispatcher slots
    # --- bandwidths ------------------------------------------------------
    wire_gbps: float = 100.0             # 100 GbE
    wire_eff_gbs: float = 12.0           # effective line rate [paper §4.6]
    pcie_gbs: float = 12.8               # PCIe 3 x16 effective bulk
    # PCIe small-request channel: one outstanding DMA issued per
    # ``dma_issue_cycles`` (random 64 B read rate ~100 M/s) [calib:
    # anchors Tiara graph throughput ~29.5 Mops at depth 3]
    dma_issue_cycles: int = 2
    # --- MP micro-costs ---------------------------------------------------
    instr_cycles: int = 1                # scalar FSM, 1 op/cycle
    dispatch_cycles: int = 4             # task setup: op_id lookup + regs
    # --- baseline systems -------------------------------------------------
    rtt_us: float = 2.5                  # [paper]
    rpc_dispatch_us: float = 1.5         # [paper]
    rpc_hop_us: float = 0.17             # cached-DRAM hop [paper]
    rpc_core_rate_mops: float = 0.222    # [calib: 16 cores = 3.55 Mops §4.2]
    rpc_cores: int = 16                  # paper's RPC baseline core count
    rpc_cores_sat: int = 22              # saturation configuration
    redn_wr_us: float = 1.1              # per chained WR [paper]
    prism_hop_us: float = 0.5            # [paper]
    rdma_verb_mops: float = 26.0         # [calib: RedN "26x below RDMA at
    #                                       depth 1" with RedN ~1 Mops §4.2]
    client_wr_build_us: float = 1.2      # client-side WR construction
    #                                    # [calib: batched RDMA 2.7 GB/s at
    #                                    #  4 KB and ~4.3 GB/s at 8 KB, Fig 10]
    rpc_per_expert_us: float = 1.225     # [calib: RPC 41.7 us at k=32 §4.5]

    @property
    def dma_us(self) -> float:
        return self.pcie_dma_cycles * self.clk_ns / 1e3

    @property
    def slots(self) -> int:
        return self.n_mps * self.tasks_per_mp

    @property
    def wire_bytes_per_us(self) -> float:
        return self.wire_eff_gbs * 1e3

    @property
    def pcie_bytes_per_us(self) -> float:
        return self.pcie_gbs * 1e3


DEFAULT_HW = HW()


# =============================================================================
# Analytical baselines — one-sided RDMA, RPC, RedN, PRISM
# =============================================================================

def rdma_chain_latency_us(depth: int, hw: HW = DEFAULT_HW) -> float:
    """Dependent chain of ``depth`` one-sided reads: depth x RTT."""
    return depth * hw.rtt_us


def rdma_chain_throughput_mops(depth: int, hw: HW = DEFAULT_HW) -> float:
    """Verb rate divided across the ``depth`` verbs each op needs."""
    return hw.rdma_verb_mops / max(depth, 1)


def rpc_latency_us(hops: int, hw: HW = DEFAULT_HW) -> float:
    """One RTT + dispatch + node-local cached-DRAM hops."""
    return hw.rtt_us + hw.rpc_dispatch_us + hops * hw.rpc_hop_us


def rpc_throughput_mops(hops: int, hw: HW = DEFAULT_HW,
                        cores: int = 0) -> float:
    del hops  # the paper's RPC rate is message-rate-bound, not hop-bound
    return (cores or hw.rpc_cores) * hw.rpc_core_rate_mops


def redn_latency_us(wrs: int, hw: HW = DEFAULT_HW) -> float:
    """Doorbell-ordered WR chain on the memory-side NIC: 1 RTT + per-WR
    fetch cost (RedN's throughput killer, paper §2.2)."""
    return hw.rtt_us + wrs * hw.redn_wr_us


def redn_throughput_mops(wrs: int, hw: HW = DEFAULT_HW) -> float:
    """8 processing units serialized by doorbell ordering."""
    return min(hw.n_mps / (wrs * hw.redn_wr_us), 1.0)


def prism_latency_us(hops: int, hw: HW = DEFAULT_HW) -> float:
    return hw.rtt_us + hops * hw.prism_hop_us


def prism_throughput_mops(hops: int, hw: HW = DEFAULT_HW) -> float:
    """PRISM tracks RDMA (NIC-native, no doorbell ordering) [paper §4.2]."""
    return rdma_chain_throughput_mops(hops, hw)


# --- workload-specific baselines -------------------------------------------

def rdma_ptw_latency_us(levels: int = 3, hw: HW = DEFAULT_HW) -> float:
    """k levels + final data fetch: (k+1) RTTs (Table 1)."""
    return (levels + 1) * hw.rtt_us


def rdma_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    """CAS + read + 2 replica writes + release: 5 sequential RTTs."""
    return 5 * hw.rtt_us


def tiara_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    """Client->primary, local CAS + parallel replica writes, ack: 2 RTTs."""
    return 2 * hw.rtt_us


def redn_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    """1 RTT but ~6 WRs of doorbell-ordered chain."""
    return hw.rtt_us + 6 * hw.redn_wr_us


def rpc_lock_latency_us(hw: HW = DEFAULT_HW) -> float:
    return 2 * hw.rtt_us + hw.rpc_dispatch_us + 4 * hw.rpc_hop_us


# Contention scaling factors, calibrated to Fig. 9's reported degradations
# between 1 and 16 clients (RDMA 2.5x, RedN 4.9x, RPC ~1.2x; Tiara read off
# the figure at ~1.9x).  latency(c) = latency(1) * (1 + alpha * (c - 1)).
LOCK_CONTENTION_ALPHA = {
    "rdma": (2.5 - 1) / 15,
    "redn": (4.9 - 1) / 15,
    "rpc": (1.2 - 1) / 15,
    "tiara": (1.94 - 1) / 15,
}


def lock_latency_contended_us(system: str, clients: int,
                              hw: HW = DEFAULT_HW) -> float:
    base = {
        "rdma": rdma_lock_latency_us(hw),
        "redn": redn_lock_latency_us(hw),
        "rpc": rpc_lock_latency_us(hw),
        "tiara": tiara_lock_latency_us(hw),
    }[system]
    return base * (1 + LOCK_CONTENTION_ALPHA[system] * (clients - 1))


# --- PagedAttention / bulk gather baselines (Fig. 10) ------------------------

def batched_rdma_gather_gbs(total_bytes: int, block_bytes: int,
                            hw: HW = DEFAULT_HW) -> float:
    """Optimally batched RDMA: 1 RTT for the block table, then the client
    builds one WR per block and posts the batch (Table 1 footnote).  WR
    construction happens before the second round can complete, so it
    serializes with the transfer — this is what keeps batched RDMA at
    2.7 GB/s for 4 KB blocks in Fig. 10."""
    n = max(total_bytes // block_bytes, 1)
    build_us = n * hw.client_wr_build_us
    transfer_us = total_bytes / hw.wire_bytes_per_us
    lat = 2 * hw.rtt_us + build_us + transfer_us
    return total_bytes / lat / 1e3  # GB/s

def rpc_gather_gbs(total_bytes: int, block_bytes: int,
                   hw: HW = DEFAULT_HW) -> float:
    """Server-side RPC resolves and streams; per-block touch cost on the
    server CPU plus wire time."""
    n = max(total_bytes // block_bytes, 1)
    per_block_us = hw.rpc_hop_us * 2
    lat = hw.rtt_us + hw.rpc_dispatch_us + max(n * per_block_us,
                                               total_bytes / hw.wire_bytes_per_us)
    return total_bytes / lat / 1e3


def redn_gather_gbs(total_bytes: int, block_bytes: int,
                    hw: HW = DEFAULT_HW) -> float:
    """WR chain per block: doorbell ordering costs ~1.1 us per block."""
    n = max(total_bytes // block_bytes, 1)
    lat = hw.rtt_us + max(n * hw.redn_wr_us,
                          total_bytes / hw.wire_bytes_per_us)
    return total_bytes / lat / 1e3


# --- MoE expert gather (§4.5) ------------------------------------------------

def rdma_moe_latency_us(k: int, slab_bytes: int = 8192,
                        hw: HW = DEFAULT_HW) -> float:
    """2 RTTs (table read, then batched slab reads) + wire serialization.
    [calib: the paper's 26.7 us at k=32 is exactly 2xRTT + 256 KB/12 GB/s,
    i.e. it charges no WR-build cost here, unlike Fig. 10.]"""
    return 2 * hw.rtt_us + k * slab_bytes / hw.wire_bytes_per_us


def rpc_moe_latency_us(k: int, slab_bytes: int = 8192,
                       hw: HW = DEFAULT_HW) -> float:
    """Per-expert dispatch dominates as k grows (paper §4.5).
    [calib: 41.7 us at k=32 = RTT + 32 x 1.225 us.]"""
    del slab_bytes
    return hw.rtt_us + k * hw.rpc_per_expert_us


# --- offload crossover model (Figs. 2 & 3) -----------------------------------

def offload_chain_latency_us(host_mem_us: float, depth: int,
                             hw: HW = DEFAULT_HW) -> float:
    """Generic memory-side offload: 1 RTT + depth x host-memory accesses.
    Offloading beats client-side RDMA iff host_mem_us < RTT (Fig. 3)."""
    return hw.rtt_us + depth * host_mem_us


BF2_HOST_ACCESS_US = 1.7      # BlueField-2 internal RDMA hop [paper §2.2]
BF3_DPA_HOST_ACCESS_US = 0.85  # BF-3 DPA datasheet [paper §2.2]
TIARA_HOST_ACCESS_US = 0.75    # PCIe DMA [paper]
BF2_CABLE_RTT_US = 1.9         # back-to-back DAC cable [paper §2.2]


# =============================================================================
# Adaptive dispatch: the software-engine cost model
# =============================================================================
#
# The registry's ``mode="auto"`` has to pick an execution engine per call:
# the scalar interpreter (one launch per request), the batch-parallel
# lockstep interpreter (one launch per wave, exact under contention), the
# trace-compiled straight-line path (fastest, needs a compilable CFG and
# a conflict-free wave), or — for mixed-op waves — the one-launch mixed
# engine vs. stable-sort-and-segment through the compiled traces.  The
# analytical model below predicts wall-clock per call from batch size,
# trace length, op-mix composition, and a contention-rate hint, using
# per-engine launch/step constants calibrated against the measured
# ``BENCH_vm_throughput.json`` sweep (10-hop GraphWalk at B=1/64/1024 on
# the CPU backend; [calib] marks each anchor).  Absolute numbers are
# host-dependent — what the decision needs is the *relative* shape:
# launches amortize over B, the vectorized macro-step cost is affine in
# B, and the compiled trace's per-lane cost is ~20x smaller than the
# interpreter's.  ``EngineCost.measured()`` rescales the launch constant
# to the running host.


@dataclasses.dataclass(frozen=True)
class EngineCost:
    """Per-engine launch/step cost constants (microseconds).

    [calib] anchors: the BENCH_vm_throughput.json sweep measured at PR 1
    (graph_walk depth=10, step bound ~38, B in {1, 64, 1024}): interp
    B=1 ~2.5 ms/call, batched B=64/1024 ~18/~130 ms, compiled B=1/1024
    ~1.2/~7 ms, fit to the affine forms below.  Individual runs drift
    ±20% — the constants carry the *relative shape* (launches amortize
    over B; compiled per-lane cost ~20x below the interpreter's), which
    is all the argmin decisions consume.
    """

    launch_us: float = 1000.0      # one XLA dispatch from Python [calib]
    interp_step_us: float = 40.0   # scalar switch interpreter, per step [calib]
    vstep_us: float = 280.0        # vectorized macro-step, base [calib]
    vlane_us: float = 3.2          # vectorized macro-step, per lane [calib]
    cstep_us: float = 3.0          # compiled trace, per position [calib]
    clane_us: float = 0.15         # compiled trace, per position-lane [calib]
    serial_lane_us: float = 12.0   # contended macro-step scan, per lane
    # Double-buffered gather chains: the split-phase schedule hides this
    # fraction of the chain's per-position cost (chunk k+1's gather
    # overlaps chunk k's scatter) at a fixed per-chunk scheduling cost.
    # ``dbuf_overlap`` starts at the cycle simulator's prior and is the
    # term ``DispatchCostModel.observe_overlap`` learns online from
    # measured serialized-vs-double-buffered pairs.
    dbuf_overlap: float = 0.45
    dbuf_chunk_us: float = 60.0    # per-chunk scatter setup/scheduling
    # Chunk size contract: compile.DBUF_CHUNK reads this field's
    # *default* once at import, so retuning means editing the default
    # here (pricing and the emitted schedule then move together).
    # Overriding it on an EngineCost *instance* is unsupported — it
    # would change pricing only, not the engine's schedule.
    dbuf_chunk_iters: int = 8
    # One cross-device collective group on the mesh axis (all_gather of
    # the requests + psum routing the words back) — the sharded engine
    # pays a fixed number of these per macro-step.  [calib: a scalar
    # psum over 8 forced-host CPU devices measures ~50-150 us; a real
    # NIC fabric hop is 3 orders of magnitude cheaper, so re-calibrate
    # on hardware.]
    collective_us: float = 80.0
    # Collectives per conflict-free sharded macro-step, busy-step upper
    # bound: the interval gather for the conflict sweep plus the
    # word-read, word-write, and memcpy window routes.  The three data
    # routes are any_lane-gated (skipped on macro-steps with no such
    # op), so real waves average below this.
    collectives_per_step: int = 4
    # Building an engine at a new (program, batch) shape is a full XLA
    # compile — seconds, not microseconds [calib: jit of one engine ~2 s
    # on the dev host].  A serving loop reuses each built shape across
    # many waves, so the model charges the amortized share per call.
    compile_us: float = 2_000_000.0
    compile_amortization: int = 100  # expected same-shape waves per build

    def _miss(self, cached: bool) -> float:
        return 0.0 if cached else self.compile_us / max(
            self.compile_amortization, 1)

    # -- per-engine per-call predictions ---------------------------------

    def batched_us(self, batch: int, steps: int,
                   contention_rate: float = 0.0, *,
                   cached: bool = True) -> float:
        """One lockstep launch; contended macro-steps pay the serialized
        scan instead of the vectorized step."""
        if batch <= 1:
            # B=1 skips the conflict machinery: the scalar datapath
            return self._miss(cached) + self.launch_us \
                + steps * self.interp_step_us
        contended = min(max(contention_rate, 0.0), 1.0) * steps
        clean = steps - contended
        return (self._miss(cached) + self.launch_us
                + clean * (self.vstep_us + batch * self.vlane_us)
                + contended * (self.vstep_us
                               + batch * self.serial_lane_us))

    def compiled_us(self, batch: int, trace_len: int, *,
                    cached: bool = True) -> float:
        """One straight-line launch over the unrolled trace."""
        return self._miss(cached) + self.launch_us \
            + trace_len * (self.cstep_us + batch * self.clane_us)

    def compiled_dbuf_us(self, batch: int, trace_len: int,
                         chain_iters: int, *,
                         cached: bool = True) -> float:
        """The double-buffered compiled trace: the gather-chain portion
        (5 trace positions per chain iteration) is discounted by the
        learned overlap term, but every ``dbuf_chunk_iters`` iterations
        pay a fixed chunk-scheduling cost — so short chains lose to the
        monolithic trace and long chains win, which is exactly the
        crossover ``mode="auto"`` needs to find."""
        chain_steps = min(max(5 * chain_iters, 0), trace_len)
        straight = trace_len - chain_steps
        per_pos = self.cstep_us + batch * self.clane_us
        n_chunks = -(-max(chain_iters, 0) // max(self.dbuf_chunk_iters, 1))
        # a chain that fits in one chunk is emitted monolithically (the
        # engine only chunks past DBUF_CHUNK iterations): no overlap to
        # win, only the scheduling cost to lose
        overlap = self.dbuf_overlap if chain_iters > self.dbuf_chunk_iters \
            else 0.0
        return (self._miss(cached) + self.launch_us
                + straight * per_pos
                + chain_steps * per_pos * (1.0 - overlap)
                + n_chunks * self.dbuf_chunk_us)

    def sharded_us(self, batch: int, n_devices: int, steps: int,
                   contention_rate: float = 0.0, *,
                   batch_per_device: Optional[int] = None,
                   cached: bool = True,
                   noconflict: bool = False) -> float:
        """One shard_map launch over the device mesh: per-device
        sub-waves advance in lockstep, each macro-step paying the fixed
        collective group that routes remote LOAD/MEMCPY traffic.  The
        lockstep lane count is the *largest* sub-wave — pass the plan's
        ``batch_per_device`` so home-skewed waves are costed at their
        real width (a fully skewed wave runs ``batch`` lanes on every
        device and sharding buys nothing); without it a balanced wave
        is assumed.  A contended macro-step replicates the wave and
        serializes over the *global* batch with a psum-routed read per
        lane — the term that makes contention catastrophically
        expensive on a mesh, which is exactly the signal placement
        decisions need.

        ``noconflict=True`` prices a statically-proven-conflict-free
        wave: the per-step footprint all_gather (one collective of the
        group) is skipped along with the sweep, and the serialized-
        fallback term vanishes — the proof replaces the contention
        guess entirely."""
        bpd = batch_per_device if batch_per_device is not None \
            else -(-batch // max(n_devices, 1))     # balanced ceil
        if noconflict:
            contention_rate = 0.0
        contended = min(max(contention_rate, 0.0), 1.0) * steps
        clean = steps - contended
        coll = self.collective_us if n_devices > 1 else 0.0
        colls = self.collectives_per_step - (1 if noconflict else 0)
        return (self._miss(cached) + self.launch_us
                + clean * (self.vstep_us + bpd * self.vlane_us
                           + colls * coll)
                + contended * (self.vstep_us
                               + batch * (self.serial_lane_us + coll)))

    @classmethod
    def measured(cls, reps: int = 20) -> "EngineCost":
        """Measure this host's actual XLA dispatch overhead and replace
        only ``launch_us`` with it.  The launch-vs-step tradeoff is what
        the dispatch decisions hinge on (a slow-dispatch host should
        batch harder and segment less), so only that constant adapts;
        the step constants keep their calibrated values."""
        import time

        import jax
        import jax.numpy as jnp

        f = jax.jit(lambda x: x + 1)
        x = jnp.zeros(())
        f(x).block_until_ready()               # warm the cache
        t0 = time.perf_counter()
        for _ in range(reps):
            f(x).block_until_ready()
        launch = (time.perf_counter() - t0) / reps * 1e6
        return dataclasses.replace(cls(), launch_us=max(launch, 1.0))


def _entropy_bits(counts) -> float:
    p = np.asarray(counts, dtype=float)
    p = p / p.sum()
    return float(-(p * np.log2(p)).sum())


def op_mix_entropy(op_ids) -> float:
    """Shannon entropy (bits) of a wave's op_id mix: 0 for a single-op
    wave, log2(k) for k ops uniformly interleaved."""
    _, counts = np.unique(np.asarray(list(op_ids)), return_counts=True)
    return _entropy_bits(counts)


@dataclasses.dataclass(frozen=True)
class SegmentStats:
    """What the cost model needs to know about one planned segment."""

    size: int
    step_bound: int
    compilable: bool
    batched_cached: bool = True    # lockstep engine built at this size?
    compiled_cached: bool = True   # compiled trace built at this size?


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """An auditable mode choice: the pick plus every candidate's
    predicted per-call cost."""

    mode: str
    costs: Dict[str, float]
    entropy_bits: float = 0.0
    contention_rate: float = 0.0
    # True when a registration-time conflict proof covered the wave: the
    # caller's contention_rate guess was discarded (forced to 0.0) and
    # the engines run with the runtime sweep statically skipped.
    static_noconflict: bool = False

    def __post_init__(self):
        if self.mode not in self.costs:
            raise ValueError(
                f"decision mode {self.mode!r} has no cost entry "
                f"(candidates: {sorted(self.costs)})")


class DispatchCostModel:
    """Analytical engine picker for the registry's ``mode="auto"``.

    Decisions are pure functions of (batch size, per-op trace lengths,
    op-mix composition, contention-rate hint) — deterministic and cheap
    enough to run per call.  A non-zero ``contention_rate`` excludes the
    compiled path: the straight-line trace assumes no request reads a
    word another request writes at the same trace position, while the
    batched interpreter detects conflicts per step and serializes
    exactly, so contended waves must stay on it.
    """

    def __init__(self, cost: Optional[EngineCost] = None):
        self.cost = cost or EngineCost()
        # online calibration state (see observe_dispatch/observe_conflicts):
        # per-(slot key, engine mode) wall-clock scale EWMAs and per-slot
        # conflict-rate EWMAs, both fed by the endpoint while serving
        self._scales: Dict[Tuple[Optional[int], str], float] = {}
        self._conflicts: Dict[Optional[int], float] = {}
        # learned home-access skew (see observe_home_access): EWMA of the
        # hottest device's share of region-access words, fed by the
        # endpoint's per-region access audit while serving
        self._home_skew: Optional[float] = None

    # -- online overlap learning ------------------------------------------

    # EWMA weight of one new overlap observation
    OVERLAP_EWMA_ALPHA = 0.25

    def observe_overlap(self, serial_us: float, dbuf_us: float, *,
                        chain_frac: float = 1.0) -> float:
        """Learn the double-buffer overlap term from one measured pair:
        the same wave timed on the monolithic compiled trace
        (``serial_us``) and on the double-buffered one (``dbuf_us``).
        ``chain_frac`` is the fraction of the trace the gather chain
        accounts for (the discount only applies to the chain portion, so
        a whole-call ratio understates it when the chain is diluted).
        Updates ``self.cost.dbuf_overlap`` by EWMA and returns the new
        value — the "learned overlap term" future ``mode="auto"``
        decisions price with."""
        if serial_us <= 0 or chain_frac <= 0:
            return self.cost.dbuf_overlap
        hidden = (1.0 - dbuf_us / serial_us) / min(chain_frac, 1.0)
        hidden = min(max(hidden, 0.0), 0.95)
        a = self.OVERLAP_EWMA_ALPHA
        new = (1 - a) * self.cost.dbuf_overlap + a * hidden
        self.cost = dataclasses.replace(self.cost, dbuf_overlap=new)
        return new

    # -- online wall-clock calibration (the observe_overlap pattern,
    #    generalized to every engine) -------------------------------------
    #
    # The static [calib] constants carry the engines' *relative shape*;
    # the running host's absolute costs drift (dispatch overhead, cache
    # state, oversubscription).  The endpoint times every retired wave
    # and feeds ``observe_dispatch``: a per-(slot key, engine mode) EWMA
    # of measured/predicted wall clock, clamped and keyed exactly like
    # the decision that will consume it, so ``mode="auto"`` and the
    # serving loop's wave-formation policy adapt online while serving
    # instead of relying on a one-shot ``EngineCost.measured()``
    # calibration.  ``key`` is the operator's slot id for single-op
    # waves and ``None`` (the wave-global bucket) for mixed waves; every
    # per-key observation also updates the global bucket, which is the
    # fallback for keys not yet seen.

    DISPATCH_EWMA_ALPHA = 0.2      # EWMA weight of one wave observation
    CONFLICT_EWMA_ALPHA = 0.2      # EWMA weight of one conflict sample
    _SCALE_CLAMP = (1.0 / 16.0, 16.0)   # one bad sample can't poison auto

    def _unscaled_us(self, mode: str, *, batch: int, step_bound: int,
                     contention_rate: float = 0.0,
                     chain_iters: int = 0) -> Optional[float]:
        """The analytical (pre-EWMA) prediction for one wave on ``mode``,
        or None for modes the model has no closed form for (sharded
        placements, segmented plans without their stats)."""
        if mode in ("mixed", "batched", "interp"):
            return self.cost.batched_us(batch, step_bound, contention_rate)
        if mode == "compiled":
            return self.cost.compiled_us(batch, step_bound)
        if mode == "compiled_dbuf":
            return self.cost.compiled_dbuf_us(batch, step_bound,
                                              chain_iters)
        return None

    def observe_dispatch(self, key: Optional[int], mode: str, *,
                         batch: int, step_bound: int, measured_us: float,
                         contention_rate: float = 0.0,
                         chain_iters: int = 0) -> Optional[float]:
        """Learn from one retired wave: EWMA the ratio of measured wall
        clock to the *unscaled* analytical prediction into the
        ``(key, mode)`` scale (and the global ``(None, mode)`` fallback).
        Returns the new per-key scale, or None when the mode has no
        analytical form (nothing learned)."""
        pred = self._unscaled_us(mode, batch=batch, step_bound=step_bound,
                                 contention_rate=contention_rate,
                                 chain_iters=chain_iters)
        if pred is None or pred <= 0.0 or measured_us <= 0.0:
            return None
        lo, hi = self._SCALE_CLAMP
        ratio = min(max(measured_us / pred, lo), hi)
        a = self.DISPATCH_EWMA_ALPHA
        for k in {(key, mode), (None, mode)}:
            prev = self._scales.get(k, 1.0)
            self._scales[k] = (1 - a) * prev + a * ratio
        return self._scales[(key, mode)]

    def dispatch_scale(self, key: Optional[int], mode: str) -> float:
        """The learned wall-clock scale for ``(key, mode)``: per-key if
        observed, else the global per-mode fallback, else 1.0."""
        s = self._scales.get((key, mode))
        if s is None:
            s = self._scales.get((None, mode), 1.0)
        return s

    def observe_conflicts(self, key: Optional[int], rate: float) -> float:
        """EWMA one wave's conflict (contended-footprint) rate into the
        per-slot estimate ``conflict_hint`` serves back as the default
        contention hint for future waves of the same operator."""
        rate = min(max(float(rate), 0.0), 1.0)
        a = self.CONFLICT_EWMA_ALPHA
        for k in {key, None}:
            prev = self._conflicts.get(k, 0.0)
            self._conflicts[k] = (1 - a) * prev + a * rate
        return self._conflicts[key]

    def conflict_hint(self, key: Optional[int] = None) -> float:
        """The learned conflict rate for a slot (global fallback; 0.0
        before any observation)."""
        c = self._conflicts.get(key)
        if c is None:
            c = self._conflicts.get(None, 0.0)
        return c

    # EWMA weight of one home-access skew sample
    HOME_EWMA_ALPHA = 0.25

    def observe_home_access(self, counts: Sequence[float]) -> float:
        """Learn home skew from one per-device access-word vector (the
        endpoint's region-access audit, see
        ``TiaraEndpoint.note_access``): EWMA the hottest device's share
        of total accessed words.  ``choose_placement`` consumes it as
        the default ``batch_per_device`` when no mixed-batch plan is
        supplied, so a skewed access pattern prices sharding honestly
        (the hot home's sub-wave is the critical path) instead of
        assuming a uniform split."""
        vec = [max(float(c), 0.0) for c in counts]
        total = sum(vec)
        if total <= 0.0 or not vec:
            return self._home_skew if self._home_skew is not None else 0.0
        share = max(vec) / total
        a = self.HOME_EWMA_ALPHA
        prev = self._home_skew if self._home_skew is not None else share
        self._home_skew = (1 - a) * prev + a * share
        return self._home_skew

    def home_skew(self) -> Optional[float]:
        """The learned hottest-home share (None before any
        observation; 1/n_devices means perfectly balanced)."""
        return self._home_skew

    def wave_us(self, *, batch: int, step_bound: int,
                key: Optional[int] = None, mode: str = "mixed",
                contention_rate: float = 0.0,
                chain_iters: int = 0,
                cert_ceiling_us: Optional[float] = None) -> float:
        """Scaled wall-clock prediction for one wave — the serving
        loop's formation-policy estimate (analytical shape x learned
        host scale).

        ``cert_ceiling_us``: the wave's summed certified worst-case
        latency (:class:`~repro.core.wcet.LineRateCertificate`), when
        the caller has one.  The prediction is clamped to it: the EWMA
        scale is a *learned* guess that a cold start or a poisoned
        sample can inflate arbitrarily, while the certificate is a
        static fact — no wave can cost more than the sum of its
        members' certified worst cases, so no prediction should
        either."""
        pred = self._unscaled_us(mode, batch=batch, step_bound=step_bound,
                                 contention_rate=contention_rate,
                                 chain_iters=chain_iters)
        if pred is None:
            pred = self.cost.batched_us(batch, step_bound, contention_rate)
        scaled = pred * self.dispatch_scale(key, mode)
        if cert_ceiling_us is not None:
            scaled = min(scaled, cert_ceiling_us)
        return scaled

    def launch_efficiency(self, *, batch: int, step_bound: int,
                          key: Optional[int] = None,
                          mode: str = "mixed",
                          contention_rate: float = 0.0) -> float:
        """Fraction of a wave's predicted cost that is per-lane (useful)
        work rather than launch/macro-step overhead — monotone in batch
        size, -> 1 as the wave widens.  The continuous batcher rings
        when this crosses its efficiency floor: below it, waiting for
        more posts amortizes the launch better than launching now."""
        total = self.wave_us(batch=batch, step_bound=step_bound, key=key,
                             mode=mode, contention_rate=contention_rate)
        per_lane = (batch * step_bound * self.cost.vlane_us
                    * self.dispatch_scale(key, mode))
        if total <= 0.0:
            return 1.0
        return min(per_lane / total, 1.0)

    # -- single-op waves --------------------------------------------------

    def choose_batched(self, *, batch: int, step_bound: int,
                       compilable: bool,
                       contention_rate: float = 0.0,
                       chain_iters: int = 0,
                       batched_cached: bool = True,
                       compiled_cached: bool = True,
                       dbuf_cached: bool = True,
                       key: Optional[int] = None,
                       static_noconflict: bool = False) -> DispatchDecision:
        """Pick the engine for a single-op wave: "batched" (the lockstep
        interpreter; at B=1 this *is* the classic scalar MP datapath),
        "compiled" (the straight-line trace), or "compiled_dbuf" (the
        double-buffered gather-chain schedule — a candidate only when
        the operator has gather chains, ``chain_iters`` > 0, and wins
        only when they are long enough for the learned overlap term to
        beat the chunk-scheduling cost).  ``*_cached`` flags charge the
        amortized XLA-compile cost for engines not yet built at this
        batch size.  ``key`` (the operator's slot id) applies that
        slot's online-learned wall-clock scales to every candidate, so
        the argmin adapts to the running host (see
        :meth:`observe_dispatch`).

        ``static_noconflict=True`` reports a registration-time conflict
        proof over the wave: the ``contention_rate`` guess is discarded
        (a proven wave never prices the serialized-fallback risk) and
        the compiled candidates stay eligible."""
        if static_noconflict:
            contention_rate = 0.0
        costs = {"batched": self.cost.batched_us(batch, step_bound,
                                                 contention_rate,
                                                 cached=batched_cached)
                 * self.dispatch_scale(key, "batched")}
        if compilable and contention_rate <= 0.0:
            costs["compiled"] = self.cost.compiled_us(
                batch, step_bound, cached=compiled_cached) \
                * self.dispatch_scale(key, "compiled")
            if chain_iters > 0:
                costs["compiled_dbuf"] = self.cost.compiled_dbuf_us(
                    batch, step_bound, chain_iters, cached=dbuf_cached) \
                    * self.dispatch_scale(key, "compiled_dbuf")
        mode = min(costs, key=costs.get)
        return DispatchDecision(mode=mode, costs=costs,
                                contention_rate=contention_rate,
                                static_noconflict=static_noconflict)

    # -- mixed-op waves ---------------------------------------------------

    def segmented_us(self, segments: Sequence[SegmentStats],
                     contention_rate: float = 0.0) -> float:
        """Stable-sort-and-segment: each same-op segment pays its own
        launch (and possibly its own engine compile) on its best
        engine."""
        total = 0.0
        for s in segments:
            best = self.cost.batched_us(s.size, s.step_bound,
                                        contention_rate,
                                        cached=s.batched_cached)
            if s.compilable and contention_rate <= 0.0:
                best = min(best,
                           self.cost.compiled_us(
                               s.size, s.step_bound,
                               cached=s.compiled_cached))
            total += best
        return total

    def mixed_us(self, segments: Sequence[SegmentStats],
                 contention_rate: float = 0.0, *,
                 cached: bool = True) -> float:
        """One mixed lockstep launch: the whole wave advances together,
        so the macro-step count is the *largest* step bound in the mix."""
        batch = sum(s.size for s in segments)
        steps = max(s.step_bound for s in segments)
        return self.cost.batched_us(batch, steps, contention_rate,
                                    cached=cached)

    # -- placement (which device(s) execute the wave) ---------------------

    def choose_placement(self, *, batch: int, n_devices: int,
                         step_bound: int, contention_rate: float = 0.0,
                         batch_per_device: Optional[int] = None,
                         sharded_feasible: bool = True,
                         mixed_cached: bool = True,
                         sharded_cached: bool = True,
                         segments: Optional[Sequence[SegmentStats]] = None,
                         static_noconflict: bool = False
                         ) -> DispatchDecision:
        """Pick where a mixed wave executes: ``"single"`` (the dense
        one-launch mixed engine — every request against the whole pool
        on one chip) vs ``"sharded"`` (home-bucketed per-device
        sub-waves over the mesh, remote traffic on collectives).

        Sharding divides the per-lane vector work by ``n_devices`` but
        adds a per-macro-step collective tax, so it wins on wide waves
        with long traces and loses on small waves — and a contended wave
        is pinned to whichever side predicts cheaper with the serialized
        term included (the sharded fallback serializes over the global
        batch with a collective per lane, so contention strongly favors
        ``"single"``).  ``step_bound`` is the wave's largest per-op
        bound, as in :meth:`mixed_us`; ``batch_per_device`` is the
        plan's real (largest) sub-wave width, so home skew is priced in
        (see :meth:`EngineCost.sharded_us`).  ``sharded_feasible=False``
        removes the sharded candidate entirely — the caller's statement
        that no mesh of ``n_devices`` devices exists on this host (a
        pool can model more homes than the process has devices), so
        "auto" must degrade to "single" rather than pick a placement
        that cannot build.

        ``segments`` (the wave's *dense* — no-homes — plan stats)
        closes the old scope gap: "single" is priced as the best
        single-chip dispatch, the cheaper of the one-launch mixed
        engine and the stable-sort-and-segment path, so a low-entropy
        wave whose best local plan is segmented (per-op compiled
        launches) is no longer routed to the mesh prematurely.  Under
        contention segmentation is excluded (it reorders requests
        across ops — see :meth:`choose_mixed`), and without ``segments``
        the mixed engine alone is priced, as before.  The audit entries
        ``single_mixed``/``single_segmented`` record both candidates.

        ``static_noconflict=True`` reports a registration-time conflict
        proof: the contention guess is discarded, and the sharded
        candidate is priced with the footprint all_gather skipped (see
        :meth:`EngineCost.sharded_us`) — the proof is what lets a
        collective leave the mesh's per-step schedule."""
        if static_noconflict:
            contention_rate = 0.0
        if (batch_per_device is None and n_devices > 1
                and self._home_skew is not None):
            # no plan supplied: price the sharded critical path from the
            # learned access skew (hottest home's share of the batch)
            share = max(self._home_skew, 1.0 / n_devices)
            batch_per_device = max(1, min(batch,
                                          int(np.ceil(batch * share))))
        costs = {"single": self.cost.batched_us(batch, step_bound,
                                                contention_rate,
                                                cached=mixed_cached)}
        if segments and contention_rate <= 0.0:
            costs["single_mixed"] = costs["single"]
            costs["single_segmented"] = self.segmented_us(
                segments, contention_rate)
            costs["single"] = min(costs["single"],
                                  costs["single_segmented"])
        if n_devices > 1 and sharded_feasible:
            costs["sharded"] = self.cost.sharded_us(
                batch, n_devices, step_bound, contention_rate,
                batch_per_device=batch_per_device,
                cached=sharded_cached, noconflict=static_noconflict)
        mode = min(costs, key=costs.get)
        return DispatchDecision(mode=mode, costs=costs,
                                contention_rate=contention_rate,
                                static_noconflict=static_noconflict)

    def choose_mixed(self, *, segments: Sequence[SegmentStats],
                     contention_rate: float = 0.0,
                     mixed_cached: bool = True,
                     key: Optional[int] = None,
                     static_noconflict: bool = False) -> DispatchDecision:
        """Pick the engine for a mixed-op wave: "mixed" (one lockstep
        launch over the merged instruction store) vs "segmented"
        (stable-sort, one compiled/batched launch per same-op segment).

        The op-mix entropy enters through the plan shape: a
        low-entropy wave has a few big segments (launches amortize —
        segmentation wins when traces compile), a high-entropy wave
        shatters into many small segments whose per-segment launches
        dominate (the one-launch mixed engine wins).

        A contended wave (``contention_rate > 0``) is pinned to "mixed":
        segmentation reorders requests across ops (all of segment A
        before any of segment B), which only matches the reference
        round-robin interleaving when cross-segment footprints are
        disjoint — exactly what the contention hint denies.  This
        mirrors :meth:`choose_batched` excluding the compiled trace.

        ``static_noconflict=True`` reports a registration-time conflict
        proof over the wave: the contention guess is discarded, so the
        segmented candidate (which *requires* cross-segment
        disjointness — now proven, not assumed) stays eligible.
        """
        if not segments:
            raise ValueError("mixed wave needs at least one segment")
        if static_noconflict:
            contention_rate = 0.0
        entropy = _entropy_bits([s.size for s in segments])
        costs = {"mixed": self.mixed_us(segments, contention_rate,
                                        cached=mixed_cached)
                 * self.dispatch_scale(key, "mixed")}
        if contention_rate <= 0.0:
            costs["segmented"] = self.segmented_us(segments,
                                                   contention_rate) \
                * self.dispatch_scale(key, "segmented")
        mode = min(costs, key=costs.get)
        return DispatchDecision(mode=mode, costs=costs,
                                entropy_bits=entropy,
                                contention_rate=contention_rate,
                                static_noconflict=static_noconflict)
