"""Registration-time line-rate certification — static WCET, traffic,
and occupancy bounds (the NIC-admission story of paper §3.3).

The verifier proves *termination* (a worst-case step count); nothing
there bounds an operator's *cost*: cycles, memory traffic, or port
occupancy.  This module closes that gap at the eBPF-load moment.
:func:`certify` abstractly interprets a verified program against the
``simulator.py`` hardware model and attaches a
:class:`LineRateCertificate` to every ``VerifiedOperator`` — sound
upper bounds the rest of the stack *enforces*:

* ``OperatorRegistry.register`` rejects over-:class:`Budget` operators
  with a per-pc diagnostic (the eBPF "program too large" moment);
* ``ServingLoop.submit`` fail-fasts ``STATUS_TIMEOUT`` at admission
  when the certified WCET already exceeds the post's deadline — the
  post is never queued, never launched, and still retires exactly one
  CQE;
* ``DispatchCostModel.wave_us`` clamps its learned EWMA to the wave's
  summed certified bound, so a cold or poisoned EWMA can never price a
  wave above what is statically possible.

Soundness argument (property-tested in ``tests/test_wcet.py`` and
re-proved over a seeded corpus on every ``benchmarks/bench_wcet.py``
run — the ``wcet_sound_ok`` hard bit):

1. The *serialized* simulator timeline (every MEMCPY synchronous)
   upper-bounds the split-phase one: by induction over events, the
   async timeline's clock, channel-free and wire-free horizons, and
   every outstanding completion time all stay <= the serialized clock
   (a serialized MEMCPY absorbs its occupancy *and* latency into ``t``,
   so ``chan_free``/``wire_free`` never run ahead of it, and WAIT/the
   implicit pre-reply join can only wait for completions that the
   serialized clock has already passed).
2. In the serialized timeline every event starts at ``t`` (the ports
   are never ahead of the clock), so total time is the *sum* of per-
   event charges — and each charge is maximized here over every device
   resolution (remote unless the operand is statically ``DEV_LOCAL``),
   every dynamic MEMCPY length (the static cap ``imm``, which the
   datapath always applies, further clamped by the region sizes —
   exactly ``pyvm``'s clamp), and the slower of the wire/PCIe rates.
3. Per-pc execution counts are bounded by the verifier's loop-cap
   multipliers (forward jumps only *skip* work), so scaling each pc's
   worst charge by its multiplier bounds any real trace.

Pipelined MPs, mid-flight MEMCPY aborts, and reply payloads only
*reduce* the charged time relative to this bound (the certificate is
computed at ``reply_payload_bytes=0``, which both the latency and the
wire-byte figures state explicitly).

Import topology: the verifier imports this module, so nothing here may
import ``verifier``/``pyvm``/``simulator``.  The three wire/DMA
constants that used to live in ``simulator.py`` moved here (simulator
re-imports them); loop metadata arrives structurally via
``access.LoopLike`` and trip multipliers via ``access.loop_multiplier``
(the verifier's step-bound definition, so ``mp_cycles == step_bound``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import isa
from repro.core.access import LoopLike, loop_multiplier
from repro.core.costmodel import DEFAULT_HW, HW
from repro.core.isa import Instr, Op
from repro.core.memory import RegionTable
from repro.core.program import TiaraProgram

# Bulk-DMA engine setup cost per transfer (descriptor fetch + doorbell),
# [calib: anchors Fig. 10's ~8.7 GB/s at 4 KB blocks].  Shared with the
# trace simulator (simulator.py re-imports these three).
DMA_SETUP_CYCLES = 18
REQUEST_BYTES = 64      # op id + 8 param registers + header
REPLY_BYTES = 16        # status + return value + header

_SMALL_OPS = (Op.LOAD, Op.STORE, Op.CAS, Op.CAA)
_SMALL_WIRE_BYTES = 2 * 32      # small RDMA read/write + ack


@dataclasses.dataclass(frozen=True)
class PcCost:
    """Worst-case charges attributed to one static instruction site."""

    pc: int
    op: str                 # mnemonic, for diagnostics
    count: int              # worst-case executions (enclosing-loop caps)
    cycles: float           # serialized NIC-resident cycles charged here
    wire_bytes: int
    memcpy_bytes: int

    def to_json(self) -> Dict[str, object]:
        return {"pc": self.pc, "op": self.op, "count": self.count,
                "cycles": self.cycles, "wire_bytes": self.wire_bytes,
                "memcpy_bytes": self.memcpy_bytes}


# resource name -> PcCost attribute the per-pc ranking reads
_RESOURCE_ATTR = {"cycles": "cycles", "wire_bytes": "wire_bytes",
                  "memcpy_bytes": "memcpy_bytes"}


@dataclasses.dataclass(frozen=True)
class LineRateCertificate:
    """Sound static upper bounds on one operator's worst-case cost.

    Every figure bounds the corresponding ``TaskSim`` field of *any*
    trace of the operator (at ``reply_payload_bytes=0``): cycles/us
    bound the NIC-resident timeline, ``words_read``/``words_written``
    bound the exact dynamic word traffic, ``memcpy_bytes`` the summed
    MEMCPY payload, ``dma_channel_cycles``/``wire_bytes`` the
    per-resource occupancy the bottleneck law divides by.
    """

    wcet_cycles: float          # NIC-resident cycles, incl. dispatch
    wcet_nic_us: float          # = wcet_cycles * clk
    wcet_latency_us: float      # client end-to-end, zero reply payload
    mp_cycles: int              # issue-slot bound (== verifier step bound)
    words_read: int
    words_written: int
    memcpy_bytes: int           # summed MEMCPY payload (local + remote)
    dma_small_reqs: int
    dma_channel_cycles: float
    wire_bytes: int             # request + reply + worst remote traffic
    bottleneck: str             # statically predicted binding resource
    per_pc: Tuple[PcCost, ...]  # cycle/traffic attribution per site

    def hottest(self, resource: str = "cycles") -> Optional[PcCost]:
        """The site contributing most to ``resource`` ("cycles",
        "wire_bytes", or "memcpy_bytes")."""
        attr = _RESOURCE_ATTR[resource]
        ranked = [p for p in self.per_pc if getattr(p, attr) > 0]
        if not ranked:
            return None
        return max(ranked, key=lambda p: float(getattr(p, attr)))

    def to_json(self) -> Dict[str, object]:
        return {
            "wcet_cycles": self.wcet_cycles,
            "wcet_nic_us": self.wcet_nic_us,
            "wcet_latency_us": self.wcet_latency_us,
            "mp_cycles": self.mp_cycles,
            "words_read": self.words_read,
            "words_written": self.words_written,
            "memcpy_bytes": self.memcpy_bytes,
            "dma_small_reqs": self.dma_small_reqs,
            "dma_channel_cycles": self.dma_channel_cycles,
            "wire_bytes": self.wire_bytes,
            "bottleneck": self.bottleneck,
            "per_pc": [p.to_json() for p in self.per_pc],
        }

    def describe(self) -> str:
        """One-line summary for ``registry.dump()`` / quickstart."""
        return (f"wcet {self.wcet_nic_us:.2f}us nic / "
                f"{self.wcet_latency_us:.2f}us e2e, "
                f"{self.wcet_cycles:.0f} cycles, "
                f"rd {self.words_read} wr {self.words_written} words, "
                f"memcpy {self.memcpy_bytes}B, wire {self.wire_bytes}B, "
                f"bottleneck {self.bottleneck}")


def _static_local(flags: int, field: int, reg_flag: int) -> bool:
    """True iff the device operand is statically the executing host
    (``DEV_LOCAL``) — the only case the worst-case analysis may treat
    as local; register-held or non-local static devices charge the
    remote worst case."""
    return not (flags & reg_flag) and int(field) == isa.DEV_LOCAL


def memcpy_word_bound(ins: Instr,
                      regions: Optional[RegionTable]) -> int:
    """Sound static bound on one MEMCPY's transferred words.  The
    datapath clamps even a register-held length at the static ``imm``
    cap, then at the burst limit and both region sizes — the exact
    ``pyvm`` clamp sequence, evaluated on the caps."""
    ext = min(int(ins.imm), isa.MAX_MEMCPY_WORDS)
    if regions is not None:
        n = len(regions)
        if 0 <= int(ins.a) < n:
            ext = min(ext, int(regions[int(ins.a)].size))
        if 0 <= int(ins.d) < n:
            ext = min(ext, int(regions[int(ins.d)].size))
    return max(ext, 0)


def certify(program: TiaraProgram, loops: Sequence[LoopLike],
            regions: Optional[RegionTable] = None,
            hw: HW = DEFAULT_HW) -> LineRateCertificate:
    """Derive the operator's line-rate certificate by abstract
    interpretation against the hardware model (see module docstring for
    the soundness argument)."""
    clk = hw.clk_ns
    dma_lat = float(hw.pcie_dma_cycles)
    rtt_cy = float(hw.rdma_rtt_cycles)
    wire_bpc = hw.wire_eff_gbs * clk            # bytes per cycle
    pcie_bpc = hw.pcie_gbs * clk
    worst_bpc = min(wire_bpc, pcie_bpc)         # cut-through worst case
    worst_lat = max(rtt_cy, dma_lat)

    instrs = isa.decode_program(program.code)
    per_pc: List[PcCost] = []
    cycles = float(hw.dispatch_cycles)
    mp_cycles = 0
    words_read = 0
    words_written = 0
    memcpy_bytes = 0
    dma_small = 0
    chan = 0.0
    wire = REQUEST_BYTES + REPLY_BYTES

    for pc, ins in enumerate(instrs):
        mult = loop_multiplier(loops, pc)
        if mult == 0:
            continue
        op = ins.op
        t = float(hw.instr_cycles)
        wb = 0
        mb = 0
        if op in _SMALL_OPS:
            dma_small += mult
            chan += mult * hw.dma_issue_cycles
            if _static_local(ins.flags, ins.e, isa.FLAG_DEV_REG):
                t += dma_lat
            else:
                t += worst_lat
                wb = _SMALL_WIRE_BYTES
            if op != Op.STORE:          # LOAD/CAS/CAA read the old word
                words_read += mult
            if op != Op.LOAD:           # STORE/CAS/CAA may write it
                words_written += mult
        elif op == Op.MEMCPY:
            n_words = memcpy_word_bound(ins, regions)
            nbytes = n_words * isa.WORD_BYTES
            words_read += mult * n_words
            words_written += mult * n_words
            mb = nbytes
            dst_local = _static_local(ins.flags, ins.dst,
                                      isa.FLAG_DSTDEV_REG)
            src_local = _static_local(ins.flags, ins.c,
                                      isa.FLAG_SRCDEV_REG)
            if dst_local and src_local:
                occ = DMA_SETUP_CYCLES + nbytes / pcie_bpc
                t += dma_lat + occ
            else:
                occ = DMA_SETUP_CYCLES + nbytes / worst_bpc
                t += occ + worst_lat
                wb = nbytes + 32        # payload + write-ack header
            chan += mult * occ
        # NOP/MOVI/ALU/JUMP/LOOP/WAIT/RET: one MP cycle.  WAIT charges
        # nothing beyond it: in the serialized bound nothing is ever
        # outstanding, and invariant (1) covers the async stalls.
        mp_cycles += mult
        cyc = mult * t
        cycles += cyc
        wire += mult * wb
        memcpy_bytes += mult * mb
        per_pc.append(PcCost(pc=pc, op=op.name, count=mult, cycles=cyc,
                             wire_bytes=mult * wb, memcpy_bytes=mult * mb))

    clk_us = clk / 1e3
    nic_us = cycles * clk_us
    latency_us = (hw.rtt_us
                  + (REQUEST_BYTES + REPLY_BYTES) / wire_bpc * clk_us
                  + nic_us)
    demands_us = {
        "mp": mp_cycles * clk_us / hw.n_mps,
        "dma_channel": chan * clk_us,
        "wire": wire / hw.wire_bytes_per_us,
        "slots": nic_us / hw.slots,
    }
    bottleneck = max(demands_us, key=lambda k: demands_us[k])
    return LineRateCertificate(
        wcet_cycles=cycles, wcet_nic_us=nic_us,
        wcet_latency_us=latency_us, mp_cycles=mp_cycles,
        words_read=words_read, words_written=words_written,
        memcpy_bytes=memcpy_bytes, dma_small_reqs=dma_small,
        dma_channel_cycles=chan, wire_bytes=wire, bottleneck=bottleneck,
        per_pc=tuple(per_pc))


# ---------------------------------------------------------------------------
# budgets — the registration-time admission contract
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Budget:
    """Registration-time admission budget.  ``None`` fields are
    unlimited; a :class:`LineRateCertificate` exceeding any limit makes
    ``OperatorRegistry.register`` reject the operator eBPF-style with a
    per-pc diagnostic (see :meth:`violations`)."""

    max_cycles: Optional[float] = None      # worst-case NIC-resident cycles
    max_wire_bytes: Optional[int] = None    # worst-case wire traffic
    max_memcpy_bytes: Optional[int] = None  # worst-case MEMCPY payload
    max_latency_us: Optional[float] = None  # worst-case end-to-end latency

    def violations(self, cert: LineRateCertificate) -> List[str]:
        """Budget-violation diagnostics, each naming the resource, the
        certified worst case, the limit, and the hottest contributing
        pc — empty when the certificate fits."""
        checks: Tuple[Tuple[str, Optional[float], float, str], ...] = (
            ("cycles", self.max_cycles, cert.wcet_cycles, "cycles"),
            ("wire bytes", None if self.max_wire_bytes is None
             else float(self.max_wire_bytes), float(cert.wire_bytes),
             "wire_bytes"),
            ("memcpy bytes", None if self.max_memcpy_bytes is None
             else float(self.max_memcpy_bytes), float(cert.memcpy_bytes),
             "memcpy_bytes"),
            ("latency us", self.max_latency_us, cert.wcet_latency_us,
             "cycles"),
        )
        out: List[str] = []
        for resource, limit, value, attr in checks:
            if limit is None or value <= limit:
                continue
            hot = cert.hottest(attr)
            where = "" if hot is None else (
                f" (hottest: pc {hot.pc} {hot.op} x{hot.count}, "
                f"{float(getattr(hot, _RESOURCE_ATTR[attr])):.0f} "
                f"{_RESOURCE_ATTR[attr]})")
            out.append(f"certified worst-case {resource} {value:.0f} "
                       f"exceeds budget {limit:.0f}{where}")
        return out


# Default admission contract: roughly 10 ms of NIC residency and 64 MB
# of traffic per invocation — far above any line-rate operator (every
# stock workload certifies orders of magnitude below), low enough to
# reject unbounded-cost programs at load time.  Gated shrink-only by
# tools/check_budgets.py against tools/wcet_baseline.json.
DEFAULT_BUDGET = Budget(max_cycles=float(1 << 21),
                        max_wire_bytes=64 << 20,
                        max_memcpy_bytes=64 << 20,
                        max_latency_us=20_000.0)
