"""Registration-time trace compiler — the interpreter-free fast path.

The facts the static verifier already proves about an operator (jumps are
forward-only, every loop has a static trip-count cap, nesting is bounded)
are exactly what a trace compiler needs: the whole program can be lowered
at registration time to **straight-line predicated dataflow** — a chain of
``jnp.take``-style gathers and deterministic scatters with no interpreter
dispatch at all.  This is the software analogue of the paper's point that
hot remote-memory paths (pointer chase, page-table walk, KV block fetch)
should run as *superoperators* baked into the fabric, not as general
interpreted programs.

Lowering rules (B = request batch, every value is an int64 ``(B,)`` lane):

  * loops unroll to their static cap; iteration ``j`` runs under the
    predicate ``j < min(trip_reg, cap)``;
  * forward jumps become predicate splits: the fall-through side continues
    under ``pred & ~take`` and the taken lanes re-join at the target pc
    (a jump that escapes loop bodies masks the remaining iterations —
    the Fig. 5 distributed-lock "break");
  * RET latches ``ret``/``status`` under the live predicate and removes
    the lane from every later instruction;
  * LOAD/STORE lower to gathers / deterministic scatters, CAS/CAA to a
    serialized ``lax.scan`` over the batch (atomics keep pyvm
    request-order), MEMCPY to a window gather plus a deterministic
    last-writer-wins scatter in round-robin commit order;
  * the canonical *gather-chain* loop (``load id; load translation;
    memcpy row``) — MoE expert gather and paged-KV block fetch — is
    recognized structurally and fused into one two-level batched gather,
    optionally routed through the ``kernels/tiara_gather`` Pallas kernel.

Exactness: at batch=1 the compiled operator is bit-identical to the
``pyvm`` oracle (memory, ret, status, steps, registers).  For batches the
semantics are the engine's round-robin interleaving; like the batched
interpreter's vectorized step it assumes no request *reads* a word another
request *writes at the same trace position* (atomics excepted — they are
fully serialized).  Contended workloads belong on the batched interpreter,
which detects conflicts per step and falls back to exact serialization.

The in-flight async counter is not modeled: WAIT only clamps a counter
that never feeds a value (copies apply functionally at issue; timing is
the simulator's job), so the compiled path drops it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import costmodel as _costmodel
from repro.core import isa
from repro.core.isa import (Alu, Instr, Op, FLAG_ASYNC, FLAG_DEV_REG,
                            FLAG_DSTDEV_REG, FLAG_IMMB, FLAG_LEN_REG,
                            FLAG_MREG, FLAG_SRCDEV_REG, DEV_LOCAL, ERR_REG)
from repro.core.memory import RegionTable
from repro.core.verifier import LoopInfo, VerifiedOperator
from repro.core import vm as _vm

_REG_MASK = isa.NUM_REGS - 1

# fault-site device column sentinels (see _Tracer.sites / _finalize_fault):
# >= 0 is a static device id, _DEV_HOME resolves to the lane's home at
# finalization, _DEV_LATCHED reads the runtime f_dev plane
_DEV_HOME = -1
_DEV_LATCHED = -2

DEFAULT_UNROLL_LIMIT = 4096

# Iterations per double-buffered gather-chain chunk: small enough that
# chunk k+1's row gather overlaps a meaningful fraction of chunk k's
# scatter, large enough that per-chunk scatter setup amortizes.  Single
# source of truth is the cost model's ``dbuf_chunk_iters`` — the auto
# dispatch prices chunk counts and the overlap-eligibility threshold
# with it, so the engine must chunk identically.
DBUF_CHUNK = _costmodel.EngineCost().dbuf_chunk_iters


class CompileError(Exception):
    pass


def why_not_compilable(op: VerifiedOperator,
                       unroll_limit: int = DEFAULT_UNROLL_LIMIT
                       ) -> Optional[str]:
    """None if the operator can be trace-compiled, else a reason string.

    The verifier already guarantees loop-freeness or bounded unrollability;
    the only extra constraint is that the fully unrolled trace stays small
    enough to be worth baking into one XLA program.
    """
    if op.step_bound > unroll_limit:
        return (f"worst-case trace of {op.step_bound} instructions exceeds "
                f"the unroll limit of {unroll_limit}")
    return None


def compilable(op: VerifiedOperator,
               unroll_limit: int = DEFAULT_UNROLL_LIMIT) -> bool:
    return why_not_compilable(op, unroll_limit) is None


# ---------------------------------------------------------------------------
# Shared lowering helpers (also used by the distributed layer)
# ---------------------------------------------------------------------------

def masked_row_gather(pool: jnp.ndarray, idx: jnp.ndarray,
                      live: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """``out[...] = pool[idx[...]]`` with rows outside ``[0, len(pool))``
    (or with ``live == False``) replaced by zeros — the memory-side half of
    the compiled gather chain, shared with ``distributed/tiara_fetch`` and
    ``distributed/paged_decode``."""
    n = pool.shape[0]
    ok = (idx >= 0) & (idx < n)
    if live is not None:
        ok = ok & live
    rows = pool[jnp.clip(idx, 0, n - 1)]
    shape = ok.shape + (1,) * (rows.ndim - ok.ndim)
    return jnp.where(ok.reshape(shape), rows, jnp.zeros((), rows.dtype))


def det_scatter(mem_flat: jnp.ndarray, idx: jnp.ndarray, val: jnp.ndarray,
                live: jnp.ndarray) -> jnp.ndarray:
    """Deterministic scatter: among duplicate targets the **last live lane
    in flat order wins** — flat order is the engine's round-robin commit
    order.  Dead lanes are routed out of bounds and dropped."""
    size = mem_flat.shape[0]
    f = jnp.where(live, idx, size).reshape(-1)
    v = val.reshape(-1)
    m = f.shape[0]
    # stable grouping: sort by (target, lane); the last element of each
    # run of equal targets is the winner
    comp = f * m + jnp.arange(m, dtype=f.dtype)
    order = jnp.argsort(comp)
    fs = f[order]
    last = jnp.concatenate([fs[1:] != fs[:-1],
                            jnp.ones((1,), dtype=bool)])
    tgt = jnp.where(last, fs, size)
    return mem_flat.at[tgt].set(v[order], mode="drop")


def _alu_static(aop: int, a, b):
    """ALU with a *static* opcode — the compiled trace emits only the one
    operation the instruction names (no 16-way select)."""
    if aop == Alu.ADD:
        return a + b
    if aop == Alu.SUB:
        return a - b
    if aop == Alu.MUL:
        return a * b
    if aop == Alu.AND:
        return a & b
    if aop == Alu.OR:
        return a | b
    if aop == Alu.XOR:
        return a ^ b
    if aop == Alu.SHL:
        return a << (b & 63)
    if aop == Alu.SHR:
        return lax.shift_right_logical(a, b & 63)
    if aop == Alu.EQ:
        return (a == b).astype(jnp.int64)
    if aop == Alu.NE:
        return (a != b).astype(jnp.int64)
    if aop == Alu.LT:
        return (a < b).astype(jnp.int64)
    if aop == Alu.GE:
        return (a >= b).astype(jnp.int64)
    if aop == Alu.MIN:
        return jnp.minimum(a, b)
    if aop == Alu.MAX:
        return jnp.maximum(a, b)
    raise CompileError(f"bad ALU op {aop}")


# ---------------------------------------------------------------------------
# Mixed-batch planner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    """One same-op_id run of the stable-sorted batch: requests at sorted
    positions ``[start, end)`` all dispatch to ``op_id``.  In a
    home-bucketed plan the run is additionally same-``home`` — the unit
    of placement on the device mesh (the whole segment executes on
    device ``home``)."""

    op_id: int
    start: int
    end: int
    home: int = 0

    @property
    def size(self) -> int:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class MixedPlan:
    """The compiled path's plan for a mixed-op batch.

    A straight-line compiled trace executes one program, so a mixed batch
    is *segmented*: requests are stable-sorted by op_id (preserving
    arrival order within an op — the ordering atomics serialize by), each
    contiguous segment runs through its own compiled trace against the
    shared pool, and per-request outputs scatter back to arrival order
    through ``inverse``.  Planning is pure bookkeeping — O(B log B) once
    per wave — and is exactly the batching a NIC dispatcher would do when
    filling per-MP task queues from a mixed arrival stream.

    **Home-bucketed (sharded) plans**: built with ``homes=`` +
    ``n_devices=``, the stable sort key becomes ``(home, op_id)`` —
    device-major, so device ``d``'s sub-wave is the contiguous slice of
    the sorted batch holding exactly the requests whose ``home`` it
    owns, itself sorted into same-op segments (segments stay the unit of
    placement; each carries its ``home``).  ``device_counts[d]`` is the
    sub-wave's size, ``batch_per_device`` the padded lane count the
    sharded engine runs, and the *same* arrival-order ``inverse``
    permutation still does the reply scatter.
    """

    op_ids: np.ndarray            # i64 [B] arrival-order op ids
    order: np.ndarray             # i64 [B]: sorted position -> arrival idx
    inverse: np.ndarray           # i64 [B]: arrival idx -> sorted position
    segments: Tuple[Segment, ...]
    homes: Optional[np.ndarray] = None   # i64 [B] arrival-order homes
    n_devices: int = 1
    device_counts: Optional[np.ndarray] = None   # i64 [n_devices]

    @property
    def batch(self) -> int:
        return int(self.op_ids.shape[0])

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def sharded(self) -> bool:
        return self.device_counts is not None

    @property
    def batch_per_device(self) -> int:
        """Per-device lane count of the sharded engine: every ragged
        sub-wave padded to the largest one (>= 1 so empty devices still
        hold a halted pad lane)."""
        if self.device_counts is None:
            return self.batch
        return max(int(self.device_counts.max(initial=0)), 1)

    def segment_indices(self, seg: Segment) -> np.ndarray:
        """Arrival indices of the requests in ``seg`` (arrival order)."""
        return self.order[seg.start:seg.end]

    def device_segments(self, device: int) -> Tuple[Segment, ...]:
        """The placement units assigned to ``device`` (sharded plans)."""
        return tuple(s for s in self.segments if s.home == device)


def plan_mixed_batch(op_ids, homes=None,
                     n_devices: Optional[int] = None) -> MixedPlan:
    """Stable-sort a batch's op_ids and segment it into same-op runs.

    With ``homes=`` and ``n_devices=``, additionally bucket the segments
    by ``home`` into per-device sub-waves (sort key ``(home, op_id)``,
    arrival-stable) — the placement plan the sharded engine executes.
    """
    ids = np.asarray(list(op_ids), dtype=np.int64)
    if ids.ndim != 1 or ids.size == 0:
        raise ValueError("op_ids must be a non-empty 1-D sequence")
    if homes is None:
        order = np.argsort(ids, kind="stable").astype(np.int64)
        hsort = None
        device_counts = None
        n_dev = 1
    else:
        if n_devices is None:
            raise ValueError("home-bucketed plans need n_devices=")
        n_dev = int(n_devices)
        h = np.asarray(list(homes), dtype=np.int64)
        if h.shape != ids.shape:
            raise ValueError(
                f"homes shape {h.shape} does not match op_ids {ids.shape}")
        if ids.size and (h.min() < 0 or h.max() >= n_dev):
            raise ValueError(
                f"homes must lie in [0, {n_dev}); got range "
                f"[{h.min()}, {h.max()}]")
        # np.lexsort: last key is primary; stable, so arrival order is
        # preserved within each (home, op) bucket
        order = np.lexsort((ids, h)).astype(np.int64)
        hsort = h[order]
        device_counts = np.bincount(h, minlength=n_dev).astype(np.int64)
    inverse = np.empty_like(order)
    inverse[order] = np.arange(ids.size, dtype=np.int64)
    sorted_ids = ids[order]
    brk = sorted_ids[1:] != sorted_ids[:-1]
    if hsort is not None:
        brk = brk | (hsort[1:] != hsort[:-1])
    starts = np.flatnonzero(np.concatenate([[True], brk]))
    bounds = list(starts) + [ids.size]
    segments = tuple(
        Segment(op_id=int(sorted_ids[s]), start=int(s), end=int(e),
                home=int(hsort[s]) if hsort is not None else 0)
        for s, e in zip(bounds[:-1], bounds[1:]))
    return MixedPlan(op_ids=ids, order=order, inverse=inverse,
                     segments=segments,
                     homes=None if homes is None else h,
                     n_devices=n_dev, device_counts=device_counts)


# ---------------------------------------------------------------------------
# Gather-chain superoperator detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GatherChain:
    """The canonical indirection loop (paper §4.5/§4.6):

        loop (n, cap):
            load  id    <- ids_region[i]
            load  paddr <- table_region[id]
            memcpy dst_region[dst] <- pool_region[paddr] x W   (async ok)
            dst += W
            i   += 1
    """

    loop_pc: int
    cap: int
    ids_rid: int
    table_rid: int
    pool_rid: int
    dst_rid: int
    row_words: int
    i_reg: int
    id_reg: int
    paddr_reg: int
    dst_reg: int
    is_async: bool


def _plain_local_load(ins: Instr) -> bool:
    return (ins.op == Op.LOAD and ins.imm == 0 and ins.flags == 0
            and ins.e == DEV_LOCAL)


def _is_add_imm(ins: Instr, reg: int, imm: Optional[int] = None) -> bool:
    """``reg += imm`` (immediate ADD updating ``reg`` in place)."""
    return (ins.op == Op.ALU and ins.d == int(Alu.ADD)
            and bool(ins.flags & FLAG_IMMB) and ins.dst == ins.a == reg
            and (imm is None or ins.imm == imm))


def match_gather_chain_ex(instrs: List[Instr], loop: LoopInfo
                          ) -> Tuple[Optional[GatherChain], Optional[str]]:
    """Structural match of the loop body against the gather-chain shape.
    Purely static — checked once at compile time.  Returns
    ``(chain, None)`` on a match and ``(None, reason)`` on a near-miss,
    where ``reason`` is the *first* structural check that failed — the
    registry surfaces it so a silently-slow almost-chain is explainable.
    """
    body = instrs[loop.start:loop.end + 1]
    if len(body) != 5:
        return None, (f"body has {len(body)} instructions, not the "
                      f"5-instruction chain shape")
    ld_id, ld_tr, mc, add_dst, add_i = body
    lp = instrs[loop.pc]

    if not _plain_local_load(ld_id):
        return None, "body[0] is not a plain local load (imm 0, no flags)"
    if not _plain_local_load(ld_tr):
        return None, "body[1] is not a plain local load (imm 0, no flags)"
    if ld_tr.b != ld_id.dst:                     # chained: id -> translation
        return None, ("body[1] offset register is not body[0]'s "
                      "destination (loads are not chained)")
    if mc.op != Op.MEMCPY:
        return None, "body[2] is not a MEMCPY"
    if mc.flags & (FLAG_LEN_REG | FLAG_DSTDEV_REG | FLAG_SRCDEV_REG):
        return None, "MEMCPY uses a dynamic length or device register"
    if mc.dst != DEV_LOCAL or mc.c != DEV_LOCAL:
        return None, "MEMCPY is not local-to-local"
    if mc.e != ld_tr.dst:                        # src offset = translation
        return None, ("MEMCPY source offset is not the translation "
                      "load's destination")
    w = int(mc.imm)
    if not (0 < w <= isa.MAX_MEMCPY_WORDS):
        return None, f"MEMCPY row width {w} outside (0, MAX_MEMCPY_WORDS]"
    if not _is_add_imm(add_dst, mc.b, w):
        return None, (f"body[3] is not 'dst += {w}' (immediate ADD of the "
                      f"row width)")
    if not _is_add_imm(add_i, ld_id.b, 1):
        return None, "body[4] is not 'i += 1' (immediate ADD of 1)"
    # distinct registers so the fused updates don't alias
    regs = (ld_id.b, ld_id.dst, ld_tr.dst, mc.b)
    if len(set(regs)) != 4:
        return None, "index/id/translation/dst registers are not distinct"
    return GatherChain(
        loop_pc=loop.pc, cap=int(lp.imm), ids_rid=ld_id.a,
        table_rid=ld_tr.a, pool_rid=mc.d, dst_rid=mc.a, row_words=w,
        i_reg=ld_id.b, id_reg=ld_id.dst, paddr_reg=ld_tr.dst,
        dst_reg=mc.b, is_async=bool(mc.flags & FLAG_ASYNC)), None


def match_gather_chain(instrs: List[Instr], loop: LoopInfo
                       ) -> Optional[GatherChain]:
    """Reason-free wrapper of :func:`match_gather_chain_ex` (hot path)."""
    return match_gather_chain_ex(instrs, loop)[0]


def find_gather_chains(op: VerifiedOperator) -> List[GatherChain]:
    """All gather-chain superoperators in a verified program (diagnostic /
    registry-level introspection)."""
    instrs = isa.decode_program(op.code)
    out = []
    for l in op.loops:
        g = match_gather_chain(instrs, l)
        if g is not None:
            out.append(g)
    return out


# ---------------------------------------------------------------------------
# Wider superoperator shapes (footprint-era matchers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScatterReduce:
    """Conditional scatter-accumulate loop — the CAA analogue of the
    gather chain:

        loop (n, cap):
            load v   <- src_region[i]
            caa  old <- acc_region[j] ?= cmp, += v
            j += stride
            i += 1

    Fused to one snapshot gather + elementwise compare + scatter-add.
    The fusion is **only exact when every CAA address in the wave is
    touched at most once** — within a lane that is the static
    ``|stride| * cap <= region size`` check, across lanes it is the
    registration-time conflict proof — so the tracer emits it only in
    ``noconflict`` builds (see :func:`build_compiled`)."""

    loop_pc: int
    cap: int
    src_rid: int
    acc_rid: int
    stride: int
    i_reg: int
    v_reg: int
    j_reg: int
    old_reg: int
    cmp_reg: int


def match_scatter_reduce(instrs: List[Instr], loop: LoopInfo
                         ) -> Optional[ScatterReduce]:
    """Structural match of the loop body against the scatter-reduce
    shape (purely static)."""
    body = instrs[loop.start:loop.end + 1]
    if len(body) != 4:
        return None
    ld, caa, add_j, add_i = body
    lp = instrs[loop.pc]
    if not _plain_local_load(ld):
        return None
    if caa.op != Op.CAA or caa.flags != 0 or caa.imm != 0 \
            or caa.e != DEV_LOCAL:
        return None
    if caa.d != ld.dst:                          # added value = loaded value
        return None
    if not (_is_add_imm(add_j, caa.b) and add_j.imm != 0):
        return None
    if not _is_add_imm(add_i, ld.b, 1):
        return None
    if caa.a == ld.a:          # src window must not alias the acc window
        return None
    regs = (ld.b, ld.dst, caa.b, caa.dst)
    if len(set(regs)) != 4 or caa.c in regs:
        return None
    return ScatterReduce(
        loop_pc=loop.pc, cap=int(lp.imm), src_rid=ld.a, acc_rid=caa.a,
        stride=int(add_j.imm), i_reg=ld.b, v_reg=ld.dst, j_reg=caa.b,
        old_reg=caa.dst, cmp_reg=caa.c)


@dataclasses.dataclass(frozen=True)
class MapLoop:
    """Elementwise map / zip-with over region windows:

        loop (n, cap):                 loop (n, cap):
            load a <- src[i]               load a <- src1[i]
            c = a OP rhs                   load b <- src2[i]
            store c -> dst[j]              c = a OP b
            j += 1                         store c -> dst[j]
            i += 1                         j += 1
                                           i += 1

    ``rhs`` in the unary form is an immediate or a loop-invariant
    register.  Fused to one window gather (two for zip), one
    elementwise ALU, and one deterministic scatter in (iteration,
    request) commit order.  ``src2_rid``/``b_reg`` are -1 and
    ``alu_imm`` carries the immediate for the unary form."""

    loop_pc: int
    cap: int
    src_rid: int
    src2_rid: int
    dst_rid: int
    alu_op: int
    alu_imm: Optional[int]
    rhs_reg: int               # invariant-register rhs for unary map, or -1
    i_reg: int
    j_reg: int
    a_reg: int
    b_reg: int
    c_reg: int
    is_zip: bool


def match_map_loop(instrs: List[Instr], loop: LoopInfo
                   ) -> Optional[MapLoop]:
    """Structural match of the loop body against the map / zip-with
    shapes (purely static)."""
    body = instrs[loop.start:loop.end + 1]
    lp = instrs[loop.pc]
    if len(body) == 5:
        ld_a, alu, st, add_j, add_i = body
        ld_b = None
    elif len(body) == 6:
        ld_a, ld_b, alu, st, add_j, add_i = body
    else:
        return None
    if not _plain_local_load(ld_a):
        return None
    if ld_b is not None:
        if not _plain_local_load(ld_b) or ld_b.b != ld_a.b \
                or ld_b.a == ld_a.a:
            return None
    if alu.op != Op.ALU or alu.d == int(Alu.ALWAYS):
        return None
    if alu.a != ld_a.dst:
        return None
    alu_imm: Optional[int] = None
    rhs_reg = -1
    if ld_b is not None:
        if (alu.flags & FLAG_IMMB) or alu.b != ld_b.dst:
            return None
    elif alu.flags & FLAG_IMMB:
        alu_imm = int(alu.imm)
    else:
        rhs_reg = alu.b
    if st.op != Op.STORE or st.imm != 0 or st.flags != 0 \
            or st.e != DEV_LOCAL:
        return None
    if st.dst != alu.dst:                        # stored value = ALU result
        return None
    if not _is_add_imm(add_j, st.b, 1):
        return None
    if not _is_add_imm(add_i, ld_a.b, 1):
        return None
    # dst window must not alias any src window (the fused gathers read a
    # pre-loop snapshot; distinct regions never alias)
    if st.a == ld_a.a or (ld_b is not None and st.a == ld_b.a):
        return None
    regs = [ld_a.b, st.b, ld_a.dst, alu.dst]
    if ld_b is not None:
        regs.append(ld_b.dst)
    if len(set(regs)) != len(regs):
        return None
    if rhs_reg >= 0 and rhs_reg in regs:         # rhs must be loop-invariant
        return None
    return MapLoop(
        loop_pc=loop.pc, cap=int(lp.imm), src_rid=ld_a.a,
        src2_rid=ld_b.a if ld_b is not None else -1, dst_rid=st.a,
        alu_op=int(alu.d), alu_imm=alu_imm, rhs_reg=rhs_reg,
        i_reg=ld_a.b, j_reg=st.b, a_reg=ld_a.dst,
        b_reg=ld_b.dst if ld_b is not None else -1, c_reg=alu.dst,
        is_zip=ld_b is not None)


def superop_report(op: VerifiedOperator) -> Dict[str, object]:
    """Which superoperators each loop of ``op`` matches, plus — when a
    loop matches nothing — the first structural reason the gather-chain
    matcher bailed (registry introspection; see ``registry.dump()``)."""
    instrs = isa.decode_program(op.code)
    matched: List[Tuple[str, int]] = []
    near_miss: Optional[str] = None
    for l in op.loops:
        g, reason = match_gather_chain_ex(instrs, l)
        if g is not None:
            matched.append(("gather_chain", l.pc))
            continue
        sr = match_scatter_reduce(instrs, l)
        if sr is not None:
            matched.append(("scatter_reduce", l.pc))
            continue
        ml = match_map_loop(instrs, l)
        if ml is not None:
            matched.append(("zip_loop" if ml.is_zip else "map_loop", l.pc))
            continue
        if near_miss is None:
            near_miss = f"pc {l.pc}: {reason}"
    return {"matched": matched, "near_miss": near_miss}


# ---------------------------------------------------------------------------
# The trace emitter
# ---------------------------------------------------------------------------

class _Tracer:
    """Emits the predicated straight-line trace of one verified operator.

    Mutable traced state: the 16 register lanes, the flattened shared
    memory pool, and the halt/ret/status/step accumulators.  Control flow
    exists only at Python time (the unroll), never in the lowered program.
    """

    def __init__(self, *, instrs, loops, base, mask, n_dev, pool_words,
                 batch, homes, failed, mem_flat, regs, impl, superops,
                 double_buffer=False, protect=True, check_failed=True,
                 noconflict=False):
        self.instrs = instrs
        self.loops = loops                  # pc -> LoopInfo
        self.base = base                    # static np arrays
        self.mask = mask
        self.n_dev = n_dev
        self.P = pool_words
        self.B = batch
        self.homes = homes                  # (B,) traced
        self.failed = failed                # (n_dev,) traced
        self.memf = mem_flat                # (n_dev * P,) traced
        self.regs = regs                    # list of 16 (B,) traced lanes
        self.impl = impl
        self.superops = superops
        self.double_buffer = double_buffer
        self.protect = protect
        self.check_failed = check_failed
        self.noconflict = noconflict
        zero = jnp.zeros(batch, jnp.int64)
        self.halted = jnp.zeros(batch, bool)
        self.ret = zero
        self.status = jnp.full(batch, isa.STATUS_FELL_OFF, jnp.int64)
        self.steps = zero
        # fault record: each faulting site appends its (B,) fault lanes
        # plus the runtime address (and device / chain-step, when not
        # static) to `pending`; a faulted lane halts, so at most one
        # site fires per lane and the whole record reduces to one fused
        # sum at trace finalization (`_finalize_fault`) — the hot path
        # pays no per-site selects at all.  `sites` is the static side
        # table the (pc, opcode, dev) columns are recovered from; its
        # last column is the site *kind* (0 plain instruction, 1 gather
        # chain, 2 scatter-reduce, 3 map loop, 4 zip loop — fused sites
        # recover pc/opcode from the latched body-instruction index).
        self.sites: List[Tuple[int, int, int, int]] = []
        self.pending: List[Tuple] = []

    # -- small helpers ---------------------------------------------------

    def _full(self, v) -> jnp.ndarray:
        return jnp.full(self.B, v, jnp.int64)

    def set_reg(self, idx: int, val, p) -> None:
        idx &= _REG_MASK
        self.regs[idx] = jnp.where(p, val, self.regs[idx])

    def dev_of(self, field: int, via_reg: bool) -> jnp.ndarray:
        if via_reg:
            d = self.regs[field & _REG_MASK]
            return jnp.where(d == DEV_LOCAL, self.homes,
                             jnp.mod(d, self.n_dev))
        if field == DEV_LOCAL:
            return self.homes
        return self._full(int(field) % self.n_dev)

    def word_addr(self, ins: Instr) -> jnp.ndarray:
        """LOAD/STORE/CAS/CAA all address ``region(a)[regs[b] + imm]``."""
        rid = ins.a
        off = self.regs[ins.b & _REG_MASK] + ins.imm
        return int(self.base[rid]) + (off & int(self.mask[rid]))

    # -- runtime protection ------------------------------------------------

    def _latch_fault(self, p, flt, pc, opcode, addr, dev=None):
        """Latch a protection fault on lanes ``p & flt``: halt them,
        record the static site index plus the runtime address (and the
        device, when it isn't statically known), and return the reduced
        predicate for the faulting instruction's own effects.  The
        (pc, opcode, dev) columns and STATUS_PROT_FAULT are recovered
        once at finalization — see `_finalize_fault`.

        ``dev``: None when the resolved device is statically the lane's
        home, an int when it is a static device id, or a traced (B,)
        array (kept live for the finalization reduction)."""
        f = p & flt
        self.halted = self.halted | f
        k = len(self.sites)
        if dev is None:
            devcol, dtr = _DEV_HOME, None
        elif isinstance(dev, int):
            devcol, dtr = dev, None
        else:
            devcol, dtr = _DEV_LATCHED, dev
        self.sites.append((pc, int(opcode), devcol, 0))
        self.pending.append((k, f, addr, dtr, None))
        return p ^ f

    def _word_fault(self, ins, p, pc):
        """Fault check shared by LOAD/STORE/CAS/CAA; mirrors pyvm's
        priority: wild device register, then out-of-region offset, then
        failed target device."""
        if not self.protect:
            return p
        via = bool(ins.flags & FLAG_DEV_REG)
        off = self.regs[ins.b & _REG_MASK] + ins.imm
        oob_off = off != (off & int(self.mask[ins.a]))
        if via:
            dev = self.dev_of(ins.e, True)
            draw = self.regs[ins.e & _REG_MASK]
            oob_dev = (draw != DEV_LOCAL) & ((draw < 0) | (draw >= self.n_dev))
            fdev = jnp.where(oob_dev, draw, dev)
            flt = oob_dev | oob_off
            if self.check_failed:
                flt = flt | self.failed[dev]
            return self._latch_fault(p, flt, pc, int(ins.op), off, fdev)
        flt = oob_off
        if self.check_failed:
            flt = flt | self.failed[self.dev_of(ins.e, False)]
        dev_static = None if ins.e == DEV_LOCAL \
            else int(ins.e) % self.n_dev
        return self._latch_fault(p, flt, pc, int(ins.op), off, dev_static)

    # -- per-opcode lowering ----------------------------------------------

    def _movi(self, ins, p):
        self.set_reg(ins.dst, self._full(ins.imm), p)

    def _alu(self, ins, p):
        rhs = self._full(ins.imm) if ins.flags & FLAG_IMMB \
            else self.regs[ins.b & _REG_MASK]
        self.set_reg(ins.dst, _alu_static(ins.d, self.regs[ins.a & _REG_MASK],
                                          rhs), p)

    def _load(self, ins, p, pc):
        p = self._word_fault(ins, p, pc)
        dev = self.dev_of(ins.e, bool(ins.flags & FLAG_DEV_REG))
        val = self.memf[dev * self.P + self.word_addr(ins)]
        self.set_reg(ins.dst, val, p)

    def _store(self, ins, p, pc):
        p = self._word_fault(ins, p, pc)
        dev = self.dev_of(ins.e, bool(ins.flags & FLAG_DEV_REG))
        idx = dev * self.P + self.word_addr(ins)
        self.memf = det_scatter(self.memf, idx,
                                self.regs[ins.dst & _REG_MASK], p)

    def _atomic(self, ins, p, pc, is_cas: bool):
        p = self._word_fault(ins, p, pc)
        dev = self.dev_of(ins.e, bool(ins.flags & FLAG_DEV_REG))
        idx = dev * self.P + self.word_addr(ins)
        cmpv = self.regs[ins.c & _REG_MASK]
        arg = self.regs[ins.d & _REG_MASK]
        size = self.memf.shape[0]

        def body(memf, x):
            i_b, cmp_b, arg_b, p_b = x
            old = memf[jnp.clip(i_b, 0, size - 1)]
            hit = (old == cmp_b) & p_b
            new = jnp.where(hit, arg_b if is_cas else old + arg_b, old)
            memf = memf.at[jnp.where(p_b, i_b, size)].set(new, mode="drop")
            return memf, old

        # atomics are serialized over the batch: pyvm request ordering
        self.memf, old = lax.scan(body, self.memf, (idx, cmpv, arg, p))
        self.set_reg(ins.dst, old, p)

    def _memcpy(self, ins, p, pc):
        via_d = bool(ins.flags & FLAG_DSTDEV_REG)
        via_s = bool(ins.flags & FLAG_SRCDEV_REG)
        ddev = self.dev_of(ins.dst, via_d)
        sdev = self.dev_of(ins.c, via_s)
        drid, srid = ins.a, ins.d
        cap = min(int(ins.imm), isa.MAX_MEMCPY_WORDS)
        if ins.flags & FLAG_LEN_REG:
            ln = jnp.clip(self.regs[ins.imm2 & _REG_MASK], 0, cap)
        else:
            ln = self._full(cap)
        ln = jnp.minimum(ln, min(int(self.mask[drid]) + 1,
                                 int(self.mask[srid]) + 1))
        if self.protect:
            doff0 = self.regs[ins.b & _REG_MASK]
            soff0 = self.regs[ins.e & _REG_MASK]
            dmask, smask = int(self.mask[drid]), int(self.mask[srid])

            def dev_oob(field, via):
                if not via:
                    return jnp.zeros(self.B, bool)
                d = self.regs[field & _REG_MASK]
                return (d != DEV_LOCAL) & ((d < 0) | (d >= self.n_dev))

            oob_dd = dev_oob(ins.dst, via_d)
            oob_sd = dev_oob(ins.c, via_s)
            d_oob = (doff0 != (doff0 & dmask)) | (doff0 + ln > dmask + 1)
            s_oob = (soff0 != (soff0 & smask)) | (soff0 + ln > smask + 1)
            flt = (ln > 0) & (oob_dd | oob_sd | d_oob | s_oob)
            faddr = jnp.where(oob_dd | (~oob_sd & d_oob), doff0, soff0)
            fdev = jnp.where(oob_dd, self.regs[ins.dst & _REG_MASK],
                             jnp.where(oob_sd, self.regs[ins.c & _REG_MASK],
                                       jnp.where(d_oob, ddev, sdev)))
            p = self._latch_fault(p, flt, pc, int(ins.op), faddr, fdev)
        if self.check_failed:
            fail = self.failed[ddev] | self.failed[sdev]
            err = self.regs[ERR_REG]
            self.regs[ERR_REG] = jnp.where(p & fail, err | 1, err)
            ln = jnp.where(fail | ~p, 0, ln)
        else:
            ln = jnp.where(p, ln, 0)
        iw = jnp.arange(cap, dtype=jnp.int64)[None, :]
        soff = self.regs[ins.e & _REG_MASK][:, None]
        doff = self.regs[ins.b & _REG_MASK][:, None]
        src = sdev[:, None] * self.P + int(self.base[srid]) + \
            ((soff + iw) & int(self.mask[srid]))
        dst = ddev[:, None] * self.P + int(self.base[drid]) + \
            ((doff + iw) & int(self.mask[drid]))
        vals = self.memf[src]
        live = iw < ln[:, None]
        self.memf = det_scatter(self.memf, dst, vals, live)

    # -- the gather-chain superoperator ------------------------------------

    def _fused_gather_chain(self, g: GatherChain, m, p) -> None:
        """One two-level batched gather for the whole loop: ids -> table ->
        pool rows -> destination window.  Commit order is (iteration,
        request) — identical to the lockstep engine.

        With ``double_buffer`` the iteration axis is split into
        ``DBUF_CHUNK``-sized chunks scheduled split-phase, the way the
        operator's *async* Memcpy issues on hardware: chunk ``k+1``'s
        row gather is emitted before chunk ``k``'s scatter, and every
        gather reads the pre-chain memory snapshot, so the two carry no
        data dependency and XLA is free to overlap transfer (scatter
        commit) with resolution (the next gather).  Bit-identical to the
        monolithic path by construction — the monolithic path *also*
        reads all rows pre-scatter."""
        B, P = self.B, self.P
        cap, W = g.cap, g.row_words
        jj = jnp.arange(cap, dtype=jnp.int64)[None, :]          # (1, cap)
        i0 = self.regs[g.i_reg][:, None]
        dst0 = self.regs[g.dst_reg][:, None]
        home = self.homes[:, None]
        valid = (jj < m[:, None]) & p[:, None]                  # (B, cap)

        ids_addr = int(self.base[g.ids_rid]) + \
            ((i0 + jj) & int(self.mask[g.ids_rid]))
        ids = self.memf[home * P + ids_addr]                    # (B, cap)
        tbl_addr = int(self.base[g.table_rid]) + \
            (ids & int(self.mask[g.table_rid]))
        paddr = self.memf[home * P + tbl_addr]                  # (B, cap)

        fail = self.failed[self.homes] if self.check_failed else None
        pool_base = int(self.base[g.pool_rid])
        pool_mask = int(self.mask[g.pool_rid])

        if self.protect:
            # Per-iteration fault scan: body instruction k in {1: load id,
            # 2: load translation, 3: memcpy row} can fault at iteration j;
            # the chain commits exactly the first j* iterations plus the
            # k*-1 committed instructions of iteration j*, mirroring the
            # un-fused engines instruction for instruction.
            dmask = int(self.mask[g.dst_rid])
            lnW = min(W, dmask + 1, pool_mask + 1)
            ids_off = i0 + jj                                   # raw (B, cap)
            doffs = dst0 + jj * W                               # raw (B, cap)
            c1 = ids_off != (ids_off & int(self.mask[g.ids_rid]))
            if fail is not None:
                c1 = fail[:, None] | c1
            c2 = ids != (ids & int(self.mask[g.table_rid]))
            d_oob = (doffs != (doffs & dmask)) | (doffs + lnW > dmask + 1)
            s_oob = (paddr != (paddr & pool_mask)) | \
                (paddr + lnW > pool_mask + 1)
            k_j = jnp.where(c1, 1, jnp.where(c2, 2,
                            jnp.where(d_oob | s_oob, 3, 0)))
            k_j = jnp.where(valid, k_j, 0)
            has = k_j > 0
            flt = jnp.any(has, axis=1)
            js = jnp.argmax(has, axis=1).astype(jnp.int64)
            jsc = js[:, None]
            kstar = jnp.take_along_axis(k_j, jsc, axis=1)[:, 0]
            a3 = jnp.where(jnp.take_along_axis(d_oob, jsc, axis=1)[:, 0],
                           jnp.take_along_axis(doffs, jsc, axis=1)[:, 0],
                           jnp.take_along_axis(paddr, jsc, axis=1)[:, 0])
            faddr = jnp.where(
                kstar == 1, jnp.take_along_axis(ids_off, jsc, axis=1)[:, 0],
                jnp.where(kstar == 2,
                          jnp.take_along_axis(ids, jsc, axis=1)[:, 0], a3))
            self.halted = self.halted | flt
            # body starts at pc+1, so pc of body instruction k* is
            # loop_pc + k* — recovered at finalization from the chain's
            # latched k* (the aux column of the pending record)
            k_site = len(self.sites)
            self.sites.append((g.loop_pc, 0, _DEV_HOME, 1))
            self.pending.append((k_site, flt, faddr, None, kstar))
            m_eff = jnp.where(flt, js, m)
            live = valid & (jj < m_eff[:, None])
        else:
            if fail is not None:
                err = self.regs[ERR_REG]
                self.regs[ERR_REG] = jnp.where(p & fail & (m > 0),
                                               err | 1, err)
                live = valid & ~fail[:, None]
            else:
                live = valid
            flt = jnp.zeros(self.B, bool)
            js = kstar = None
            m_eff = m
        iw = jnp.arange(W, dtype=jnp.int64)
        mem0 = self.memf              # pre-chain snapshot: all rows read it

        def gather_rows(pa):
            src = home[:, :, None] * P + pool_base + \
                ((pa[:, :, None] + iw) & pool_mask)     # (B, chunk, W)
            return mem0[src]

        dst_addr = home[:, :, None] * P + int(self.base[g.dst_rid]) + \
            ((dst0[:, :, None] + jj[:, :, None] * W + iw)
             & int(self.mask[g.dst_rid]))
        wmask = jnp.broadcast_to(live[:, :, None], dst_addr.shape)

        if self.double_buffer and cap > DBUF_CHUNK:
            # split-phase schedule: rows for chunk k+1 are gathered
            # before chunk k's scatter is emitted
            bounds = list(range(0, cap, DBUF_CHUNK)) + [cap]
            rows_next = gather_rows(paddr[:, bounds[0]:bounds[1]])
            for k in range(len(bounds) - 1):
                lo, hi = bounds[k], bounds[k + 1]
                rows_k = rows_next
                if k + 2 < len(bounds):
                    rows_next = gather_rows(
                        paddr[:, bounds[k + 1]:bounds[k + 2]])
                # commit chunk k in (iteration, request, word) order
                self.memf = det_scatter(
                    self.memf,
                    jnp.transpose(dst_addr[:, lo:hi], (1, 0, 2)),
                    jnp.transpose(rows_k, (1, 0, 2)),
                    jnp.transpose(wmask[:, lo:hi], (1, 0, 2)))
        else:
            if self.impl in ("kernel", "kernel_interpret") \
                    and self.n_dev == 1 and (pool_mask + 1) % W == 0:
                # Route the row gather through the Pallas double-
                # indirection kernel: rows must be W-aligned in the pool
                # region (true for every translation table the
                # workloads build).
                from repro.kernels.tiara_gather.kernel import \
                    tiara_gather_kernel
                pool_view = lax.dynamic_slice(
                    self.memf, (pool_base,),
                    (pool_mask + 1,)).reshape(-1, W)
                rows = tiara_gather_kernel(
                    pool_view,
                    ((paddr & pool_mask).reshape(-1) // W).astype(jnp.int32),
                    jnp.arange(B * cap, dtype=jnp.int32),
                    interpret=(self.impl == "kernel_interpret"),
                ).reshape(B, cap, W).astype(jnp.int64)
            else:
                rows = gather_rows(paddr)                # (B, cap, W)
            # commit in (iteration, request, word) order = round-robin
            self.memf = det_scatter(self.memf,
                                    jnp.transpose(dst_addr, (1, 0, 2)),
                                    jnp.transpose(rows, (1, 0, 2)),
                                    jnp.transpose(wmask, (1, 0, 2)))

        # architectural register effects of the executed iterations; a
        # faulted lane commits the loads that retired before the fault
        if self.protect:
            n_id = jnp.where(flt, js + (kstar >= 2).astype(jnp.int64), m)
            n_pa = jnp.where(flt, js + (kstar >= 3).astype(jnp.int64), m)
            steps_n = jnp.where(flt, js * 5 + kstar, m * 5)
        else:
            n_id = n_pa = m
            steps_n = m * 5
        self.set_reg(g.i_reg, self.regs[g.i_reg] + m_eff, p)
        self.set_reg(g.dst_reg, self.regs[g.dst_reg] + m_eff * W, p)
        self.set_reg(g.id_reg,
                     jnp.take_along_axis(
                         ids, jnp.clip(n_id - 1, 0, cap - 1)[:, None],
                         axis=1)[:, 0], p & (n_id > 0))
        self.set_reg(g.paddr_reg,
                     jnp.take_along_axis(
                         paddr, jnp.clip(n_pa - 1, 0, cap - 1)[:, None],
                         axis=1)[:, 0], p & (n_pa > 0))
        self.steps = self.steps + jnp.where(p, steps_n, 0)

    # -- the scatter-reduce superoperator ---------------------------------

    def _fused_scatter_reduce(self, sr: ScatterReduce, m, p) -> None:
        """One snapshot gather + elementwise compare + scatter-add for
        the whole CAA loop.  Exact only because every accumulator
        address is touched at most once: within a lane by the static
        ``|stride| * cap <= acc region size`` check (emit_segment), and
        across lanes by the ``noconflict`` wave proof the build asserts
        — so each CAA's ``old`` equals the pre-loop snapshot value and
        the conditional add commutes into one scatter-add."""
        B, P = self.B, self.P
        cap, s = sr.cap, sr.stride
        it = jnp.arange(cap, dtype=jnp.int64)[None, :]          # (1, cap)
        i0 = self.regs[sr.i_reg][:, None]
        j0 = self.regs[sr.j_reg][:, None]
        home = self.homes[:, None]
        valid = (it < m[:, None]) & p[:, None]                  # (B, cap)
        src_off = i0 + it
        acc_off = j0 + it * s
        src_mask = int(self.mask[sr.src_rid])
        acc_mask = int(self.mask[sr.acc_rid])

        if self.protect:
            # per-iteration fault scan: body instruction k in {1: load,
            # 2: caa} can fault at iteration j; commit exactly the first
            # j* iterations (a faulting CAA has zero effect).
            c1 = src_off != (src_off & src_mask)
            if self.check_failed:
                c1 = self.failed[self.homes][:, None] | c1
            c2 = acc_off != (acc_off & acc_mask)
            k_j = jnp.where(c1, 1, jnp.where(c2, 2, 0))
            k_j = jnp.where(valid, k_j, 0)
            has = k_j > 0
            flt = jnp.any(has, axis=1)
            js = jnp.argmax(has, axis=1).astype(jnp.int64)
            jsc = js[:, None]
            kstar = jnp.take_along_axis(k_j, jsc, axis=1)[:, 0]
            faddr = jnp.where(
                kstar == 1, jnp.take_along_axis(src_off, jsc, axis=1)[:, 0],
                jnp.take_along_axis(acc_off, jsc, axis=1)[:, 0])
            self.halted = self.halted | flt
            k_site = len(self.sites)
            self.sites.append((sr.loop_pc, 0, _DEV_HOME, 2))
            self.pending.append((k_site, flt, faddr, None, kstar))
            m_eff = jnp.where(flt, js, m)
        else:
            flt = jnp.zeros(B, bool)
            js = kstar = None
            m_eff = m
        live = valid & (it < m_eff[:, None])

        mem0 = self.memf             # pre-loop snapshot (exactness above)
        v = mem0[home * P + int(self.base[sr.src_rid])
                 + (src_off & src_mask)]                        # (B, cap)
        acc_addr = home * P + int(self.base[sr.acc_rid]) + \
            (acc_off & acc_mask)
        old = mem0[acc_addr]                                    # (B, cap)
        hit = (old == self.regs[sr.cmp_reg][:, None]) & live
        delta = jnp.where(hit, v, jnp.zeros((), jnp.int64))
        size = self.memf.shape[0]
        tgt = jnp.where(live, acc_addr, size)
        self.memf = self.memf.at[tgt].add(delta, mode="drop")

        if self.protect:
            n_v = jnp.where(flt, js + (kstar >= 2).astype(jnp.int64), m)
            steps_n = jnp.where(flt, js * 4 + kstar, m * 4)
        else:
            n_v = m
            steps_n = m * 4
        self.set_reg(sr.i_reg, self.regs[sr.i_reg] + m_eff, p)
        self.set_reg(sr.j_reg, self.regs[sr.j_reg] + m_eff * s, p)
        self.set_reg(sr.v_reg,
                     jnp.take_along_axis(
                         v, jnp.clip(n_v - 1, 0, cap - 1)[:, None],
                         axis=1)[:, 0], p & (n_v > 0))
        self.set_reg(sr.old_reg,
                     jnp.take_along_axis(
                         old, jnp.clip(m_eff - 1, 0, cap - 1)[:, None],
                         axis=1)[:, 0], p & (m_eff > 0))
        self.steps = self.steps + jnp.where(p, steps_n, 0)

    # -- the map / zip-with superoperator ---------------------------------

    def _fused_map_loop(self, ml: MapLoop, m, p) -> None:
        """Window gather(s) + one elementwise ALU + one deterministic
        scatter in (iteration, request) commit order.  The gathers read
        the pre-loop snapshot; the matcher requires the destination
        region to differ from every source region, so within a lane no
        store feeds a later load, and across lanes the compiled path's
        standing no-conflict assumption applies (same class as the
        gather chain and plain STORE lowering)."""
        B, P = self.B, self.P
        cap = ml.cap
        body_len = 6 if ml.is_zip else 5
        it = jnp.arange(cap, dtype=jnp.int64)[None, :]          # (1, cap)
        i0 = self.regs[ml.i_reg][:, None]
        j0 = self.regs[ml.j_reg][:, None]
        home = self.homes[:, None]
        valid = (it < m[:, None]) & p[:, None]
        src_off = i0 + it
        dst_off = j0 + it
        src_mask = int(self.mask[ml.src_rid])
        dst_mask = int(self.mask[ml.dst_rid])
        src2_mask = int(self.mask[ml.src2_rid]) if ml.is_zip else 0
        store_k = 4 if ml.is_zip else 3

        if self.protect:
            c1 = src_off != (src_off & src_mask)
            if self.check_failed:
                c1 = self.failed[self.homes][:, None] | c1
            c2 = (src_off != (src_off & src2_mask)) if ml.is_zip \
                else jnp.zeros_like(c1)
            c_st = dst_off != (dst_off & dst_mask)
            k_j = jnp.where(c1, 1, jnp.where(c2, 2,
                            jnp.where(c_st, store_k, 0)))
            k_j = jnp.where(valid, k_j, 0)
            has = k_j > 0
            flt = jnp.any(has, axis=1)
            js = jnp.argmax(has, axis=1).astype(jnp.int64)
            jsc = js[:, None]
            kstar = jnp.take_along_axis(k_j, jsc, axis=1)[:, 0]
            faddr = jnp.where(
                kstar == store_k,
                jnp.take_along_axis(dst_off, jsc, axis=1)[:, 0],
                jnp.take_along_axis(src_off, jsc, axis=1)[:, 0])
            self.halted = self.halted | flt
            k_site = len(self.sites)
            self.sites.append((ml.loop_pc, 0, _DEV_HOME,
                               4 if ml.is_zip else 3))
            self.pending.append((k_site, flt, faddr, None, kstar))
            m_eff = jnp.where(flt, js, m)
        else:
            flt = jnp.zeros(B, bool)
            js = kstar = None
            m_eff = m
        live = valid & (it < m_eff[:, None])

        mem0 = self.memf
        a_vals = mem0[home * P + int(self.base[ml.src_rid])
                      + (src_off & src_mask)]                   # (B, cap)
        if ml.is_zip:
            b_vals = mem0[home * P + int(self.base[ml.src2_rid])
                          + (src_off & src2_mask)]
            rhs = b_vals
        elif ml.alu_imm is not None:
            rhs = jnp.full((B, cap), ml.alu_imm, jnp.int64)
            b_vals = None
        else:
            rhs = self.regs[ml.rhs_reg][:, None] + jnp.zeros(
                (B, cap), jnp.int64)
            b_vals = None
        c_vals = _alu_static(ml.alu_op, a_vals, rhs)
        dst_addr = home * P + int(self.base[ml.dst_rid]) + \
            (dst_off & dst_mask)
        # commit in (iteration, request) order = the engine's round robin
        self.memf = det_scatter(self.memf,
                                jnp.transpose(dst_addr, (1, 0)),
                                jnp.transpose(c_vals, (1, 0)),
                                jnp.transpose(live, (1, 0)))

        if self.protect:
            n_a = jnp.where(flt, js + (kstar >= 2).astype(jnp.int64), m)
            n_c = jnp.where(flt, js + (kstar >= store_k).astype(jnp.int64),
                            m)
            steps_n = jnp.where(flt, js * body_len + kstar, m * body_len)
        else:
            n_a = n_c = m
            steps_n = m * body_len
        self.set_reg(ml.i_reg, self.regs[ml.i_reg] + m_eff, p)
        self.set_reg(ml.j_reg, self.regs[ml.j_reg] + m_eff, p)
        self.set_reg(ml.a_reg,
                     jnp.take_along_axis(
                         a_vals, jnp.clip(n_a - 1, 0, cap - 1)[:, None],
                         axis=1)[:, 0], p & (n_a > 0))
        if ml.is_zip:
            n_b = jnp.where(flt, js + (kstar >= 3).astype(jnp.int64), m) \
                if self.protect else m
            self.set_reg(ml.b_reg,
                         jnp.take_along_axis(
                             b_vals, jnp.clip(n_b - 1, 0, cap - 1)[:, None],
                             axis=1)[:, 0], p & (n_b > 0))
        self.set_reg(ml.c_reg,
                     jnp.take_along_axis(
                         c_vals, jnp.clip(n_c - 1, 0, cap - 1)[:, None],
                         axis=1)[:, 0], p & (n_c > 0))
        self.steps = self.steps + jnp.where(p, steps_n, 0)

    # -- segment emission ---------------------------------------------------

    def emit_segment(self, lo: int, hi: int, pred) -> Dict[int, jnp.ndarray]:
        """Emit instructions [lo, hi) under ``pred``; returns the escape
        predicates {target_pc: lanes} for jumps leaving the segment."""
        escapes: Dict[int, jnp.ndarray] = {}
        resume: Dict[int, jnp.ndarray] = {}
        pc = lo
        while pc < hi:
            if pc in resume:
                pred = pred | resume.pop(pc)
            ins = self.instrs[pc]
            p = pred & ~self.halted

            if ins.op == Op.LOOP:
                l = self.loops[pc]
                body_hi = l.end + 1
                self.steps = self.steps + p      # LOOP itself runs once
                cap = int(ins.imm)
                if ins.flags & FLAG_MREG:
                    m = jnp.clip(self.regs[ins.b & _REG_MASK], 0, cap)
                else:
                    m = self._full(cap)
                g = match_gather_chain(self.instrs, l) if self.superops \
                    else None
                if g is not None:
                    self._fused_gather_chain(g, m, p)
                    pc = body_hi
                    continue
                if self.superops and cap > 0:
                    # scatter-reduce fusion is exact only under the wave
                    # conflict proof plus the static within-lane address-
                    # uniqueness check (see ScatterReduce docstring)
                    sr = match_scatter_reduce(self.instrs, l) \
                        if self.noconflict else None
                    if sr is not None and \
                            abs(sr.stride) * cap <= int(
                                self.mask[sr.acc_rid]) + 1:
                        self._fused_scatter_reduce(sr, m, p)
                        pc = body_hi
                        continue
                    ml = match_map_loop(self.instrs, l)
                    if ml is not None:
                        self._fused_map_loop(ml, m, p)
                        pc = body_hi
                        continue
                broken = jnp.zeros(self.B, bool)
                for it in range(cap):
                    it_pred = pred & (it < m) & ~broken
                    esc = self.emit_segment(l.start, body_hi, it_pred)
                    for tgt, ep in esc.items():
                        broken = broken | ep
                        pred = pred & ~ep
                        if tgt < hi:
                            resume[tgt] = resume.get(
                                tgt, jnp.zeros(self.B, bool)) | ep
                        else:
                            escapes[tgt] = escapes.get(
                                tgt, jnp.zeros(self.B, bool)) | ep
                pc = body_hi
                continue

            if ins.op == Op.JUMP:
                self.steps = self.steps + p
                if ins.d == int(Alu.ALWAYS):
                    take = p
                else:
                    lhs = self.regs[ins.a & _REG_MASK]
                    rhs = self._full(ins.imm) if ins.flags & FLAG_IMMB \
                        else self.regs[ins.b & _REG_MASK]
                    take = p & (_alu_static(ins.d, lhs, rhs) != 0)
                tgt = pc + 1 + ins.imm2
                pred = pred & ~take
                if tgt < hi:
                    resume[tgt] = resume.get(
                        tgt, jnp.zeros(self.B, bool)) | take
                else:
                    escapes[tgt] = escapes.get(
                        tgt, jnp.zeros(self.B, bool)) | take
                pc += 1
                continue

            self.steps = self.steps + p
            if ins.op in (Op.NOP, Op.WAIT):
                pass                     # WAIT has no functional effect
            elif ins.op == Op.MOVI:
                self._movi(ins, p)
            elif ins.op == Op.ALU:
                self._alu(ins, p)
            elif ins.op == Op.LOAD:
                self._load(ins, p, pc)
            elif ins.op == Op.STORE:
                self._store(ins, p, pc)
            elif ins.op == Op.MEMCPY:
                self._memcpy(ins, p, pc)
            elif ins.op == Op.CAS:
                self._atomic(ins, p, pc, True)
            elif ins.op == Op.CAA:
                self._atomic(ins, p, pc, False)
            elif ins.op == Op.RET:
                self.ret = jnp.where(p, self.regs[ins.a & _REG_MASK],
                                     self.ret)
                self.status = jnp.where(p, self._full(ins.imm), self.status)
                self.halted = self.halted | p
            else:
                raise CompileError(f"pc {pc}: unsupported opcode {ins.op}")
            pc += 1
        return escapes


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _finalize_fault(tracer: _Tracer):
    """Reduce the pending per-site fault lanes to (pc, opcode, addr,
    dev) rows and apply STATUS_PROT_FAULT once.  A faulting lane halts
    at its first fault, so the per-site lane masks are mutually
    exclusive and every latched column is a plain masked sum — one
    fused elementwise reduction here instead of per-site selects on
    the hot path.  (A latched fault also implies the lane halted
    before any RET could retire it, so the single status override is
    equivalent to a per-site status write.)"""
    B = tracer.B
    if not tracer.pending:
        none = jnp.zeros(B, jnp.int64)
        return tracer.status, jnp.stack([none - 1, none, none, none],
                                        axis=1)
    zero = jnp.zeros(B, jnp.int64)
    site, addr, devp, aux = zero, zero, zero, zero
    for k, f, a, d, x in tracer.pending:
        fi = f.astype(jnp.int64)
        site = site + fi * (k + 1)
        addr = addr + fi * a
        if d is not None:
            devp = devp + fi * d
        if x is not None:
            aux = aux + fi * x
    site = site - 1
    pc_t, op_t, dev_t, kind_t = (jnp.asarray(np.asarray(col, np.int64))
                                 for col in zip(*tracer.sites))
    sidx = jnp.maximum(site, 0)
    pcs, opv, devc = pc_t[sidx], op_t[sidx], dev_t[sidx]
    kind = kind_t[sidx]
    chain = kind != 0
    # fused sites latch the faulting body-instruction index k* in `aux`;
    # body starts at loop_pc + 1, so the faulting pc is loop_pc + k*,
    # and the opcode follows from the shape (gather chain: k*=3 is the
    # MEMCPY; scatter-reduce: k*=2 is the CAA; map/zip loop: the STORE
    # sits at k*=3/4; everything earlier is a LOAD)
    f_pc = jnp.where(chain, pcs + aux, pcs)
    fused_op = jnp.where(
        kind == 1, jnp.where(aux == 3, int(Op.MEMCPY), int(Op.LOAD)),
        jnp.where(kind == 2,
                  jnp.where(aux == 2, int(Op.CAA), int(Op.LOAD)),
                  jnp.where(aux == jnp.where(kind == 4, 4, 3),
                            int(Op.STORE), int(Op.LOAD))))
    f_op = jnp.where(chain, fused_op, opv)
    f_dev = jnp.where(devc == _DEV_LATCHED, devp,
                      jnp.where(devc == _DEV_HOME, tracer.homes, devc))
    faulted = site >= 0
    status = jnp.where(faulted, isa.STATUS_PROT_FAULT, tracer.status)
    fault = jnp.stack([jnp.where(faulted, f_pc, -1),
                       jnp.where(faulted, f_op, 0), addr,
                       jnp.where(faulted, f_dev, 0)], axis=1)
    return status, fault


def build_compiled(op: VerifiedOperator, regions: RegionTable,
                   n_devices: int, batch: int, *, impl: str = "xla",
                   superops: bool = True, double_buffer: bool = False,
                   protect: bool = True, check_failed: bool = True,
                   noconflict: bool = False,
                   unroll_limit: int = DEFAULT_UNROLL_LIMIT):
    """Trace-compile a verified operator; returns a jit-compiled
    ``f(mem, params, homes, failed) -> vm.VMResult`` with batched fields
    (the same signature as :func:`vm.build_batched_vm`).

    ``impl``: "xla" lowers the gather-chain superoperator to plain jnp
    gathers; "kernel" / "kernel_interpret" route row gathers through the
    ``tiara_gather`` Pallas kernel (rows must be row-aligned in the pool,
    which all stock translation tables are).

    ``double_buffer``: emit gather-chain superoperators as a chunked
    split-phase schedule (chunk k+1's row gather issued before chunk
    k's scatter — the compiled analogue of the operator's async Memcpy
    pipelining).  Bit-identical results; takes precedence over the
    kernel row-gather route for the chain.

    ``check_failed=False`` statically elides every failed-device check
    (the ``failed`` argument is accepted and ignored) — the variant the
    invoke path builds for the fault-free hot path, where no device is
    down and the per-op mask gather would be pure overhead.

    ``noconflict=True`` asserts the caller holds a registration-time
    proof (``access.prove_wave_noconflict``) that no word written by one
    request is touched by another in the waves this engine will run.
    It unlocks the scatter-reduce superoperator fusion, whose
    snapshot-read lowering is exact only under that proof.
    """
    reason = why_not_compilable(op, unroll_limit)
    if reason is not None:
        raise CompileError(reason)
    instrs = isa.decode_program(op.code)
    loops = {l.pc: l for l in op.loops}
    base, mask, _ = regions.as_arrays()
    n_instr = len(instrs)
    n_dev = int(n_devices)
    B = int(batch)

    def run(mem, params, homes, failed):
        mem = jnp.asarray(mem, jnp.int64)
        pool_words = mem.shape[1]
        homes = jnp.asarray(homes, jnp.int64).reshape(B)
        failed = jnp.asarray(failed, jnp.bool_)
        params = jnp.asarray(params, jnp.int64).reshape(B, -1)
        regs = [params[:, i] if i < params.shape[1]
                else jnp.zeros(B, jnp.int64)
                for i in range(isa.NUM_REGS)]
        tracer = _Tracer(
            instrs=instrs, loops=loops, base=base, mask=mask, n_dev=n_dev,
            pool_words=int(pool_words), batch=B, homes=homes, failed=failed,
            mem_flat=mem.reshape(-1), regs=regs, impl=impl,
            superops=superops, double_buffer=double_buffer, protect=protect,
            check_failed=check_failed, noconflict=noconflict)
        esc = tracer.emit_segment(0, n_instr, jnp.ones(B, bool))
        assert not esc, "verifier admitted a jump past the program end"
        status, fault = _finalize_fault(tracer)
        return _vm.VMResult(
            mem=tracer.memf.reshape(n_dev, pool_words),
            ret=tracer.ret, status=status, steps=tracer.steps,
            regs=jnp.stack(tracer.regs, axis=1), fault=fault)

    return jax.jit(run)


_COMPILED_CACHE: Dict = {}


def compiled_cached(op: VerifiedOperator, regions: RegionTable,
                    n_dev: int, batch: int, impl: str = "xla",
                    superops: bool = True, double_buffer: bool = False,
                    protect: bool = True,
                    failed: Optional[Set[int]] = None,
                    noconflict: bool = False) -> bool:
    """True iff the compiled trace for this (op, batch) is already
    built (see :func:`vm.engine_cached`).  ``failed`` mirrors the invoke
    argument: the fault-free hot path (``failed=None``) and the
    degraded-mode path compile to different variants."""
    return _vm.engine_key(op, regions, n_dev, batch, impl, superops,
                          double_buffer, bool(protect),
                          failed is not None,
                          bool(noconflict)) in _COMPILED_CACHE


def _cached_compiled(op: VerifiedOperator, regions: RegionTable, n_dev: int,
                     batch: int, impl: str, superops: bool,
                     double_buffer: bool = False, protect: bool = True,
                     check_failed: bool = True, noconflict: bool = False):
    key = _vm.engine_key(op, regions, n_dev, batch, impl, superops,
                         double_buffer, bool(protect), bool(check_failed),
                         bool(noconflict))
    fn = _COMPILED_CACHE.get(key)
    if fn is None:
        fn = build_compiled(op, regions, n_dev, batch, impl=impl,
                            superops=superops, double_buffer=double_buffer,
                            protect=protect, check_failed=check_failed,
                            noconflict=noconflict)
        _COMPILED_CACHE[key] = fn
    return fn


def invoke_compiled(op: VerifiedOperator, regions: RegionTable,
                    mem: np.ndarray, params: Sequence[Sequence[int]],
                    *, homes: Union[int, Sequence[int]] = 0,
                    failed: Optional[Set[int]] = None, impl: str = "xla",
                    superops: bool = True, double_buffer: bool = False,
                    protect: bool = True, noconflict: bool = False,
                    block: bool = True) -> "_vm.BatchedInvokeResult":
    """Numpy-in/numpy-out batched execution on the compiled fast path
    (same contract as :func:`vm.invoke_batched`).  ``failed=None``
    selects the variant with every failed-device check statically
    elided — the fault-free hot path pays nothing for the fencing.
    ``noconflict=True`` asserts the wave conflict proof (see
    :func:`build_compiled`)."""
    p, h = _vm._marshal_batch(params, homes)
    fn = _cached_compiled(op, regions, int(mem.shape[0]), p.shape[0],
                          impl, superops, double_buffer, protect,
                          check_failed=failed is not None,
                          noconflict=noconflict)
    return _vm.run_batched_fn(fn, mem, p, h, failed, block=block)
