"""Fault-injection harness — chaos plans for the endpoint and simulator.

Real RNIC stacks are validated by injecting faults at every layer —
cable pulls, CQE errors, doorbell losses — and checking that the
*semantics* (QP error states, flushed WQEs, containment) hold.  This
module is that harness for the software Tiara stack: a
:class:`FaultPlan` is a declarative, composable bundle of injections
that :meth:`~repro.core.endpoint.TiaraEndpoint.inject` applies to the
live endpoint:

  * ``fail_devices``    mark pool devices failed.  Word ops targeting a
                        failed device take a runtime protection fault
                        (``STATUS_PROT_FAULT``); a Memcpy touching one
                        sets the error register and drops the copy — the
                        paper's §3.2 degraded mode.
  * ``corrupt``         overwrite pool words *before the next wave*
                        (device, word, value) — stale block-table
                        entries, torn pointers: the wild-address seeds
                        the runtime protection checks exist to catch.
  * ``transient_launch_failures``
                        the next N doorbell launches raise
                        :class:`TransientError` before dispatch — a
                        lost doorbell / launch-queue hiccup.  The
                        endpoint's bounded retry-with-backoff absorbs up
                        to its ``retry_limit``.
  * ``poison_materialize``
                        the next N deferred-wave materializations raise
                        :class:`InjectedEngineError` — a split-phase
                        launch that dies *after* issue.  Retirement must
                        leave the wave queued so a later wait retries it
                        (no lost CQEs, no double delivery).
  * ``delay_waves``     charge the next N doorbell launches the given
                        extra seconds (through the endpoint's injectable
                        ``sleep`` hook, so virtual clocks make it free) —
                        a slow NIC / congested launch queue.  Overload
                        tests use it to age queued work past deadlines.
  * ``stall_tenants``   withhold the named tenants' posts from doorbell
                        drains for the given duration (endpoint clock) —
                        a stalled QP / paused scheduler.  Their posts sit
                        in the SQ aging; the serving loop's deadlines and
                        load shedding must degrade them deterministically
                        instead of wedging the wave pipeline.

Plans compose with ``+`` so a chaos test can pile independent failures
into one injection.  The plan itself is immutable; the endpoint copies
its counters/lists at injection time.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, Iterable, Tuple


class TransientError(Exception):
    """A launch-time failure that a retry may cure (lost doorbell)."""


class InjectedEngineError(Exception):
    """A deferred engine failure injected at materialization time."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One composable bundle of fault injections (see module docstring).

    ``corrupt`` entries are ``(device, word_index, value)`` triples
    applied to the raw pool (absolute word index, not region-relative)
    immediately before the next wave dispatches.
    """

    fail_devices: FrozenSet[int] = frozenset()
    corrupt: Tuple[Tuple[int, int, int], ...] = ()
    transient_launch_failures: int = 0
    poison_materialize: int = 0
    delay_waves: Tuple[float, ...] = ()
    stall_tenants: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "fail_devices",
                           frozenset(int(d) for d in self.fail_devices))
        object.__setattr__(
            self, "corrupt",
            tuple((int(d), int(w), int(v)) for d, w, v in self.corrupt))
        object.__setattr__(
            self, "delay_waves",
            tuple(float(d) for d in self.delay_waves))
        object.__setattr__(
            self, "stall_tenants",
            tuple((str(t), float(s)) for t, s in self.stall_tenants))
        if self.transient_launch_failures < 0 or self.poison_materialize < 0:
            raise ValueError("fault counters must be non-negative")
        if any(d < 0 for d in self.delay_waves) or \
                any(s < 0 for _, s in self.stall_tenants):
            raise ValueError("fault durations must be non-negative")

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        if not isinstance(other, FaultPlan):
            return NotImplemented
        return FaultPlan(
            fail_devices=self.fail_devices | other.fail_devices,
            corrupt=self.corrupt + other.corrupt,
            transient_launch_failures=(self.transient_launch_failures
                                       + other.transient_launch_failures),
            poison_materialize=(self.poison_materialize
                                + other.poison_materialize),
            delay_waves=self.delay_waves + other.delay_waves,
            stall_tenants=self.stall_tenants + other.stall_tenants)

    @property
    def empty(self) -> bool:
        return (not self.fail_devices and not self.corrupt
                and self.transient_launch_failures == 0
                and self.poison_materialize == 0
                and not self.delay_waves
                and not self.stall_tenants)


def fail_devices(*devices: int) -> FaultPlan:
    return FaultPlan(fail_devices=frozenset(devices))


def corrupt_words(entries: Iterable[Tuple[int, int, int]]) -> FaultPlan:
    return FaultPlan(corrupt=tuple(entries))


def drop_doorbells(n: int) -> FaultPlan:
    return FaultPlan(transient_launch_failures=n)


def poison_materialize(n: int = 1) -> FaultPlan:
    return FaultPlan(poison_materialize=n)


def delay_waves(*seconds: float) -> FaultPlan:
    """Charge the next ``len(seconds)`` doorbell launches the given extra
    delays, in order (a congested launch queue / slow NIC)."""
    return FaultPlan(delay_waves=tuple(seconds))


def stall_tenant(tenant: str, seconds: float) -> FaultPlan:
    """Withhold ``tenant``'s posts from doorbell drains for ``seconds``
    of endpoint-clock time starting at injection."""
    return FaultPlan(stall_tenants=((tenant, seconds),))
