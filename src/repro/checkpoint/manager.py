"""Checkpointing: step-atomic, async, retention, reshard-on-load.

Layout:  <dir>/step_<n>/arrays.npz + tree.json + data_state.json
Writes go to a temp directory renamed into place (a crash mid-save never
corrupts the latest checkpoint).  Arrays are saved device-agnostic (full
host values); restore applies the *target* mesh's shardings, so a run may
resume on a different pod count (elastic re-scale) — the reshard is just a
different ``device_put``.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, List, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"n:{p.name}"
    return f"r:{p}"


def save(tree: Any, directory: str, step: int, *,
         extra: Optional[dict] = None) -> str:
    """Synchronous atomic save; returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "n_arrays": len(arrays), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncSaver:
    """Backgrounds the host-side write; at most one save in flight."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, tree, directory: str, step: int,
             extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            self.last_path = save(host_tree, directory, step, extra=extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1))
             for name in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", name))]
    return max(steps) if steps else None


def restore(target_like: Any, directory: str,
            step: Optional[int] = None, *,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_like``; if ``shardings`` is
    given (pytree of jax.sharding.Sharding or a callable leaf->sharding),
    arrays land sharded on the *current* mesh — elastic re-scale."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = jax.tree_util.tree_flatten_with_path(target_like)
    leaves, treedef = jax.tree_util.tree_flatten(target_like)
    out = []
    shard_leaves = None
    if shardings is not None and not callable(shardings):
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
    for i, (pathkeys, leaf) in enumerate(flat[0]):
        key = _SEP.join(_path_str(p) for p in pathkeys)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if hasattr(leaf, "dtype"):
            if arr.dtype.kind == "V":
                # npz stores ml_dtypes (bfloat16, ...) as raw void bytes;
                # reinterpret against the target leaf dtype
                arr = arr.view(leaf.dtype)
            else:
                arr = arr.astype(leaf.dtype)
        if shardings is None:
            out.append(jax.numpy.asarray(arr))
        else:
            sh = (shardings(leaf) if callable(shardings)
                  else shard_leaves[i])
            out.append(jax.device_put(arr, sh))
    return jax.tree_util.tree_unflatten(treedef, out)


def retain(directory: str, keep: int) -> List[str]:
    """Delete all but the newest ``keep`` checkpoints; returns removed."""
    if not os.path.isdir(directory):
        return []
    steps = sorted(int(m.group(1))
                   for name in os.listdir(directory)
                   if (m := re.fullmatch(r"step_(\d+)", name)))
    removed = []
    for s in steps[:-keep] if keep > 0 else []:
        p = os.path.join(directory, f"step_{s:08d}")
        shutil.rmtree(p)
        removed.append(p)
    return removed


def meta(directory: str, step: int) -> dict:
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)
