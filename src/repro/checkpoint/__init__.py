from repro.checkpoint import manager

__all__ = ["manager"]
