"""Training driver: checkpoint/restart, async saves, straggler watchdog.

Designed for the 1000-node posture: every piece of run state (model,
optimizer, step, data position) restores from disk; saves are atomic and
asynchronous; a per-step watchdog flags stragglers (steps slower than
``straggler_factor`` x the running median) and can trigger the configured
mitigation hook (re-dispatch / skip — on CPU we exercise the bookkeeping,
not real stragglers).  SIGINT/SIGTERM trigger a final synchronous save so
preemption never loses more than one step.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable, Dict, List, Optional

import jax

from repro.checkpoint import manager as ckpt
from repro.configs import ArchConfig
from repro.data.pipeline import DataConfig, LMPipeline
from repro.training.optimizer import AdamWConfig, warmup_cosine
from repro.training.train_step import TrainState, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    peak_lr: float = 3e-4
    warmup: int = 20
    micro_batches: int = 1
    state_bits: int = 32
    straggler_factor: float = 3.0
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig,
                 data_cfg: DataConfig, *,
                 grad_compressor: Optional[Callable] = None,
                 straggler_hook: Optional[Callable[[int, float], None]]
                 = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.pipeline = LMPipeline(data_cfg)
        opt_cfg = AdamWConfig(
            lr=warmup_cosine(tcfg.peak_lr, tcfg.warmup, tcfg.total_steps),
            state_bits=tcfg.state_bits)
        self._init_state, step_fn = make_train_step(
            cfg, opt_cfg, micro_batches=tcfg.micro_batches,
            grad_compressor=grad_compressor)
        self.train_step = jax.jit(step_fn, donate_argnums=(0,))
        self.saver = ckpt.AsyncSaver()
        self.straggler_hook = straggler_hook
        self.step_times: List[float] = []
        self.straggler_steps: List[int] = []
        self.metrics_log: List[Dict[str, float]] = []
        self._interrupted = False

    # -- lifecycle --------------------------------------------------------

    def init_or_restore(self) -> TrainState:
        key = jax.random.PRNGKey(self.tcfg.seed)
        state = self._init_state(key)
        d = self.tcfg.ckpt_dir
        if d is not None and ckpt.latest_step(d) is not None:
            state = ckpt.restore(state, d)
        return state

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._interrupted = True
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass    # not in main thread (tests)

    # -- main loop ----------------------------------------------------------

    def run(self, state: Optional[TrainState] = None) -> TrainState:
        self._install_signal_handlers()
        if state is None:
            state = self.init_or_restore()
        start = int(state.step)
        for step in range(start, self.tcfg.total_steps):
            t0 = time.monotonic()
            batch_np = self.pipeline.batch(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            state, metrics = self.train_step(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self._watchdog(step, dt)
            if step % self.tcfg.log_every == 0 or \
                    step == self.tcfg.total_steps - 1:
                metrics["step"] = step
                metrics["sec_per_step"] = dt
                self.metrics_log.append(metrics)
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} "
                      f"gnorm {metrics['grad_norm']:.3f} [{dt:.2f}s]",
                      flush=True)
            if self.tcfg.ckpt_dir and (
                    (step + 1) % self.tcfg.ckpt_every == 0):
                self.saver.save(state, self.tcfg.ckpt_dir, step + 1)
                self.saver.wait()
                ckpt.retain(self.tcfg.ckpt_dir, self.tcfg.keep_ckpts)
                self.pipeline.save_state(
                    f"{self.tcfg.ckpt_dir}/data_state.json", step + 1)
            if self._interrupted:
                print(f"interrupted at step {step}; saving and exiting",
                      flush=True)
                break
        if self.tcfg.ckpt_dir:
            self.saver.wait()
            ckpt.save(state, self.tcfg.ckpt_dir, int(state.step))
            self.pipeline.save_state(
                f"{self.tcfg.ckpt_dir}/data_state.json", int(state.step))
        return state

    # -- straggler mitigation --------------------------------------------

    def _watchdog(self, step: int, dt: float) -> None:
        self.step_times.append(dt)
        window = self.step_times[-32:]
        if len(window) >= 8:
            med = statistics.median(window)
            if dt > self.tcfg.straggler_factor * med:
                self.straggler_steps.append(step)
                if self.straggler_hook is not None:
                    self.straggler_hook(step, dt / med)
