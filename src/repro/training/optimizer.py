"""Optimizers: AdamW with fp32 state, and 8-bit block-quantized AdamW.

The 8-bit variant keeps both moments as int8 codes with per-block fp32
absmax scales (block = 256 elements).  On maverick-400B this is what
brings optimizer state under v5e HBM at 256 chips (see EXPERIMENTS.md
§Roofline); the quantization error is bounded by the blockwise absmax and
validated by a convergence test against fp32 Adam.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

QBLOCK = 256


# ---------------------------------------------------------------------------
# Blockwise int8 quantization
# ---------------------------------------------------------------------------


class Q8(NamedTuple):
    codes: jax.Array      # int8, original shape
    scales: jax.Array     # f32, x.shape[:-1] + (ceil(last/QBLOCK),)


def _last_blocks(n: int) -> int:
    return (n + QBLOCK - 1) // QBLOCK


def quantize8(x: jax.Array) -> Q8:
    """Blockwise int8 along the LAST axis only.  Shape-preserving per
    leading dim, so a sharded tensor quantizes shard-locally — a global
    flatten would force GSPMD to all-gather the whole tensor (measured:
    5.9 TiB/device on maverick-400B before this fix, EXPERIMENTS.md
    §Perf)."""
    *lead, n = x.shape
    nb = _last_blocks(n)
    pad = nb * QBLOCK - n
    blocks = jnp.pad(x.astype(jnp.float32),
                     [(0, 0)] * len(lead) + [(0, pad)])
    blocks = blocks.reshape(*lead, nb, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    codes = codes.astype(jnp.int8).reshape(*lead, nb * QBLOCK)
    codes = jax.lax.slice_in_dim(codes, 0, n, axis=len(lead))
    return Q8(codes=codes, scales=scale)


def dequantize8(q: Q8, shape) -> jax.Array:
    *lead, n = shape
    nb = _last_blocks(n)
    pad = nb * QBLOCK - n
    flat = jnp.pad(q.codes.astype(jnp.float32),
                   [(0, 0)] * len(lead) + [(0, pad)])
    vals = flat.reshape(*lead, nb, QBLOCK) * q.scales[..., None]
    vals = vals.reshape(*lead, nb * QBLOCK)
    return jax.lax.slice_in_dim(vals, 0, n, axis=len(lead))


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_bits: int = 32          # 32 or 8


class AdamWState(NamedTuple):
    count: jax.Array
    mu: any
    nu: any


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def make_adamw(cfg: AdamWConfig):
    """Returns (init_fn, update_fn).  update: (grads, state, params) ->
    (new_params, new_state, metrics)."""
    q8 = cfg.state_bits == 8

    # The second moment is quantized in the *sqrt domain*: linear int8
    # flushes small nu to zero inside high-dynamic-range blocks, which
    # explodes mu/sqrt(nu) (the reason bitsandbytes uses dynamic quant).
    def init(params) -> AdamWState:
        def zero(p):
            z = jnp.zeros(p.shape, jnp.float32)
            return quantize8(z) if q8 else z
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zero, params),
                          nu=jax.tree_util.tree_map(zero, params))

    def update(grads, state: AdamWState, params):
        gnorm = _global_norm(grads)
        if cfg.clip_norm is not None:
            scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: (g.astype(jnp.float32) * scale), grads)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        count = state.count + 1
        lr = cfg.lr(count)
        c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
        c2 = 1 - cfg.b2 ** count.astype(jnp.float32)

        def upd(p, g, mu, nu):
            if q8:
                mu = dequantize8(mu, p.shape)
                nu = jnp.square(dequantize8(nu, p.shape))
            mu = cfg.b1 * mu + (1 - cfg.b1) * g
            nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
            step = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            if q8:
                mu, nu = quantize8(mu), quantize8(jnp.sqrt(nu))
            return new_p, mu, nu

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_mu = tdef.flatten_up_to(state.mu)
        flat_nu = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, n) for p, g, m, n
               in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_mu = tdef.unflatten([o[1] for o in out])
        new_nu = tdef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(count, new_mu, new_nu), metrics

    return init, update
