from repro.training.optimizer import (AdamWConfig, make_adamw,
                                      warmup_cosine)
from repro.training.train_step import (TrainState, lm_loss,
                                       make_train_step)
from repro.training.trainer import Trainer, TrainerConfig

__all__ = [
    "AdamWConfig",
    "make_adamw",
    "warmup_cosine",
    "TrainState",
    "lm_loss",
    "make_train_step",
    "Trainer",
    "TrainerConfig",
]
