"""Train-step builder: loss, grad accumulation, remat, optional int8
gradient compression for the cross-pod all-reduce."""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ArchConfig
from repro.models import transformer as tf
from repro.training.optimizer import AdamWConfig, AdamWState, make_adamw


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: AdamWState


def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None,
            z_coef: float = 1e-4):
    """Next-token cross entropy + z-loss; logits f32 (B, S, V)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    zloss = z_coef * jnp.square(logz)
    per_tok = nll + zloss
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"nll": jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                    micro_batches: int = 1,
                    grad_compressor: Optional[Callable] = None):
    """Returns (init_state_fn, train_step_fn).

    ``grad_compressor``: optional fn(grads)->grads inserted between accum
    and the optimizer (e.g. the int8 all-reduce wrapper for the cross-pod
    hop; see repro.distributed.compression)."""
    opt_init, opt_update = make_adamw(opt_cfg)

    def init_state(key) -> TrainState:
        params = tf.init_params(cfg, key)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt=opt_init(params))

    def loss_fn(params, batch):
        out = tf.apply_model(params, cfg, batch, mode="train")
        loss, m = lm_loss(out.logits, batch["labels"],
                          batch.get("loss_mask"))
        return loss + out.aux_loss, {**m, "aux": out.aux_loss,
                                     "loss": loss}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one_micro(params, mbatch):
        (_, metrics), grads = grad_fn(params, mbatch)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict[str, Any]):
        params = state.params
        if micro_batches <= 1:
            grads, metrics = one_micro(params, batch)
        else:
            def reshape(x):
                return x.reshape((micro_batches,
                                  x.shape[0] // micro_batches)
                                 + x.shape[1:])
            mb = jax.tree_util.tree_map(reshape, batch)

            def body(acc, mbatch):
                grads, metrics = one_micro(params, mbatch)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_stack = lax.scan(body, zeros, mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / micro_batches, grads)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics_stack)
        if grad_compressor is not None:
            grads = grad_compressor(grads)
        new_params, new_opt, opt_metrics = opt_update(grads, state.opt,
                                                      params)
        metrics = {**metrics, **opt_metrics}
        return TrainState(step=state.step + 1, params=new_params,
                          opt=new_opt), metrics

    return init_state, train_step
