"""Roofline terms from the compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
    memory     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective = collective_bytes / (chips x 50e9 B/s per ICI link)

``cost_analysis`` provides FLOPs/bytes; collective bytes are NOT in it, so
we parse the (post-SPMD) HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) exposes remat/routing
waste via the MODEL/HLO ratio.

Hardware constants are TPU v5e-class per the assignment.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (~3 links usable per axis hop)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  bf16[16,4096,128]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?"
    r"\(?([a-z0-9\-\.]+\[[^\)]*)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum output-shape bytes of collective ops in (post-SPMD) HLO text.

    Counts each op once by its result shape (the payload that crosses the
    interconnect per participating device, up to the op's algorithmic
    factor — all-reduce moves ~2x in a ring; we report raw operand bytes
    and apply algorithm factors in the term computation)."""
    per_kind: Dict[str, int] = {}
    total = 0
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        total += nbytes
    return total, per_kind


def collective_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for kind in ("all-gather", "all-reduce", "reduce-scatter",
                 "all-to-all", "collective-permute"):
        # count op starts only (async pairs otherwise double-count)
        n = len(re.findall(rf"\b{kind}(?:-start)?\(", hlo_text))
        n_done = len(re.findall(rf"\b{kind}-done\(", hlo_text))
        out[kind] = max(n - n_done, 0) if n_done else n
    return out


# algorithmic on-wire factors per collective (ring algorithms), applied to
# the result-shape bytes parsed above
_ALGO_FACTOR = {
    "all-gather": 1.0,        # result is the gathered (full) buffer
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,    # input-sized traffic, result is the shard
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class Roofline:
    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_per_kind: Dict[str, int]
    model_flops: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        if not self.model_flops or not self.hlo_flops:
            return None
        return self.model_flops / self.hlo_flops

    def row(self) -> Dict:
        return {
            "name": self.name, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "coll_per_kind": self.coll_per_kind,
        }


def from_compiled(name: str, compiled, chips: int,
                  model_flops: Optional[float] = None,
                  hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):          # some backends return [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    raw, per_kind = collective_bytes(text)
    weighted = sum(_ALGO_FACTOR[k] * v for k, v in per_kind.items())
    return Roofline(name=name, chips=chips, hlo_flops=flops,
                    hlo_bytes=nbytes, coll_bytes=weighted,
                    coll_per_kind=per_kind, model_flops=model_flops)


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6 * N * D (dense) or 6 * N_active * D (MoE)
# ---------------------------------------------------------------------------


def model_flops(cfg, shape, n_params_total: int,
                n_params_active: int) -> float:
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        tokens = shape.global_batch          # one new token per sequence
        return 2.0 * n_params_active * tokens   # forward only
    if shape.kind == "prefill":
        return 2.0 * n_params_active * tokens
    return 6.0 * n_params_active * tokens


def count_active_params(cfg, params_or_shapes) -> Tuple[int, int]:
    """(total, active) parameter counts; active scales MoE expert blocks
    by top_k/n_experts (+ shared expert fully)."""
    import jax
    total = 0
    active = 0
    flat = jax.tree_util.tree_flatten_with_path(params_or_shapes)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "ffn" in keys and any(k in ("wi", "wg", "wo") for k in keys) \
                and "shared" not in keys:
            # stacked expert tensors (E, ...) on a MoE layer
            moe_specs = [s.moe for s in cfg.pattern if s.moe is not None]
            if moe_specs and len(leaf.shape) >= 3:
                spec = moe_specs[0]
                n = n * spec.top_k // spec.n_experts
        active += n
    return total, active
