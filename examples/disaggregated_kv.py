"""Disaggregated KV over a simulated multi-node pool: the paper's §4.6
PagedAttention workload end to end, functional + timed.

A 4-node memory pool holds a paged KV cache; a compute node resolves
logical block ids through each node's Block Table with ONE Tiara
invocation per node, and the blocks stream straight back to the client
(remote-reply Memcpy).  Compare against stop-and-wait RDMA and optimally
batched RDMA.

    PYTHONPATH=src python examples/disaggregated_kv.py
"""

import numpy as np

from repro.core import costmodel as cm
from repro.core import simulator as sim
from repro.core.endpoint import TiaraEndpoint
from repro.core import operators as ops

N_NODES = 4
BLOCK_BYTES = 8192
BLOCKS_PER_NODE = 64
REQ_BLOCKS = 160          # the paper's LLaMA3-70B request: 160 blocks


def main() -> None:
    k = ops.PagedKVFetch(n_blocks_pool=BLOCKS_PER_NODE,
                         block_bytes=BLOCK_BYTES,
                         max_req_blocks=REQ_BLOCKS)

    # devices 0..N-1 = memory nodes, device N = the compute node
    # (client); one endpoint owns the whole multi-node pool
    ep, sessions = TiaraEndpoint.for_tenants([("kv", k.regions())],
                                             n_devices=N_NODES + 1)
    sess = sessions["kv"]
    op_id = sess.register(k.build(sess.view, remote_reply=True))
    vop = ep.registry[op_id].verified
    for d in range(N_NODES):
        k.populate(sess.pool, sess.view, device=d, seed=d)

    rng = np.random.default_rng(0)
    want = rng.integers(0, N_NODES * BLOCKS_PER_NODE, REQ_BLOCKS)
    total_us = 0.0
    fetched = 0
    for node in range(N_NODES):
        ids = [int(b % BLOCKS_PER_NODE) for b in want
               if b // BLOCKS_PER_NODE == node][:REQ_BLOCKS]
        if not ids:
            continue
        k.make_request(sess.pool, sess.view, ids, device=node)
        res = sess.trace(op_id, [len(ids), N_NODES], home=node)
        assert res.status == 0 and res.ret == len(ids)
        ts = sim.simulate_task(vop, res.trace, pipelined=True,
                               serial_chain=False)
        total_us = max(total_us, ts.latency_us)   # nodes work in parallel
        fetched += len(ids)
        print(f"node {node}: {len(ids):3d} blocks in one invocation "
              f"({ts.latency_us:7.1f} us, "
              f"wire {ts.wire_bytes / 1e6:.2f} MB)")

    payload = fetched * BLOCK_BYTES
    saw = 160 * cm.DEFAULT_HW.rtt_us + payload / cm.DEFAULT_HW.wire_bytes_per_us
    batched = payload / cm.batched_rdma_gather_gbs(payload, BLOCK_BYTES) / 1e3
    print(f"\nfetched {fetched} blocks = {payload / 2**20:.1f} MiB")
    print(f"  tiara (parallel nodes, 1 invocation each): {total_us:9.1f} us")
    print(f"  stop-and-wait RDMA (as deployed, Table 1): {saw:9.1f} us")
    print(f"  optimally batched RDMA (2 RTTs + WR build): {batched:9.1f} us")
    print(f"  -> {saw / total_us:.1f}x over stop-and-wait, "
          f"{batched / total_us:.2f}x over batched")


if __name__ == "__main__":
    main()
