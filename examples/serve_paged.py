"""Serve a small model with batched requests through the paged-KV engine
(continuous batching, Tiara paged-attention decode path).

    PYTHONPATH=src python examples/serve_paged.py --requests 8
"""

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config, reduce_config
from repro.models import transformer as tf
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full 110M tiny-lm (slower on CPU)")
    args = ap.parse_args()

    cfg = get_config("tiny-lm")
    if not args.full_size:
        cfg = reduce_config(cfg)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_slots=args.slots, max_seq=128,
                           temperature=args.temperature, eos_id=-1)

    rng = np.random.default_rng(0)
    sids = []
    for i in range(args.requests):
        prompt = list(rng.integers(1, cfg.vocab, 4 + i % 9))
        sids.append(engine.submit(prompt, max_new=args.max_new))
    print(f"submitted {len(sids)} requests into {args.slots} slots "
          f"({engine.allocator.n_pages} KV pages of {cfg.page_size} tokens)")

    t0 = time.time()
    steps = 0
    while not engine.finished():
        engine.step()
        steps += 1
        if steps % 8 == 0:
            act = sum(1 for s in engine.active if s)
            print(f"  step {steps}: active={act} waiting="
                  f"{len(engine.waiting)} page-util="
                  f"{engine.allocator.utilization():.0%}")
    dt = time.time() - t0
    out = engine.results()
    n_tok = sum(len(v) for v in out.values())
    print(f"\ngenerated {n_tok} tokens in {steps} engine steps "
          f"({dt:.1f}s, {n_tok / dt:.1f} tok/s on CPU)")
    for sid in sids[:4]:
        print(f"  seq {sid}: {out[sid]}")
    assert engine.allocator.free_pages == engine.allocator.n_pages, \
        "page leak!"


if __name__ == "__main__":
    main()
