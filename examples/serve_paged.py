"""Serve a small model with batched requests through the paged-KV engine
(continuous batching, Tiara paged-attention decode path).

    PYTHONPATH=src python examples/serve_paged.py --requests 8
    PYTHONPATH=src python examples/serve_paged.py --resolver tiara --homes 4

With ``--resolver tiara`` every decode step resolves its block tables by
posting PagedKVFetch operators from per-sequence sessions through the
ServingLoop (the disaggregated path); with ``--homes > 1`` the regions
shard over a device mesh and the INDIGO-style re-homing sweep migrates
hot regions toward their accessors (see the audit printed at the end).
"""

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config, reduce_config
from repro.models import transformer as tf
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--resolver", choices=("host", "tiara"),
                    default="host",
                    help="block-table resolution: local (host) or "
                         "posted through the endpoint (tiara)")
    ap.add_argument("--homes", type=int, default=1,
                    help="device-mesh rows homing the tiara regions")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full 110M tiny-lm (slower on CPU)")
    args = ap.parse_args()

    cfg = get_config("tiny-lm")
    if not args.full_size:
        cfg = reduce_config(cfg)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, max_slots=args.slots, max_seq=128,
        temperature=args.temperature, eos_id=-1,
        resolver=args.resolver, n_homes=args.homes,
        placement="auto" if args.homes > 1 else "single",
        rehome_every=2)

    rng = np.random.default_rng(0)
    handles = []
    for i in range(args.requests):
        prompt = list(rng.integers(1, cfg.vocab, 4 + i % 9))
        handles.append(engine.submit(prompt, max_new=args.max_new))
    print(f"submitted {len(handles)} requests into {args.slots} slots "
          f"({engine.allocator.n_pages} KV pages of {cfg.page_size} tokens, "
          f"resolver={args.resolver})")

    t0 = time.time()
    steps = 0
    while not engine.finished():
        engine.step()
        steps += 1
        if steps % 8 == 0:
            act = sum(1 for s in engine.active if s)
            print(f"  step {steps}: active={act} waiting="
                  f"{len(engine.waiting)} page-util="
                  f"{engine.allocator.utilization():.0%}")
    dt = time.time() - t0
    out = engine.results()
    n_tok = sum(len(v) for v in out.values())
    print(f"\ngenerated {n_tok} tokens in {steps} engine steps "
          f"({dt:.1f}s, {n_tok / dt:.1f} tok/s on CPU)")
    for h in handles[:4]:
        print(f"  seq {h.sid} [{'ok' if h.ok else h.status}]: "
              f"{out[h.sid]}")
    aud = engine.resolver_audit()
    if aud:
        print(f"resolver audit: {aud['waves']:.0f} waves, "
              f"{aud['rehomes']:.0f} rehomes "
              f"({aud['rehomed_words']:.0f} words moved), "
              f"cross-device reply words {aud['cross_device_words']:.0f}, "
              f"home skew {aud['home_skew']:.2f}")
    assert engine.allocator.free_pages == engine.allocator.n_pages, \
        "page leak!"


if __name__ == "__main__":
    main()
