"""Quickstart: write a Tiara operator, register it on an endpoint, post
work to your queue pair, ring the doorbell, poll the completion.

    PYTHONPATH=src python examples/quickstart.py

The surface mirrors an RNIC: a ``TiaraEndpoint`` owns the memory pool
and the dispatch table; ``connect()`` gives each tenant a ``Session``
(queue pair) with its regions and grant wired automatically;
``Session.post`` enqueues pre-registered operator invocations; one
``doorbell()`` drains every session's posts as a single batched wave.
"""

from repro.core import costmodel as cm
from repro.core import simulator as sim
from repro.core import operators as ops
from repro.core.endpoint import TiaraEndpoint
from repro.core.frontend import compile_source


def main() -> None:
    # A disaggregated memory node: a graph region and a reply region.
    w = ops.GraphWalk(n_nodes=4096, max_depth=64)

    # 1. Stand up the endpoint (it owns the pool) and connect: the
    #    tenant's regions, view, and grant are wired in one call.
    ep, sessions = TiaraEndpoint.for_tenants([("quickstart", w.regions())])
    sess = sessions["quickstart"]

    # 2. Write the operator in the restricted source subset (paper §3.3).
    program = compile_source('''
def walk(start, depth):
    cur = start
    for _ in bounded(depth, 64):
        cur = load("graph", cur + 1)     # the loaded value IS the next
    memcpy("reply", 0, "graph", cur, 8)  # address: register-chained loads
    return load("graph", cur)
''', regions=sess.view)
    print("compiled operator:")
    print(program.disassemble(), "\n")

    # 3. Register it: compile -> static verification against the
    #    session's grant -> op_id in the endpoint's dispatch table.
    op_id = sess.register(program)
    vop = ep.registry[op_id].verified
    print(f"registered as op {op_id}; proven step bound = "
          f"{vop.step_bound}, loop depth = {vop.max_loop_depth}\n")

    # 4. Populate the memory node and post work to the queue pair.  The
    #    doorbell drains the send queue as one wave; completions land in
    #    the session's completion queue.
    order = w.populate(sess.pool, sess.view)
    start, depth = int(order[0]) * 8, 24
    completion = sess.post("walk", [start, depth])
    ep.doorbell()
    (done,) = sess.poll_cq()
    assert done is completion and completion.done
    expect = w.reference(order, int(order[0]), depth)
    print(f"walk(depth={depth}) -> {completion.result()} "
          f"(reference {expect}, steps {completion.steps})")
    assert completion.result() == expect

    # ... or let the handle flush for you: result() rings the doorbell
    # if the post is still outstanding.
    assert sess.post("walk", [start, 12]).result() == \
        w.reference(order, int(order[0]), 12)

    # 5. What did it cost?  Cycle-level NIC timing vs one-sided RDMA
    #    (Session.trace replays the invocation on the pyvm oracle).
    trace = sess.trace("walk", [start, depth]).trace
    ts = sim.simulate_task(vop, trace)
    print(f"\nTiara:  {ts.latency_us:6.2f} us  (1 round trip + "
          f"{depth} local DMA hops)")
    print(f"RDMA:   {cm.rdma_chain_latency_us(depth):6.2f} us  "
          f"({depth} dependent round trips)")
    print(f"speedup: {cm.rdma_chain_latency_us(depth) / ts.latency_us:.2f}x"
          f"  (paper: 2.85x at depth 10)")


if __name__ == "__main__":
    main()
