"""Quickstart: write a Tiara operator, verify it, run it, time it.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import costmodel as cm
from repro.core import memory, pyvm, simulator as sim
from repro.core.frontend import compile_source
from repro.core.memory import Grant
from repro.core.registry import OperatorRegistry
from repro.core import operators as ops


def main() -> None:
    # A disaggregated memory node: a graph region and a reply region.
    w = ops.GraphWalk(n_nodes=4096, max_depth=64)
    regions = w.regions()

    # 1. Write the operator in the restricted source subset (paper §3.3).
    program = compile_source('''
def walk(start, depth):
    cur = start
    for _ in bounded(depth, 64):
        cur = load("graph", cur + 1)     # the loaded value IS the next
    memcpy("reply", 0, "graph", cur, 8)  # address: register-chained loads
    return load("graph", cur)
''', regions=regions)
    print("compiled operator:")
    print(program.disassemble(), "\n")

    # 2. Register it: compile -> static verification -> op_id.
    registry = OperatorRegistry(regions)
    registry.add_tenant(Grant.all_of(regions, "quickstart"))
    op_id = registry.register("quickstart", program)
    vop = registry[op_id].verified
    print(f"registered as op {op_id}; proven step bound = "
          f"{vop.step_bound}, loop depth = {vop.max_loop_depth}\n")

    # 3. Populate the memory node and invoke (one message, one reply).
    mem = memory.make_pool(1, regions)
    order = w.populate(mem, regions)
    start, depth = int(order[0]) * 8, 24
    result = registry.invoke(op_id, mem, [start, depth])
    expect = w.reference(order, int(order[0]), depth)
    print(f"walk(depth={depth}) -> {result.ret} "
          f"(reference {expect}, steps {result.steps})")
    assert result.ret == expect

    # 4. What did it cost?  Cycle-level NIC timing vs one-sided RDMA.
    trace = pyvm.run(vop, regions, mem.copy(), [start, depth],
                     record_trace=True).trace
    ts = sim.simulate_task(vop, trace)
    print(f"\nTiara:  {ts.latency_us:6.2f} us  (1 round trip + "
          f"{depth} local DMA hops)")
    print(f"RDMA:   {cm.rdma_chain_latency_us(depth):6.2f} us  "
          f"({depth} dependent round trips)")
    print(f"speedup: {cm.rdma_chain_latency_us(depth) / ts.latency_us:.2f}x"
          f"  (paper: 2.85x at depth 10)")


if __name__ == "__main__":
    main()
