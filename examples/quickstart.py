"""Quickstart: write a Tiara operator, register it on an endpoint, post
work to your queue pair, ring the doorbell, poll the completion.

    PYTHONPATH=src python examples/quickstart.py

The surface mirrors an RNIC: a ``TiaraEndpoint`` owns the memory pool
and the dispatch table; ``connect()`` gives each tenant a ``Session``
(queue pair) with its regions and grant wired automatically;
``Session.post`` enqueues pre-registered operator invocations; one
``doorbell()`` drains every session's posts as a single batched wave.
"""

from repro import jaxcompat
from repro.core import costmodel as cm
from repro.core import faults
from repro.core import simulator as sim
from repro.core import operators as ops
from repro.core import serving_loop as serving
from repro.core.endpoint import EndpointError, TiaraEndpoint
from repro.core.frontend import compile_source


def main() -> None:
    # A disaggregated memory node: a graph region and a reply region.
    w = ops.GraphWalk(n_nodes=4096, max_depth=64)

    # 1. Stand up the endpoint (it owns the pool) and connect: the
    #    tenant's regions, view, and grant are wired in one call.  With
    #    more than one device row the pool can later shard over a mesh
    #    (step 6); under XLA_FLAGS=--xla_force_host_platform_device_
    #    count=8 the mesh is 4 real host devices wide.
    n_dev = max(1, min(4, jaxcompat.device_count()))
    ep, sessions = TiaraEndpoint.for_tenants(
        [("quickstart", w.regions())], n_devices=n_dev)
    sess = sessions["quickstart"]

    # 2. Write the operator in the restricted source subset (paper §3.3).
    walk_src = '''
def walk(start, depth):
    cur = start
    for _ in bounded(depth, 64):
        cur = load("graph", cur + 1)     # the loaded value IS the next
    memcpy("reply", 0, "graph", cur, 8)  # address: register-chained loads
    return load("graph", cur)
'''
    program = compile_source(walk_src, regions=sess.view)
    print("compiled operator:")
    print(program.disassemble(), "\n")

    # 3. Register it: compile -> static verification against the
    #    session's grant -> op_id in the endpoint's dispatch table.
    op_id = sess.register(program)
    vop = ep.registry[op_id].verified
    print(f"registered as op {op_id}; proven step bound = "
          f"{vop.step_bound}, loop depth = {vop.max_loop_depth}")

    #    Registration also derives the operator's symbolic access
    #    footprint (core/access.py).  This walk chases loaded addresses,
    #    so its footprint is ⊤ ("could touch anywhere in the region")
    #    and its waves keep the runtime conflict sweep.  Operators with
    #    affine footprints get whole waves proven conflict-free at plan
    #    time instead — the sweep (and, sharded, a collective per step)
    #    is then compiled out; `ep.last_noconflict` after a doorbell
    #    says which way the last wave went, and
    #    `OperatorRegistry(static_analysis=False)` turns the proofs off.
    print("access analysis:", ep.registry[op_id].describe_analysis(),
          "\n")

    #    ... and its line-rate certificate (core/wcet.py): sound static
    #    upper bounds on worst-case cycles, word/wire traffic, and
    #    per-resource occupancy, with the statically predicted
    #    bottleneck.  The registry rejects operators whose certificate
    #    exceeds its Budget (eBPF-style, naming the offending pc), and
    #    the serving loop fail-fasts posts whose deadline is already
    #    below the certified WCET.
    cert = ep.registry[op_id].certificate
    print("line-rate certificate:", cert.describe())
    hot = cert.hottest("cycles")
    print(f"hottest site: pc {hot.pc} {hot.op} x{hot.count} "
          f"({hot.cycles:.0f} worst-case cycles)\n")

    # 4. Populate the memory node and post work to the queue pair.  The
    #    doorbell drains the send queue as one wave; completions land in
    #    the session's completion queue.
    order = w.populate(sess.pool, sess.view)
    start, depth = int(order[0]) * 8, 24
    completion = sess.post("walk", [start, depth])
    ep.doorbell()
    (done,) = sess.poll_cq()
    assert done is completion and completion.done
    expect = w.reference(order, int(order[0]), depth)
    print(f"walk(depth={depth}) -> {completion.result()} "
          f"(reference {expect}, steps {completion.steps})")
    assert completion.result() == expect

    # ... or let the handle flush for you: result() rings the doorbell
    # if the post is still outstanding.
    assert sess.post("walk", [start, 12]).result() == \
        w.reference(order, int(order[0]), 12)

    # 5. What did it cost?  Cycle-level NIC timing vs one-sided RDMA
    #    (Session.trace replays the invocation on the pyvm oracle).
    trace = sess.trace("walk", [start, depth]).trace
    ts = sim.simulate_task(vop, trace)
    print(f"\nTiara:  {ts.latency_us:6.2f} us  (1 round trip + "
          f"{depth} local DMA hops)")
    print(f"RDMA:   {cm.rdma_chain_latency_us(depth):6.2f} us  "
          f"({depth} dependent round trips)")
    print(f"speedup: {cm.rdma_chain_latency_us(depth) / ts.latency_us:.2f}x"
          f"  (paper: 2.85x at depth 10)")

    # 6. Sharded placement: the pool's device rows shard over a mesh and
    #    each device executes the posts whose `home` it owns — placement
    #    is a doorbell concern, the posts don't change.  Every wave is
    #    bit-identical to single-chip execution (and to the pyvm
    #    oracle), whatever the placement.
    orders = [w.populate(sess.pool, sess.view, device=d, seed=d)
              for d in range(n_dev)]
    wave = [sess.post("walk", [int(orders[d][0]) * 8, 12], home=d)
            for d in range(n_dev)]
    ep.doorbell(placement="sharded")
    print(f"\nsharded wave over {n_dev} device(s):")
    for d, c in enumerate(wave):
        expect = w.reference(orders[d], int(orders[d][0]), 12)
        assert c.result() == expect
        print(f"  home {d}: walk(depth=12) -> {c.ret}  (reference ok)")

    # 7. Split-phase pipelining: doorbell(wait=False) *launches* a wave
    #    and returns an in-flight WaveHandle immediately — post the next
    #    wave while the first is still computing (the pool dependency
    #    chains through XLA's async dispatch), then retire both with one
    #    wait_all().  Completions still arrive per-session FIFO, and
    #    each carries a CompletionEvent with its retire timestamp.
    wave1 = [sess.post("walk", [start, d]) for d in (6, 18)]
    h1 = ep.doorbell(wait=False)              # launched, NOT retired
    wave2 = [sess.post("walk", [start, d]) for d in (30, 42)]
    ep.doorbell(wait=False)                   # pipelined behind wave 1
    assert not wave1[0].done and ep.in_flight == 4
    n = ep.wait_all()                         # retires both, wave order
    print(f"\npipelined two-wave step: {n} completions retired "
          f"(wave {h1.wave_id} first)")
    for c, d in zip(wave1 + wave2, (6, 18, 30, 42)):
        assert c.result() == w.reference(orders[0], int(orders[0][0]), d)
        print(f"  walk(depth={d}) -> {c.ret}  "
              f"(wave {c.event.wave}, retired at {c.event.retired_at:.3f})")

    # 8. Fault model (RNIC semantics).  Every engine runs with runtime
    #    protection on: a wild pointer, out-of-region window, or access
    #    to a failed device halts JUST that lane with
    #    STATUS_PROT_FAULT, suppresses all its writes, and the CQE
    #    carries FaultInfo(pc, opcode, addr, device).  Like a QP, the
    #    owning session enters an error state — later posts retire
    #    STATUS_FLUSHED without executing — until reset().  Here we
    #    tear one next-pointer via the declarative fault-injection
    #    harness (`core/faults.py`; plans compose with `+`):
    ep.inject(faults.corrupt_words(
        [(0, sess.view["graph"].base + start + 1, -999_999)]))
    torn = sess.post("walk", [start, 4])
    ep.doorbell()
    assert torn.faulted and sess.in_error
    print(f"\ntorn pointer -> {torn.fault}")
    flushed = sess.post("walk", [start, 4])     # QP in error: flushed
    assert flushed.flushed
    try:
        torn.result()                           # result() surfaces it
    except EndpointError as e:
        print(f"result() raised: {e}")
    sess.reset()                                # error state is sticky
    w.populate(sess.pool, sess.view, device=0, seed=0)   # heal the ring
    healed = sess.post("walk", [start, 12])
    ep.doorbell()
    assert healed.ok
    print(f"after reset + repair: walk(depth=12) -> {healed.result()}")

    # 9. Overload-safe serving.  Production callers don't ring the
    #    doorbell by hand: `ServingLoop` wraps the split-phase surface
    #    with admission control (per-tenant token buckets + weighted
    #    fair queueing), a continuous batcher (ring on size, head age,
    #    or cost-model launch efficiency), bounded in-flight waves,
    #    per-post deadlines, and load shedding.  Every submitted post
    #    retires exactly one CQE: executed, or STATUS_EAGAIN
    #    (reject/shed), STATUS_TIMEOUT (expired before launch),
    #    STATUS_FLUSHED (QP in error).  On a VirtualClock the whole
    #    run — including the injected overload below — is
    #    deterministic.
    vc = serving.VirtualClock()
    ep2, tenants = TiaraEndpoint.for_tenants(
        [("gold", w.regions()), ("econ", w.regions())],
        clock=vc, sleep=vc.sleep)
    starts, refs = {}, {}
    for name, s in tenants.items():
        s.register(compile_source(walk_src, regions=s.view))
        torder = w.populate(s.pool, s.view)
        starts[name] = int(torder[0]) * 8
        refs[name] = torder
    loop = serving.ServingLoop(
        ep2,
        serving.ServingConfig(ring_size=4, ring_age_s=0.002,
                              max_inflight_waves=2, max_pending=8,
                              shed_watermark=12,
                              default_deadline_s=0.05,
                              opportunistic_poll=False),
        qos={"gold": serving.TenantQoS(weight=2.0),
             "econ": serving.TenantQoS(weight=1.0, rate=300.0,
                                       burst=2)})
    # the injected overload: every wave is slowed by 20 ms of NIC
    # delay and "econ" is stalled outright for 60 ms — longer than
    # the 50 ms deadline, so its queued posts age out deterministically
    ep2.inject(faults.delay_waves(0.02) + faults.stall_tenant("econ", 0.06))
    posts = []
    for i in range(24):
        tenant = "gold" if i % 3 else "econ"
        depth = 6 + (i % 4)
        posts.append((tenant, depth,
                      loop.submit(tenant, "walk",
                                  [starts[tenant], depth])))
        loop.pump()
    loop.drain()
    ep2.clear_faults()
    st = loop.stats
    print(f"\nserving under injected overload ({st.submitted} posts):")
    print(f"  executed {st.executed} (ok {st.ok}), timed out "
          f"{st.timed_out}, rejected {st.rejected}, shed {st.shed}")
    # exactly one terminal outcome per submitted post ...
    assert st.submitted == (st.executed + st.flushed + st.timed_out
                            + st.rejected + st.shed)
    # ... the fabric kept serving, and the overload actually bit
    assert st.executed > 0 and st.timed_out + st.rejected + st.shed > 0
    for tenant, depth, c in posts:
        if c.ok:
            assert c.ret == w.reference(
                refs[tenant], int(refs[tenant][0]), depth)

    # 10. End-to-end disaggregated decode.  The serving engine's
    #     "tiara" resolver puts the whole stack behind a model: each
    #     decode lane is a session (queue pair) whose block table and
    #     KV-pool descriptors live on the endpoint, and every decode
    #     step posts a PagedKVFetch per active sequence through a
    #     ServingLoop — the operator's remote-reply MEMCPY streams the
    #     resolved block-table row to the client device the next
    #     decode consumes.  Output is bit-identical to the local
    #     resolver; the INDIGO-style re-homing sweep migrates hot
    #     regions toward their accessors while it serves.
    import jax
    from repro.configs import get_config, reduce_config
    from repro.models import transformer as tf
    from repro.serving import ServingEngine

    cfg = reduce_config(get_config("tiny-lm"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[5, 9, 13, 2], [3, 1, 4, 1, 5]]
    outs = {}
    for resolver in ("host", "tiara"):
        eng = ServingEngine(cfg, params, max_slots=2, max_seq=64,
                            temperature=0.0, eos_id=-1,
                            resolver=resolver, n_homes=2,
                            placement="auto", rehome_every=2)
        handles = [eng.submit(p, max_new=4) for p in prompts]
        outs[resolver] = eng.run_to_completion()
        assert all(h.ok for h in handles)
        if resolver == "tiara":
            aud = eng.resolver_audit()
            print(f"\n2-session tiara-resolved decode: "
                  f"{sum(len(v) for v in outs['tiara'].values())} tokens "
                  f"over {aud['waves']:.0f} fabric waves, "
                  f"{aud['rehomes']:.0f} rehomes, cross-device words "
                  f"{aud['cross_device_words']:.0f}")
    assert outs["tiara"] == outs["host"], "disaggregated decode diverged"
    print("tiara resolver output is bit-identical to host resolve")


if __name__ == "__main__":
    main()
