"""End-to-end training driver: ~110M-parameter tiny-lm for a few hundred
steps on the synthetic (learnable) stream, with checkpoint/restart.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 300
    PYTHONPATH=src python examples/train_tiny_lm.py --steps 400   # resumes
"""

import argparse

from repro.configs import get_config
from repro.data import DataConfig
from repro.training import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/tiny_lm_ckpt")
    ap.add_argument("--state-bits", type=int, default=32, choices=[8, 32])
    args = ap.parse_args()

    cfg = get_config("tiny-lm")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    tcfg = TrainerConfig(total_steps=args.steps, log_every=10,
                         ckpt_every=50, ckpt_dir=args.ckpt,
                         peak_lr=args.lr, warmup=30,
                         state_bits=args.state_bits)
    trainer = Trainer(cfg, tcfg, dcfg)
    state = trainer.run()
    first = trainer.metrics_log[0]["loss"] if trainer.metrics_log else None
    last = trainer.metrics_log[-1]["loss"] if trainer.metrics_log else None
    print(f"\ndone at step {int(state.step)}: loss {first:.3f} -> {last:.3f}"
          f"  (stragglers flagged: {len(trainer.straggler_steps)})")


if __name__ == "__main__":
    main()
