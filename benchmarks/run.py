"""Benchmark harness — one module per paper table/figure.

Prints the required ``name,us_per_call,derived`` CSV followed by
human-readable comparison tables (derived vs. the paper's claimed value
with the ratio).  The Tiara side is the cycle-level MP simulator replaying
verified-operator traces; baselines are the paper's analytical models.

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig10] [--json out]
"""

from __future__ import annotations

import argparse
import contextlib
import inspect
import json
import os
import signal
import sys
import threading
import time
import traceback
import warnings

from benchmarks import (bench_async_overlap, bench_e2e_paged,
                        bench_fault_overhead, bench_graph, bench_lock,
                        bench_mixed_batch, bench_moe, bench_offload,
                        bench_paged_attention, bench_ptw, bench_serving,
                        bench_sharded, bench_static_analysis,
                        bench_table1, bench_vm_throughput, bench_wcet)
from benchmarks._workbench import fmt_table

# Per-module wall-clock budget: one hung bench (an XLA compile gone
# quadratic, a deadlocked wait) must report as a module failure instead
# of eating the CI job's whole 45-minute budget.  0 disables the alarm.
MODULE_TIMEOUT_S = int(os.environ.get("BENCH_MODULE_TIMEOUT_S", "900"))


class ModuleTimeout(Exception):
    pass


@contextlib.contextmanager
def _deadline(seconds: int, key: str):
    """SIGALRM-based wall-clock cap around one module (main thread,
    POSIX only — a no-op where SIGALRM is unavailable).  ``signal()``
    raises ``ValueError`` off the main thread (e.g. the harness driven
    from a worker thread of an embedding process), so warn and run
    uncapped instead of crashing every module."""
    if seconds <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return
    if threading.current_thread() is not threading.main_thread():
        warnings.warn(
            f"benchmark module {key!r}: SIGALRM timeout unavailable off "
            f"the main thread; running without a wall-clock cap",
            RuntimeWarning, stacklevel=2)
        yield
        return

    def _fire(signum, frame):
        raise ModuleTimeout(
            f"benchmark module {key!r} exceeded {seconds}s "
            f"(BENCH_MODULE_TIMEOUT_S)")

    prev = signal.signal(signal.SIGALRM, _fire)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)

MODULES = [
    ("table1", "Table 1: RTT cost of indirection", bench_table1),
    ("fig2_3", "Figures 2-3: offload crossover", bench_offload),
    ("fig6_7", "Figures 6-7: graph traversal", bench_graph),
    ("fig8", "Figure 8: page-table walk", bench_ptw),
    ("fig9", "Figure 9: distributed lock", bench_lock),
    ("fig10", "Figure 10: disaggregated PagedAttention",
     bench_paged_attention),
    ("sec4.5", "Section 4.5: MoE expert gather", bench_moe),
    ("vm_tput", "Engine throughput: interp vs batched vs compiled",
     bench_vm_throughput),
    ("mixed", "Multi-tenant mixed-op batching vs per-op launches",
     bench_mixed_batch),
    ("sharded", "Sharded pool over a device mesh vs single device",
     bench_sharded),
    ("async_overlap", "Async MEMCPY overlap: split-phase vs serialized",
     bench_async_overlap),
    ("fault_overhead", "Runtime protection cost on the fault-free path",
     bench_fault_overhead),
    ("serving", "Overload-safe serving loop: goodput and tails at 2x",
     bench_serving),
    ("static_analysis", "Static conflict proofs: sweep-skip + soundness",
     bench_static_analysis),
    ("e2e_paged", "End-to-end disaggregated paged decode vs host resolve",
     bench_e2e_paged),
    ("wcet", "Line-rate certification: soundness corpus + admission "
     "fail-fast", bench_wcet),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module key")
    ap.add_argument("--json", default=None, help="dump rows as JSON")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke configuration: small batches, few "
                         "reps, for modules that support it")
    args = ap.parse_args()

    all_rows = []
    tables = []
    crashed = []
    for key, title, mod in MODULES:
        if args.only and args.only not in key:
            continue
        kwargs = {}
        if args.quick and "quick" in inspect.signature(mod.rows).parameters:
            kwargs["quick"] = True
        t0 = time.time()
        # a crashed (or hung — see _deadline) module must not silently
        # vanish from the report: run the remaining modules, but exit
        # nonzero so the scheduled bench-smoke job cannot pass on it
        try:
            with _deadline(MODULE_TIMEOUT_S, key):
                rows = mod.rows(**kwargs)
        except Exception:
            traceback.print_exc()
            print(f"::error::benchmark module {key!r} crashed",
                  file=sys.stderr)
            crashed.append(key)
            continue
        dt = time.time() - t0
        all_rows.extend(rows)
        tables.append(fmt_table(rows, f"{title}  [{dt:.1f}s]"))

    print("name,us_per_call,derived")
    for r in all_rows:
        print(r.csv())
    print()
    for t in tables:
        print(t)
        print()

    claims = [r for r in all_rows if r.paper is not None]
    ok = sum(1 for r in claims if r.ratio() is not None
             and 0.7 <= r.ratio() <= 1.3)
    print(f"== claim check: {ok}/{len(claims)} paper-anchored rows within "
          f"+/-30% of the claimed value ==")
    worst = sorted((r for r in claims if r.ratio() is not None),
                   key=lambda r: abs(1 - r.ratio()), reverse=True)[:5]
    for r in worst:
        print(f"   largest deviation: {r.name}: derived {r.derived:.3g} "
              f"vs paper {r.paper:.3g} (x{r.ratio():.2f}) {r.note}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump([r.__dict__ for r in all_rows], f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)

    if crashed:
        print(f"== {len(crashed)} benchmark module(s) crashed: "
              f"{', '.join(crashed)} ==", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
