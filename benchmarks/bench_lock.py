"""Figure 9 — distributed-lock latency vs. contention.

Paper anchors: RDMA needs 5 sequential RTTs uncontended and degrades 2.5x
from 1 to 16 clients; RedN degrades 4.9x; RPC ~1.2x (overtaking Tiara at
~4 clients); Tiara collapses to 2 RTTs (abstract: 2.9x lower uncontended,
3.1x lower at 16 clients).

Faithfulness note (reported, not hidden): the paper's own RTT accounting
caps the uncontended gain at 5 RTT / 2 RTT = 2.5x, yet the abstract claims
2.9x — the claims are internally inconsistent at the ~15% level.  We report
both our cycle-level simulation (which additionally pays the four local
DMA ops of Fig. 5) and the pure RTT-count model.
"""

from __future__ import annotations

from typing import List

from repro.core import costmodel as cm
from repro.core import memory
from repro.core import operators as ops
from repro.core import simulator as sim

from benchmarks._workbench import Row, run_traced

CLIENTS = (1, 2, 4, 8, 16)


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    d = ops.DistLock()

    def setup(mem, rt):
        memory.write_region(mem, rt, 0, "lock", [0, 42])

    vop, trace, res, rt, _ = run_traced(
        d, d.build, [0, 1, 777, 1, 1, 2, 1], n_devices=3, setup_fn=setup)
    assert res.ok
    ts = sim.simulate_task(vop, trace, hw)

    out: List[Row] = [
        Row("fig9/lock/tiara/uncontended(sim)", ts.latency_us,
            ts.latency_us, "us",
            note="CAS + state rw + parallel replica writes + release"),
        Row("fig9/lock/tiara/uncontended(rtt-model)",
            cm.tiara_lock_latency_us(hw), cm.tiara_lock_latency_us(hw),
            "us", note="paper's 2-RTT accounting"),
        Row("fig9/lock/rdma/uncontended", cm.rdma_lock_latency_us(hw),
            cm.rdma_lock_latency_us(hw), "us", 12.5, note="5 RTTs"),
        Row("fig9/lock/speedup/tiara_vs_rdma(sim)", ts.latency_us,
            cm.rdma_lock_latency_us(hw) / ts.latency_us, "x", 2.9,
            note="paper claim exceeds its own 5RTT/2RTT=2.5 bound"),
        Row("fig9/lock/speedup/tiara_vs_rdma(rtt-model)",
            cm.tiara_lock_latency_us(hw),
            cm.rdma_lock_latency_us(hw) / cm.tiara_lock_latency_us(hw),
            "x", 2.9),
    ]
    for c in CLIENTS:
        for system in ("tiara", "rdma", "rpc", "redn"):
            lat = cm.lock_latency_contended_us(system, c, hw)
            paper = None
            if c == 16 and system == "rdma":
                paper = cm.rdma_lock_latency_us(hw) * 2.5
            out.append(Row(f"fig9/lock/{system}/clients={c}", lat, lat, "us",
                           paper))
    # degradation factors 1 -> 16 clients
    for system, claim in (("rdma", 2.5), ("redn", 4.9), ("rpc", 1.2),
                          ("tiara", None)):
        deg = (cm.lock_latency_contended_us(system, 16, hw)
               / cm.lock_latency_contended_us(system, 1, hw))
        out.append(Row(f"fig9/lock/degradation/{system}",
                       cm.lock_latency_contended_us(system, 16, hw),
                       deg, "x", claim))
    out.append(Row(
        "fig9/lock/speedup/tiara_vs_rdma/clients=16",
        cm.lock_latency_contended_us("tiara", 16, hw),
        cm.lock_latency_contended_us("rdma", 16, hw)
        / cm.lock_latency_contended_us("tiara", 16, hw), "x", 3.1))
    return out
