"""Figures 6 & 7 — graph-traversal latency and throughput vs. depth.

Tiara numbers come from the cycle-level MP simulator replaying the traced
operator; baselines are the paper's analytical models (§4.1).
Paper anchors: depth-10 latency 8.78 us vs RDMA 25.0 us (2.85x);
depth-3 saturated throughput 29.5 Mops (3.4x RDMA), RPC 3.55 Mops at
16 cores / 4.88 at 22, RedN ~1 Mops.
"""

from __future__ import annotations

from typing import List

from repro.core import costmodel as cm
from repro.core import operators as ops
from repro.core import simulator as sim
from repro.core.frontend import compile_source

from benchmarks._workbench import Row, run_traced

DEPTHS = (1, 2, 3, 5, 10)
MAX_DEPTH = 16

_WALK_SRC = '''
def walk(start, depth):
    cur = start
    for _ in bounded(depth, {cap}):
        cur = load("graph", cur + 1)
    return cur
'''


def _tiara(depth: int, hw: cm.HW):
    w = ops.GraphWalk(n_nodes=1024, max_depth=MAX_DEPTH)
    rt = w.regions()

    def build(rt):
        return compile_source(_WALK_SRC.format(cap=MAX_DEPTH), regions=rt)

    def do(mem, rt_):
        pass

    vop, trace, res, rt, _ = run_traced(w, build, [0, depth])
    # pointer chase: loop-carried address chain, never pipelineable
    return sim.simulate_task(vop, trace, hw, serial_chain=True)


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    out: List[Row] = []
    paper_lat = {10: 8.78}
    paper_rdma_lat = {10: 25.0}
    paper_tput = {3: 29.5}
    for d in DEPTHS:
        ts = _tiara(d, hw)
        tput = sim.saturated_throughput_mops(ts, hw)
        out.append(Row(f"fig6/graph/tiara/depth={d}", ts.latency_us,
                       ts.latency_us, "us", paper_lat.get(d),
                       note=f"bottleneck={sim.bottleneck(ts, hw)}"))
        out.append(Row(f"fig6/graph/rdma/depth={d}",
                       cm.rdma_chain_latency_us(d),
                       cm.rdma_chain_latency_us(d), "us",
                       paper_rdma_lat.get(d)))
        out.append(Row(f"fig6/graph/rpc/depth={d}", cm.rpc_latency_us(d),
                       cm.rpc_latency_us(d), "us"))
        out.append(Row(f"fig6/graph/redn/depth={d}",
                       cm.redn_latency_us(2 * d),
                       cm.redn_latency_us(2 * d), "us"))
        out.append(Row(f"fig6/graph/prism/depth={d}",
                       cm.prism_latency_us(d), cm.prism_latency_us(d), "us"))
        out.append(Row(f"fig7/graph/tiara/depth={d}", ts.latency_us, tput,
                       "Mops", paper_tput.get(d)))
        out.append(Row(f"fig7/graph/rdma/depth={d}",
                       cm.rdma_chain_latency_us(d),
                       cm.rdma_chain_throughput_mops(d), "Mops"))
        out.append(Row(f"fig7/graph/rpc16/depth={d}", cm.rpc_latency_us(d),
                       cm.rpc_throughput_mops(d), "Mops",
                       3.55 if d == 3 else None))
        out.append(Row(f"fig7/graph/rpc22/depth={d}", cm.rpc_latency_us(d),
                       cm.rpc_throughput_mops(d, cores=hw.rpc_cores_sat),
                       "Mops", 4.88 if d == 3 else None))
        out.append(Row(f"fig7/graph/redn/depth={d}",
                       cm.redn_latency_us(2 * d),
                       cm.redn_throughput_mops(2 * d), "Mops",
                       1.0 if d == 1 else None))
    # headline ratios
    t10 = _tiara(10, hw)
    out.append(Row("fig6/speedup/tiara_vs_rdma/depth=10", t10.latency_us,
                   cm.rdma_chain_latency_us(10) / t10.latency_us, "x", 2.85))
    t3 = _tiara(3, hw)
    out.append(Row("fig7/speedup/tiara_vs_rdma/depth=3", t3.latency_us,
                   sim.saturated_throughput_mops(t3, hw)
                   / cm.rdma_chain_throughput_mops(3), "x", 3.4))
    return out
