"""Line-rate certification: soundness corpus + admission fail-fast A/B.

Two claims from the registration-time WCET certifier (``core/wcet``),
each with its own record:

  * **Soundness corpus** (``section="soundness"``): a seeded corpus of
    random verified programs (static and register-capped loops,
    local/remote word ops, sync/async MEMCPYs with static and
    register-held lengths, data-dependent forward jumps, WAITs,
    atomics, register-chased offsets), each run on the ``pyvm`` oracle
    with random params and replayed through ``simulate_task`` in
    split-phase, serialized, and pipelined modes.  ``wcet_sound_ok``
    is a hard bit: every simulated timing and occupancy figure, and
    the trace's *exact* dynamic word/byte traffic, stays within the
    certificate on every program — AND the corpus is non-vacuous (it
    actually exercised loops, memcpys, async issues, remote ops, and
    data-dependent skips).  ``check_regression`` fails the build on a
    False, unconditionally.  ``bottleneck_agree_frac`` reports how
    often the statically predicted bottleneck matches the simulator's
    on the same program (informational — the certificate maximizes
    over paths the trace need not take).
  * **Admission fail-fast** (``section="failfast"``): a deterministic
    overloaded serving run on a ``VirtualClock`` where every doorbell
    pays an injected launch delay (``faults.delay_waves``).  Half the
    posts carry deadlines the certificate proves infeasible (window
    far below the certified WCET) yet still in the future both at
    admission and at launch, so without certificates they are queued,
    launched, and retire *after* their deadline — pure wasted fabric
    work.  With ``ServingConfig(admission_wcet=True)`` they retire
    ``STATUS_TIMEOUT`` at admission, unlaunched, while the feasible
    half executes identically.  ``speedup_failfast`` (gated as a
    lower bound) is the launched-then-late ratio ``(1 + late_off) /
    (1 + late_on)``; ``wcet_failfast_ok`` is the hard bit that the
    fail-fast run wastes nothing, loses no feasible work, and both
    runs retire exactly one CQE per submission.
"""

from __future__ import annotations

import json
import os
from typing import List, Set, Tuple

import numpy as np

from repro.core import faults, isa, memory, pyvm, simulator
from repro.core.endpoint import TiaraEndpoint
from repro.core.isa import Alu, Op
from repro.core.memory import RegionTable
from repro.core.program import OperatorBuilder, TiaraProgram
from repro.core.serving_loop import (ServingConfig, ServingLoop,
                                     VirtualClock)
from repro.core.verifier import VerificationError, VerifiedOperator, verify

from benchmarks._workbench import Row

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_wcet.json")

N_DEVICES = 2
ROUNDS = 300
QUICK_ROUNDS = 60
# timing comparisons allow float roundoff only — the bound itself must
# hold structurally, not within a tolerance
_EPS = 1e-6


# ---------------------------------------------------------------------------
# Part A: random-program soundness corpus
# ---------------------------------------------------------------------------

def corpus_table() -> RegionTable:
    return memory.packed_table([("src", 1024), ("dst", 1024),
                                ("acc", 256)])


def random_program(rng: np.random.Generator, rt: RegionTable,
                   idx: int) -> Tuple[TiaraProgram, Set[str]]:
    """One random draw from the corpus grammar plus the set of feature
    tags it exercised (for the non-vacuity check).  A draw may fail
    verification (e.g. a loop nest over the step limit) — callers
    redraw."""
    b = OperatorBuilder(f"rand{idx}", n_params=4, regions=rt)
    off = b.reg()       # word-op cursor into src/dst, masked to 1023
    moff = b.reg()      # memcpy cursor, masked to 511 so off+len fits
    aoff = b.reg()      # atomics cursor into acc, masked to 255
    v = b.reg()         # live value
    w = b.reg()         # scratch (register lengths / atomics result)
    b.alu(off, b.param(0), Alu.AND, 1023)
    b.alu(moff, b.param(0), Alu.AND, 511)
    b.alu(aoff, b.param(1), Alu.AND, 255)
    b.alu(v, b.param(2), Alu.ADD, 3)
    state = {"async": 0}
    feats: Set[str] = set()

    def rand_dev() -> int:
        # DEV_LOCAL resolves to the executing home; 0/1 are explicit
        # pool rows (the certificate must charge remote for anything
        # not statically DEV_LOCAL)
        return int(rng.choice([isa.DEV_LOCAL, isa.DEV_LOCAL, 0, 1]))

    def emit(depth: int) -> None:
        k = int(rng.integers(8))
        if k == 0:
            aop = Alu(int(rng.choice([Alu.ADD, Alu.SUB, Alu.XOR,
                                      Alu.MIN, Alu.MAX])))
            b.alu(v, v, aop, int(rng.integers(0, 64)))
        elif k == 1:
            dev = rand_dev()
            if dev != isa.DEV_LOCAL:
                feats.add("remote")
            b.load(v, "src", off, dev=dev)
            if rng.random() < 0.5:
                # data-dependent cursor: the chased-address family
                b.alu(off, v, Alu.AND, 1023)
                feats.add("chase")
        elif k == 2:
            dev = rand_dev()
            if dev != isa.DEV_LOCAL:
                feats.add("remote")
            b.store(v, "dst", off, dev=dev)
            feats.add("store")
        elif k == 3:
            feats.add("memcpy")
            if rng.random() < 0.5:
                n_words: object = int(rng.integers(1, 96))
            else:
                b.alu(w, b.param(3), Alu.AND, 63)
                n_words = (w, int(rng.integers(8, 128)))
                feats.add("reg_len")
            is_async = bool(rng.random() < 0.35)
            sdev, ddev = rand_dev(), rand_dev()
            if isa.DEV_LOCAL not in (sdev, ddev):
                feats.add("remote")
            b.memcpy(dst_region="dst", dst_off=moff, src_region="src",
                     src_off=moff, n_words=n_words, dst_dev=ddev,
                     src_dev=sdev, is_async=is_async)
            if is_async:
                state["async"] += 1
                feats.add("async")
        elif k == 4:
            b.caa(w, "acc", aoff, v, v)
            feats.add("atomic")
        elif k == 5 and depth < 2:
            feats.add("loop")
            if rng.random() < 0.5:
                m: object = int(rng.integers(2, 7))
            else:
                m = (b.param(1), int(rng.integers(2, 9)))
                feats.add("mreg_loop")
            with b.loop(m):
                for _ in range(int(rng.integers(1, 3))):
                    emit(depth + 1)
                b.alu(off, off, Alu.ADD, 1)
                b.alu(off, off, Alu.AND, 1023)
        elif k == 6:
            # a data-dependent forward jump over a couple of
            # constructs: the certificate must stay sound when the
            # skipped work never runs
            feats.add("jump")
            lbl = b.mklabel()
            b.jump(lbl, a=v, cond=Alu(int(rng.choice([Alu.LT, Alu.GE]))),
                   b=int(rng.integers(0, 2048)))
            for _ in range(int(rng.integers(1, 3))):
                emit(depth + 1)
            b.bind(lbl)
        else:
            b.wait(int(rng.integers(0, 2)))
    for _ in range(int(rng.integers(3, 8))):
        emit(0)
    if state["async"]:
        b.wait(0)
    b.ret(v)
    return b.build(), feats


def _trace_traffic(trace: List[pyvm.TraceEvent]) -> Tuple[int, int, int]:
    """Exact dynamic (words_read, words_written, memcpy_bytes) of one
    executed trace — what the certificate's traffic fields bound."""
    rd = wr = mb = 0
    for ev in trace:
        if ev.op in (Op.LOAD, Op.CAS, Op.CAA):
            rd += 1
        if ev.op in (Op.STORE, Op.CAS, Op.CAA):
            wr += 1
        if ev.op == Op.MEMCPY:
            rd += ev.n_words
            wr += ev.n_words
            mb += ev.n_words * isa.WORD_BYTES
    return rd, wr, mb


def check_one(vop: VerifiedOperator, rt: RegionTable,
              mem: np.ndarray, params: List[int],
              home: int) -> Tuple[List[str], bool]:
    """Run one program on the oracle and check every simulated figure
    against the certificate.  Returns (violations, bottleneck_agree)."""
    cert = vop.certificate
    assert cert is not None
    res = pyvm.run(vop, rt, mem, params, home=home, record_trace=True)
    bad: List[str] = []
    agree = False
    for mode_kw in ({}, dict(serialize_async=True),
                    dict(pipelined=True, serial_chain=False)):
        sim = simulator.simulate_task(vop, res.trace, **mode_kw)
        checks = [
            ("nic_us", sim.nic_resident_us, cert.wcet_nic_us),
            ("latency_us", sim.latency_us, cert.wcet_latency_us),
            ("mp_cycles", sim.mp_cycles, cert.mp_cycles),
            ("chan_cycles", sim.dma_channel_cycles,
             cert.dma_channel_cycles),
            ("small_reqs", sim.dma_small_reqs, cert.dma_small_reqs),
            ("wire_bytes", sim.wire_bytes, cert.wire_bytes),
        ]
        for name, got, bound in checks:
            if float(got) > float(bound) * (1 + _EPS) + _EPS:
                bad.append(f"{vop.name}: {name} {got} > certified "
                           f"{bound} ({mode_kw or 'split-phase'})")
        if not mode_kw:
            agree = simulator.bottleneck(sim) == cert.bottleneck
    rd, wr, mb = _trace_traffic(list(res.trace))
    for name, got, bound in (("words_read", rd, cert.words_read),
                             ("words_written", wr, cert.words_written),
                             ("memcpy_bytes", mb, cert.memcpy_bytes)):
        if got > bound:
            bad.append(f"{vop.name}: {name} {got} > certified {bound}")
    if cert.mp_cycles != vop.step_bound:
        bad.append(f"{vop.name}: certificate mp_cycles "
                   f"{cert.mp_cycles} != step bound {vop.step_bound}")
    return bad, agree


def _soundness(quick: bool) -> dict:
    rounds = QUICK_ROUNDS if quick else ROUNDS
    rt = corpus_table()
    rng = np.random.default_rng(2026)
    mem0 = rng.integers(0, 2048,
                        size=(N_DEVICES, rt.pool_words)).astype(np.int64)
    checked = rejected = agree = 0
    feats: Set[str] = set()
    violations: List[str] = []
    idx = 0
    while checked < rounds:
        prog, prog_feats = random_program(rng, rt, idx)
        idx += 1
        try:
            vop = verify(prog, regions=rt)
        except VerificationError:
            rejected += 1       # a drawn nest over the step cap — fine
            continue
        params = [int(rng.integers(0, 2048)) for _ in range(4)]
        bad, a = check_one(vop, rt, mem0.copy(), params,
                           home=int(rng.integers(N_DEVICES)))
        violations.extend(bad)
        agree += int(a)
        checked += 1
        feats |= prog_feats
    needed = {"loop", "memcpy", "async", "remote", "store", "jump",
              "atomic", "chase"}
    vacuous = sorted(needed - feats)
    ok = not violations and not vacuous and checked == rounds
    return dict(section="soundness", rounds=rounds,
                checked=checked, rejected_draws=rejected,
                bound_violations=len(violations),
                violation_examples=violations[:5],
                missing_features=vacuous,
                bottleneck_agree_frac=agree / max(checked, 1),
                wcet_sound_ok=bool(ok))


# ---------------------------------------------------------------------------
# Part B: admission fail-fast A/B on an overloaded VirtualClock run
# ---------------------------------------------------------------------------

N_INFEASIBLE = 32
N_FEASIBLE = 32
RING = 4
WAVE_DELAY_S = 5e-6         # injected per-wave launch delay


def _failfast_op() -> Tuple[TiaraProgram, RegionTable]:
    """A bulk gather whose certified WCET (~hundred microseconds)
    dwarfs the per-wave injected delay, so mid-wave deadlines are
    statically infeasible for every wave of the run."""
    rt = memory.packed_table([("src", 4096), ("dst", 4096)])
    b = OperatorBuilder("gather32", n_params=1, regions=rt)
    off = b.reg()
    b.alu(off, b.param(0), Alu.AND, 1023)
    with b.loop(32):
        b.memcpy(dst_region="dst", dst_off=off, src_region="src",
                 src_off=off, n_words=2048, src_dev=0)
        b.alu(off, off, Alu.ADD, 7)
        b.alu(off, off, Alu.AND, 1023)
    b.ret()
    return b.build(), rt


def _failfast_run(admission_wcet: bool) -> dict:
    prog, rt = _failfast_op()
    clk = VirtualClock()
    ep, sessions = TiaraEndpoint.for_tenants(
        [("t", rt)], n_devices=1, clock=clk, sleep=clk.sleep)
    sess = sessions["t"]
    sess.register(prog)
    op_id, _ = sess._resolve("gather32")
    cert = ep.registry[op_id].certificate
    assert cert is not None
    wcet_s = cert.wcet_latency_us * 1e-6
    loop = ServingLoop(ep, ServingConfig(
        ring_size=RING, ring_age_s=0.0, max_pending=256,
        admission_wcet=admission_wcet))
    n_posts = N_INFEASIBLE + N_FEASIBLE
    n_waves = n_posts // RING + 2
    # the deadline scheme below needs every mid-wave deadline to sit
    # under the certified WCET — i.e. the whole run is shorter than one
    # worst-case execution
    assert wcet_s > (n_waves + 1) * WAVE_DELAY_S
    # every wave's doorbell pays an injected launch delay, so virtual
    # time marches WAVE_DELAY_S per wave — the congested-NIC shape
    ep.inject(faults.delay_waves(*([WAVE_DELAY_S] * n_waves)))
    posts = []
    for i in range(n_posts):
        if i % 2 == 0:
            # statically infeasible, but in the future both at
            # admission (t=0) and at its wave's launch (wave k fires at
            # k*D): a mid-wave deadline k*D + 0.6*D.  Without the
            # certificate check the post launches and retires at
            # (k+1)*D — after its deadline.  The window is always far
            # below the certified WCET (asserted above).
            deadline = (i // RING) * WAVE_DELAY_S + 0.6 * WAVE_DELAY_S
            posts.append((loop.submit("t", "gather32", [i],
                                      deadline_s=deadline), True))
        else:
            posts.append((loop.submit("t", "gather32", [i],
                                      deadline_s=10.0), False))
    loop.drain()
    late = sum(
        1 for c, _ in posts
        if c.event is not None and c.event.wave >= 0
        and c.deadline is not None and c.event.retired_at > c.deadline)
    st = loop.stats
    cqe_ok = (st.submitted
              == st.executed + st.flushed + st.timed_out + st.rejected
              + st.shed)
    feasible_ok = sum(1 for c, inf in posts
                      if not inf and c.status == isa.STATUS_OK)
    return dict(admission_wcet=admission_wcet, launched=st.launched,
                executed=st.executed, timed_out=st.timed_out,
                late_launched=late, feasible_ok=feasible_ok,
                cqe_ok=bool(cqe_ok))


def _failfast(quick: bool) -> dict:
    del quick       # deterministic and fast either way
    off = _failfast_run(False)
    on = _failfast_run(True)
    # the gated lower bound: how much launched-then-late work the
    # certificate check removed (1.0 = the feature does nothing)
    speedup = (1 + off["late_launched"]) / (1 + on["late_launched"])
    ok = (on["late_launched"] == 0
          and on["feasible_ok"] == N_FEASIBLE
          and off["feasible_ok"] == N_FEASIBLE
          and off["late_launched"] > 0
          and on["cqe_ok"] and off["cqe_ok"])
    return dict(section="failfast", n_infeasible=N_INFEASIBLE,
                n_feasible=N_FEASIBLE, ring=RING,
                wave_delay_us=WAVE_DELAY_S * 1e6,
                late_launched_off=off["late_launched"],
                late_launched_on=on["late_launched"],
                launched_off=off["launched"], launched_on=on["launched"],
                timed_out_off=off["timed_out"],
                timed_out_on=on["timed_out"],
                speedup_failfast=float(speedup),
                wcet_failfast_ok=bool(ok))


def measure(quick: bool = False) -> List[dict]:
    return [_soundness(quick), _failfast(quick)]


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="line-rate certification: random-program WCET "
                 "soundness corpus (pyvm trace + cycle sim vs "
                 "certificate) + deterministic admission fail-fast A/B "
                 "on a VirtualClock overload run",
        unit="programs / posts",
        acceptance="simulated cycles/traffic never exceed the "
                   "certificate on a non-vacuous corpus "
                   "(wcet_sound_ok); statically-infeasible deadlines "
                   "retire at admission without launching, removing "
                   "all launched-then-late work (wcet_failfast_ok, "
                   "speedup_failfast)",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        if r["section"] == "soundness":
            out.append(Row(
                name=f"wcet/soundness/rounds={r['rounds']}",
                us_per_call=0.0, derived=float(r["checked"]),
                unit="programs",
                note=(f"{r['bound_violations']} violations, "
                      f"bottleneck agree "
                      f"{r['bottleneck_agree_frac']:.0%}"
                      + ("" if r["wcet_sound_ok"] else "  UNSOUND"))))
        else:
            out.append(Row(
                name=f"wcet/failfast/inf={r['n_infeasible']}",
                us_per_call=0.0,
                derived=float(r["speedup_failfast"]), unit="x",
                note=(f"late launches {r['late_launched_off']} -> "
                      f"{r['late_launched_on']}"
                      + ("" if r["wcet_failfast_ok"]
                         else "  FAILFAST-BROKEN"))))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
