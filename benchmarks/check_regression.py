"""Bench-regression gate: compare fresh BENCH_*.json payloads against a
committed baseline snapshot and fail on a >30% drop in any
speedup-normalized metric.

Absolute ops/s drifts with CI host state (the PR 3 finding: the
untouched serial control itself measures 0.7-1.1x across runs), so the
gate only tracks metrics normalized to an in-run baseline — any record
field starting with ``speedup`` — plus the ``parity_ok`` correctness
bit.  Records are matched between baseline and current by their
identity fields (everything that is not a measurement), so a quick CI
run that covers a subset of the committed batch sizes compares just the
overlap.

Usage (the bench-smoke CI job snapshots the committed JSONs before the
run overwrites them):

    cp BENCH_*.json /tmp/bench_baseline/
    python -m benchmarks.run --quick
    python -m benchmarks.check_regression --baseline /tmp/bench_baseline
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

# measurement fields: never part of a record's identity
_MEASURED = ("us_per_call", "ops_per_s", "subwave_ops_per_s", "parity_ok",
             # bench_async_overlap: simulated NIC residencies (inputs to
             # the gated speedup_overlap_sim ratio) and the cost model's
             # learned overlap term — measurements, not identity
             "nic_us_async", "nic_us_serialized", "learned_overlap",
             # bench_fault_overhead: the unprotected build's side of the
             # gated speedup_protect ratio
             "us_per_call_noprotect", "ops_per_s_noprotect",
             # bench_serving: overload outcome counters, latency tails,
             # and the hard invariant bits — measurements, not identity
             "submitted", "executed", "ok", "timed_out", "rejected",
             "shed", "goodput_frac", "fairness_min_share",
             "p50_x_deadline", "p99_x_deadline", "deterministic_ok",
             "inflight_bound_ok", "p50_ms_wall", "p99_ms_wall",
             # bench_static_analysis: the always-sweep side of the gated
             # speedup_sweep_skip ratio and the soundness-corpus tallies
             "us_per_call_sweep", "ops_per_s_sweep", "soundness_ok",
             "proven_waves", "refused_waves", "unsound_clears",
             # bench_e2e_paged: token counts, fabric times and rehome
             # audit — measurements feeding the gated speedups and hard
             # bits, not identity
             "tokens", "posts", "waves", "exec_us_per_post", "bottleneck",
             "fabric_us_host", "fabric_us_tiara", "tokens_per_s_host",
             "tokens_per_s_tiara", "p99_resolve_us", "rehomes",
             "rehomed_words", "home_skew", "cross_words_rehome",
             "cross_words_static", "tiara_not_slower_ok",
             "rehome_reduces_traffic_ok",
             # bench_wcet: soundness-corpus tallies and fail-fast A/B
             # counters — measurements feeding wcet_sound_ok /
             # wcet_failfast_ok and the gated speedup_failfast
             "checked", "rejected_draws", "bound_violations",
             "violation_examples", "missing_features",
             "bottleneck_agree_frac", "wcet_sound_ok",
             "late_launched_off", "late_launched_on", "launched_off",
             "launched_on", "timed_out_off", "timed_out_on",
             "wcet_failfast_ok")

# gated non-speedup metrics.  Lower-bounded metrics fail when the
# current value drops more than the band below baseline (like
# speedups); upper-bounded ones fail when it RISES more than the band
# above (latency tails).  The serving virtual section runs entirely on
# a seeded VirtualClock — the values are bit-stable across hosts — so
# the bands only absorb intentional small policy retunes, not noise.
_GATED_LOWER = ("goodput_frac", "fairness_min_share")
_GATED_UPPER = ("p99_x_deadline",)

# hard correctness bits, checked unconditionally on every current
# record that carries them (missing = not applicable = pass)
_HARD_BITS = {
    "parity_ok": "engine output diverged from the pyvm oracle",
    "deterministic_ok": "same-seed overload runs produced different "
                        "per-seq CQE statuses",
    "inflight_bound_ok": "in-flight waves exceeded max_inflight_waves",
    "soundness_ok": "static conflict proof cleared a wave the dynamic "
                    "sweep would have flagged (or the corpus was "
                    "vacuous)",
    "tiara_not_slower_ok": "tiara-resolved decode fell below 1.0x the "
                           "host-resolve baseline at the resolution "
                           "fabric",
    "rehome_reduces_traffic_ok": "adaptive re-homing failed to reduce "
                                 "cross-device reply words vs the "
                                 "static-home run",
    "wcet_sound_ok": "a simulated execution exceeded its registration "
                     "certificate (or the seeded corpus was vacuous) — "
                     "the line-rate certifier is unsound",
    "wcet_failfast_ok": "certificate admission fail-fast launched a "
                        "statically-infeasible post, lost feasible "
                        "work, or broke the one-CQE-per-post identity",
}

# per-metric thresholds overriding --threshold: some normalizers are
# noisier than the in-run serial baseline the 30% default was designed
# for.  speedup_vs_single is dominated by the forced-host collective
# cost, which varies ~3x across hosts (see EngineCost.collective_us)
# and ~2x run-to-run in quick mode (measured: pristine HEAD scored 0.34
# and 0.61 at B=64 in back-to-back runs) — its band only catches
# order-of-magnitude structural regressions; bit-correctness is the
# parity_ok check, which is unconditional.  speedup_vs_interp
# normalizes to the B=1 interpreter, whose per-call launch overhead
# drifts ~2x with host load (measured: the same commit scored 19.9x and
# 11.1x at B=64 in two sessions of one container).  A real structural
# regression (losing vectorization ~ 10x) still trips the wider bands.
# speedup_protect is an in-run interleaved min-of-N A/B ratio — the most
# stable normalization the host allows (absolute times still swing tens
# of percent between runs; the committed baseline measured 0.83 at
# B=1024, and bench_fault_overhead additionally hard-gates the
# deterministic HLO traffic ratio).  0.15 tolerates quick-mode jitter at
# B=64 while still tripping on a structural cost regression in the
# protection checks.
_METRIC_THRESHOLDS = {"speedup_vs_single": 0.75,
                      "speedup_vs_interp": 0.5,
                      "speedup_protect": 0.15,
                      # serving virtual metrics are deterministic
                      # (seeded VirtualClock); tight bands
                      "goodput_frac": 0.05,
                      "fairness_min_share": 0.05,
                      "p99_x_deadline": 0.10,
                      # speedup_sweep_skip is an in-run A/B on one
                      # endpoint (only the host-side sweep differs), but
                      # the sweep's share of a doorbell swings with host
                      # load; the band catches losing the skip entirely
                      # (ratio -> ~1.0 from a >1 baseline), not jitter
                      "speedup_sweep_skip": 0.4,
                      # bench_e2e_paged prices both sides on a seeded
                      # VirtualClock + cycle sim — bit-stable; tight
                      # bands absorb intentional retunes only
                      "speedup_tiara_resolve": 0.05,
                      "speedup_rehome_traffic": 0.05,
                      # bench_wcet's fail-fast A/B is fully
                      # deterministic (seeded VirtualClock, injected
                      # delays); any drop is a policy change
                      "speedup_failfast": 0.05}


def _identity(rec: dict) -> Tuple:
    return tuple(sorted(
        (k, json.dumps(v) if isinstance(v, (list, dict)) else v)
        for k, v in rec.items()
        if k not in _MEASURED and not k.startswith("speedup")))


def _speedup_keys(rec: dict) -> List[str]:
    return ([k for k in rec if k.startswith("speedup")]
            + [k for k in _GATED_LOWER if k in rec])


def _index(payload: dict) -> Dict[Tuple, dict]:
    out = {}
    for rec in payload.get("results", []):
        out[_identity(rec)] = rec
    return out


def compare_file(name: str, baseline: dict, current: dict,
                 threshold: float) -> Tuple[List[str], int]:
    """Returns (failure messages, number of compared metrics)."""
    fails: List[str] = []
    compared = 0
    base_idx = _index(baseline)
    cur_idx = _index(current)
    # hard correctness bits, checked on EVERY current record — a
    # bit-parity (or determinism/bound) break at a shape the committed
    # baseline never covered must still fail
    for ident, cur_rec in cur_idx.items():
        for bit, why in _HARD_BITS.items():
            if not cur_rec.get(bit, True):
                fails.append(
                    f"{name}: {dict(ident)}: {bit} is False — {why}")
    for ident, base_rec in base_idx.items():
        cur_rec = cur_idx.get(ident)
        if cur_rec is None:
            continue        # quick runs cover a subset of batch sizes
        for k in _speedup_keys(base_rec):
            if k not in cur_rec:
                continue
            base_v, cur_v = float(base_rec[k]), float(cur_rec[k])
            if base_v <= 0:
                continue
            compared += 1
            thr = _METRIC_THRESHOLDS.get(k, threshold)
            if cur_v < base_v * (1.0 - thr):
                fails.append(
                    f"{name}: {dict(ident)}: {k} regressed "
                    f"{base_v:.2f} -> {cur_v:.2f} "
                    f"({cur_v / base_v:.0%} of baseline, "
                    f"threshold {thr:.0%})")
        for k in _GATED_UPPER:
            if k not in base_rec or k not in cur_rec:
                continue
            base_v, cur_v = float(base_rec[k]), float(cur_rec[k])
            if base_v <= 0:
                continue
            compared += 1
            thr = _METRIC_THRESHOLDS.get(k, threshold)
            if cur_v > base_v * (1.0 + thr):
                fails.append(
                    f"{name}: {dict(ident)}: {k} regressed upward "
                    f"{base_v:.2f} -> {cur_v:.2f} "
                    f"({cur_v / base_v:.0%} of baseline, "
                    f"ceiling +{thr:.0%})")
    # a baseline file that carries speedup records but matched nothing
    # is a silent coverage hole (e.g. the CI device count diverged from
    # the committed baseline's), not a pass
    has_speedups = any(_speedup_keys(r) for r in base_idx.values())
    if has_speedups and compared == 0:
        fails.append(
            f"{name}: no record matched the baseline identities — the "
            f"gate compared nothing for this file (device count or "
            f"batch set diverged from the committed run?)")
    return fails, compared


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json "
                         "snapshot")
    ap.add_argument("--current", default=".",
                    help="directory holding the freshly measured "
                         "BENCH_*.json files (default: repo root)")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="maximum tolerated fractional drop in a "
                         "speedup metric (default 0.30)")
    args = ap.parse_args()

    base_files = sorted(glob.glob(os.path.join(args.baseline,
                                               "BENCH_*.json")))
    if not base_files:
        print(f"::error::no BENCH_*.json baselines in {args.baseline}",
              file=sys.stderr)
        sys.exit(2)

    all_fails: List[str] = []
    total = 0
    for bf in base_files:
        name = os.path.basename(bf)
        cf = os.path.join(args.current, name)
        if not os.path.exists(cf):
            # a committed benchmark whose module stopped producing its
            # JSON is itself a regression
            all_fails.append(f"{name}: missing from current run")
            continue
        with open(bf) as f:
            baseline = json.load(f)
        with open(cf) as f:
            current = json.load(f)
        fails, compared = compare_file(name, baseline, current,
                                       args.threshold)
        total += compared
        all_fails.extend(fails)
        print(f"{name}: {compared} speedup metrics compared, "
              f"{len(fails)} failures")

    if total == 0 and not all_fails:
        # every baseline record failed to match: the gate compared
        # nothing, which is itself a silent-pass hazard (e.g. the CI
        # run's device count diverged from the committed baseline's)
        print("::error::no speedup metrics matched any baseline record "
              "— the gate compared nothing", file=sys.stderr)
        sys.exit(2)
    if all_fails:
        print(f"\n== bench regression check FAILED "
              f"({len(all_fails)} issues) ==")
        for msg in all_fails:
            print(f"  {msg}")
            print(f"::error::{msg}", file=sys.stderr)
        sys.exit(1)
    print(f"\n== bench regression check passed ({total} speedup metrics "
          f"within thresholds; default {args.threshold:.0%}) ==")


if __name__ == "__main__":
    main()
