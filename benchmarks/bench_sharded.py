"""Sharded memory pool over a device mesh vs the single-device engine.

The paper's end state is a fabric of memory-side NICs, each executing
the operators whose data it owns.  This benchmark stands up the same
4-tenant interleaved serving wave as ``bench_mixed_batch`` — but posts
round-robin their ``home`` across the mesh, so ``doorbell(placement=
"sharded")`` buckets each wave into per-device sub-waves and the
shard_map engine executes them in lockstep with remote LOAD/MEMCPY on
collectives.  Compared engines at each batch size:

  * ``mixed_single``  the one-launch mixed lockstep engine on a single
                      chip against the whole pool — the PR 2 reference
                      (``placement="single"``, the in-run baseline that
                      speedups normalize to).
  * ``sharded``       home-bucketed per-device sub-waves over the mesh
                      (``placement="sharded"``).

Every wave is checked bit-identical against the per-request ``pyvm``
oracle before timing (``parity_ok``).  Per-device sub-wave sizes, ops/s
and the speedup normalized to the in-run ``mixed_single`` baseline land
in ``BENCH_sharded.json``.

A note on reading the numbers: under ``XLA_FLAGS=--xla_force_host_
platform_device_count=8`` all "devices" are threads of one CPU, so the
collective tax is real but the per-device parallelism is not — the
speedup column measures the cost of the sharded execution structure,
not a fabric win.  On one device the mesh is degenerate (n_devices=1)
and the comparison is pure overhead accounting.
"""

from __future__ import annotations

import json
import os
from typing import List

import jax

from repro.core import compile as tc

from benchmarks._workbench import Row, rate as _rate
from benchmarks.bench_mixed_batch import (_drain, _oracle, _parity,
                                          _post_wave, _setup)

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_sharded.json")
BATCHES = (64, 256, 1024)
QUICK_BATCHES = (16, 64)
MIN_SECONDS = 0.25
ENGINES = ("mixed_single", "sharded")
_DOORBELL = {"mixed_single": dict(mode="mixed", placement="single"),
             "sharded": dict(mode="mixed", placement="sharded")}


def measure(quick: bool = False) -> List[dict]:
    n_dev = min(8, len(jax.devices()))
    batches = QUICK_BATCHES if quick else BATCHES
    min_seconds = 0.05 if quick else MIN_SECONDS
    ep, sessions, names, order, vas = _setup(max(batches),
                                             n_devices=n_dev)
    out: List[dict] = []
    for b in batches:
        oracle = None
        rates = {}
        for engine in ENGINES:
            cs = _post_wave(sessions, names, order, vas, b,
                            n_devices=n_dev)
            if oracle is None:
                oracle = _oracle(ep, cs)
            ep.doorbell(**_DOORBELL[engine])
            parity = _parity(ep, cs, oracle)
            _drain(sessions)

            def call(engine=engine):
                _post_wave(sessions, names, order, vas, b,
                           n_devices=n_dev)
                ep.doorbell(**_DOORBELL[engine])
                _drain(sessions)

            us, rate = _rate(call, b, min_seconds)
            rates[engine] = rate
            plan = tc.plan_mixed_batch(
                [c.op_id for c in cs], homes=[c.home for c in cs],
                n_devices=n_dev)
            out.append(dict(
                engine=engine, batch=b, us_per_call=us, ops_per_s=rate,
                parity_ok=bool(parity), n_devices=n_dev,
                batch_per_device=plan.batch_per_device,
                device_counts=plan.device_counts.tolist(),
                subwave_ops_per_s=rate / n_dev))
        for r in out:
            if r["batch"] == b:
                r["speedup_vs_single"] = \
                    r["ops_per_s"] / rates["mixed_single"]
    return out


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="4-tenant interleaved mix (graph_walk + ptw3 + "
                 "paged_kv_fetch + moe_expert_gather), posts round-robin "
                 "homes over the mesh; doorbell(placement=...)",
        unit="ops/s",
        acceptance="sharded placement bit-identical to the pyvm oracle "
                   "at every batch; speedup_vs_single is the in-run-"
                   "normalized metric the regression gate tracks "
                   "(absolute ops/s is host-noise informational)",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        flag = "" if r["parity_ok"] else "  PARITY-MISMATCH"
        out.append(Row(
            name=f"sharded/{r['engine']}/B={r['batch']}",
            us_per_call=r["us_per_call"],
            derived=r["ops_per_s"] / 1e6, unit="Mops",
            note=f"x{r['speedup_vs_single']:.2f} vs single, "
                 f"{r['n_devices']} dev, Bp={r['batch_per_device']}"
                 f"{flag}"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
