"""§4.5 — MoE expert-gather latency through a translation table.

Paper anchors at k=32 (8 KB slabs): Tiara 14.2 us, RDMA 26.7 us (1.88x),
RPC 41.7 us (2.93x).

Faithfulness note (reported, not hidden): 32 x 8 KB = 256 KB takes 21.8 us
to serialize at the paper's own 12 GB/s effective line rate, so the claimed
14.2 us is below the wire floor for the payload.  Our simulator respects
the wire: Tiara's derived win comes from removing the table-read RTT and
WR-build overheads, converging to wire time + ~1 RTT.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import costmodel as cm
from repro.core import memory
from repro.core import simulator as sim
from repro.serving.resolver import expert_layout

from benchmarks._workbench import Row, run_traced

KS = (4, 8, 16, 32, 64)


def tiara_moe_latency(k: int, hw: cm.HW):
    # the serving resolver's layout export at the paper's 8 KB slabs —
    # same region geometry as the engine's expert gather path
    m = expert_layout(256, max_k=64, slab_bytes=8192)
    rng = np.random.default_rng(1)
    eids = rng.choice(256, size=k, replace=False)

    def setup(mem, rt):
        memory.write_region(mem, rt, 0, "expert_ids",
                            eids.astype(np.int64))

    vop, trace, res, _, _ = run_traced(
        m, lambda rt: m.build(rt, remote_reply=True), [k, 1],
        n_devices=2, setup_fn=setup)
    assert res.ok
    return sim.simulate_task(vop, trace, hw, pipelined=True,
                             serial_chain=False)


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    out: List[Row] = []
    paper = {32: (14.2, 26.7, 41.7)}
    for k in KS:
        ts = tiara_moe_latency(k, hw)
        pt, pr, pc = paper.get(k, (None, None, None))
        out.append(Row(f"sec4.5/moe/tiara/k={k}", ts.latency_us,
                       ts.latency_us, "us", pt,
                       note=f"wire floor {k * 8192 / hw.wire_bytes_per_us:.1f}us"))
        out.append(Row(f"sec4.5/moe/rdma/k={k}", cm.rdma_moe_latency_us(k),
                       cm.rdma_moe_latency_us(k), "us", pr,
                       note="paper's model: no WR-build charge"))
        wrb = cm.rdma_moe_latency_us(k) + k * hw.client_wr_build_us
        out.append(Row(f"sec4.5/moe/rdma+wrbuild/k={k}", wrb, wrb, "us",
                       note="Fig.10-consistent accounting"))
        out.append(Row(f"sec4.5/moe/rpc/k={k}", cm.rpc_moe_latency_us(k),
                       cm.rpc_moe_latency_us(k), "us", pc))
    ts32 = tiara_moe_latency(32, hw)
    out.append(Row("sec4.5/moe/speedup/tiara_vs_rdma/k=32", ts32.latency_us,
                   cm.rdma_moe_latency_us(32) / ts32.latency_us, "x", 1.88))
    out.append(Row("sec4.5/moe/speedup/tiara_vs_rpc/k=32", ts32.latency_us,
                   cm.rpc_moe_latency_us(32) / ts32.latency_us, "x", 2.93))
    return out
