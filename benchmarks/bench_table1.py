"""Table 1 — RTT cost of indirection across workloads.

The Tiara column is *derived from executed traces*: we count the request/
reply round trip plus every remote synchronous op and every Wait joining
remote async ops.  The RDMA column is the dependence-depth accounting the
table states.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import costmodel as cm
from repro.core import memory
from repro.core import operators as ops
from repro.core import pyvm
from repro.core.memory import Grant
from repro.core.verifier import verify

from benchmarks._workbench import Row, count_rtts, run_traced


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    out: List[Row] = []

    # graph traversal, depth 10
    w = ops.GraphWalk(n_nodes=256, max_depth=16)
    vop, trace, _, _, _ = run_traced(w, w.build, [0, 10])
    out.append(Row("table1/graph_d10/tiara", 0, count_rtts(trace), "RTT", 1))
    out.append(Row("table1/graph_d10/rdma", 0, 10, "RTT", 10))

    # 3-level page-table walk (+ data fetch)
    p = ops.PageTableWalk(fanout=16, n_pages=16)
    rt = p.regions()
    mem = memory.make_pool(1, rt)
    vamap = p.populate(mem, rt)
    va = next(iter(vamap.keys()))
    vop = verify(p.build(rt), grant=Grant.all_of(rt), regions=rt)
    res = pyvm.run(vop, rt, mem, [va], record_trace=True)
    out.append(Row("table1/ptw3/tiara", 0, count_rtts(res.trace), "RTT", 1))
    out.append(Row("table1/ptw3/rdma", 0, 4, "RTT", 4))

    # distributed lock + replication
    d = ops.DistLock()
    rt = d.regions()
    mem = memory.make_pool(3, rt)
    memory.write_region(mem, rt, 0, "lock", [0, 0])
    vop = verify(d.build(rt), grant=Grant.all_of(rt), regions=rt)
    res = pyvm.run(vop, rt, mem, [0, 1, 9, 1, 1, 2, 1], record_trace=True)
    out.append(Row("table1/dist_lock/tiara", 0, count_rtts(res.trace),
                   "RTT", 2))
    out.append(Row("table1/dist_lock/rdma", 0, 5, "RTT", 5))

    # PagedAttention (unoptimized stop-and-wait vs optimally batched)
    k = ops.PagedKVFetch(n_blocks_pool=16, block_bytes=4096,
                         max_req_blocks=8)
    rt = k.regions()
    mem = memory.make_pool(2, rt)
    k.populate(mem, rt)
    k.make_request(mem, rt, [1, 3, 5, 7])
    vop = verify(k.build(rt, remote_reply=True), grant=Grant.all_of(rt),
                 regions=rt)
    res = pyvm.run(vop, rt, mem, [4, 1], record_trace=True)
    out.append(Row("table1/paged_attention/tiara", 0, count_rtts(res.trace, client_dev=1),
                   "RTT", 1))
    out.append(Row("table1/paged_attention/rdma_stop_and_wait", 0, 160,
                   "RTT", 160, note="LLaMA3-70B request, Yue et al."))
    out.append(Row("table1/paged_attention/rdma_batched", 0, 2, "RTT", 2))

    # MoE expert loading
    m = ops.MoEExpertGather(n_experts=16, max_k=8)
    rt = m.regions()
    mem = memory.make_pool(2, rt)
    m.populate(mem, rt)
    memory.write_region(mem, rt, 0, "expert_ids",
                        np.asarray([2, 5], dtype=np.int64))
    vop = verify(m.build(rt, remote_reply=True), grant=Grant.all_of(rt),
                 regions=rt)
    res = pyvm.run(vop, rt, mem, [2, 1], record_trace=True)
    out.append(Row("table1/moe_gather/tiara", 0, count_rtts(res.trace, client_dev=1),
                   "RTT", 1))
    out.append(Row("table1/moe_gather/rdma", 0, 2, "RTT", 2))

    # NSA score-then-select
    s = ops.NSASelect(n_scores=16, block_words=64)
    rt = s.regions()
    mem = memory.make_pool(1, rt)
    s.populate(mem, rt)
    vop = verify(s.build(rt), grant=Grant.all_of(rt), regions=rt)
    res = pyvm.run(vop, rt, mem, [16, 50], record_trace=True)
    out.append(Row("table1/nsa_select/tiara", 0, count_rtts(res.trace),
                   "RTT", 1))
    out.append(Row("table1/nsa_select/rdma", 0, 2, "RTT", 2))
    return out
