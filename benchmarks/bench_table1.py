"""Table 1 — RTT cost of indirection across workloads.

The Tiara column is *derived from executed traces*: every workload is
registered on a queue-pair endpoint (``run_traced``) and we count the
request/reply round trip plus every remote synchronous op and every Wait
joining remote async ops.  The RDMA column is the dependence-depth
accounting the table states.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import costmodel as cm
from repro.core import memory
from repro.core import operators as ops

from benchmarks._workbench import Row, count_rtts, run_traced


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    out: List[Row] = []

    # graph traversal, depth 10
    w = ops.GraphWalk(n_nodes=256, max_depth=16)
    vop, trace, _, _, _ = run_traced(w, w.build, [0, 10])
    out.append(Row("table1/graph_d10/tiara", 0, count_rtts(trace), "RTT", 1))
    out.append(Row("table1/graph_d10/rdma", 0, 10, "RTT", 10))

    # 3-level page-table walk (+ data fetch); populate is deterministic,
    # so a scratch pool yields the same VA map as the endpoint's
    p = ops.PageTableWalk(fanout=16, n_pages=16)
    rt0 = p.regions()
    vamap = p.populate(memory.make_pool(1, rt0), rt0)
    va = next(iter(vamap.keys()))
    _, trace, _, _, _ = run_traced(p, p.build, [va])
    out.append(Row("table1/ptw3/tiara", 0, count_rtts(trace), "RTT", 1))
    out.append(Row("table1/ptw3/rdma", 0, 4, "RTT", 4))

    # distributed lock + replication
    d = ops.DistLock()

    def lock_setup(mem, rt):
        memory.write_region(mem, rt, 0, "lock", [0, 0])

    _, trace, _, _, _ = run_traced(d, d.build, [0, 1, 9, 1, 1, 2, 1],
                                   n_devices=3, setup_fn=lock_setup)
    out.append(Row("table1/dist_lock/tiara", 0, count_rtts(trace),
                   "RTT", 2))
    out.append(Row("table1/dist_lock/rdma", 0, 5, "RTT", 5))

    # PagedAttention (unoptimized stop-and-wait vs optimally batched)
    k = ops.PagedKVFetch(n_blocks_pool=16, block_bytes=4096,
                         max_req_blocks=8)

    def kv_setup(mem, rt):
        k.make_request(mem, rt, [1, 3, 5, 7])

    _, trace, _, _, _ = run_traced(
        k, lambda rt: k.build(rt, remote_reply=True), [4, 1],
        n_devices=2, setup_fn=kv_setup)
    out.append(Row("table1/paged_attention/tiara", 0,
                   count_rtts(trace, client_dev=1), "RTT", 1))
    out.append(Row("table1/paged_attention/rdma_stop_and_wait", 0, 160,
                   "RTT", 160, note="LLaMA3-70B request, Yue et al."))
    out.append(Row("table1/paged_attention/rdma_batched", 0, 2, "RTT", 2))

    # MoE expert loading
    m = ops.MoEExpertGather(n_experts=16, max_k=8)

    def moe_setup(mem, rt):
        memory.write_region(mem, rt, 0, "expert_ids",
                            np.asarray([2, 5], dtype=np.int64))

    _, trace, _, _, _ = run_traced(
        m, lambda rt: m.build(rt, remote_reply=True), [2, 1],
        n_devices=2, setup_fn=moe_setup)
    out.append(Row("table1/moe_gather/tiara", 0,
                   count_rtts(trace, client_dev=1), "RTT", 1))
    out.append(Row("table1/moe_gather/rdma", 0, 2, "RTT", 2))

    # NSA score-then-select
    s = ops.NSASelect(n_scores=16, block_words=64)
    _, trace, _, _, _ = run_traced(s, s.build, [16, 50])
    out.append(Row("table1/nsa_select/tiara", 0, count_rtts(trace),
                   "RTT", 1))
    out.append(Row("table1/nsa_select/rdma", 0, 2, "RTT", 2))
    return out
