"""Static conflict proofs: sweep-skip speedup and a soundness gate.

Two claims from the registration-time access analysis
(``core/access``), each with its own record:

  * **Sweep-skip speedup** (``section="sweep_skip"``): a 4-tenant
    reply-slot serving wave (every lane's footprint is affine in its
    params, every slot disjoint) is statically proven conflict-free at
    plan time, so the engines run with the per-step runtime conflict
    sweep compiled out.  The A/B is the same endpoint with
    ``registry.static_analysis`` toggled — identical wave, identical
    engine family, the only delta is proof-vs-sweep — timed through the
    full posting surface at B=1024.  Measured on the dense mixed engine
    (the skip drops the per-step lane-interval build + sweep sort from
    the compiled loop) and, when the host exposes a mesh, on
    ``placement="sharded"``, where the proof also deletes the footprint
    ``all_gather`` collective every macro-step — the structural win.
    ``speedup_sweep_skip`` is the gated ratio; every proven wave is
    checked bit-identical against the per-request ``pyvm`` oracle first
    (``parity_ok``).
  * **Soundness corpus** (``section="soundness"``): a seeded corpus of
    random 4-lane waves (affine, trip-capped-window, data-dependent-⊤
    and atomic families; colliding and slot-strided draws) where each
    lane's *exact* dynamic read/write cell sets are computed in closed
    form — exactly what feeds the runtime sweep.  ``soundness_ok`` is a
    hard bit: the static verdict never clears a wave whose dynamic
    sets conflict cross-lane, AND the corpus is non-vacuous (some waves
    prove, some are refused).  ``check_regression`` fails the build on
    a False, unconditionally.
"""

from __future__ import annotations

import json
import os
from typing import List

import time

import jax
import numpy as np

from repro.core import memory, pyvm
from repro.core.isa import Alu
from repro.core.memory import Grant
from repro.core.endpoint import TiaraEndpoint
from repro.core.program import OperatorBuilder
from repro.core.registry import OperatorRegistry

from benchmarks._workbench import Row

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_static_analysis.json")
# one B=1024 mixed wave takes ~0.2s on a CI host and the sweep is a
# fraction of that, so the A/B is a strictly interleaved min-of-N (the
# bench_fault_overhead protocol — robust to the several-×10% swings a
# two-pass measurement shows on this host)
REPS = 8
QUICK_REPS = 3
# full mode measures the quick batch too, so the CI smoke run's records
# overlap the committed baseline (how every other bench gates)
BATCHES = (128, 1024)
QUICK_BATCHES = (128,)
TENANTS = ("t0", "t1", "t2", "t3")
SLOT_WORDS = 8      # reply-slot stride; the op touches 4 of the 8
WINDOW = 4


# ---------------------------------------------------------------------------
# Part A: sweep-skip speedup on a provably-disjoint serving wave
# ---------------------------------------------------------------------------

def _op_slot(rt):
    """The serving-shaped lane: copy a 4-word window src[p1..p1+3] into
    the caller's reply slot reply[p0..p0+3].  A static 4-trip loop over
    a pure-increment cursor, so the derived footprint is an exact
    affine window in (p0, p1) — provable, never ⊤."""
    b = OperatorBuilder("slot_copy", n_params=2, regions=rt)
    i, j, v = b.reg(), b.reg(), b.reg()
    b.alu(i, b.param(0), Alu.ADD, 0)
    b.alu(j, b.param(1), Alu.ADD, 0)
    with b.loop(WINDOW):
        b.load(v, "src", j)
        b.store(v, "reply", i)
        b.alu(i, i, Alu.ADD, 1)
        b.alu(j, j, Alu.ADD, 1)
    b.ret(v)
    return b.build()


def _setup(batch: int, n_devices: int):
    slots = batch // len(TENANTS)
    words = max(slots * SLOT_WORDS, 64)
    tables = [(t, memory.packed_table([("src", words), ("reply", words)]))
              for t in TENANTS]
    ep, sessions = TiaraEndpoint.for_tenants(tables, n_devices=n_devices)
    names = {}
    for t in TENANTS:
        s = sessions[t]
        prog = _op_slot(s.view)
        names[t] = prog.name
        s.register(prog)
        for d in range(n_devices):
            s.write_region("src", np.arange(words, dtype=np.int64) * 3 + 1,
                           device=d)
    return ep, sessions, names


def _post_wave(sessions, names, batch, n_devices):
    cs = []
    slot = {t: 0 for t in TENANTS}
    for i in range(batch):
        t = TENANTS[i % len(TENANTS)]
        j = slot[t]
        slot[t] += 1
        cs.append(sessions[t].post(
            names[t], [j * SLOT_WORDS, j * SLOT_WORDS],
            home=i % n_devices))
    return cs


def _oracle(ep, cs):
    vops = ep.registry.store_ops()
    seq = ep.mem.copy()
    rets = []
    for c in sorted(cs, key=lambda c: c.seq):
        r = pyvm.run(vops[c.op_id], ep.regions, seq, list(c.params),
                     home=c.home)
        assert r.status == 0
        rets.append(r.ret)
    return seq, rets


def _sweep_skip(quick: bool) -> List[dict]:
    batches = QUICK_BATCHES if quick else BATCHES
    reps = QUICK_REPS if quick else REPS
    n_dev = min(4, len(jax.devices()))
    engines = [("mixed", 1, dict(mode="mixed"))]
    if n_dev > 1:
        engines.append(("sharded", n_dev,
                        dict(mode="mixed", placement="sharded")))
    out = []
    for batch in batches:
        for engine, devs, db_kwargs in engines:
            out.append(_sweep_skip_one(batch, reps, engine, devs,
                                       db_kwargs))
    return out


def _sweep_skip_one(batch, reps, engine, devs, db_kwargs) -> dict:
    ep, sessions, names = _setup(batch, devs)

    def drain():
        for s in sessions.values():
            s.poll_cq()

    # parity + proof audit before timing: the proven wave must actually
    # prove (sweep skipped), and both variants must match the pyvm
    # oracle bit-for-bit
    parity = True
    for analysis, want in ((True, True), (False, False)):
        ep.registry.static_analysis = analysis
        cs = _post_wave(sessions, names, batch, devs)
        seq, rets = _oracle(ep, cs)
        ep.doorbell(**db_kwargs)
        parity = (parity and np.array_equal(ep.mem, seq)
                  and [c.ret for c in sorted(cs, key=lambda c: c.seq)]
                  == rets)
        assert ep.last_noconflict is want, (
            f"{engine}: static_analysis={analysis}: expected proof "
            f"verdict {want}, got {ep.last_noconflict}")
        drain()

    # min-of-N doorbell wall clock, strictly interleaved so slow host
    # phases (GC, thermal, noisy neighbors) hit both sides alike.  Only
    # the doorbell is timed — the posting loop and CQ drain are
    # identical on both sides and the skip can't touch them, so
    # including them would just dilute the ratio with the host's
    # largest noise source.
    times = {True: [], False: []}
    for _ in range(reps):
        for analysis in (True, False):
            ep.registry.static_analysis = analysis
            _post_wave(sessions, names, batch, devs)
            t0 = time.perf_counter()
            ep.doorbell(**db_kwargs)
            times[analysis].append(time.perf_counter() - t0)
            drain()
    s_proof, s_sweep = min(times[True]), min(times[False])
    return dict(
        section="sweep_skip", engine=engine, batch=batch,
        tenants=len(TENANTS), n_devices=devs,
        us_per_call=s_proof * 1e6, ops_per_s=batch / s_proof,
        us_per_call_sweep=s_sweep * 1e6,
        ops_per_s_sweep=batch / s_sweep,
        speedup_sweep_skip=s_sweep / s_proof,
        parity_ok=bool(parity))


# ---------------------------------------------------------------------------
# Part B: soundness of the proof vs exact dynamic footprints
# ---------------------------------------------------------------------------

def _corpus_table():
    return memory.packed_table([("src", 1024), ("reply", 1024),
                                ("acc", 256)])


def _corpus_registry(rt):
    reg = OperatorRegistry(rt, n_devices=2)
    reg.add_tenant(Grant.all_of(rt, "t"))

    def pair():
        b = OperatorBuilder("pair", n_params=2, regions=rt)
        t = b.reg()
        b.alu(t, b.param(1), Alu.ADD, 7)
        b.store(t, "reply", b.param(0))
        b.store(t, "reply", b.param(0), disp=1)
        b.ret(t)
        return b.build()

    def window():
        b = OperatorBuilder("window", n_params=3, regions=rt)
        i, v = b.reg(), b.reg()
        b.alu(i, b.param(0), Alu.ADD, 0)
        with b.loop((b.param(2), 8)):
            b.load(v, "src", i)
            b.store(v, "reply", i)
            b.alu(i, i, Alu.ADD, 1)
        b.ret(v)
        return b.build()

    def chase():
        b = OperatorBuilder("chase", n_params=1, regions=rt)
        v = b.reg()
        b.load(v, "src", b.param(0))
        b.store(v, "reply", v)
        b.ret(v)
        return b.build()

    def atom():
        b = OperatorBuilder("atom", n_params=3, regions=rt)
        old = b.reg()
        b.caa(old, "acc", b.param(0), b.param(1), b.param(2))
        b.ret(old)
        return b.build()

    builders = dict(pair=pair, window=window, chase=chase, atom=atom)
    return reg, {f: reg.register("t", fn()) for f, fn in builders.items()}


def _touched(fam, rt, mem0, params, home):
    """Exact dynamic (read_cells, write_cells) of one lane — what the
    runtime sweep sees: masked in-region word addresses, atomics as
    writes whatever the compare outcome."""
    src, rep, acc = rt["src"], rt["reply"], rt["acc"]
    p = list(params) + [0] * 8
    if fam == "pair":
        return set(), {(home, rep.base + (p[0] & rep.mask)),
                       (home, rep.base + ((p[0] + 1) & rep.mask))}
    if fam == "window":
        trip = min(max(p[2], 0), 8)
        return ({(home, src.base + ((p[0] + t) & src.mask))
                 for t in range(trip)},
                {(home, rep.base + ((p[0] + t) & rep.mask))
                 for t in range(trip)})
    if fam == "chase":
        cell = src.base + (p[0] & src.mask)
        v = int(mem0[home, cell])
        return {(home, cell)}, {(home, rep.base + (v & rep.mask))}
    return set(), {(home, acc.base + (p[0] & acc.mask))}


def _would_conflict(lanes):
    for i in range(len(lanes)):
        ri, wi = lanes[i]
        for j in range(i):
            rj, wj = lanes[j]
            if (wi & (rj | wj)) or (wj & ri):
                return True
    return False


def _soundness(quick: bool) -> dict:
    fams_all = ("pair", "window", "chase", "atom")
    rounds = 60 if quick else 400
    rt = _corpus_table()
    reg, ids = _corpus_registry(rt)
    rng = np.random.default_rng(2026)
    mem0 = rng.integers(0, 2048, size=(2, rt.pool_words)).astype(np.int64)
    proven = refused = unsound = 0
    for k in range(rounds):
        disjoint = k % 2 == 0
        fams, params, homes = [], [], []
        for lane in range(4):
            fam = fams_all[int(rng.integers(len(fams_all)))]
            if disjoint:
                if fam == "chase":
                    fam = "pair"            # ⊤ footprints never prove
                base = 64 * lane
                p = {"pair": [base, 3], "window": [base, 0, 5],
                     "atom": [32 * lane, 0, 1]}[fam]
                home = lane % 2
            else:
                p = {"pair": [int(rng.integers(1024)), 3],
                     "window": [int(rng.integers(1024)), 0,
                                int(rng.integers(12))],
                     "chase": [int(rng.integers(1024))],
                     "atom": [int(rng.integers(256)), 0, 1]}[fam]
                home = int(rng.integers(2))
            fams.append(fam)
            params.append(p)
            homes.append(home)
        verdict = reg.prove_wave_noconflict(
            [ids[f] for f in fams], params, homes, n_devices=2)
        lanes = [_touched(f, rt, mem0, p, h)
                 for f, p, h in zip(fams, params, homes)]
        if verdict:
            proven += 1
            if _would_conflict(lanes):
                unsound += 1
        else:
            refused += 1
    ok = unsound == 0 and proven > 0 and refused > 0
    return dict(section="soundness", rounds=rounds,
                proven_waves=proven, refused_waves=refused,
                unsound_clears=unsound, soundness_ok=bool(ok))


def measure(quick: bool = False) -> List[dict]:
    return _sweep_skip(quick) + [_soundness(quick)]


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="static conflict proofs: 4-tenant reply-slot wave "
                 "(mixed engine, sweep skipped under proof) + seeded "
                 "random-wave soundness corpus vs exact dynamic "
                 "footprints",
        unit="ops/s",
        acceptance="proven wave bit-identical to pyvm with the runtime "
                   "sweep skipped; the proof never clears a wave whose "
                   "dynamic read/write sets conflict (soundness_ok)",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        if r["section"] == "sweep_skip":
            flag = "" if r["parity_ok"] else "  PARITY-MISMATCH"
            out.append(Row(
                name=(f"static_analysis/sweep_skip/{r['engine']}"
                      f"/B={r['batch']}"),
                us_per_call=r["us_per_call"],
                derived=r["ops_per_s"] / 1e6, unit="Mops",
                note=f"x{r['speedup_sweep_skip']:.2f} vs always-sweep, "
                     f"{r['n_devices']} dev{flag}"))
        else:
            out.append(Row(
                name=f"static_analysis/soundness/rounds={r['rounds']}",
                us_per_call=0.0,
                derived=float(r["proven_waves"]), unit="waves",
                note=(f"{r['proven_waves']} proven / "
                      f"{r['refused_waves']} refused, "
                      f"{r['unsound_clears']} unsound"
                      + ("" if r["soundness_ok"] else "  UNSOUND"))))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
