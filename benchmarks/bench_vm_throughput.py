"""Operator throughput through the endpoint: interp vs batched vs compiled.

The paper's Fig. 7 point is that the NIC pipeline keeps many requests in
flight, so *throughput*, not latency, is the headline.  This benchmark
drives the software analogue through the queue-pair surface — the 10-hop
graph-traversal operator posted to a ``Session`` and drained by
``doorbell(mode=...)`` —

  * one request per doorbell on the single-request interpreter (the
    pre-batching engine — every launch pays dispatch + a 13-way switch
    per instruction),
  * B posts per doorbell on the batch-parallel interpreter, and
  * B posts per doorbell on the registration-time trace-compiled path
    (no interpreter at all: straight-line gather chains).

Timing includes the posting loop, so this is also the endpoint-overhead
case the scheduled quick-bench job watches.  Wall-clock ops/s at B in
{1, 64, 1024} are printed as rows and written to
``BENCH_vm_throughput.json`` for machine consumption.
"""

from __future__ import annotations

import json
import os
from typing import List

from repro.core import operators as ops
from repro.core.endpoint import TiaraEndpoint

from benchmarks._workbench import Row, rate as _wb_rate

# anchored to the repo root regardless of the invoking cwd
JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_vm_throughput.json")
BATCHES = (1, 64, 1024)
# quick mode overlaps the committed B=64 row so the CI regression gate
# compares a real-batch metric, not just launch-overhead-dominated B=1
QUICK_BATCHES = (1, 64)
DEPTH = 10                    # the paper's 10-hop traversal
MAX_DEPTH = 16
N_NODES = 4096
MIN_SECONDS = 0.3


def _setup(max_batch: int):
    w = ops.GraphWalk(n_nodes=N_NODES, max_depth=MAX_DEPTH,
                      reply_words=max_batch * ops.NODE_WORDS)
    ep, sessions = TiaraEndpoint.for_tenants([("bench", w.regions())])
    s = sessions["bench"]
    prog = w.build(s.view, reply_param=True)
    s.register(prog)
    order = w.populate(s.pool, s.view)
    return ep, s, prog.name, order


def _post(s, name, order, batch: int):
    for i in range(batch):
        s.post(name, [int(order[i % N_NODES]) * 8, DEPTH,
                      i * ops.NODE_WORDS])


def _rate(fn, per_call_ops: int) -> tuple:
    return _wb_rate(fn, per_call_ops, MIN_SECONDS)


def measure(quick: bool = False) -> List[dict]:
    batches = QUICK_BATCHES if quick else BATCHES
    ep, s, name, order = _setup(max(batches))
    out: List[dict] = []

    def wave(batch: int, mode: str):
        _post(s, name, order, batch)
        ep.doorbell(mode=mode)
        s.poll_cq()

    # single-request interpreter: one doorbell per request
    def interp_one():
        wave(1, "interp")

    us, rate = _rate(interp_one, 1)
    base = rate
    out.append(dict(engine="interp", batch=1, us_per_call=us, ops_per_s=rate,
                    speedup_vs_interp=1.0))

    for engine in ("batched", "compiled"):
        for b in batches:
            def call(b=b, engine=engine):
                wave(b, engine)

            us, rate = _rate(call, b)
            out.append(dict(engine=engine, batch=b, us_per_call=us,
                            ops_per_s=rate, speedup_vs_interp=rate / base))
    return out


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(workload=f"graph_walk depth={DEPTH} n_nodes={N_NODES} "
                            f"via Session.post + doorbell",
                   unit="ops/s", results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        out.append(Row(
            name=f"vm_tput/{r['engine']}/B={r['batch']}",
            us_per_call=r["us_per_call"],
            derived=r["ops_per_s"] / 1e6, unit="Mops",
            note=f"x{r['speedup_vs_interp']:.1f} vs 1-req interpreter"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
