"""Operator throughput: interpreter vs batch-parallel vs trace-compiled.

The paper's Fig. 7 point is that the NIC pipeline keeps many requests in
flight, so *throughput*, not latency, is the headline.  This benchmark
drives the software analogue: the 10-hop graph-traversal operator executed

  * one request per XLA launch on the single-request interpreter (the
    pre-batching engine — every launch pays dispatch + a 13-way switch
    per instruction),
  * B requests per launch on the batch-parallel interpreter, and
  * B requests per launch on the registration-time trace-compiled path
    (no interpreter at all: straight-line gather chains).

Wall-clock ops/s at B in {1, 64, 1024} are printed as rows and written to
``BENCH_vm_throughput.json`` for machine consumption.
"""

from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from repro.core import compile as tc
from repro.core import memory, vm
from repro.core import operators as ops
from repro.core.memory import Grant
from repro.core.verifier import verify

from benchmarks._workbench import Row, rate as _wb_rate

# anchored to the repo root regardless of the invoking cwd
JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_vm_throughput.json")
BATCHES = (1, 64, 1024)
QUICK_BATCHES = (1, 32)
DEPTH = 10                    # the paper's 10-hop traversal
MAX_DEPTH = 16
N_NODES = 4096
MIN_SECONDS = 0.3


def _setup(max_batch: int):
    w = ops.GraphWalk(n_nodes=N_NODES, max_depth=MAX_DEPTH,
                      reply_words=max_batch * ops.NODE_WORDS)
    rt = w.regions()
    vop = verify(w.build(rt, reply_param=True), grant=Grant.all_of(rt),
                 regions=rt)
    mem = memory.make_pool(1, rt)
    order = w.populate(mem, rt)
    return w, rt, vop, mem, order


def _params(order, batch: int):
    return [[int(order[i % N_NODES]) * 8, DEPTH, i * ops.NODE_WORDS]
            for i in range(batch)]


def _rate(fn, per_call_ops: int) -> tuple:
    return _wb_rate(fn, per_call_ops, MIN_SECONDS)


def measure(quick: bool = False) -> List[dict]:
    batches = QUICK_BATCHES if quick else BATCHES
    w, rt, vop, mem, order = _setup(max(batches))
    out: List[dict] = []

    # single-request interpreter: one launch per request
    p1 = _params(order, 1)[0]

    def interp_one():
        vm.invoke(vop, rt, mem, p1)

    us, rate = _rate(interp_one, 1)
    base = rate
    out.append(dict(engine="interp", batch=1, us_per_call=us, ops_per_s=rate,
                    speedup_vs_interp=1.0))

    for b in batches:
        pb = _params(order, b)

        def batched():
            vm.invoke_batched(vop, rt, mem, pb)

        us, rate = _rate(batched, b)
        out.append(dict(engine="batched", batch=b, us_per_call=us,
                        ops_per_s=rate, speedup_vs_interp=rate / base))

    for b in batches:
        pb = _params(order, b)

        def compiled():
            tc.invoke_compiled(vop, rt, mem, pb)

        us, rate = _rate(compiled, b)
        out.append(dict(engine="compiled", batch=b, us_per_call=us,
                        ops_per_s=rate, speedup_vs_interp=rate / base))
    return out


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(workload=f"graph_walk depth={DEPTH} n_nodes={N_NODES}",
                   unit="ops/s", results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        out.append(Row(
            name=f"vm_tput/{r['engine']}/B={r['batch']}",
            us_per_call=r["us_per_call"],
            derived=r["ops_per_s"] / 1e6, unit="Mops",
            note=f"x{r['speedup_vs_interp']:.1f} vs 1-req interpreter"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
