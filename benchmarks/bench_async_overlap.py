"""Async-MEMCPY overlap: split-phase vs serialized gather chains.

Two views of the same question — how much does the paper's async
MEMCPY + WAIT split-phase actually buy?

  * **Simulated cycles** (deterministic): the 10-chunk async gather
    chain's trace replayed on the cycle model with real deferred
    completion vs the same trace with every Memcpy serialized
    (``simulate_task(serialize_async=True)``).  The ratio is the gated
    ``speedup_overlap_sim`` metric — pure model, no host noise.
  * **Wall clock** (informational): the double-buffered compiled
    gather chain (``mode="compiled_dbuf"``: chunk k+1's gather issued
    before chunk k's scatter) vs the monolithic compiled trace, and the
    split-phase endpoint pipeline (``doorbell(wait=False)`` with two
    waves in flight) vs blocking per-wave doorbells.  On one CPU the
    XLA scheduler may hide little — the numbers measure the schedule's
    structural cost, and the measured mono/dbuf pair feeds
    ``DispatchCostModel.observe_overlap`` (the learned term future
    ``mode="auto"`` picks price with, recorded as ``learned_overlap``).

Every timed wave is checked bit-identical against the per-request
``pyvm`` oracle first (``parity_ok`` — gated unconditionally by
``check_regression``).
"""

from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from repro.core import operators as ops
from repro.core import pyvm
from repro.core import simulator as sim
from repro.core.endpoint import TiaraEndpoint
from repro.core.memory import write_region

from benchmarks._workbench import Row, rate as _rate, run_traced

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_async_overlap.json")
MIN_SECONDS = 0.25
SLAB_WORDS = 256


def _sim_overlap(chunks: int) -> dict:
    """Deterministic cycle-model overlap of a ``chunks``-chunk async
    gather chain (MoE-shaped: id -> table -> slab memcpy, all async,
    one WAIT(0) join)."""
    moe = ops.MoEExpertGather(n_experts=64, max_k=32,
                              slab_words=SLAB_WORDS)

    def setup(mem, rt):
        write_region(mem, rt, 0, "expert_ids",
                     np.arange(chunks, dtype=np.int64))

    vop, trace, res, _, _ = run_traced(moe, moe.build, [chunks],
                                       setup_fn=setup)
    asyn = sim.simulate_task(vop, trace)
    ser = sim.simulate_task(vop, trace, serialize_async=True)
    assert asyn.async_issued == chunks
    return dict(section="sim", workload="moe_gather_chain",
                chunks=chunks,
                nic_us_async=asyn.nic_resident_us,
                nic_us_serialized=ser.nic_resident_us,
                speedup_overlap_sim=ser.nic_resident_us
                / asyn.nic_resident_us,
                parity_ok=bool(res.status == 0))


def _wall_clock(quick: bool) -> List[dict]:
    """Wall clock for the double-buffered vs monolithic compiled chain
    and for the pipelined split-phase doorbell, through the endpoint."""
    B = 8 if quick else 16
    k = 10                                # the 10-chunk chain
    min_seconds = 0.05 if quick else MIN_SECONDS
    moe = ops.MoEExpertGather(n_experts=64, max_k=32,
                              slab_words=SLAB_WORDS, reply_slots=B)
    ep, sessions = TiaraEndpoint.for_tenants([("bench", moe.regions())])
    s = sessions["bench"]
    s.register(moe.build(s.view, reply_param=True))
    moe.populate(s.pool, s.view)
    s.write_region("expert_ids", np.arange(32, dtype=np.int64) % 64)
    stride = 32 * SLAB_WORDS              # disjoint per-request slots

    def post_wave(n=B):
        return [s.post("moe_expert_gather", [k, i * stride])
                for i in range(n)]

    vops = ep.registry.store_ops()

    def oracle_parity(cs) -> bool:
        """Replay the (already retired) posts one at a time on pyvm
        from the pre-wave pool snapshot and compare bit-for-bit."""
        rets = [pyvm.run(vops[c.op_id], ep.regions, _seq, list(c.params)
                         ).ret
                for c in sorted(cs, key=lambda c: c.seq)]
        return (np.array_equal(np.asarray(ep._host_view()), _seq)
                and [c.ret for c in sorted(cs, key=lambda c: c.seq)]
                == rets)

    # parity: every timed schedule's wave vs the per-request pyvm oracle
    _seq = np.array(ep._host_view())
    cs = post_wave()
    ep.doorbell(mode="compiled_dbuf")
    parity_dbuf = oracle_parity(cs)
    s.poll_cq()
    _seq = np.array(ep._host_view())
    cs = post_wave()
    ep.doorbell(mode="compiled")
    parity_mono = oracle_parity(cs)
    s.poll_cq()

    def run_mode(mode):
        def call():
            post_wave()
            ep.doorbell(mode=mode)
            s.poll_cq()
        return _rate(call, B, min_seconds)

    mono_us, mono_rate = run_mode("compiled")
    dbuf_us, dbuf_rate = run_mode("compiled_dbuf")
    # the measured pair is exactly what the cost model learns from:
    # the whole trace is chain, so chain_frac=1
    learned = ep.registry.cost_model.observe_overlap(mono_us, dbuf_us)
    out = [dict(section="wall", engine="compiled_mono", batch=B,
                chunks=k, us_per_call=mono_us, ops_per_s=mono_rate,
                parity_ok=bool(parity_mono)),
           dict(section="wall", engine="compiled_dbuf", batch=B,
                chunks=k, us_per_call=dbuf_us, ops_per_s=dbuf_rate,
                parity_ok=bool(parity_dbuf), learned_overlap=learned)]

    # split-phase endpoint pipeline: two half-waves in flight vs two
    # blocking doorbells (same total work, same engines)
    half = B // 2

    def blocking(wait=True):
        cs = post_wave(half)
        h1 = ep.doorbell(mode="compiled", wait=wait)
        cs += post_wave(half)
        ep.doorbell(mode="compiled", wait=wait)
        if not wait:
            assert not h1.done          # really launched split-phase
            ep.wait_all()
        s.poll_cq()
        return cs

    _seq = np.array(ep._host_view())
    parity_blk = oracle_parity(blocking(wait=True))
    _seq = np.array(ep._host_view())
    parity_pip = oracle_parity(blocking(wait=False))

    blk_us, blk_rate = _rate(lambda: blocking(wait=True), B, min_seconds)
    pip_us, pip_rate = _rate(lambda: blocking(wait=False), B,
                             min_seconds)
    out.append(dict(section="wall", engine="doorbell_blocking", batch=B,
                    chunks=k, us_per_call=blk_us, ops_per_s=blk_rate,
                    parity_ok=bool(parity_blk)))
    out.append(dict(section="wall", engine="doorbell_pipelined", batch=B,
                    chunks=k, us_per_call=pip_us, ops_per_s=pip_rate,
                    parity_ok=bool(parity_pip)))
    return out


def measure(quick: bool = False) -> List[dict]:
    results = [_sim_overlap(10)]
    if not quick:
        results.append(_sim_overlap(32))
    results.extend(_wall_clock(quick))
    return results


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="async MEMCPY overlap: split-phase (deferred "
                 "completion) vs serialized gather chains, simulated "
                 "cycles + wall clock via the endpoint",
        unit="x (sim) / ops/s (wall)",
        acceptance="simulated overlap speedup > 1.3x on the 10-chunk "
                   "chain; double-buffered wave bit-identical to the "
                   "pyvm oracle",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        if r["section"] == "sim":
            out.append(Row(
                name=f"async_overlap/sim_chain{r['chunks']}",
                us_per_call=r["nic_us_async"],
                derived=r["speedup_overlap_sim"], unit="x",
                note="simulated serialized/async NIC residency"))
        else:
            out.append(Row(
                name=f"async_overlap/wall_{r['engine']}_B{r['batch']}",
                us_per_call=r["us_per_call"],
                derived=r["ops_per_s"], unit="ops/s",
                note="host wall clock (informational)"))
    return out
