"""Multi-tenant mixed-op batching: one doorbell for an interleaved mix.

The paper's NIC multiplexes *many tenants'* pre-registered operators
through the 256-entry dispatch table at line rate.  The software analogue
is the queue-pair endpoint surface: four tenants each hold a ``Session``
on one ``TiaraEndpoint``, post an interleaved serving wave (GraphWalk,
PageTableWalk, PagedAttention KV fetch, MoE expert gather, round-robin by
tenant — the worst case for launch batching, every adjacent pair differs
in op_id), and one ``doorbell()`` drains it.  Engines compared at each
batch size (``doorbell(mode=...)``):

  * ``serial``     the no-mixed-batching baseline: one launch per
                   contiguous same-op run in arrival order.  A fully
                   interleaved wave degenerates to one XLA launch per
                   request — this is what "one operator per launch"
                   costs a realistic mix.
  * ``mixed``      one lockstep launch over the merged instruction store;
                   each request enters at its op's ``start_pc`` from the
                   dispatch table.
  * ``segmented``  stable-sort by op_id + one compiled straight-line
                   launch per segment, outputs scattered back to arrival
                   order.
  * ``auto``       whatever the analytical cost model picks.

Timing includes the posting loop — the measured quantity is the cost of
the *surface*, not just the launch.  Every engine's results are checked
bit-identical against the per-request ``pyvm`` oracle before timing
(``parity_ok`` in the JSON).  Wall-clock ops/s and the speedup over
``serial`` are written to ``BENCH_mixed_batch.json``.
"""

from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from repro.core import pyvm
from repro.core import operators as ops
from repro.core.endpoint import TiaraEndpoint

from benchmarks._workbench import Row, rate as _rate

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_mixed_batch.json")
BATCHES = (64, 256, 1024)
QUICK_BATCHES = (16, 64)
GRAPH_DEPTH = 10
MIN_SECONDS = 0.25
ENGINES = ("serial", "mixed", "segmented", "auto")
TENANTS = ("gw", "ptw", "kv", "moe")


def _setup(max_batch: int, n_devices: int = 1):
    """One endpoint, four tenant sessions, one shared pool.  Every
    workload gets per-request disjoint reply slots (``reply_param``) —
    the serving configuration, and what lets the whole wave run
    conflict-free.  With ``n_devices > 1`` every device's pool row is
    populated identically, so waves may scatter posts over any homes
    (``bench_sharded`` reuses this setup over a device mesh)."""
    n_slots = max(max_batch // 4 + 1, 64)
    gw = ops.GraphWalk(n_nodes=1024, max_depth=16,
                       reply_words=n_slots * ops.NODE_WORDS)
    ptw = ops.PageTableWalk(fanout=16, n_pages=32, reply_pages=n_slots)
    kv = ops.PagedKVFetch(n_blocks_pool=64, block_bytes=2048,
                          max_req_blocks=4, reply_slots=n_slots)
    moe = ops.MoEExpertGather(n_experts=64, max_k=4, slab_words=256,
                              reply_slots=n_slots)
    ep, sessions = TiaraEndpoint.for_tenants([
        ("gw", gw.regions()), ("ptw", ptw.regions()),
        ("kv", kv.regions()), ("moe", moe.regions())],
        n_devices=n_devices)
    names = {}
    for tenant, wl in (("gw", gw), ("ptw", ptw), ("kv", kv), ("moe", moe)):
        s = sessions[tenant]
        prog = wl.build(s.view, reply_param=True)
        s.register(prog)
        names[tenant] = prog.name
    for d in range(n_devices):
        order = gw.populate(sessions["gw"].pool, sessions["gw"].view,
                            device=d)
        vamap = ptw.populate(sessions["ptw"].pool, sessions["ptw"].view,
                             device=d)
        kv.populate(sessions["kv"].pool, sessions["kv"].view, device=d)
        kv.make_request(sessions["kv"].pool, sessions["kv"].view,
                        [3, 9, 1], device=d)
        moe.populate(sessions["moe"].pool, sessions["moe"].view, device=d)
        sessions["moe"].write_region(
            "expert_ids", np.asarray([7, 0, 31, 12], dtype=np.int64),
            device=d)
    vas = sorted(vamap.keys())
    return ep, sessions, names, order, vas


def _post_wave(sessions: dict, names: dict, order, vas, batch: int,
               n_devices: int = 1):
    """Round-robin 4-tenant interleaving posted across the sessions: the
    worst case for per-op launch batching (every adjacent pair differs in
    op_id).  With ``n_devices > 1`` the posts also round-robin their
    ``home`` over the devices (the sharded-placement wave).  Returns the
    completion handles in arrival order."""
    cs = []
    slot = {t: 0 for t in TENANTS}
    for i in range(batch):
        t = TENANTS[i % 4]
        j = slot[t]
        slot[t] += 1
        if t == "gw":
            p = [int(order[i % len(order)]) * 8, GRAPH_DEPTH,
                 j * ops.NODE_WORDS]
        elif t == "ptw":
            p = [int(vas[i % len(vas)]), j * ops.PAGE_WORDS]
        elif t == "kv":
            # varied block counts, disjoint reply slots per request
            p = [1 + i % 3, j * 4 * 256]
        else:
            p = [1 + i % 4, j * 4 * 256]
        cs.append(sessions[t].post(names[t], p, home=i % n_devices))
    return cs


def _oracle(ep, cs):
    """Per-request pyvm replay of the posted wave in arrival order."""
    vops = ep.registry.store_ops()
    seq = ep.mem.copy()
    rets, stats, steps = [], [], []
    for c in sorted(cs, key=lambda c: c.seq):
        r = pyvm.run(vops[c.op_id], ep.regions, seq, list(c.params),
                     home=c.home)
        rets.append(r.ret)
        stats.append(r.status)
        steps.append(r.steps)
    return seq, np.array(rets), np.array(stats), np.array(steps)


def _parity(ep, cs, oracle) -> bool:
    seq, rets, stats, steps = oracle
    cs = sorted(cs, key=lambda c: c.seq)
    return (np.array_equal(ep.mem, seq)
            and rets.tolist() == [c.ret for c in cs]
            and stats.tolist() == [c.status for c in cs]
            and steps.tolist() == [c.steps for c in cs])


def _drain(sessions: dict) -> None:
    for s in sessions.values():
        s.poll_cq()


def measure(quick: bool = False) -> List[dict]:
    batches = QUICK_BATCHES if quick else BATCHES
    min_seconds = 0.05 if quick else MIN_SECONDS
    ep, sessions, names, order, vas = _setup(max(batches))
    out: List[dict] = []
    for b in batches:
        oracle = None
        rates = {}
        for engine in ENGINES:
            # the workloads only write their (per-request) reply slots,
            # so re-posting the same wave is idempotent — repetition for
            # timing leaves the pool in the oracle state
            cs = _post_wave(sessions, names, order, vas, b)
            if oracle is None:
                oracle = _oracle(ep, cs)
            ep.doorbell(mode=engine)
            parity = _parity(ep, cs, oracle)
            _drain(sessions)

            def call(engine=engine):
                _post_wave(sessions, names, order, vas, b)
                ep.doorbell(mode=engine)
                _drain(sessions)

            us, rate = _rate(call, b, min_seconds)
            rates[engine] = rate
            out.append(dict(engine=engine, batch=b, us_per_call=us,
                            ops_per_s=rate, parity_ok=bool(parity)))
        for r in out:
            if r["batch"] == b:
                r["speedup_vs_serial"] = r["ops_per_s"] / rates["serial"]
    return out


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="4-tenant interleaved mix: graph_walk + ptw3 + "
                 "paged_kv_fetch + moe_expert_gather (round-robin), "
                 "posted via Session.post + TiaraEndpoint.doorbell",
        unit="ops/s",
        acceptance="mixed-op engine at max batch >= 5x serial ops/s, "
                   "all engines bit-identical to the pyvm oracle",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        flag = "" if r["parity_ok"] else "  PARITY-MISMATCH"
        out.append(Row(
            name=f"mixed_batch/{r['engine']}/B={r['batch']}",
            us_per_call=r["us_per_call"],
            derived=r["ops_per_s"] / 1e6, unit="Mops",
            note=f"x{r['speedup_vs_serial']:.1f} vs serial{flag}"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
