"""Multi-tenant mixed-op batching: one launch for an interleaved serving mix.

The paper's NIC multiplexes *many tenants'* pre-registered operators
through the 256-entry dispatch table at line rate.  The software analogue:
a serving wave that interleaves GraphWalk, PageTableWalk, PagedAttention
KV fetch and MoE expert gather requests (round-robin by tenant — the worst
case for launch batching, every adjacent pair differs in op_id).  Engines
compared at each batch size:

  * ``serial``     the no-mixed-batching baseline: one ``invoke_batched``
                   launch per contiguous same-op run in arrival order.  A
                   fully interleaved wave degenerates to one XLA launch
                   per request — this is what "one operator per launch"
                   costs a realistic mix.
  * ``mixed``      one lockstep launch over the merged instruction store;
                   each request enters at its op's ``start_pc`` from the
                   dispatch table.
  * ``segmented``  stable-sort by op_id + one compiled straight-line
                   launch per segment, outputs scattered back to arrival
                   order.
  * ``auto``       whatever the analytical cost model picks.

Every engine's results are checked bit-identical against the per-request
``pyvm`` oracle before timing (``parity_ok`` in the JSON).  Wall-clock
ops/s and the speedup over ``serial`` are written to
``BENCH_mixed_batch.json``.
"""

from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from repro.core import memory, pyvm
from repro.core import operators as ops
from repro.core.memory import Grant, merge_tables
from repro.core.registry import OperatorRegistry

from benchmarks._workbench import Row, rate as _rate

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_mixed_batch.json")
BATCHES = (64, 256, 1024)
QUICK_BATCHES = (16, 64)
GRAPH_DEPTH = 10
MIN_SECONDS = 0.25
ENGINES = ("serial", "mixed", "segmented", "auto")


def _setup(max_batch: int):
    """One registry, four tenants, one shared pool.  Every workload gets
    per-request disjoint reply slots (``reply_param``) — the serving
    configuration, and what lets the whole wave run conflict-free."""
    n_slots = max(max_batch // 4 + 1, 64)
    gw = ops.GraphWalk(n_nodes=1024, max_depth=16,
                       reply_words=n_slots * ops.NODE_WORDS)
    ptw = ops.PageTableWalk(fanout=16, n_pages=32, reply_pages=n_slots)
    kv = ops.PagedKVFetch(n_blocks_pool=64, block_bytes=2048,
                          max_req_blocks=4, reply_slots=n_slots)
    moe = ops.MoEExpertGather(n_experts=64, max_k=4, slab_words=256,
                              reply_slots=n_slots)
    combined, views = merge_tables([
        ("gw", gw.regions()), ("ptw", ptw.regions()),
        ("kv", kv.regions()), ("moe", moe.regions())])
    reg = OperatorRegistry(combined)
    for tenant in views:
        reg.add_tenant(Grant.all_of(views[tenant], tenant))
    op_ids = {
        "gw": reg.register("gw", gw.build(views["gw"], reply_param=True)),
        "ptw": reg.register("ptw",
                            ptw.build(views["ptw"], reply_param=True)),
        "kv": reg.register("kv", kv.build(views["kv"],
                                          reply_param=True)),
        "moe": reg.register("moe", moe.build(views["moe"],
                                             reply_param=True)),
    }
    mem = memory.make_pool(1, combined)
    order = gw.populate(mem, views["gw"])
    vamap = ptw.populate(mem, views["ptw"])
    kv.populate(mem, views["kv"])
    kv.make_request(mem, views["kv"], [3, 9, 1])
    moe.populate(mem, views["moe"])
    memory.write_region(mem, views["moe"], 0, "expert_ids",
                        np.asarray([7, 0, 31, 12], dtype=np.int64))
    vas = sorted(vamap.keys())
    return reg, mem, op_ids, order, vas


def _mix(op_ids: dict, order, vas, batch: int):
    """Round-robin 4-tenant interleaving: the worst case for per-op
    launch batching (every adjacent pair differs in op_id)."""
    tenants = ("gw", "ptw", "kv", "moe")
    ids, params = [], []
    slot = {t: 0 for t in tenants}
    for i in range(batch):
        t = tenants[i % 4]
        ids.append(op_ids[t])
        j = slot[t]
        slot[t] += 1
        if t == "gw":
            params.append([int(order[i % len(order)]) * 8,
                           GRAPH_DEPTH, j * ops.NODE_WORDS])
        elif t == "ptw":
            params.append([int(vas[i % len(vas)]), j * ops.PAGE_WORDS])
        elif t == "kv":
            # varied block counts, disjoint reply slots per request
            params.append([1 + i % 3, j * 4 * 256])
        else:
            params.append([1 + i % 4, j * 4 * 256])
    return ids, params


def _oracle(reg, mem, ids, params):
    vops = reg.store_ops()
    seq = mem.copy()
    rets, stats, steps = [], [], []
    for op_id, p in zip(ids, params):
        r = pyvm.run(vops[op_id], reg.regions, seq, p)
        rets.append(r.ret)
        stats.append(r.status)
        steps.append(r.steps)
    return seq, np.array(rets), np.array(stats), np.array(steps)


def _parity(res, oracle) -> bool:
    seq, rets, stats, steps = oracle
    return (np.array_equal(res.mem, seq) and np.array_equal(res.ret, rets)
            and np.array_equal(res.status, stats)
            and np.array_equal(res.steps, steps))


def measure(quick: bool = False) -> List[dict]:
    batches = QUICK_BATCHES if quick else BATCHES
    min_seconds = 0.05 if quick else MIN_SECONDS
    reg, mem, op_ids, order, vas = _setup(max(batches))
    out: List[dict] = []
    for b in batches:
        ids, params = _mix(op_ids, order, vas, b)
        oracle = _oracle(reg, mem, ids, params)
        rates = {}
        for engine in ENGINES:
            res = reg.invoke_mixed(ids, mem, params, mode=engine)
            parity = _parity(res, oracle)

            def call(engine=engine):
                reg.invoke_mixed(ids, mem, params, mode=engine)

            us, rate = _rate(call, b, min_seconds)
            rates[engine] = rate
            out.append(dict(engine=engine, batch=b, us_per_call=us,
                            ops_per_s=rate, parity_ok=bool(parity)))
        for r in out:
            if r["batch"] == b:
                r["speedup_vs_serial"] = r["ops_per_s"] / rates["serial"]
    return out


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="4-tenant interleaved mix: graph_walk + ptw3 + "
                 "paged_kv_fetch + moe_expert_gather (round-robin)",
        unit="ops/s",
        acceptance="mixed-op engine at max batch >= 5x serial ops/s, "
                   "all engines bit-identical to the pyvm oracle",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        flag = "" if r["parity_ok"] else "  PARITY-MISMATCH"
        out.append(Row(
            name=f"mixed_batch/{r['engine']}/B={r['batch']}",
            us_per_call=r["us_per_call"],
            derived=r["ops_per_s"] / 1e6, unit="Mops",
            note=f"x{r['speedup_vs_serial']:.1f} vs serial{flag}"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
