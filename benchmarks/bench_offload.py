"""Figures 2 & 3 — when does memory-side offloading beat one-sided RDMA?

Fig. 2 (BlueField-2 measurement): off-path offload *increases* latency for
every operator because each ARM-core host-memory access costs 1.7 us via
internal RDMA, close to the 1.9 us cable RTT.  Model: one-sided RDMA pays
the cable RTT plus one NIC-native host access (~0.7 us [calib: reproduces
the paper's 38% atomic-read regression]); BF-2 pays the RTT plus one
internal-RDMA hop per dependent access.

Fig. 3 (analytical sweep): offload latency = RTT + depth x host_mem; the
crossover where offload wins sits at host_mem ~ RTT x (d-1)/d -> RTT.
Tiara's 0.75 us PCIe DMA and BF-3 DPA's 0.85 us both sit well below it.
"""

from __future__ import annotations

from typing import List

from repro.core import costmodel as cm

from benchmarks._workbench import Row

NIC_NATIVE_HOST_US = 0.7   # [calib: 38% BF-2 atomic-read regression]

# (name, dependent host accesses per op)
OPERATORS = (("atomic_read", 1), ("ptw3", 3), ("graph_d5", 5))


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    out: List[Row] = []
    rtt = cm.BF2_CABLE_RTT_US
    for name, hops in OPERATORS:
        # each dependent one-sided access pays the cable RTT, which already
        # ends in the remote NIC's native host access
        one_sided = hops * (rtt + NIC_NATIVE_HOST_US)
        bf2 = rtt + hops * cm.BF2_HOST_ACCESS_US
        tiara = rtt + hops * cm.TIARA_HOST_ACCESS_US
        bf3 = rtt + hops * cm.BF3_DPA_HOST_ACCESS_US
        out.append(Row(f"fig2/{name}/one_sided_rdma", one_sided, one_sided,
                       "us"))
        out.append(Row(f"fig2/{name}/bf2_offload", bf2, bf2, "us",
                       note="off-path ARM, 1.7us/host access"))
        out.append(Row(f"fig2/{name}/tiara", tiara, tiara, "us"))
        out.append(Row(f"fig2/{name}/bf3_dpa", bf3, bf3, "us"))
        if name == "atomic_read":
            out.append(Row("fig2/atomic_read/bf2_regression", bf2,
                           bf2 / one_sided - 1, "frac", 0.38,
                           note="paper: BF-2 regresses 38%"))

    # Fig 3: sweep host-memory latency at depth 16; crossover -> RTT
    depth = 16
    client = depth * hw.rtt_us
    for h_us in (0.35, cm.TIARA_HOST_ACCESS_US, cm.BF3_DPA_HOST_ACCESS_US,
                 1.7, 2.4, 2.5, 3.0):
        off = cm.offload_chain_latency_us(h_us, depth, hw)
        out.append(Row(f"fig3/depth16/host_mem={h_us}us", off,
                       client / off, "x",
                       note="speedup>1 means offload wins"))
    crossover = hw.rtt_us * (depth - 1) / depth
    out.append(Row("fig3/crossover_host_mem_latency", crossover, crossover,
                   "us", hw.rtt_us,
                   note="-> RTT as depth grows (paper Fig. 3)"))
    return out
