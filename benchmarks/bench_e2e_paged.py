"""End-to-end disaggregated paged decode: ``ServingEngine`` with the
``"tiara"`` resolver vs the host-resolve baseline (ROADMAP item 3).

Every decode step of the tiara lanes really posts one ``PagedKVFetch``
per active slot from its per-sequence session through the
:class:`~repro.core.serving_loop.ServingLoop`; the engine's next decode
consumes the block-table rows the operator's remote-reply MEMCPY
streamed to the client device.  Token output is bit-checked against the
host-resolve engine (``parity_ok``) — the fabric carries real
indirection, not a mock.

Fabric pricing (seeded + deterministic, like ``bench_serving``):

  * **Tiara** — the cycle simulator replays one verified
    ``paged_kv_fetch`` trace (the Fig. 10 methodology) to get the
    per-post blade execution time; a wave of S posts over ``n_mps``
    processors costs ``rtt + ceil(S / n_mps) * exec``, charged to a
    :class:`VirtualClock` as each wave launches.
  * **Host** — the most *charitable* batched-RDMA baseline: all S
    sequences resolve concurrently, so a step's critical path is one
    dependent block-table-read RTT plus the per-block WR builds plus
    the data RTT (``2*rtt + pages_per_seq*client_wr_build_us``; the
    Fig. 10-consistent accounting, with perfect cross-sequence
    overlap).  Tiara's gated speedup is therefore a lower bound.

Lanes: ``single`` (1 home, informational), ``mesh8`` (8 homes,
placement="auto", clients spread over the mesh) with adaptive re-homing
on and off — the on/off delta gates that INDIGO-style migration reduces
cross-device reply words.  The mesh lane runs twice on the same seed
for ``deterministic_ok``.  Gated lanes use identical geometry in
``--quick`` and full runs so the regression gate always matches.
"""

from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Tuple

import numpy as np

from repro.core import costmodel as cm
from repro.core import isa
from repro.core import simulator as sim
from repro.core.serving_loop import VirtualClock
from repro.serving.allocator import BlockAllocator
from repro.serving.engine import ServingEngine

from benchmarks._workbench import Row, run_traced

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_e2e_paged.json")

# gated-lane geometry: identical in quick and full (the regression gate
# matches records by identity, so the shape may not drift with --quick)
N_SEQS = 16
MAX_NEW = 8
SLOTS = 8
N_HOMES = 8
MAX_SEQ = 64
SEED = 9
REHOME_EVERY = 2


def _model():
    from repro.configs import get_config, reduce_config
    from repro.models import transformer as tf
    import jax
    cfg = reduce_config(get_config("tiny-lm"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg) -> List[List[int]]:
    rng = np.random.default_rng(SEED)
    return [list(map(int, rng.integers(1, cfg.vocab, 5 + i % 4)))
            for i in range(N_SEQS)]


def _calibrate_exec_us(pages: int, hw: cm.HW) -> Tuple[float, str]:
    """Per-post blade execution time of one descriptor-granularity
    ``paged_kv_fetch`` (the resolver's exact geometry), from the cycle
    simulator replaying a verified trace — not the engine cost model,
    whose wave prediction includes host launch overheads."""
    k = BlockAllocator(64).region_layout(
        block_bytes=isa.WORD_BYTES, max_req_blocks=pages)

    def setup(mem, rt):
        k.make_request(mem, rt, list(range(pages)))

    vop, trace, res, _, _ = run_traced(
        k, lambda rt: k.build(rt, remote_reply=True), [pages, 1],
        n_devices=2, setup_fn=setup)
    assert res.ok
    ts = sim.simulate_task(vop, trace, hw, pipelined=True,
                           serial_chain=False,
                           reply_payload_bytes=pages * isa.WORD_BYTES)
    return max(ts.latency_us - hw.rtt_us, 0.1), sim.bottleneck(ts, hw)


def _host_step_us(pages: int, hw: cm.HW) -> float:
    # charitable batched-RDMA: table-read RTT -> per-block WR builds ->
    # data RTT, all sequences perfectly overlapped
    return 2 * hw.rtt_us + pages * hw.client_wr_build_us


def _run_host(cfg, params, hw: cm.HW) -> Tuple[Dict[int, List[int]], dict]:
    eng = ServingEngine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                        temperature=0.0, eos_id=-1)
    for p in _prompts(cfg):
        eng.submit(p, max_new=MAX_NEW)
    steps = 0
    while not eng.finished():
        eng.step()
        steps += 1
        assert steps < 10_000
    out = eng.run_to_completion()
    fabric_us = steps * _host_step_us(eng.pages_per_seq, hw)
    return out, dict(steps=steps, fabric_us=fabric_us,
                     pages_per_seq=eng.pages_per_seq)


def _run_tiara(cfg, params, hw: cm.HW, exec_us: float, *, n_homes: int,
               placement: str, rehome: bool
               ) -> Tuple[Dict[int, List[int]], dict]:
    vc = VirtualClock()
    eng = ServingEngine(cfg, params, max_slots=SLOTS, max_seq=MAX_SEQ,
                        temperature=0.0, eos_id=-1,
                        resolver="tiara", n_homes=n_homes,
                        placement=placement, clock=vc, sleep=vc.sleep,
                        rehome=rehome, rehome_every=REHOME_EVERY)
    res = eng.resolver
    assert res is not None
    res.on_wave = lambda r: vc.advance(
        (hw.rtt_us + math.ceil(r.launched / hw.n_mps) * exec_us) * 1e-6)
    for p in _prompts(cfg):
        eng.submit(p, max_new=MAX_NEW)
    out = eng.run_to_completion()
    assert eng.finished()
    st = res.loop.stats
    audit = res.audit()
    # the audit's fabric_us is cost-model-priced (engine wall-clock
    # prediction); the bench's fabric time is the cycle-sim-priced
    # virtual clock charged in on_wave
    audit.pop("fabric_us", None)
    audit.pop("waves", None)
    info = dict(fabric_us=vc() * 1e6, waves=res.waves,
                posts=st.submitted, executed=st.executed,
                p99_resolve_us=st.p99_s * 1e6, **audit)
    return out, info


def _tokens(out: Dict[int, List[int]]) -> int:
    return sum(len(v) for v in out.values())


def measure(quick: bool = False) -> List[dict]:
    hw = cm.DEFAULT_HW
    cfg, params = _model()
    host_out, host = _run_host(cfg, params, hw)
    exec_us, bottleneck = _calibrate_exec_us(host["pages_per_seq"], hw)
    tokens = _tokens(host_out)
    host_tps = tokens / (host["fabric_us"] * 1e-6)

    mesh_out, mesh = _run_tiara(cfg, params, hw, exec_us,
                                n_homes=N_HOMES, placement="auto",
                                rehome=True)
    mesh_out2, mesh2 = _run_tiara(cfg, params, hw, exec_us,
                                  n_homes=N_HOMES, placement="auto",
                                  rehome=True)
    static_out, static = _run_tiara(cfg, params, hw, exec_us,
                                    n_homes=N_HOMES, placement="auto",
                                    rehome=False)
    det_keys = ("fabric_us", "waves", "posts", "executed", "rehomes",
                "rehomed_words", "cross_device_words")
    deterministic = (mesh_out == mesh_out2 and
                     all(mesh[k] == mesh2[k] for k in det_keys))
    tiara_tps = tokens / (mesh["fabric_us"] * 1e-6)
    speedup = host["fabric_us"] / mesh["fabric_us"]
    cross_rehome = mesh["cross_device_words"]
    cross_static = static["cross_device_words"]
    traffic = cross_static / max(cross_rehome, 1.0)
    results = [dict(
        section="mesh8", n_seqs=N_SEQS, max_new=MAX_NEW, n_slots=SLOTS,
        n_homes=N_HOMES, placement="auto", seed=SEED,
        pages_per_seq=host["pages_per_seq"],
        rehome_every=REHOME_EVERY,
        tokens=tokens, posts=mesh["posts"], waves=mesh["waves"],
        exec_us_per_post=exec_us, bottleneck=bottleneck,
        fabric_us_host=host["fabric_us"],
        fabric_us_tiara=mesh["fabric_us"],
        tokens_per_s_host=host_tps, tokens_per_s_tiara=tiara_tps,
        p99_resolve_us=mesh["p99_resolve_us"],
        speedup_tiara_resolve=speedup,
        rehomes=mesh["rehomes"], rehomed_words=mesh["rehomed_words"],
        home_skew=mesh["home_skew"],
        cross_words_rehome=cross_rehome, cross_words_static=cross_static,
        speedup_rehome_traffic=traffic,
        parity_ok=bool(mesh_out == host_out
                       and static_out == host_out),
        deterministic_ok=bool(deterministic),
        tiara_not_slower_ok=bool(speedup >= 1.0),
        rehome_reduces_traffic_ok=bool(traffic >= 1.0))]
    if not quick:
        single_out, single = _run_tiara(cfg, params, hw, exec_us,
                                        n_homes=1, placement="single",
                                        rehome=True)
        results.append(dict(
            section="single", n_seqs=N_SEQS, max_new=MAX_NEW,
            n_slots=SLOTS, n_homes=1, placement="single", seed=SEED,
            pages_per_seq=host["pages_per_seq"],
            tokens=_tokens(single_out), posts=single["posts"],
            waves=single["waves"],
            fabric_us_tiara=single["fabric_us"],
            tokens_per_s_tiara=_tokens(single_out)
            / (single["fabric_us"] * 1e-6),
            p99_resolve_us=single["p99_resolve_us"],
            parity_ok=bool(single_out == host_out)))
    return results


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="end-to-end disaggregated paged decode: tiny-lm through "
                 "ServingEngine(resolver='tiara'), PagedKVFetch per slot "
                 "per step via per-sequence sessions + ServingLoop, "
                 "cycle-sim fabric pricing on a VirtualClock, vs the "
                 "charitable batched-RDMA host-resolve baseline",
        unit="tokens/s at the resolution fabric",
        acceptance="token bit-parity with host resolve on every lane; "
                   "same-seed determinism; tiara resolve >= 1.0x host "
                   "(hard bit + gated speedup); rehome reduces "
                   "cross-device reply words >= 1.0x (hard bit + gated)",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out: List[Row] = []
    for r in data:
        if r["section"] == "mesh8":
            out.append(Row(
                name=f"e2e_paged/mesh{r['n_homes']}_resolve",
                us_per_call=r["fabric_us_tiara"] / max(r["tokens"], 1),
                derived=r["speedup_tiara_resolve"], unit="x",
                note=f"{r['tokens']} tok, {r['posts']} posts, "
                     f"p99 {r['p99_resolve_us']:.1f}us, "
                     f"parity={r['parity_ok']} "
                     f"det={r['deterministic_ok']}"))
            out.append(Row(
                name=f"e2e_paged/mesh{r['n_homes']}_rehome_traffic",
                us_per_call=0.0,
                derived=r["speedup_rehome_traffic"], unit="x",
                note=f"cross words {r['cross_words_static']:.0f} -> "
                     f"{r['cross_words_rehome']:.0f}, "
                     f"{r['rehomes']:.0f} rehomes, "
                     f"skew {r['home_skew']:.2f}"))
        else:
            out.append(Row(
                name="e2e_paged/single_resolve",
                us_per_call=r["fabric_us_tiara"] / max(r["tokens"], 1),
                derived=r["tokens_per_s_tiara"], unit="tok/s",
                note=f"1 home (informational), "
                     f"parity={r['parity_ok']}"))
    return out
