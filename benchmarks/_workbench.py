"""Shared helpers for the paper-figure benchmarks.

Every benchmark produces ``Row``s carrying the derived metric next to the
paper's claimed value (when the paper states one), so faithfulness is
auditable from the CSV alone.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

from repro.core.endpoint import TiaraEndpoint
from repro.core.isa import Op
from repro.core.pyvm import TraceEvent


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float        # latency of one op (or blank for rate rows)
    derived: float            # the figure's metric (latency us, Mops, GB/s)
    unit: str = "us"
    paper: Optional[float] = None   # the paper's claimed value, if stated
    note: str = ""

    def ratio(self) -> Optional[float]:
        if self.paper in (None, 0):
            return None
        return self.derived / self.paper

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.4g},{self.derived:.4g}"


def rate(fn, per_call_ops: int, min_seconds: float = 0.3) -> tuple:
    """(us_per_call, ops_per_s) for ``fn`` with a warmup call (jit
    compile) and an adaptive repeat count targeting ``min_seconds`` of
    steady-state measurement — the one timing protocol every wall-clock
    benchmark shares."""
    fn()                                    # warmup: jit compile
    t0 = time.perf_counter()
    fn()
    dt = time.perf_counter() - t0
    reps = max(1, int(min_seconds / max(dt, 1e-6)))
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    dt = (time.perf_counter() - t0) / reps
    return dt * 1e6, per_call_ops / dt


def run_traced(workload, build_fn, params: Sequence[int], *,
               n_devices: int = 1, home: int = 0,
               populate_args: Optional[dict] = None,
               setup_fn=None, max_steps: Optional[int] = None) -> tuple:
    """Register + populate + trace one invocation through an endpoint.

    The workload becomes one tenant of a fresh :class:`TiaraEndpoint`
    (which owns the pool); the invocation runs on the ``pyvm`` oracle
    via ``Session.trace`` so the cycle simulator gets an event trace.

    Returns (vop, trace, result, rt, mem_before) — ``rt`` is the
    tenant's region view over the endpoint pool."""
    ep, sessions = TiaraEndpoint.for_tenants(
        [("bench", workload.regions())], n_devices=n_devices,
        max_steps=max_steps)
    s = sessions["bench"]
    op_id = s.register(build_fn(s.view))
    if hasattr(workload, "populate"):
        workload.populate(s.pool, s.view, **(populate_args or {}))
    if setup_fn is not None:
        setup_fn(s.pool, s.view)
    before = ep.mem.copy()
    res = s.trace(op_id, list(params), home=home)
    assert res.status in (0, 1), f"operator failed: status={res.status}"
    return ep.registry[op_id].verified, res.trace, res, s.view, before


def count_rtts(trace: Sequence[TraceEvent], *,
               client_dev: Optional[int] = None) -> int:
    """Round trips a Tiara invocation costs: 1 for request/reply, plus one
    per remote synchronous op, plus one per Wait that joins remote async
    ops to *third parties* (parallel replica writes count once — the
    paper's 2-RTT lock).  Writes streamed back to the requester itself
    (``client_dev``) ride the reply path and add no round trip."""
    rtts = 1
    pending_third_party = False
    for ev in trace:
        if ev.op == Op.MEMCPY and ev.remote:
            to_client = client_dev is not None and ev.dst_dev == client_dev \
                and not ev.src_remote
            if to_client:
                continue
            if ev.is_async:
                pending_third_party = True
            else:
                rtts += 1
        elif ev.op in (Op.LOAD, Op.STORE, Op.CAS, Op.CAA) and ev.remote:
            rtts += 1
        elif ev.op == Op.WAIT and pending_third_party:
            rtts += 1
            pending_third_party = False
    if pending_third_party:
        rtts += 1
    return rtts


def fmt_table(rows: List[Row], title: str) -> str:
    out = [f"== {title} =="]
    out.append(f"{'name':44s} {'latency_us':>11s} {'derived':>10s} "
               f"{'unit':>6s} {'paper':>8s} {'ratio':>6s}  note")
    for r in rows:
        ratio = r.ratio()
        out.append(
            f"{r.name:44s} {r.us_per_call:11.3f} {r.derived:10.3f} "
            f"{r.unit:>6s} "
            f"{(f'{r.paper:8.3f}' if r.paper is not None else '       -')} "
            f"{(f'{ratio:6.2f}' if ratio is not None else '     -')}  {r.note}")
    return "\n".join(out)
