"""Figure 8 — 3-level page-table walk latency + throughput.

Paper anchors: RDMA 4 RTTs = 10.0 us; Tiara 3.75 us (62% lower, 2.7x);
throughput ~25 Mops vs RDMA 0.1 Mops.  Note the paper's 3.75 us implies a
~0.42 us effective per-level cost, tighter than its own Fig. 6 per-hop
0.79 us — we report our simulator's number (serialized 0.75 us DMAs) and
the ratio, see EXPERIMENTS.md §Calibration.
"""

from __future__ import annotations

from typing import List

from repro.core import costmodel as cm
from repro.core import operators as ops
from repro.core import simulator as sim

from benchmarks._workbench import Row, run_traced


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    p = ops.PageTableWalk(fanout=64, n_pages=64)

    # Latency: full walk + 4 KB page fetch streamed back to the caller.
    vop, trace, res, rt, _ = run_traced(
        p, p.build, [_first_va(p)], populate_args={"seed": 7})
    ts_full = sim.simulate_task(vop, trace, hw, reply_payload_bytes=0)

    # Throughput: translation-only ('each translation is one message').
    vop_t, trace_t, _, _, _ = run_traced(
        p, p.build_translate_only, [_first_va(p)], populate_args={"seed": 7})
    ts_tr = sim.simulate_task(vop_t, trace_t, hw)
    tput = sim.saturated_throughput_mops(ts_tr, hw)

    rdma_lat = cm.rdma_ptw_latency_us(3, hw)
    return [
        Row("fig8/ptw/tiara/latency", ts_tr.latency_us, ts_tr.latency_us,
            "us", 3.75, note="translate-only walk, 3 chained DMAs"),
        Row("fig8/ptw/tiara/latency+page", ts_full.latency_us,
            ts_full.latency_us, "us",
            note="with 4 KB page fetch (ODRP-style remote paging)"),
        Row("fig8/ptw/rdma/latency", rdma_lat, rdma_lat, "us", 10.0),
        Row("fig8/ptw/rpc/latency", cm.rpc_latency_us(3, hw),
            cm.rpc_latency_us(3, hw), "us"),
        Row("fig8/ptw/redn/latency", cm.redn_latency_us(9, hw),
            cm.redn_latency_us(9, hw), "us",
            note="3 WRs/level for shift/mask arithmetic"),
        Row("fig8/ptw/tiara/throughput", ts_tr.latency_us, tput, "Mops",
            25.0, note=f"bottleneck={sim.bottleneck(ts_tr, hw)}"),
        Row("fig8/ptw/rdma/throughput", rdma_lat,
            cm.rdma_chain_throughput_mops(4, hw), "Mops",
            note="paper quotes 0.1 Mops measured; verb-rate model shown"),
        Row("fig8/ptw/reduction/tiara_vs_rdma", ts_tr.latency_us,
            1 - ts_tr.latency_us / rdma_lat, "frac", 0.62),
    ]


def _first_va(p: ops.PageTableWalk) -> int:
    from repro.core import memory
    rt = p.regions()
    mem = memory.make_pool(1, rt)
    vamap = p.populate(mem, rt, seed=7)
    return next(iter(vamap.keys()))
