"""Runtime-protection overhead on the fault-free hot path.

The runtime protection checks (wild-pointer containment, failed-device
fencing — see ``core/pyvm.py``) ride every word op and MEMCPY of every
engine.  An RNIC does this in parallel check hardware for free; a
software engine pays real vector work, so the cost must be watched:
this benchmark runs the B-request graph-walk wave through the batched
and trace-compiled engines with protection on (the default every
caller gets) vs the legacy unprotected build (``protect=False``) and
reports the throughput ratio.

Two gated metrics, one deterministic and one measured:

* ``traffic_ratio`` — unprotected / protected "bytes accessed" from
  XLA's own cost analysis of the two compiled B=1024 programs.  This
  is a property of the lowered HLO, not of the host, so it never
  flakes; it is the hard gate (>= ``GATE_TRAFFIC``).  Measured today:
  ~0.90 (the checks add ~11% memory traffic — the 10% design target
  is just missed; see the ROADMAP fault-model table for the residual:
  predicate chains re-materialized across gather-broken fusions).
* ``speedup_protect`` — protected / unprotected wall-clock throughput,
  min-of-N interleaved A/B (robust to the several-×10% swings this
  host shows between runs).  Gated only against a catastrophic floor
  (>= ``GATE_WALL``); drift is tracked by ``check_regression.py``
  against the committed baseline.

``parity_ok`` asserts both builds produce bit-identical results on the
clean wave — the checks may never change fault-free architectural
behavior.  Results land in ``BENCH_fault_overhead.json``.
"""

from __future__ import annotations

import json
import os
import time
from typing import List, Optional

import numpy as np

from repro.core import compile as tc
from repro.core import memory, vm
from repro.core import operators as ops
from repro.core.memory import Grant
from repro.core.verifier import verify

from benchmarks._workbench import Row

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_fault_overhead.json")
# quick mode overlaps the committed B=64 row so the CI regression gate
# always has a matching identity to compare
BATCHES = (64, 1024)
QUICK_BATCHES = (64,)
DEPTH = 10                    # the paper's 10-hop traversal
MAX_DEPTH = 16
N_NODES = 4096
REPS = 30                     # interleaved A/B rounds (full mode)
QUICK_REPS = 8
GATE_TRAFFIC = 0.88           # deterministic: HLO bytes-accessed ratio
GATE_WALL = 0.70              # catastrophic floor for the measured ratio


def _setup(max_batch: int):
    w = ops.GraphWalk(n_nodes=N_NODES, max_depth=MAX_DEPTH,
                      reply_words=max_batch * ops.NODE_WORDS)
    rt = w.regions()
    vop = verify(w.build(rt, reply_param=True), grant=Grant.all_of(rt),
                 regions=rt)
    mem = memory.make_pool(1, rt)
    order = w.populate(mem, rt)
    return vop, rt, mem, order


def _invoke(engine: str, vop, rt, mem, params, protect: bool):
    if engine == "batched":
        return vm.invoke_batched(vop, rt, mem, params, protect=protect)
    return tc.invoke_compiled(vop, rt, mem, params, protect=protect)


def _traffic_ratio(vop, rt, B: int) -> Optional[float]:
    """Unprotected / protected bytes-accessed of the compiled trace,
    from XLA's cost analysis — deterministic for a given lowering."""
    import jax.numpy as jnp
    with vm.x64():
        args = (jnp.asarray(memory.make_pool(1, rt), jnp.int64),
                jnp.zeros((B, 3), jnp.int64), jnp.zeros(B, jnp.int64),
                jnp.zeros(1, bool))
        byts = {}
        for protect in (True, False):
            fn = tc.build_compiled(vop, rt, 1, B, protect=protect,
                                   check_failed=False)
            try:
                ca = fn.lower(*args).compile().cost_analysis()
            except Exception:
                return None
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            b = (ca or {}).get("bytes accessed")
            if not b:
                return None
            byts[protect] = float(b)
    return byts[False] / byts[True]


def _interleaved_min(call_a, call_b, reps: int):
    """min-of-N wall clock for two calls, strictly interleaved so slow
    host phases (GC, thermal, noisy neighbors) hit both sides alike."""
    ta, tb = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        call_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        call_b()
        tb.append(time.perf_counter() - t0)
    return min(ta), min(tb)


def measure(quick: bool = False) -> dict:
    batches = QUICK_BATCHES if quick else BATCHES
    reps = QUICK_REPS if quick else REPS
    vop, rt, mem, order = _setup(max(batches))
    results: List[dict] = []
    for engine in ("batched", "compiled"):
        for B in batches:
            params = [[int(order[i % N_NODES]) * 8, DEPTH,
                       i * ops.NODE_WORDS] for i in range(B)]
            r_p = _invoke(engine, vop, rt, mem, params, True)
            r_n = _invoke(engine, vop, rt, mem, params, False)
            # the checks may never perturb a fault-free wave
            parity_ok = bool(
                np.array_equal(r_p.ret, r_n.ret)
                and np.array_equal(r_p.status, r_n.status)
                and np.array_equal(r_p.steps, r_n.steps)
                and np.array_equal(r_p.mem, r_n.mem)
                and np.asarray(r_p.fault)[:, 0].max() < 0)
            s_p, s_n = _interleaved_min(
                lambda: _invoke(engine, vop, rt, mem, params, True),
                lambda: _invoke(engine, vop, rt, mem, params, False),
                reps)
            results.append(dict(
                engine=engine, batch=B,
                us_per_call=s_p * 1e6, ops_per_s=B / s_p,
                us_per_call_noprotect=s_n * 1e6,
                ops_per_s_noprotect=B / s_n,
                speedup_protect=s_n / s_p, parity_ok=parity_ok))
    ratio = _traffic_ratio(vop, rt, max(batches)) if not quick else None
    return dict(results=results, traffic_ratio=ratio)


def rows(quick: bool = False) -> List[Row]:
    m = measure(quick=quick)
    data, ratio = m["results"], m["traffic_ratio"]
    payload = dict(workload=f"graph_walk depth={DEPTH} n_nodes={N_NODES}: "
                            f"protect=True vs protect=False",
                   unit="ratio (protected/unprotected ops/s)",
                   gate_traffic=GATE_TRAFFIC, gate_wall=GATE_WALL,
                   traffic_ratio=ratio, results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        overhead = (1.0 - r["speedup_protect"]) * 100
        out.append(Row(
            name=f"fault_overhead/{r['engine']}/B={r['batch']}",
            us_per_call=r["us_per_call"],
            derived=r["speedup_protect"], unit="ratio",
            note=f"protection overhead {overhead:+.1f}%"
                 + ("" if r["parity_ok"] else "  PARITY BROKEN")))
    if ratio is not None:
        out.append(Row(name="fault_overhead/traffic/compiled",
                       us_per_call=0.0, derived=ratio, unit="ratio",
                       note=f"HLO bytes-accessed, noprotect/protect "
                            f"(gate >= {GATE_TRAFFIC})"))
    # hard gates (full mode; quick batches are launch-overhead dominated)
    for r in data:
        if not r["parity_ok"]:
            raise RuntimeError(
                f"protect=True changed fault-free results "
                f"({r['engine']} B={r['batch']})")
        if (not quick and r["engine"] == "compiled"
                and r["batch"] == max(BATCHES)
                and r["speedup_protect"] < GATE_WALL):
            raise RuntimeError(
                f"runtime protection costs too much: compiled "
                f"B={r['batch']} keeps only "
                f"{r['speedup_protect']:.0%} of unprotected throughput "
                f"(floor {GATE_WALL:.0%})")
    if ratio is not None and ratio < GATE_TRAFFIC:
        raise RuntimeError(
            f"runtime protection traffic regressed: the protected "
            f"compiled trace moves {1 / ratio - 1:+.1%} more bytes than "
            f"the unprotected one (gate >= {GATE_TRAFFIC})")
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
    print(f"wrote {JSON_PATH}")
