"""Figure 10 — disaggregated PagedAttention throughput vs. KV block size.

Task (paper §4.6): fetch 8 MB of KV data (one layer's KV for 2048 tokens,
LLaMA3-70B) over 100 GbE through a Block Table.  The Tiara operator
resolves each block id via register-chained loads and streams the block to
the requester with async Memcpy, pipelining resolution with transfer; the
cycle simulator serializes transfers on the wire, so throughput converges
to effective line rate (~12 GB/s) exactly as the paper describes.

Paper anchors: Tiara 8.7 GB/s at 4 KB (vs batched RDMA 2.7); saturates
~12 GB/s at 8 KB (2.8x batched RDMA); other systems converge >= 256 KB.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import costmodel as cm
from repro.core import simulator as sim
from repro.serving.allocator import BlockAllocator

from benchmarks._workbench import Row, run_traced

TOTAL_BYTES = 8 * 1024 * 1024
BLOCK_SIZES = (1024, 4096, 8192, 32768, 262144)
POOL_BLOCKS = 128            # physical pool (ids repeat; trace shape is
#                              identical to a 8 MB-resident pool)


def tiara_gather_gbs(block_bytes: int, hw: cm.HW) -> float:
    n_req = TOTAL_BYTES // block_bytes
    # the bench's region geometry comes from the serving allocator's
    # layout export — the exact table the engine registers, so the
    # bench path and the serving path cannot drift
    k = BlockAllocator(POOL_BLOCKS).region_layout(
        block_bytes=block_bytes, max_req_blocks=n_req)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, POOL_BLOCKS, size=n_req)

    def setup(mem, rt):
        k.make_request(mem, rt, list(ids))

    # dev0 = memory node, dev1 = client
    vop, trace, res, _, _ = run_traced(
        k, lambda rt: k.build(rt, remote_reply=True), [n_req, 1],
        n_devices=2, setup_fn=setup, max_steps=1 << 22)
    assert res.ok and res.ret == n_req
    ts = sim.simulate_task(vop, trace, hw, pipelined=True,
                           serial_chain=False, reply_payload_bytes=0)
    return sim.effective_gather_gbs(ts, TOTAL_BYTES, hw), ts


def rows(hw: cm.HW = cm.DEFAULT_HW) -> List[Row]:
    out: List[Row] = []
    paper_tiara = {4096: 8.7, 8192: 12.0}
    paper_rdma = {4096: 2.7}
    for bb in BLOCK_SIZES:
        gbs, ts = tiara_gather_gbs(bb, hw)
        kb = bb // 1024
        out.append(Row(f"fig10/paged/tiara/block={kb}KB", ts.latency_us,
                       gbs, "GB/s", paper_tiara.get(bb),
                       note=f"{TOTAL_BYTES // bb} blocks, "
                            f"bottleneck={sim.bottleneck(ts, hw)}"))
        out.append(Row(f"fig10/paged/rdma_batched/block={kb}KB", 0.0,
                       cm.batched_rdma_gather_gbs(TOTAL_BYTES, bb, hw),
                       "GB/s", paper_rdma.get(bb)))
        out.append(Row(f"fig10/paged/rpc/block={kb}KB", 0.0,
                       cm.rpc_gather_gbs(TOTAL_BYTES, bb, hw), "GB/s"))
        out.append(Row(f"fig10/paged/redn/block={kb}KB", 0.0,
                       cm.redn_gather_gbs(TOTAL_BYTES, bb, hw), "GB/s"))
    gbs8, _ = tiara_gather_gbs(8192, hw)
    out.append(Row("fig10/speedup/tiara_vs_rdma/block=8KB", 0.0,
                   gbs8 / cm.batched_rdma_gather_gbs(TOTAL_BYTES, 8192, hw),
                   "x", 2.8))
    return out
