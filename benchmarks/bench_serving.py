"""Overload serving: open-loop Poisson arrivals through the
:class:`ServingLoop` at a fixed overload factor.

Two sections:

  * **Virtual** (deterministic, gated): 8 weighted tenants submit a
    seeded Poisson trace at ``OVERLOAD_X`` times the rate the cost
    model says one wave pipeline sustains, on a :class:`VirtualClock`
    (the driver charges each launched wave's predicted service time to
    the clock, so deadlines, rate limits and sheds bite exactly the
    same way on every host).  The run executes real waves — parity is
    checked against the per-request ``pyvm`` oracle in launch order —
    but every scheduling decision reads the virtual clock, so the gated
    metrics (``goodput_frac``, ``fairness_min_share``,
    ``p99_x_deadline``) and the ``deterministic_ok`` /
    ``inflight_bound_ok`` bits are bit-stable across runs and hosts.
  * **Wall** (informational): the same loop on the real clock,
    closed-loop, for an achieved-goodput ops/s number.  Absolute host
    throughput drifts run to run; nothing here is gated.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import memory, pyvm
from repro.core.endpoint import TiaraEndpoint
from repro.core.program import OperatorBuilder
from repro.core.serving_loop import (ServingConfig, ServingLoop, TenantQoS,
                                     VirtualClock)

from benchmarks._workbench import Row

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json")

N_TENANTS = 8
N_POSTS = 512           # virtual section: identical in quick and full
OVERLOAD_X = 2.0         # offered rate / sustainable rate
RING = 8
DEADLINE_WAVES = 3.0     # per-post deadline, in predicted wave times
SEED = 11


def _layout():
    return memory.packed_table([("data", 64), ("reply", 512)])


def _sum_op(rt):
    b = OperatorBuilder("sum2", n_params=2, regions=rt)
    x, y = b.reg(), b.reg()
    b.load(x, "data", b.param(0))
    b.load(y, "data", b.param(0), disp=1)
    b.add(x, x, y)
    b.store(x, "reply", b.param(1))
    b.ret(x)
    return b.build()


def _connect(n_tenants: int, **ep_kwargs):
    named = [(f"t{i}", _layout()) for i in range(n_tenants)]
    ep, sessions = TiaraEndpoint.for_tenants(named, **ep_kwargs)
    for s in sessions.values():
        s.register(_sum_op(s.view))
        s.write_region("data", np.arange(10, 74, dtype=np.int64))
    return ep, sessions


def _qos(n_tenants: int) -> Dict[str, TenantQoS]:
    # equal weights so the fair share is the mean; one tenant in four
    # is rate-limited to exercise the token-bucket reject path
    return {f"t{i}": TenantQoS(weight=1.0,
                               rate=None if i % 4 else 400.0, burst=4)
            for i in range(n_tenants)}


def _virtual_run(seed: int) -> Tuple[List[Tuple[int, int]], dict]:
    vc = VirtualClock()
    ep, sessions = _connect(N_TENANTS, clock=vc, sleep=vc.sleep)
    # the sustainable service rate from the (unlearned) cost model: one
    # RING-sized wave's predicted time, amortized per post.  The driver
    # charges every launched wave's prediction to the virtual clock, so
    # the clock IS the service bottleneck — arrivals at OVERLOAD_X
    # times that rate grow the queue exactly as an overloaded host
    # would, deterministically.
    step_bound = ep.registry[0].verified.step_bound
    wave_s = ep.cost_model.wave_us(
        batch=RING, step_bound=step_bound, mode="mixed") * 1e-6
    svc_per_post = wave_s / RING
    deadline_s = DEADLINE_WAVES * wave_s
    cfg = ServingConfig(ring_size=RING, ring_age_s=wave_s / 2,
                        min_efficiency=0.9, max_inflight_waves=2,
                        shed_watermark=5 * RING,
                        default_deadline_s=deadline_s,
                        opportunistic_poll=False)
    loop = ServingLoop(ep, cfg, qos=_qos(N_TENANTS))
    mem0 = ep.mem.copy()
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(svc_per_post / OVERLOAD_X, size=N_POSTS)
    arrivals = []
    t = 0.0
    for i, g in enumerate(gaps):
        t += float(g)
        arrivals.append((t, f"t{i % N_TENANTS}",
                         [int(rng.integers(0, 30)), i % 500],
                         float(rng.random() < 0.1)))
    launch_order: List = []
    max_waves = 0
    idx = 0
    pumps = 0
    while idx < len(arrivals) or loop.backlog:
        progressed = False
        while idx < len(arrivals) and arrivals[idx][0] <= vc():
            _, tenant, params, contention = arrivals[idx]
            loop.submit(tenant, "sum2", params, contention=contention)
            idx += 1
            progressed = True
        report = loop.pump(force=idx >= len(arrivals))
        if report.launched:
            launch_order.extend(loop._launched[-report.launched:])
            vc.advance(report.predicted_us * 1e-6)   # the service time
        if (report.launched or report.timed_out or report.shed
                or report.flushed):
            progressed = True
        max_waves = max(max_waves, ep.in_flight_waves)
        if not progressed:
            if idx < len(arrivals):
                vc.advance_to(arrivals[idx][0])      # idle to next post
            else:
                vc.advance(svc_per_post)
        pumps += 1
        assert pumps < 100_000, "virtual drive did not converge"
    ep.wait_all()
    loop._harvest()
    # oracle parity for everything executed, replayed in launch order
    vops = ep.registry.store_ops()
    mem = mem0.copy()
    parity = True
    for c in launch_order:
        r = pyvm.run(vops[c.op_id], ep.regions, mem, list(c.params),
                     home=c.home)
        parity &= (c.ret, c.status, c.steps) == (r.ret, r.status, r.steps)
    parity &= bool(np.array_equal(ep.mem, mem))
    statuses = []
    for s in sessions.values():
        statuses.extend((c.seq, c.status) for c in s.poll_cq())
    statuses.sort()
    st = loop.stats
    info = dict(stats=st, parity_ok=bool(parity),
                deadline_s=deadline_s,
                inflight_bound_ok=bool(
                    max_waves <= cfg.max_inflight_waves))
    return statuses, info


def _virtual_section() -> dict:
    s1, a = _virtual_run(SEED)
    s2, b = _virtual_run(SEED)
    st = a["stats"]
    total_ok = st.ok
    oks = [st.per_tenant.get(f"t{i}", {}).get("ok", 0)
           for i in range(N_TENANTS) if i % 4]      # unlimited tenants
    fair = sum(oks) / len(oks) if oks else 0.0
    deadline_s = a["deadline_s"]
    return dict(
        section="virtual", n_tenants=N_TENANTS, n_posts=N_POSTS,
        overload_x=OVERLOAD_X, ring_size=RING,
        deadline_waves=DEADLINE_WAVES, seed=SEED,
        submitted=st.submitted, executed=st.executed, ok=total_ok,
        timed_out=st.timed_out, rejected=st.rejected, shed=st.shed,
        goodput_frac=total_ok / max(st.submitted, 1),
        fairness_min_share=(min(oks) / fair) if fair > 0 else 1.0,
        p50_x_deadline=st.p50_s / deadline_s,
        p99_x_deadline=st.p99_s / deadline_s,
        deterministic_ok=bool(s1 == s2),
        parity_ok=bool(a["parity_ok"] and b["parity_ok"]),
        inflight_bound_ok=bool(a["inflight_bound_ok"]))


def _wall_section(quick: bool) -> dict:
    n_posts = 64 if quick else 256
    ep, _ = _connect(N_TENANTS)
    cfg = ServingConfig(ring_size=RING, ring_age_s=0.002,
                        min_efficiency=0.9, max_inflight_waves=2)
    loop = ServingLoop(ep, cfg, qos={f"t{i}": TenantQoS()
                                     for i in range(N_TENANTS)})
    rng = np.random.default_rng(SEED)
    t0 = time.perf_counter()
    for i in range(n_posts):
        loop.submit(f"t{i % N_TENANTS}", "sum2",
                    [int(rng.integers(0, 30)), i % 500])
        loop.pump()
    loop.drain()
    dt = time.perf_counter() - t0
    st = loop.stats
    return dict(section="wall", n_tenants=N_TENANTS, n_posts=n_posts,
                ok=st.ok, ops_per_s=st.ok / dt,
                p50_ms_wall=st.p50_s * 1e3, p99_ms_wall=st.p99_s * 1e3,
                parity_ok=True)


def measure(quick: bool = False) -> List[dict]:
    return [_virtual_section(), _wall_section(quick)]


def rows(quick: bool = False) -> List[Row]:
    data = measure(quick=quick)
    payload = dict(
        workload="overload-safe serving loop: seeded open-loop Poisson "
                 "arrivals at 2x the sustainable rate over 8 weighted "
                 "tenants, virtual-clock deterministic + wall clock",
        unit="goodput fraction (virtual) / ops/s (wall)",
        acceptance="deterministic shed/timeout across same-seed runs; "
                   "pyvm bit-parity for executed posts; in-flight waves "
                   "within bound; no unlimited tenant >10% below fair "
                   "share; goodput and p99/deadline gated vs baseline",
        results=data)
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    out = []
    for r in data:
        if r["section"] == "virtual":
            out.append(Row(
                name=f"serving/virtual_{r['overload_x']:g}x_"
                     f"t{r['n_tenants']}",
                us_per_call=r["p99_x_deadline"],
                derived=r["goodput_frac"], unit="frac",
                note=f"goodput under {r['overload_x']:g}x overload "
                     f"(det={r['deterministic_ok']}, "
                     f"fair_min={r['fairness_min_share']:.2f})"))
        else:
            out.append(Row(
                name=f"serving/wall_t{r['n_tenants']}_n{r['n_posts']}",
                us_per_call=r["p99_ms_wall"] * 1e3,
                derived=r["ops_per_s"], unit="ops/s",
                note="host wall clock (informational)"))
    return out
