"""Registration-time access analysis (``core/access``): symbolic
footprints, static wave conflict proofs, the proof-gated sweep-skip
fast path, and the widened superoperator matcher.

The invariants under test:

1. Footprints are per-site symbolic offsets — affine in params,
   trip-scaled loop windows with static caps, or top — and the edge
   cases stay sound *windows*, never silently wrong: jump-out-of-loop
   (the Fig. 5 lock break) joins to an interval, a dynamic
   (``FLAG_MREG``) loop is bounded by its static cap, and an MREG body
   degrades to a cap-bounded window rather than ⊤.
2. ``prove_wave_noconflict`` is *sound*: it never clears a wave whose
   exact dynamic read/write sets conflict cross-lane (seeded sweep
   always; hypothesis when installed), and a cleared wave executes
   bit-identically to the sequential ``pyvm`` oracle on every engine
   (dense mixed, segmented, compiled, sharded).
3. The proof is *useful*: provably-disjoint waves do prove, reach the
   engines as a separately-keyed sweep-skip variant, replace the
   caller's contention guess in the cost model, and override a slot's
   learned conflict EWMA at wave formation.
4. The widened superoperator matcher (scatter-reduce, map, zip-with)
   is exact vs ``pyvm`` including faults, scatter-reduce fusion stays
   gated on a no-conflict build, and the registry surfaces footprints,
   matches, and near-miss reasons.
"""

import jax
import numpy as np
import pytest

from repro.core import access, isa, memory, pyvm, vm
from repro.core import compile as tc
from repro.core.costmodel import DispatchCostModel, SegmentStats
from repro.core.endpoint import TiaraEndpoint
from repro.core.isa import Alu
from repro.core.memory import Grant
from repro.core.program import OperatorBuilder
from repro.core.registry import OperatorRegistry
from repro.core.serving_loop import ServingConfig, ServingLoop, VirtualClock
from repro.core.verifier import VerificationError, verify

N_DEV = len(jax.devices())

two_devices = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs 2 devices (run under XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _table():
    return memory.packed_table([("src", 1024), ("reply", 1024),
                                ("acc", 256)])


# ---------------------------------------------------------------------------
# Operator families with exact dynamic-footprint companions.  The
# companions mirror what feeds the runtime sweep (``vm.lane_intervals``):
# word accesses at masked in-region addresses, atomics as writes whatever
# the compare outcome.  No family writes ``src``, so every companion is
# exact regardless of wave interleaving.
# ---------------------------------------------------------------------------

def _op_pair(rt):
    """Writes reply[p0] and reply[p0+1] — pure affine footprint."""
    b = OperatorBuilder("pair", n_params=2, regions=rt)
    t = b.reg()
    b.alu(t, b.param(1), Alu.ADD, 7)
    b.store(t, "reply", b.param(0))
    b.store(t, "reply", b.param(0), disp=1)
    b.ret(t)
    return b.build()


def _op_window(rt):
    """MREG loop, trip p2 capped at 8: reads src[p0+t], writes
    reply[p0+t] — trip-scaled window footprint."""
    b = OperatorBuilder("window", n_params=3, regions=rt)
    i, v = b.reg(), b.reg()
    b.alu(i, b.param(0), Alu.ADD, 0)
    with b.loop((b.param(2), 8)):
        b.load(v, "src", i)
        b.store(v, "reply", i)
        b.alu(i, i, Alu.ADD, 1)
    b.ret(v)
    return b.build()


def _op_chase(rt):
    """Writes reply[src[p0]] — data-dependent offset, ⊤ footprint."""
    b = OperatorBuilder("chase", n_params=1, regions=rt)
    v = b.reg()
    b.load(v, "src", b.param(0))
    b.store(v, "reply", v)
    b.ret(v)
    return b.build()


def _op_atom(rt):
    """CAA on acc[p0] — one-word atomic footprint."""
    b = OperatorBuilder("atom", n_params=3, regions=rt)
    old = b.reg()
    b.caa(old, "acc", b.param(0), b.param(1), b.param(2))
    b.ret(old)
    return b.build()


FAMILIES = ("pair", "window", "chase", "atom")


def _registry(rt, *, n_devices=1, **kw):
    reg = OperatorRegistry(rt, n_devices=n_devices, **kw)
    reg.add_tenant(Grant.all_of(rt, "t"))
    ids = {}
    for fam, build in (("pair", _op_pair), ("window", _op_window),
                       ("chase", _op_chase), ("atom", _op_atom)):
        ids[fam] = reg.register("t", build(rt))
    return reg, ids


def _touched(fam, rt, mem0, params, home):
    """Exact dynamic (read_cells, write_cells) of one lane, as the
    runtime sweep would see them: sets of (device, pool_addr)."""
    src, rep, acc = rt["src"], rt["reply"], rt["acc"]
    p = list(params) + [0] * 8
    if fam == "pair":
        w = {(home, rep.base + (p[0] & rep.mask)),
             (home, rep.base + ((p[0] + 1) & rep.mask))}
        return set(), w
    if fam == "window":
        trip = min(max(p[2], 0), 8)
        r = {(home, src.base + ((p[0] + t) & src.mask))
             for t in range(trip)}
        w = {(home, rep.base + ((p[0] + t) & rep.mask))
             for t in range(trip)}
        return r, w
    if fam == "chase":
        cell = src.base + (p[0] & src.mask)
        v = int(mem0[home, cell])
        return {(home, cell)}, {(home, rep.base + (v & rep.mask))}
    assert fam == "atom"
    return set(), {(home, acc.base + (p[0] & acc.mask))}


def _would_conflict(lanes):
    """Would the dynamic sweep ever flag this wave?  True iff some
    lane's writes intersect another lane's reads or writes."""
    for i in range(len(lanes)):
        ri, wi = lanes[i]
        for j in range(i):
            rj, wj = lanes[j]
            if (wi & (rj | wj)) or (wj & ri):
                return True
    return False


def _draw_wave(rng, disjoint):
    """One 4-lane wave: op family, params, home per lane.  With
    ``disjoint`` the lanes are slot-strided far apart (should prove);
    otherwise params collide freely (must never prove unsoundly)."""
    fams, params, homes = [], [], []
    for lane in range(4):
        fam = FAMILIES[int(rng.integers(len(FAMILIES)))]
        if disjoint and fam == "chase":
            fam = "pair"  # ⊤ footprints never prove
        home = int(rng.integers(2))
        if disjoint:
            base = 64 * lane
            p = {"pair": [base, 3], "window": [base, 0, 5],
                 "atom": [32 * lane, 0, 1]}[fam]
            home = lane % 2
        else:
            p = {"pair": [int(rng.integers(1024)), 3],
                 "window": [int(rng.integers(1024)), 0,
                            int(rng.integers(12))],
                 "chase": [int(rng.integers(1024))],
                 "atom": [int(rng.integers(256)), 0, 1]}[fam]
        fams.append(fam)
        params.append(p)
        homes.append(home)
    return fams, params, homes


def _soundness_round(reg, op_ids, rt, mem0, fams, params, homes):
    ids = [op_ids[f] for f in fams]
    verdict = reg.prove_wave_noconflict(ids, params, homes, n_devices=2)
    lanes = [_touched(f, rt, mem0, p, h)
             for f, p, h in zip(fams, params, homes)]
    if verdict:
        assert not _would_conflict(lanes), (
            f"UNSOUND: proof cleared a conflicting wave {fams} {params}")
    return verdict


def test_soundness_seeded_sweep():
    rt = _table()
    reg, ids = _registry(rt, n_devices=2)
    rng = np.random.default_rng(0)
    mem0 = rng.integers(0, 2048, size=(2, rt.pool_words)).astype(np.int64)
    verdicts = []
    for k in range(120):
        fams, params, homes = _draw_wave(rng, disjoint=(k % 3 == 0))
        verdicts.append(
            _soundness_round(reg, ids, rt, mem0, fams, params, homes))
    # non-vacuity: the proof must both clear and refuse across the sweep
    assert any(verdicts) and not all(verdicts)


def test_soundness_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    rt = _table()
    reg, ids = _registry(rt, n_devices=2)
    rng0 = np.random.default_rng(7)
    mem0 = rng0.integers(0, 2048, size=(2, rt.pool_words)).astype(np.int64)

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), disjoint=st.booleans())
    def prop(seed, disjoint):
        rng = np.random.default_rng(seed)
        fams, params, homes = _draw_wave(rng, disjoint)
        _soundness_round(reg, ids, rt, mem0, fams, params, homes)

    prop()


# ---------------------------------------------------------------------------
# Bit-parity of statically-cleared waves across every engine
# ---------------------------------------------------------------------------

def _pyvm_replay(reg, rt, mem, ids, params, homes):
    out = []
    for i, p, h in zip(ids, params, homes):
        out.append(pyvm.run(reg[i].verified, rt, mem, p, home=h))
    return out


def test_proven_wave_parity_dense_and_segmented():
    rt = _table()
    reg, ids = _registry(rt)
    fams = ["pair", "window", "atom", "pair"]
    wave = [ids[f] for f in fams]
    params = [[0, 3], [128, 0, 5], [64, 0, 9], [300, 4]]
    rng = np.random.default_rng(1)
    mem0 = rng.integers(0, 512, size=(1, rt.pool_words)).astype(np.int64)

    oracle_mem = mem0.copy()
    oracle = _pyvm_replay(reg, rt, oracle_mem, wave, params, [0] * 4)

    for mode in ("mixed", "segmented"):
        r = reg._invoke_mixed(wave, mem0.copy(), params, mode=mode)
        assert reg.last_noconflict is True
        assert np.array_equal(np.asarray(r.mem), oracle_mem), mode
        assert [int(x) for x in r.ret] == [o.ret for o in oracle], mode
        assert [int(x) for x in r.status] == [o.status for o in oracle]
        assert [int(x) for x in r.steps] == [o.steps for o in oracle]
    # the sweep-skip variant is what actually got built: it is cached
    # under its own engine key
    assert vm.mixed_engine_cached(reg.store_ops(), rt, 1, 4,
                                  static_noconflict=True)


def test_proven_wave_parity_compiled():
    rt = _table()
    reg, ids = _registry(rt)
    # single-op MREG wave, slots strided past the 8-iteration cap
    params = [[32 * i, 0, 6] for i in range(4)]
    rng = np.random.default_rng(2)
    mem0 = rng.integers(0, 512, size=(1, rt.pool_words)).astype(np.int64)
    oracle_mem = mem0.copy()
    oracle = _pyvm_replay(reg, rt, oracle_mem,
                          [ids["window"]] * 4, params, [0] * 4)
    r = reg._invoke_batched(ids["window"], mem0.copy(), params,
                            mode="compiled")
    assert reg.last_noconflict is True
    assert np.array_equal(np.asarray(r.mem), oracle_mem)
    assert [int(x) for x in r.ret] == [o.ret for o in oracle]
    assert [int(x) for x in r.steps] == [o.steps for o in oracle]


@two_devices
def test_proven_wave_parity_sharded():
    rt = _table()
    reg, ids = _registry(rt, n_devices=2)
    fams = ["pair", "window", "pair", "atom"]
    wave = [ids[f] for f in fams]
    params = [[0, 3], [128, 0, 5], [0, 9], [64, 0, 1]]
    homes = [0, 0, 1, 1]
    rng = np.random.default_rng(3)
    mem0 = rng.integers(0, 512, size=(2, rt.pool_words)).astype(np.int64)
    oracle_mem = mem0.copy()
    oracle = _pyvm_replay(reg, rt, oracle_mem, wave, params, homes)
    r = reg._invoke_mixed(wave, mem0.copy(), params, homes=homes,
                          mode="mixed", placement="sharded")
    assert reg.last_noconflict is True
    assert np.array_equal(np.asarray(r.mem), oracle_mem)
    assert [int(x) for x in r.ret] == [o.ret for o in oracle]
    assert [int(x) for x in r.status] == [o.status for o in oracle]


def test_unproven_wave_keeps_sweep_and_stays_exact():
    """A colliding wave must not prove, and the sweep fallback keeps the
    deterministic serialized semantics (matches the dense mixed engine's
    own contract — here vs sequential replay on *non*-colliding params
    and simple overlap on colliding ones)."""
    rt = _table()
    reg, ids = _registry(rt)
    wave = [ids["pair"], ids["pair"]]
    params = [[10, 1], [11, 2]]  # reply[10,11] vs reply[11,12]: overlap
    assert reg.prove_wave_noconflict(wave, params, 0) is False
    mem0 = np.zeros((1, rt.pool_words), dtype=np.int64)
    r = reg._invoke_mixed(wave, mem0, params, mode="mixed")
    assert reg.last_noconflict is False
    # lockstep: per step the lanes' words are disjoint (lane 0 touches
    # reply[10] while lane 1 touches reply[11], then 11 vs 12), so the
    # contended word retires in *step* order — lane 0's second store
    # lands last.  The refused proof is conservatively sound: its
    # whole-execution spans overlap even though no single step does.
    rep = rt["reply"]
    assert int(np.asarray(r.mem)[0, rep.base + 11]) == 1 + 7


# ---------------------------------------------------------------------------
# Footprint edge cases (verifier interaction)
# ---------------------------------------------------------------------------

def _verified(rt, build):
    return verify(build(rt), grant=Grant.all_of(rt, "t"), regions=rt)


def test_jump_out_of_loop_joins_to_window():
    """The Fig. 5 lock-break shape: a conditional jump out of a loop.
    The post-loop state is the join of every exit — the footprint must
    widen to the full window, not track one path."""
    rt = _table()

    def build(rt):
        b = OperatorBuilder("lockbreak", n_params=2, regions=rt)
        i, t = b.reg(), b.reg()
        b.alu(i, b.param(0), Alu.ADD, 0)
        out = b.mklabel()
        with b.loop(4):
            b.alu(t, b.param(1), Alu.ADD, 1)
            b.store(t, "reply", i)
            b.jump(out, i, Alu.EQ, b.param(1))   # break mid-window
            b.alu(i, i, Alu.ADD, 1)
        b.bind(out)
        b.store(t, "acc", i)                     # post-join access
        b.ret(t)
        return b.build()

    v = _verified(rt, build)
    fp = v.footprint
    assert fp is not None and fp.exact  # joined, not ⊤
    # full static window overlaps => must refuse; far apart => proves
    reg, _ = _registry(rt)
    op = reg.register("t", build(rt))
    assert reg.prove_wave_noconflict(
        [op, op], [[0, 999], [2, 998]], 0) is False
    assert reg.prove_wave_noconflict(
        [op, op], [[0, 999], [64, 998]], 0) is True


def test_dynamic_loop_cap_bounds_window():
    """A FLAG_MREG loop's window is bounded by the *static cap* even
    when the trip register is huge — lanes strided by the cap prove."""
    rt = _table()
    reg, ids = _registry(rt)
    op = ids["window"]
    huge = 1 << 40
    # the trip symbol spans [0, m] inclusive (one symbol covers both the
    # body iterations and the post-loop cursor), so the provable stride
    # is cap+1 — what matters is that a 2^40 trip register still proves
    assert reg.prove_wave_noconflict(
        [op, op], [[0, 0, huge], [16, 0, huge]], 0) is True
    assert reg.prove_wave_noconflict(
        [op, op], [[0, 0, huge], [4, 0, huge]], 0) is False


def test_mreg_loop_degrades_to_window_not_top():
    rt = _table()
    v = _verified(rt, _op_window)
    fp = v.footprint
    assert fp is not None
    assert fp.exact, "MREG body must stay a cap-bounded window, not ⊤"
    assert len(fp.aux_trips) == 1 and fp.aux_trips[0][1] == 8
    d = access.describe_footprint(fp, rt)
    assert "t0" in d and "⊤" not in d


def test_data_dependent_offset_is_top():
    rt = _table()
    v = _verified(rt, _op_chase)
    assert v.footprint is not None and not v.footprint.exact
    assert "⊤" in access.describe_footprint(v.footprint, rt)


def test_verifier_diagnostics_carry_operator_name():
    rt = _table()
    grant = Grant.of("t", readable=[rt.rid("src")], writable=[])

    def build(rt):
        b = OperatorBuilder("nogrant", n_params=1, regions=rt)
        v = b.reg()
        b.load(v, "src", b.param(0))
        b.store(v, "reply", b.param(0))
        b.ret(v)
        return b.build()

    with pytest.raises(VerificationError) as ei:
        verify(build(rt), grant=grant, regions=rt)
    assert ei.value.errors, "expected at least one diagnostic"
    for err in ei.value.errors:
        assert err.startswith("nogrant: pc "), err


# ---------------------------------------------------------------------------
# Registry surface: toggle, dump, compile_reason, cross-op fusion
# ---------------------------------------------------------------------------

def test_static_analysis_toggle_disables_proofs():
    rt = _table()
    reg, ids = _registry(rt, static_analysis=False)
    assert reg.prove_wave_noconflict(
        [ids["pair"], ids["pair"]], [[0, 1], [64, 2]], 0) is False


def test_dump_reports_footprints_and_superops():
    rt = _table()
    reg, _ = _registry(rt)
    d = reg.dump()
    assert "footprint:" in d
    assert "⊤" in d                       # chase's top shows up
    assert "superop near-miss: pc" in d   # window's non-chain loop


def test_compile_reason_carries_analysis():
    rt = _table()
    reg, _ = _registry(rt)

    def build(rt):  # step bound past the unroll limit -> interp-only
        b = OperatorBuilder("bigloop", n_params=1, regions=rt)
        v = b.reg()
        with b.loop(4096):
            b.load(v, "src", b.param(0))
            b.alu(v, v, Alu.ADD, 1)
        b.ret(v)
        return b.build()

    op = reg.register("t", build(rt))
    slot = reg[op]
    assert not slot.compilable
    assert "footprint:" in slot.compile_reason
    assert "superop near-miss: pc" in slot.compile_reason


def test_cross_op_fusion_of_identical_programs():
    """Two tenants registering the same program get distinct op_ids;
    the segmented path coalesces their segments into one launch."""
    rt = _table()
    reg = OperatorRegistry(rt)
    reg.add_tenant(Grant.all_of(rt, "a"))
    reg.add_tenant(Grant.all_of(rt, "b"))
    opa = reg.register("a", _op_pair(rt))
    opb = reg.register("b", _op_pair(rt))
    wave = [opa, opb, opa, opb]
    params = [[64 * i, i] for i in range(4)]
    mem0 = np.zeros((1, rt.pool_words), dtype=np.int64)
    oracle_mem = mem0.copy()
    oracle = _pyvm_replay(reg, rt, oracle_mem, wave, params, [0] * 4)
    r = reg._invoke_mixed(wave, mem0, params, mode="segmented")
    assert reg.last_fused_groups == [[opa, opb]]
    assert np.array_equal(np.asarray(r.mem), oracle_mem)
    assert [int(x) for x in r.ret] == [o.ret for o in oracle]


# ---------------------------------------------------------------------------
# Cost model: a proof replaces the contention guess
# ---------------------------------------------------------------------------

def test_choose_batched_proof_overrides_contention():
    m = DispatchCostModel()
    guess = m.choose_batched(batch=64, step_bound=32, compilable=True,
                             contention_rate=0.9)
    assert "compiled" not in guess.costs  # guess blocks the trace
    proven = m.choose_batched(batch=64, step_bound=32, compilable=True,
                              contention_rate=0.9, static_noconflict=True)
    assert proven.static_noconflict and proven.contention_rate == 0.0
    assert "compiled" in proven.costs


def test_choose_mixed_proof_enables_segmented():
    m = DispatchCostModel()
    segs = [SegmentStats(size=32, step_bound=16, compilable=True)] * 2
    guess = m.choose_mixed(segments=segs, contention_rate=0.5)
    assert "segmented" not in guess.costs
    proven = m.choose_mixed(segments=segs, contention_rate=0.5,
                            static_noconflict=True)
    assert "segmented" in proven.costs and proven.static_noconflict


def test_sharded_cost_drops_collective_under_proof():
    m = DispatchCostModel()
    base = m.cost.sharded_us(64, 4, 32, 0.0, batch_per_device=16)
    nc = m.cost.sharded_us(64, 4, 32, 0.0, batch_per_device=16,
                           noconflict=True)
    assert nc < base  # the footprint all_gather left the step


# ---------------------------------------------------------------------------
# Endpoint + serving loop integration
# ---------------------------------------------------------------------------

def test_endpoint_last_noconflict_audit():
    layout = memory.packed_table([("src", 64), ("reply", 64)])
    ep, sessions = TiaraEndpoint.for_tenants(
        [("t0", layout), ("t1", layout)])

    def build(rt):
        b = OperatorBuilder("w1", n_params=2, regions=rt)
        t = b.reg()
        b.alu(t, b.param(1), Alu.ADD, 0)
        b.store(t, "reply", b.param(0))
        b.ret(t)
        return b.build()

    for s in sessions.values():
        s.register(build(s.view))
    assert ep.last_noconflict is None
    c0 = sessions["t0"].post("w1", [1, 11])
    c1 = sessions["t1"].post("w1", [1, 22])   # distinct regions: disjoint
    ep.doorbell(mode="mixed")
    assert ep.last_noconflict is True
    assert c0.ok and c1.ok


def test_wave_profile_proof_overrides_learned_contention():
    layout = memory.packed_table([("src", 64), ("reply", 64)])
    vc = VirtualClock()
    ep, sessions = TiaraEndpoint.for_tenants(
        [("t0", layout), ("t1", layout)], clock=vc, sleep=vc.sleep)

    def build(rt):
        b = OperatorBuilder("w1", n_params=2, regions=rt)
        t = b.reg()
        b.alu(t, b.param(1), Alu.ADD, 0)
        b.store(t, "reply", b.param(0))
        b.ret(t)
        return b.build()

    for s in sessions.values():
        s.register(build(s.view))
    loop = ServingLoop(ep, ServingConfig(ring_size=4))
    # poison the EWMA: the slots look contended from history
    loop.submit("t0", "w1", [1, 5], contention=1.0)
    loop.submit("t1", "w1", [2, 6], contention=1.0)
    picked = [q[0] for q in loop._pending.values()]
    ids = sorted({c.op_id for c in picked})
    assert max(ep.cost_model.conflict_hint(i) for i in ids) > 0.0
    _, _, contention = loop._wave_profile(picked)
    assert contention == 0.0, \
        "static proof must override the learned contention guess"


# ---------------------------------------------------------------------------
# Widened superoperator matcher: scatter-reduce, map, zip-with
# ---------------------------------------------------------------------------

def _sr_table():
    return memory.packed_table([("src", 256), ("acc", 256)])


def _op_scatter_reduce(rt, stride=2, cap=8, dev=isa.DEV_LOCAL):
    b = OperatorBuilder("scatred", n_params=3, regions=rt)
    i, j, v, old = b.reg(), b.reg(), b.reg(), b.reg()
    b.alu(i, b.param(0), Alu.ADD, 0)
    b.alu(j, b.param(1), Alu.ADD, 0)
    with b.loop(cap):
        b.load(v, "src", i)
        b.caa(old, "acc", j, b.param(2), v, dev=dev)
        b.alu(j, j, Alu.ADD, stride)
        b.alu(i, i, Alu.ADD, 1)
    b.ret(old)
    return b.build()


def test_scatter_reduce_matched_and_exact():
    rt = _sr_table()
    v = verify(_op_scatter_reduce(rt), grant=Grant.all_of(rt, "t"),
               regions=rt)
    rep = tc.superop_report(v)
    assert ("scatter_reduce", 2) in rep["matched"]
    rng = np.random.default_rng(4)
    mem0 = rng.integers(0, 64, size=(1, rt.pool_words)).astype(np.int64)
    params = [[0, 0, 0], [16, 64, 5]]
    oracle_mem = mem0.copy()
    oracle = [pyvm.run(v, rt, oracle_mem, p) for p in params]
    for noconflict in (True, False):   # fused and unfused both exact
        r = tc.invoke_compiled(v, rt, mem0.copy(), params,
                               noconflict=noconflict)
        assert np.array_equal(np.asarray(r.mem), oracle_mem), noconflict
        assert [int(x) for x in r.ret] == [o.ret for o in oracle]
        assert [int(x) for x in r.steps] == [o.steps for o in oracle]


def test_scatter_reduce_fault_parity():
    """A CAA landing on a failed device faults mid-chain: the fused
    schedule must retire the same registers, steps, and fault record as
    the interpreter."""
    rt = _sr_table()
    v = verify(_op_scatter_reduce(rt, dev=1), grant=Grant.all_of(rt, "t"),
               regions=rt)
    rng = np.random.default_rng(5)
    mem0 = rng.integers(0, 64, size=(2, rt.pool_words)).astype(np.int64)
    params = [[0, 0, 0]]
    oracle_mem = mem0.copy()
    o = pyvm.run(v, rt, oracle_mem, params[0], failed={1})
    assert o.status == isa.STATUS_PROT_FAULT
    r = tc.invoke_compiled(v, rt, mem0.copy(), params, failed={1},
                           noconflict=True)
    assert int(r.status[0]) == o.status
    assert np.array_equal(np.asarray(r.mem), oracle_mem)
    f = r.fault_at(0)
    assert f is not None and (f.pc, f.opcode) == (o.fault.pc,
                                                  o.fault.opcode)
    assert int(r.steps[0]) == o.steps
    assert [int(x) for x in r.regs[0]] == o.regs


def test_map_and_zip_loops_exact():
    rt = memory.packed_table([("a", 256), ("b", 256), ("dst", 256)])

    def map_op(rt):
        b = OperatorBuilder("maploop", n_params=2, regions=rt)
        i, j, x, c = b.reg(), b.reg(), b.reg(), b.reg()
        b.alu(i, b.param(0), Alu.ADD, 0)
        b.alu(j, b.param(1), Alu.ADD, 0)
        with b.loop(8):
            b.load(x, "a", i)
            b.alu(c, x, Alu.MUL, 3)
            b.store(c, "dst", j)
            b.alu(j, j, Alu.ADD, 1)
            b.alu(i, i, Alu.ADD, 1)
        b.ret(c)
        return b.build()

    def zip_op(rt):
        bb = OperatorBuilder("ziploop", n_params=3, regions=rt)
        i, j, x, y, c = bb.reg(), bb.reg(), bb.reg(), bb.reg(), bb.reg()
        bb.alu(i, bb.param(0), Alu.ADD, 0)
        bb.alu(j, bb.param(1), Alu.ADD, 0)
        with bb.loop((bb.param(2), 8)):
            bb.load(x, "a", i)
            bb.load(y, "b", i)
            bb.alu(c, x, Alu.ADD, y)
            bb.store(c, "dst", j)
            bb.alu(j, j, Alu.ADD, 1)
            bb.alu(i, i, Alu.ADD, 1)
        bb.ret(c)
        return bb.build()

    rng = np.random.default_rng(6)
    for build, kind, params in (
            (map_op, "map_loop", [[0, 0], [16, 32]]),
            (zip_op, "zip_loop", [[0, 0, 5], [16, 32, 99]])):
        v = verify(build(rt), grant=Grant.all_of(rt, "t"), regions=rt)
        rep = tc.superop_report(v)
        assert any(k == kind for k, _ in rep["matched"]), (kind, rep)
        mem0 = rng.integers(0, 64, size=(1, rt.pool_words)).astype(np.int64)
        oracle_mem = mem0.copy()
        oracle = [pyvm.run(v, rt, oracle_mem, p) for p in params]
        r = tc.invoke_compiled(v, rt, mem0.copy(), params)
        assert np.array_equal(np.asarray(r.mem), oracle_mem), kind
        assert [int(x) for x in r.ret] == [o.ret for o in oracle]
        assert [int(x) for x in r.steps] == [o.steps for o in oracle]


def test_gather_chain_near_miss_reason():
    rt = _table()
    v = verify(_op_window(rt), grant=Grant.all_of(rt, "t"), regions=rt)
    instrs = isa.decode_program(v.code)
    g, reason = tc.match_gather_chain_ex(instrs, v.loops[0])
    assert g is None and "5-instruction chain shape" in reason
    assert tc.superop_report(v)["near_miss"].startswith("pc ")
