"""Line-rate certification (``core/wcet``): registration-time WCET /
traffic / occupancy certificates and their three enforcement points.

The invariants under test:

1. Every successful registration carries a ``LineRateCertificate``; it
   survives a JSON round-trip, and the registry surfaces it through
   ``describe_analysis()`` / ``dump()``.
2. The certificate is *sound*: simulated executions (pyvm trace
   through the cycle simulator, all modes) never exceed the certified
   cycles, occupancy, or traffic on a seeded random-program corpus
   (hypothesis-driven when installed), and ``mp_cycles`` equals the
   verifier's step bound exactly.
3. The certificate is *enforced*: an over-budget operator is rejected
   at registration with a diagnostic naming the hottest pc and the
   violated resource; a statically-infeasible deadline retires
   ``STATUS_TIMEOUT`` at admission without ever launching (and the
   check can be disabled); the dispatch cost model's learned wave
   estimate clamps to the summed certified bound.
4. The stock operator suite registers within ``wcet.DEFAULT_BUDGET``.
"""

import json

import numpy as np
import pytest

from repro.core import isa, memory, operators, wcet
from repro.core.costmodel import DispatchCostModel
from repro.core.endpoint import TiaraEndpoint
from repro.core.isa import Alu
from repro.core.memory import Grant
from repro.core.program import OperatorBuilder
from repro.core.registry import OperatorRegistry, RegistrationError
from repro.core.serving_loop import ServingConfig, ServingLoop, VirtualClock
from repro.core.verifier import VerificationError, verify

from benchmarks.bench_wcet import (check_one, corpus_table,
                                   random_program, _failfast_op)


def _table():
    return memory.packed_table([("src", 1024), ("dst", 1024)])


def _hog(rt):
    """~2.5M certified cycles (4096 iterations x 4 local loads) — over
    the default 2^21-cycle budget while staying under the verifier's
    step cap."""
    b = OperatorBuilder("hog", n_params=1, regions=rt)
    z = b.const(0)
    r = b.reg()
    with b.loop(4096):
        for _ in range(4):
            b.load(r, "src", z)
    b.ret(r)
    return b.build()


# ---------------------------------------------------------------------------
# 1. attachment + reporting
# ---------------------------------------------------------------------------

def test_certificate_attached_at_registration():
    rt = _table()
    reg = OperatorRegistry(rt)
    reg.add_tenant(Grant.all_of(rt, "t"))
    b = OperatorBuilder("probe", n_params=1, regions=rt)
    off = b.reg()
    b.alu(off, b.param(0), Alu.AND, 1023)
    b.load(b.reg(), "src", off, dev=0)
    b.ret()
    op_id = reg.register("t", b.build())
    cert = reg[op_id].certificate
    assert cert is not None
    assert cert.wcet_cycles > 0 and cert.wcet_latency_us > 0
    assert cert.words_read >= 1
    assert cert.bottleneck in ("mp", "dma_channel", "wire", "slots")
    assert cert.per_pc        # per-site attribution is never empty


def test_certificate_json_roundtrip_and_dump():
    rt = _table()
    reg = OperatorRegistry(rt)
    reg.add_tenant(Grant.all_of(rt, "t"))
    b = OperatorBuilder("rep", n_params=1, regions=rt)
    off = b.reg()
    b.alu(off, b.param(0), Alu.AND, 511)
    with b.loop(8):
        b.memcpy(dst_region="dst", dst_off=off, src_region="src",
                 src_off=off, n_words=64, src_dev=0)
    b.ret()
    op_id = reg.register("t", b.build())
    cert = reg[op_id].certificate
    blob = json.loads(json.dumps(cert.to_json()))
    assert blob["wcet_cycles"] == pytest.approx(cert.wcet_cycles)
    assert blob["memcpy_bytes"] == cert.memcpy_bytes
    assert blob["bottleneck"] == cert.bottleneck
    pcs = {e["pc"] for e in blob["per_pc"]}
    assert all(isinstance(e["op"], str) for e in blob["per_pc"])
    assert pcs == {e.pc for e in cert.per_pc}
    # the registry surfaces the certificate in its analysis reporting
    assert "certificate:" in reg[op_id].describe_analysis()
    assert "certificate:" in reg.dump()


def test_hottest_site_attribution():
    rt = _table()
    vop = verify(_hog(rt), regions=rt)
    hot = vop.certificate.hottest("cycles")
    assert hot.count == 4096 * 4 or hot.count == 4096
    assert hot.op == "LOAD"


# ---------------------------------------------------------------------------
# 2. soundness
# ---------------------------------------------------------------------------

def test_mp_cycles_equals_step_bound():
    rt = corpus_table()
    rng = np.random.default_rng(11)
    for idx in range(20):
        prog, _ = random_program(rng, rt, idx)
        try:
            vop = verify(prog, regions=rt)
        except VerificationError:
            continue
        assert vop.certificate.mp_cycles == vop.step_bound


def test_soundness_seeded_corpus():
    rt = corpus_table()
    rng = np.random.default_rng(3)
    mem0 = rng.integers(0, 2048, size=(2, rt.pool_words)).astype(np.int64)
    feats = set()
    checked = 0
    for idx in range(40):
        prog, prog_feats = random_program(rng, rt, idx)
        try:
            vop = verify(prog, regions=rt)
        except VerificationError:
            continue
        params = [int(rng.integers(0, 2048)) for _ in range(4)]
        bad, _ = check_one(vop, rt, mem0.copy(), params,
                           home=int(rng.integers(2)))
        assert not bad, bad
        feats |= prog_feats
        checked += 1
    # non-vacuity: the draw actually exercised the hard families
    assert checked >= 30
    assert {"loop", "memcpy", "remote"} <= feats


def test_soundness_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    rt = corpus_table()
    rng0 = np.random.default_rng(5)
    mem0 = rng0.integers(0, 2048, size=(2, rt.pool_words)).astype(np.int64)

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        prog, _ = random_program(rng, rt, 0)
        try:
            vop = verify(prog, regions=rt)
        except VerificationError:
            return
        params = [int(rng.integers(0, 2048)) for _ in range(4)]
        bad, _ = check_one(vop, rt, mem0.copy(), params,
                           home=int(rng.integers(2)))
        assert not bad, bad

    prop()


# ---------------------------------------------------------------------------
# 3. enforcement
# ---------------------------------------------------------------------------

def test_over_budget_rejected_names_pc_and_resource():
    rt = _table()
    reg = OperatorRegistry(rt)       # wcet.DEFAULT_BUDGET
    reg.add_tenant(Grant.all_of(rt, "t"))
    with pytest.raises(RegistrationError) as ei:
        reg.register("t", _hog(rt))
    msg = str(ei.value)
    assert "hog" in msg
    assert "cycles" in msg           # the violated resource
    assert "pc" in msg               # the hottest site
    assert "LOAD" in msg


def test_budget_none_admits_and_tight_budget_rejects_traffic():
    rt = _table()
    reg = OperatorRegistry(rt, budget=None)
    reg.add_tenant(Grant.all_of(rt, "t"))
    reg.register("t", _hog(rt))      # no budget, no gate
    tight = OperatorRegistry(
        rt, budget=wcet.Budget(max_memcpy_bytes=128))
    tight.add_tenant(Grant.all_of(rt, "t"))
    b = OperatorBuilder("mover", n_params=0, regions=rt)
    z = b.const(0)
    b.memcpy(dst_region="dst", dst_off=z, src_region="src", src_off=z,
             n_words=64, src_dev=0)
    b.ret()
    with pytest.raises(RegistrationError) as ei:
        tight.register("t", b.build())
    assert "memcpy" in str(ei.value).lower()


def test_admission_failfast_retires_timeout_without_launch():
    prog, rt = _failfast_op()
    clk = VirtualClock()
    ep, sessions = TiaraEndpoint.for_tenants(
        [("t", rt)], n_devices=1, clock=clk, sleep=clk.sleep)
    sessions["t"].register(prog)
    loop = ServingLoop(ep, ServingConfig(ring_size=2, ring_age_s=0.0))
    op_id, _ = sessions["t"]._resolve("gather32")
    cert = ep.registry[op_id].certificate
    wcet_s = cert.wcet_latency_us * 1e-6
    # infeasible: in the future, but below the certified WCET
    c = loop.submit("t", "gather32", [0], deadline_s=0.25 * wcet_s)
    assert c.done and c.status == isa.STATUS_TIMEOUT
    assert c.event is not None and c.event.wave == -1   # never launched
    assert loop.stats.launched == 0
    assert loop.stats.timed_out == 1
    # a feasible post on the same loop still executes
    c2 = loop.submit("t", "gather32", [1], deadline_s=10.0)
    loop.drain()
    assert c2.status == isa.STATUS_OK and c2.event.wave >= 0
    st = loop.stats
    assert st.submitted == (st.executed + st.flushed + st.timed_out
                            + st.rejected + st.shed)


def test_admission_failfast_disabled_launches():
    prog, rt = _failfast_op()
    clk = VirtualClock()
    ep, sessions = TiaraEndpoint.for_tenants(
        [("t", rt)], n_devices=1, clock=clk, sleep=clk.sleep)
    sessions["t"].register(prog)
    loop = ServingLoop(ep, ServingConfig(
        ring_size=1, ring_age_s=0.0, admission_wcet=False))
    op_id, _ = sessions["t"]._resolve("gather32")
    wcet_s = ep.registry[op_id].certificate.wcet_latency_us * 1e-6
    c = loop.submit("t", "gather32", [0], deadline_s=0.25 * wcet_s)
    loop.drain()
    # without the certificate check the post launches normally (the
    # virtual clock never passes the deadline here, so it completes)
    assert c.event is not None and c.event.wave >= 0
    assert loop.stats.launched == 1


def test_wave_us_clamps_to_certified_ceiling():
    m = DispatchCostModel()
    free = m.wave_us(batch=8, step_bound=4096, key=1)
    assert m.wave_us(batch=8, step_bound=4096, key=1,
                     cert_ceiling_us=free * 0.5) <= free * 0.5
    # a ceiling above the estimate changes nothing
    assert m.wave_us(batch=8, step_bound=4096, key=1,
                     cert_ceiling_us=free * 10) == pytest.approx(free)


# ---------------------------------------------------------------------------
# 4. stock suite fits the default budget
# ---------------------------------------------------------------------------

def test_stock_operators_within_default_budget():
    specs = [operators.GraphWalk(), operators.PageTableWalk(),
             operators.DistLock(), operators.PagedKVFetch(),
             operators.MoEExpertGather(), operators.NSASelect()]
    for w in specs:
        rt = w.regions()
        vop = verify(w.build(rt), regions=rt)
        assert vop.certificate is not None
        assert wcet.DEFAULT_BUDGET.violations(vop.certificate) == []
