"""Hypothesis property tests: the system's core invariants.

1. VM == oracle on randomized (valid) operators — full architectural state.
2. Termination: executed steps never exceed the verified bound.
3. Isolation: no reachable execution writes outside the declared writable
   regions, for any parameters and any memory contents.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import isa, memory, pyvm, vm
from repro.core.isa import Alu
from repro.core.memory import Grant, packed_table
from repro.core.program import OperatorBuilder
from repro.core.verifier import verify

REGIONS = [("r0", 64), ("r1", 32), ("ro", 16)]


def build_table():
    rt = packed_table(REGIONS)
    return rt


@st.composite
def random_operator(draw):
    """A random *structurally valid* operator: straight-line ALU/memory
    instructions, an optional bounded loop with a conditional break, and a
    final Ret.  Offsets/values are unconstrained int64 — isolation must
    hold regardless (register-chained loads chase arbitrary data)."""
    rt = build_table()
    b = OperatorBuilder("rand", n_params=4, regions=rt)
    regs = [b.reg() for _ in range(4)]

    def rand_instr(depth):
        kind = draw(st.sampled_from(
            ["movi", "alu", "load", "store", "memcpy", "cas"]))
        r = draw(st.sampled_from(regs))
        a = draw(st.sampled_from(regs + list(b.params)))
        region = draw(st.sampled_from(["r0", "r1", "ro"]))
        wregion = draw(st.sampled_from(["r0", "r1"]))
        if kind == "movi":
            b.movi(r, draw(st.integers(-2**40, 2**40)))
        elif kind == "alu":
            op = draw(st.sampled_from([Alu.ADD, Alu.SUB, Alu.MUL, Alu.XOR,
                                       Alu.SHL, Alu.SHR, Alu.MIN]))
            b.alu(r, a, op, draw(st.sampled_from(
                regs + [draw(st.integers(-63, 63))])))
        elif kind == "load":
            b.load(r, region, a, draw(st.integers(0, 8)))
        elif kind == "store":
            b.store(r, wregion, a)
        elif kind == "memcpy":
            b.memcpy(dst_region=wregion, dst_off=r,
                     src_region=region, src_off=a,
                     n_words=draw(st.integers(1, 16)),
                     is_async=draw(st.booleans()))
        elif kind == "cas":
            b.cas(r, wregion, a, draw(st.sampled_from(regs)),
                  draw(st.sampled_from(regs)))

    for _ in range(draw(st.integers(1, 4))):
        rand_instr(0)
    if draw(st.booleans()):
        n_iters = draw(st.integers(0, 5))
        brk = b.mklabel("brk")
        with b.loop(n_iters):
            for _ in range(draw(st.integers(1, 3))):
                rand_instr(1)
            if draw(st.booleans()):
                b.jump(brk, regs[0], Alu.EQ, draw(st.integers(-2, 2)))
            b.nop()
        b.bind(brk)
    if draw(st.booleans()):
        b.wait(0)
    b.ret(regs[0])
    params = draw(st.lists(st.integers(-2**50, 2**50),
                           min_size=4, max_size=4))
    seed = draw(st.integers(0, 2**31 - 1))
    return rt, b.build(), params, seed


@settings(max_examples=30, deadline=None)
@given(random_operator())
def test_vm_matches_oracle_and_terminates(op_spec):
    rt, prog, params, seed = op_spec
    grant = Grant.of("t", readable=[0, 1, 2], writable=[0, 1])
    vop = verify(prog, grant=grant, regions=rt)
    rng = np.random.default_rng(seed)
    mem = rng.integers(-2**40, 2**40,
                       size=(2, rt.pool_words)).astype(np.int64)
    r_py = pyvm.run(vop, rt, mem.copy(), params)
    r_jx = vm.invoke(vop, rt, mem.copy(), params)

    # 1. lockstep equivalence
    assert r_py.ret == r_jx.ret
    assert r_py.status == r_jx.status
    assert r_py.steps == r_jx.steps
    assert np.array_equal(r_py.mem, r_jx.mem)
    assert np.array_equal(np.asarray(r_py.regs), r_jx.regs)

    # 2. termination within the static bound (fuel never exhausted)
    assert r_py.status != isa.STATUS_FUEL
    assert r_py.steps <= vop.step_bound

    # 3. isolation: only writable granted regions may change
    changed = r_jx.mem != mem
    allowed = np.zeros(rt.pool_words, bool)
    for rid in (0, 1):
        reg = rt[rid]
        allowed[reg.base:reg.end] = True
    assert not changed[:, ~allowed].any(), \
        "write escaped the granted regions"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**63 - 1), st.integers(0, 31), st.integers(1, 30))
def test_pointer_chase_isolation(start, depth, seed):
    """Adversarial pointer chase: arbitrary garbage pointers in memory can
    never leak reads/writes outside the region.  A garbage pointer that
    leaves the granted region now takes a runtime protection fault (the
    lane halts with every write suppressed) instead of silently
    wrapping; either way nothing outside the grant changes."""
    from repro.core import operators as ops
    w = ops.GraphWalk(n_nodes=16, max_depth=32)
    rt = w.regions()
    vop = verify(w.build(rt), grant=Grant.all_of(rt), regions=rt)
    rng = np.random.default_rng(seed)
    mem = rng.integers(-2**62, 2**62,
                       size=(1, rt.pool_words)).astype(np.int64)
    before = mem.copy()
    r = vm.invoke(vop, rt, mem, [start, depth])
    assert r.status in (isa.STATUS_OK, isa.STATUS_PROT_FAULT)
    assert (r.fault is not None) == (r.status == isa.STATUS_PROT_FAULT)
    reply = rt["reply"]
    changed = r.mem[0] != before[0]
    outside = np.ones(rt.pool_words, bool)
    outside[reply.base:reply.end] = False
    assert not changed[outside].any()
    if r.status == isa.STATUS_PROT_FAULT:
        # containment: the faulting lane's writes are fully suppressed
        assert not changed.any()


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(-2**63, 2**63 - 1), min_size=2, max_size=2),
       st.sampled_from(list(Alu)[:14]))
def test_alu_semantics_match_int64(vals, op):
    """Oracle ALU == JAX ALU == numpy int64 semantics."""
    rt = packed_table([("r0", 16)])
    b = OperatorBuilder("alu", n_params=2, regions=rt)
    r = b.reg()
    b.alu(r, b.param(0), op, b.param(1))
    b.ret(r)
    vop = verify(b.build(), grant=Grant.all_of(rt), regions=rt)
    mem = memory.make_pool(1, rt)
    r1 = pyvm.run(vop, rt, mem.copy(), vals)
    r2 = vm.invoke(vop, rt, mem.copy(), vals)
    assert r1.ret == r2.ret
