"""End-to-end disaggregated paged decode (PR 9).

Token bit-parity of ``ServingEngine(resolver="tiara")`` against the
host-resolve path, the unified submit surface (SequenceHandle +
deprecated positional shim), the allocator API additions, the
exactly-one-CQE-per-post identity through the resolver's serving loop,
and fault surfacing (mid-decode device failure terminates sequences
with ``STATUS_PROT_FAULT`` through their handles — never a hang).
"""

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduce_config
from repro.core import faults, isa
from repro.core.endpoint import EndpointError
from repro.core.serving_loop import VirtualClock
from repro.models import transformer as tf
from repro.serving import (BlockAllocator, OutOfPages, ServingEngine,
                           TiaraResolver)


@pytest.fixture(scope="module")
def tiny():
    cfg = reduce_config(get_config("tiny-lm"))
    return cfg, tf.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, n, seed=3):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, cfg.vocab, 4 + i)))
            for i in range(n)]


def _engine(cfg, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 64)
    return ServingEngine(cfg, params, temperature=0.0, eos_id=-1, **kw)


# -- allocator API (satellite 2) ------------------------------------------

def test_alloc_many_all_or_nothing():
    a = BlockAllocator(8)
    got = a.alloc_many([(1, 3), (2, 4)])
    assert sorted(got) == [1, 2]
    assert len(got[1]) == 3 and len(got[2]) == 4
    assert a.free_pages == 1
    # total doesn't fit: nothing is allocated, free count untouched
    with pytest.raises(OutOfPages) as ei:
        a.alloc_many([(3, 1), (4, 1)])
    assert ei.value.needed == 2 and ei.value.free == 1
    assert a.free_pages == 1 and a.owned_by(3) == [] \
        and a.owned_by(4) == []


def test_out_of_pages_structured_fields():
    a = BlockAllocator(4)
    a.alloc(3, owner=1)
    with pytest.raises(OutOfPages) as ei:
        a.alloc(2, owner=2)
    assert (ei.value.needed, ei.value.free) == (2, 1)
    assert "2 pages" in str(ei.value) and "1 free" in str(ei.value)


def test_region_layout_export():
    k = BlockAllocator(16).region_layout(max_req_blocks=4)
    rt = k.regions()
    # the four regions the endpoint registers, addressable by name
    for region in ("req", "blocktable", "kvpool", "reply"):
        assert rt[region].size >= 1
    assert k.block_words == 1          # descriptor granularity default


# -- unified submit surface (satellite 1) ----------------------------------

def test_submit_returns_handle_and_positional_gone(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params)
    h = eng.submit(_prompts(cfg, 1)[0], max_new=3)
    assert h.sid == 0 and not h.done
    toks = h.result()
    assert h.ok and h.status == isa.STATUS_OK and toks == h.tokens
    assert len(toks) == 3
    # the PR-9 positional shim is gone: max_new is keyword-only now
    with pytest.raises(TypeError):
        eng.submit(_prompts(cfg, 1)[0], 3)  # type: ignore[misc]
    h2 = eng.submit(_prompts(cfg, 1)[0], max_new=3)
    assert h2.sid == 1
    out = eng.run_to_completion()
    assert out[h2.sid] == h2.tokens


def test_submit_admission_statuses(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, max_pending=0)
    h = eng.submit(_prompts(cfg, 1)[0], max_new=3)
    assert h.rejected and h.status == isa.STATUS_EAGAIN
    with pytest.raises(EndpointError):
        h.result()
    assert h.result(check=False) == []
    eng2 = _engine(cfg, params)
    h2 = eng2.submit(_prompts(cfg, 1)[0], max_new=3, deadline_s=0.0)
    assert h2.timed_out and h2.status == isa.STATUS_TIMEOUT


# -- parity (the acceptance bit) ------------------------------------------

def test_tiara_parity_single_home(tiny):
    cfg, params = tiny
    prompts = _prompts(cfg, 3)
    host = _engine(cfg, params)
    for p in prompts:
        host.submit(p, max_new=4)
    want = host.run_to_completion()
    eng = _engine(cfg, params, resolver="tiara")
    hs = [eng.submit(p, max_new=4) for p in prompts]
    assert eng.run_to_completion() == want
    assert all(h.ok for h in hs)


def test_tiara_parity_sharded_8dev_with_rehome(tiny):
    cfg, params = tiny
    prompts = _prompts(cfg, 5)
    host = _engine(cfg, params, max_slots=3)
    for p in prompts:
        host.submit(p, max_new=4)
    want = host.run_to_completion()
    eng = _engine(cfg, params, max_slots=3, resolver="tiara",
                  n_homes=8, placement="auto", rehome_every=2)
    for p in prompts:
        eng.submit(p, max_new=4)
    assert eng.run_to_completion() == want
    aud = eng.resolver_audit()
    # clients are spread over the mesh: the audit saw cross-device
    # traffic and the INDIGO sweep migrated hot regions toward it
    assert aud["rehomes"] >= 1 and aud["rehomed_words"] > 0
    assert aud["cross_device_words"] > 0


def test_tiara_parity_moe_expert_gather():
    cfg = reduce_config(get_config("llama4-scout-17b-a16e"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, 2)
    host = _engine(cfg, params)
    for p in prompts:
        host.submit(p, max_new=3)
    want = host.run_to_completion()
    eng = _engine(cfg, params, resolver="tiara", n_homes=2)
    for p in prompts:
        eng.submit(p, max_new=3)
    # resolve_step integrity-checks every gathered expert slab against
    # the requested route and raises on mismatch, so completing at
    # parity proves the MoEExpertGather path ran clean end to end
    assert eng.run_to_completion() == want
    assert eng.resolver is not None and eng.resolver.moe is not None
    assert eng.resolver.loop.stats.executed > 0


# -- exactly-one-CQE identity ---------------------------------------------

def test_exactly_one_cqe_per_post_including_faults():
    vc = VirtualClock()
    a = BlockAllocator(8)
    r = TiaraResolver(a, max_slots=2, pages_per_seq=4, n_homes=2,
                      clock=vc, sleep=vc.sleep)
    r.bind(0, [0, 1, 2, 3])
    r.bind(1, [4, 5, 6, 7])
    kv, _ = r.resolve_step([0, 1])
    assert all(isinstance(v, np.ndarray) for v in kv.values())
    assert list(kv[1]) == [4, 5, 6, 7]
    # kill slot 0's home mid-serve: its post must still retire exactly
    # one CQE (a failed one), and slot 1 keeps resolving
    r.ep.inject(faults.fail_devices(0))
    kv2, _ = r.resolve_step([0, 1])
    assert not isinstance(kv2[0], np.ndarray)
    assert int(kv2[0].status) in (isa.STATUS_PROT_FAULT,
                                  isa.STATUS_FLUSHED)
    st = r.loop.stats
    assert st.submitted == 4
    assert st.submitted == (st.executed + st.flushed + st.timed_out
                            + st.rejected + st.shed)


# -- fault surfacing through SequenceHandle --------------------------------

def test_mid_decode_device_failure_surfaces_cleanly(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, resolver="tiara", n_homes=1)
    hs = [eng.submit(p, max_new=8) for p in _prompts(cfg, 2)]
    eng.step()                      # healthy first decode step
    assert eng.resolver is not None
    eng.resolver.ep.inject(faults.fail_devices(0))
    out = eng.run_to_completion(max_steps=100)   # bounded: never hangs
    assert eng.finished()
    for h in hs:
        assert h.done and (h.faulted or h.flushed)
        assert h.status in (isa.STATUS_PROT_FAULT, isa.STATUS_FLUSHED)
        with pytest.raises(EndpointError):
            h.result()
        assert out[h.sid] == h.tokens   # partial output is preserved
