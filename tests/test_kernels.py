"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import (flash_attention_chunked,
                                               flash_attention_ref)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.tiara_gather import tiara_gather

RNG = np.random.default_rng(0)


def randn(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,qh,kvh,d,pages,page,maxp", [
    (2, 4, 4, 32, 8, 8, 3),      # MHA
    (3, 8, 2, 64, 16, 8, 5),     # GQA 4:1
    (1, 7, 1, 16, 4, 4, 2),      # MQA, odd heads
    (2, 4, 2, 128, 8, 16, 4),    # TPU-aligned head_dim
])
def test_paged_attention_sweep(dtype, b, qh, kvh, d, pages, page, maxp):
    q = randn((b, qh, d), dtype)
    k = randn((pages, page, kvh, d), dtype)
    v = randn((pages, page, kvh, d), dtype)
    bt = jnp.asarray(RNG.integers(0, pages, (b, maxp)), jnp.int32)
    ln = jnp.asarray(RNG.integers(1, maxp * page + 1, (b,)), jnp.int32)
    ref = paged_attention(q, k, v, bt, ln, impl="xla")
    ker = paged_attention(q, k, v, bt, ln, impl="kernel_interpret")
    tol = 3e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(ker, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,qh,kvh,s,d,bq,bk", [
    (2, 4, 2, 64, 32, 16, 16),
    (1, 8, 8, 128, 64, 32, 64),
    (2, 6, 2, 96, 16, 32, 32),
])
def test_flash_attention_sweep(dtype, causal, b, qh, kvh, s, d, bq, bk):
    q = randn((b, qh, s, d), dtype)
    k = randn((b, kvh, s, d), dtype)
    v = randn((b, kvh, s, d), dtype)
    ln = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    ref = flash_attention(q, k, v, ln, causal=causal, impl="xla")
    ker = flash_attention(q, k, v, ln, causal=causal,
                          impl="kernel_interpret", block_q=bq, block_k=bk)
    tol = 3e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(ker, np.float32),
        atol=tol, rtol=tol)


def test_chunked_ref_matches_dense_ref():
    q = randn((2, 4, 4096, 16), jnp.float32)
    k = randn((2, 2, 4096, 16), jnp.float32)
    v = randn((2, 2, 4096, 16), jnp.float32)
    ln = jnp.asarray([4096, 1000], jnp.int32)
    a = flash_attention_ref(q, k, v, ln, causal=True)
    c = flash_attention_chunked(q, k, v, ln, causal=True, chunk=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("n,rows,r", [(4, 16, 8), (7, 32, 128), (1, 4, 256)])
def test_tiara_gather_sweep(dtype, n, rows, r):
    if dtype == jnp.int32:
        pool = jnp.asarray(RNG.integers(0, 1000, (rows, r)), dtype)
    else:
        pool = randn((rows, r), dtype)
    table = jnp.asarray(RNG.permutation(rows), jnp.int32)
    ids = jnp.asarray(RNG.integers(0, rows, n), jnp.int32)
    ref = tiara_gather(pool, table, ids, impl="xla")
    ker = tiara_gather(pool, table, ids, impl="kernel_interpret")
    assert jnp.array_equal(ref, ker)


def test_paged_attention_matches_flash_on_same_kv():
    """Cross-kernel consistency: decode over a paged layout == the last
    row of full attention over the equivalent contiguous KV."""
    b, qh, kvh, d, page, maxp = 2, 4, 2, 32, 8, 4
    s = maxp * page
    k_lin = randn((b, kvh, s, d), jnp.float32)
    v_lin = randn((b, kvh, s, d), jnp.float32)
    q1 = randn((b, qh, d), jnp.float32)
    # pack the contiguous KV into pages with an identity block table
    bt = (jnp.arange(b)[:, None] * maxp + jnp.arange(maxp)[None]) \
        .astype(jnp.int32)
    k_pages = k_lin.transpose(0, 2, 1, 3).reshape(b * maxp, page, kvh, d)
    v_pages = v_lin.transpose(0, 2, 1, 3).reshape(b * maxp, page, kvh, d)
    ln = jnp.asarray([s, s - 5], jnp.int32)
    out_paged = paged_attention(q1, k_pages, v_pages, bt, ln, impl="xla")
    # reference: non-causal single-query attention over first ln tokens
    out_ref = flash_attention(q1[:, :, None, :], k_lin, v_lin, ln,
                              causal=False, impl="xla")[:, :, 0]
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_ref),
                               atol=3e-5, rtol=3e-5)
