"""Split-phase completion surface: ``doorbell(wait=False)`` launches a
wave and returns an in-flight :class:`WaveHandle`; completions retire on
``poll_cq`` / ``wait_any`` / ``wait_all`` / ``Completion.wait`` — always
in wave order, so per-session FIFO survives any number of waves in
flight, and every retirement is bit-identical to replaying the posts one
at a time on the ``pyvm`` oracle.

The property test drives random interleavings of
post / doorbell(wait=False) / poll_cq / wait_any across 3 sessions
(seeded sweep always; hypothesis when installed), including contended
STORE/CAS posts pipelined behind an in-flight async-MEMCPY wave.
"""

import numpy as np
import pytest

from repro.core import memory, pyvm
from repro.core.endpoint import (CompletionEvent, TiaraEndpoint,
                                 WaveHandle)
from repro.core.program import OperatorBuilder

# ---------------------------------------------------------------------------
# Tenant workload: compute + contended atomics + an async-MEMCPY gather
# (the paper's split-phase pair) in one layout.
# ---------------------------------------------------------------------------


def _layout():
    return memory.packed_table([("latch", 8), ("data", 64), ("reply", 64),
                                ("table", 16), ("pool", 256),
                                ("gout", 256)])


def _sum_op(rt):
    b = OperatorBuilder("sum2", n_params=2, regions=rt)
    x, y = b.reg(), b.reg()
    b.load(x, "data", b.param(0))
    b.load(y, "data", b.param(0), disp=1)
    b.add(x, x, y)
    b.store(x, "reply", b.param(1))
    b.ret(x)
    return b.build()


def _cas_op(rt):
    b = OperatorBuilder("cas_latch", n_params=1, regions=rt)
    zero = b.const(0)
    old = b.reg()
    b.cas(old, "latch", zero, cmp=zero, swap=b.param(0))
    b.ret(old)
    return b.build()


def _store_op(rt):
    b = OperatorBuilder("store_latch", n_params=1, regions=rt)
    one = b.const(1)
    b.store(b.param(0), "latch", one)
    b.ret(b.param(0))
    return b.build()


def _gather_op(rt):
    """Async-MEMCPY gather chain (ids -> table -> pool rows -> gout):
    params r0 = n rows, r1 = gout slot offset.  The copies issue async
    and a WAIT(0) joins them — the trace the deferred-completion cycle
    model overlaps, and in-wave the op that keeps the engine busy while
    later waves post behind it."""
    b = OperatorBuilder("agather", n_params=2, regions=rt)
    n = b.param(0)
    i = b.const(0)
    idv, paddr = b.reg(), b.reg()
    dst = b.mov(b.reg(), b.param(1))
    with b.loop((n, 8)):
        b.load(idv, "data", i)
        b.load(paddr, "table", idv)
        b.memcpy(dst_region="gout", dst_off=dst,
                 src_region="pool", src_off=paddr,
                 n_words=8, is_async=True)
        b.add(dst, dst, 8)
        b.add(i, i, 1)
    b.wait(0)
    b.ret(n)
    return b.build()


_OPS = ("sum2", "cas_latch", "store_latch", "agather")


def _connect(n_tenants=3, **kwargs):
    named = [(f"t{i}", _layout()) for i in range(n_tenants)]
    ep, sessions = TiaraEndpoint.for_tenants(named, **kwargs)
    for s in sessions.values():
        for build in (_sum_op, _cas_op, _store_op, _gather_op):
            s.register(build(s.view))
        s.write_region("data", np.arange(10, 74, dtype=np.int64) % 16)
        s.write_region("table", (np.arange(16, dtype=np.int64) * 16) % 256)
        s.write_region("pool", np.arange(1000, 1256, dtype=np.int64))
    return ep, [sessions[f"t{i}"] for i in range(n_tenants)]


def _post(session, i, oi, arg):
    name = _OPS[oi % len(_OPS)]
    if name == "sum2":
        params = [arg % 32, i % 64]
    elif name == "agather":
        params = [1 + arg % 4, (i % 4) * 64]   # disjoint 64-word slots
    else:
        params = [arg]
    return session.post(name, params)


class _Oracle:
    """Replays posts one at a time on pyvm in global arrival order,
    incrementally — the sequential reference the split-phase retirement
    must match bit-for-bit."""

    def __init__(self, ep):
        self.ep = ep
        self.vops = ep.registry.store_ops()
        self.mem = np.array(ep._host_view())
        self.expect = {}
        self.next_seq = 0

    def absorb(self, completions):
        """Advance the reference over the given (seq-sorted) posts."""
        for c in sorted(completions, key=lambda c: c.seq):
            assert c.seq >= self.next_seq
            r = pyvm.run(self.vops[c.op_id], self.ep.regions, self.mem,
                         list(c.params), home=c.home)
            self.expect[c.seq] = (r.ret, r.status, r.steps)
            self.next_seq = c.seq + 1

    def check(self, completions):
        for c in completions:
            assert c.done and c.event is not None
            got = (c.ret, c.status, c.steps)
            assert got == self.expect[c.seq], (c.op_name, c.seq)
            assert (c.event.ret, c.event.status, c.event.steps) == got

    def check_mem(self):
        assert np.array_equal(np.asarray(self.ep._host_view()), self.mem)


# ---------------------------------------------------------------------------
# Basics
# ---------------------------------------------------------------------------


def test_doorbell_nowait_returns_before_retirement():
    """The acceptance bit: doorbell(wait=False) hands back an in-flight
    handle before any async MEMCPY (or anything else) retires a CQE."""
    ep, (s0, *_) = _connect()
    c = s0.post("agather", [4, 0])
    h = ep.doorbell(wait=False)
    assert isinstance(h, WaveHandle) and not h.done
    assert not c.done and c.in_flight and c.wave_handle is h
    assert ep.in_flight == 1 and ep.in_flight_waves == 1
    assert s0.outstanding == 0          # drained from the send queue
    got = h.wait()
    assert got == [c] and c.done and c.ret == 4
    assert ep.in_flight == 0 and h.done and h.ready


def test_wait_all_retires_every_wave_in_order():
    ep, sessions = _connect()
    oracle = _Oracle(ep)
    waves = []
    for w in range(3):
        cs = [_post(sessions[i % 3], i, i, w * 10 + i) for i in range(6)]
        oracle.absorb(cs)
        waves.append((cs, ep.doorbell(wait=False)))
    assert ep.in_flight_waves == 3
    n = ep.wait_all()
    assert n == 18 and ep.in_flight_waves == 0
    for cs, h in waves:
        oracle.check(cs)
        assert h.done
    oracle.check_mem()
    # wave ids in the events are strictly increasing across waves
    ids = [cs[0].event.wave for cs, _ in waves]
    assert ids == sorted(ids) and len(set(ids)) == 3


def test_wait_any_retires_oldest_wave_only():
    ep, (s0, s1, _) = _connect()
    oracle = _Oracle(ep)
    c1 = s0.post("sum2", [2, 0])
    oracle.absorb([c1])
    h1 = ep.doorbell(wait=False)
    c2 = s1.post("sum2", [4, 1])
    oracle.absorb([c2])
    ep.doorbell(wait=False)
    got = ep.wait_any()
    assert got == [c1] and h1.done
    assert not c2.done and ep.in_flight_waves == 1
    assert ep.wait_any() == [c2]
    assert ep.wait_any() == []
    oracle.check([c1, c2])
    oracle.check_mem()


def test_completion_wait_retires_through_earlier_waves():
    """Retiring a later wave first would break per-session FIFO; waiting
    on wave 2 must deliver wave 1's CQEs first."""
    ep, (s0, *_) = _connect()
    c1 = s0.post("sum2", [0, 0])
    ep.doorbell(wait=False)
    c2 = s0.post("sum2", [2, 1])
    ep.doorbell(wait=False)
    assert c2.wait() is c2
    assert c1.done                       # FIFO: wave 1 retired first
    assert s0.poll_cq() == [c1, c2]


def test_result_on_in_flight_post_needs_no_flush():
    ep, (s0, *_) = _connect()
    c = s0.post("sum2", [0, 0])
    ep.doorbell(wait=False)
    # no doorbell ring needed: the post is launched, flush=False is fine
    assert c.result(flush=False) == (10 % 16) + (11 % 16)


def test_poll_cq_retires_ready_waves_nonblocking():
    ep, (s0, *_) = _connect()
    c = s0.post("sum2", [0, 0])
    ep.doorbell(wait=False)
    # the launch is tiny: spin until it lands, then poll_cq must
    # deliver without any explicit wait call
    deadline = 200
    got = []
    while not got and deadline:
        got = s0.poll_cq()
        deadline -= 1
    if not got:                      # ready() never flipped: force once
        ep.wait_all()
        got = s0.poll_cq()
    assert got == [c] and c.done


def test_pipelined_waves_chain_the_pool_dependency():
    """Wave 2 posts against wave 1's in-flight output: a sum2 reading
    the reply slot a wave-1 sum2 wrote must observe it."""
    ep, (s0, *_) = _connect()
    # wave 1: reply[0] = data[4] + data[5]
    c1 = s0.post("sum2", [4, 0])
    ep.doorbell(wait=False)
    # wave 2 (posted while wave 1 is in flight): sum over data[8:10]
    c2 = s0.post("sum2", [8, 1])
    ep.doorbell(wait=False)
    assert ep.wait_all() == 2
    assert c1.ret == (14 % 16) + (15 % 16)
    assert c2.ret == (18 % 16) + (19 % 16)
    r = s0.read_region("reply", count=2)
    assert r.tolist() == [c1.ret, c2.ret]


def test_empty_nowait_doorbell_returns_done_handle():
    ep, _ = _connect()
    h = ep.doorbell(wait=False)
    assert isinstance(h, WaveHandle) and h.done and len(h) == 0
    assert h.wait() == []


def test_blocking_doorbell_retires_pending_waves_too():
    """A wait=True doorbell behind in-flight waves retires them first
    (wave order), so its own completions join a consistent CQ tail."""
    ep, (s0, *_) = _connect()
    c1 = s0.post("sum2", [0, 0])
    ep.doorbell(wait=False)
    c2 = s0.post("sum2", [2, 1])
    n = ep.doorbell()                # blocking
    assert n == 1 and c1.done and c2.done
    assert s0.poll_cq() == [c1, c2]


def test_completion_event_carries_retire_timestamp():
    ep, (s0, *_) = _connect()
    c = s0.post("sum2", [0, 0])
    h = ep.doorbell(wait=False)
    assert c.event is None
    h.wait()
    assert isinstance(c.event, CompletionEvent)
    assert c.event.ok and c.event.retired_at > 0
    assert c.event.wave == h.wave_id and c.event.seq == c.seq


def test_host_reads_block_on_in_flight_waves():
    """Control-path reads must observe every launched wave — reading a
    region while a wave is in flight blocks until it lands (but does
    not retire its CQEs)."""
    ep, (s0, *_) = _connect()
    c = s0.post("sum2", [4, 3])
    ep.doorbell(wait=False)
    r = s0.read_region("reply", offset=3, count=1)
    assert r[0] == (14 % 16) + (15 % 16)
    assert not c.done                     # reads don't retire CQEs
    assert ep.wait_all() == 1


def test_contended_atomics_behind_in_flight_async_memcpy_wave():
    """The acceptance interleaving: a wave of async-MEMCPY gathers goes
    in flight, then a wave of contended STORE/CAS posts on the same
    latch pipelines behind it — retirement is bit-identical to the
    sequential oracle and the first-arriving CAS wins."""
    ep, sessions = _connect()
    oracle = _Oracle(ep)
    g = [sessions[i].post("agather", [3 + i, 0]) for i in range(3)]
    oracle.absorb(g)
    ep.doorbell(wait=False)
    cs = []
    for i in range(9):
        s = sessions[i % 3]
        cs.append(s.post("cas_latch", [100 + i]) if i % 2 == 0
                  else s.post("store_latch", [200 + i]))
    oracle.absorb(cs)
    ep.doorbell(wait=False)
    assert ep.in_flight_waves == 2
    assert ep.wait_all() == 12
    oracle.check(g + cs)
    oracle.check_mem()
    for t, s in enumerate(sessions):
        winner = next(c for c in cs if c.session is s
                      and c.op_name == "cas_latch")
        assert s.read_region("latch", count=1)[0] == winner.params[0]
        assert winner.ret == 0


# ---------------------------------------------------------------------------
# Property: any interleaving of post / doorbell(wait=False) / poll_cq /
# wait_any across 3 sessions retires bit-identically to the pyvm oracle
# and preserves per-session FIFO.
# ---------------------------------------------------------------------------


def _run_async_interleaving(posts, rings, polls, waits):
    """posts: per-post (session_idx, op_idx, arg); rings/polls/waits:
    post indices after which to ring doorbell(wait=False) / poll_cq /
    wait_any.  Ends with wait_all + full CQ drain."""
    ep, sessions = _connect()
    oracle = _Oracle(ep)
    polled = {s.tenant: [] for s in sessions}
    posted = {s.tenant: [] for s in sessions}
    pending = []
    all_cs = []

    def drain_cqs():
        for s in sessions:
            polled[s.tenant].extend(s.poll_cq())

    for i, (si, oi, arg) in enumerate(posts):
        s = sessions[si % 3]
        c = _post(s, i, oi, arg)
        pending.append(c)
        posted[s.tenant].append(c)
        all_cs.append(c)
        if i in rings and pending:
            oracle.absorb(pending)
            ep.doorbell(wait=False)
            pending = []
        if i in polls:
            drain_cqs()
        if i in waits:
            for c2 in ep.wait_any():
                assert c2.done
    if pending:
        oracle.absorb(pending)
        ep.doorbell(wait=False)
    ep.wait_all()
    drain_cqs()
    oracle.check(all_cs)
    oracle.check_mem()
    for s in sessions:
        assert polled[s.tenant] == posted[s.tenant]   # per-session FIFO


@pytest.mark.parametrize("seed", range(6))
def test_async_interleavings_seeded(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 18))
    posts = [(int(rng.integers(0, 3)), int(rng.integers(0, 4)),
              int(rng.integers(0, 1000))) for _ in range(n)]

    def some(k):
        return set(int(x) for x in
                   rng.choice(n, size=int(rng.integers(0, k)),
                              replace=False))

    _run_async_interleaving(posts, some(4), some(3), some(3))


def test_async_interleaving_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    post = st.tuples(st.integers(0, 2), st.integers(0, 3),
                     st.integers(0, 2**63 - 1))

    @settings(max_examples=15, deadline=None)
    @given(posts=st.lists(post, min_size=1, max_size=10), data=st.data())
    def prop(posts, data):
        n = len(posts)
        idx = st.lists(st.integers(0, n - 1), max_size=3)
        _run_async_interleaving(posts, set(data.draw(idx)),
                                set(data.draw(idx)),
                                set(data.draw(idx)))

    prop()
